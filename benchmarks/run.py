"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per the harness convention plus
a human-readable table per benchmark. Paper mapping:

  table1_characterization   Table 1 — #instruction variants per uarch, tool
                            runtime, and measured-vs-legacy-analyzer
                            agreement (μops %, ports %) with planted
                            IACA-style bugs adjudicated by ground truth
  table_throughput_defs     §4.2 — instructions where the Intel (LP) and Fog
                            (measured) throughput definitions diverge
  fig_case_aesdec           §7.3.1 — AESDEC per-pair latency across uarches
  fig_case_shld             §7.3.2 — SHLD same-register effect
  fig_case_movq2dq          §7.3.3 — isolation-measurement fallacy
  table_multi_latency       §7.3.5 — instructions with pair-dependent latency
  table_zero_idioms         §7.3.6 — dependency-breaking idiom detection
  bench_lp                  §5.3.2 — LP solve rate
  bench_simulator           measurement-machine μop throughput
  bench_batch_sim           vectorized measurement substrate: scalar loop
                            vs NumPy vs jax batched backend, wave sweep +
                            thin-chunk scalar-crossover sweep (min_lanes)
  bench_backend_matrix      device-resident wave execution: numpy vs jax
                            (blocked scan) vs pallas (interpret off-TPU)
                            across wave widths, cold vs warm lowering
                            cache, with the kernel recompile probe
  bench_trace_overhead      observability tax: numpy wave sweep with
                            repro.obs tracing off vs on, plus the analytic
                            disabled-overhead bound the CI <2% gate asserts
  bench_device_scaling      mesh-parallel wave execution: warm wave
                            throughput at 1/2/4 forced host devices
                            (subprocess — XLA_FLAGS must precede the jax
                            import), bit-identity + recompile probe
  bench_characterize        cold scheduler-fused characterize: wall-clock
                            + fused-wave-width telemetry (CI smoke records
                            this into benchmarks.smoke.json)
  bench_wave_fusion         per-instruction (legacy) vs scheduler-fused
                            characterization across SIM_UARCHES
  bench_corpus_eval         corpus-evaluation throughput: seeded block
                            corpus through fused mega-waves at several
                            wave widths, numpy vs jax wave backend, cold
                            vs warm lowering/jit caches
  bench_hardware_corpus     §6.2-analogue — real-JAX op corpus wall-clock
  bench_kernel_contention   blocking-kernel unit attribution harness
  table_roofline            §Roofline — dry-run roofline summary (if runs
                            exist under experiments/dryrun)
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us: float, derived: str = ""):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}")


def _timed(f):
    t0 = time.perf_counter()
    out = f()
    return out, (time.perf_counter() - t0) * 1e6


# ---------------------------------------------------------------------------


def table1_characterization():
    """Table 1 analogue: one Campaign characterizes the full μISA on all
    simulated uarches concurrently; compare against the legacy (IACA-like,
    bug-planted) analyzer."""
    from repro.core.engine import Campaign
    from repro.core.isa import TEST_ISA
    from repro.core.simulator import SimMachine
    from repro.core.uarch import SIM_UARCHES

    # planted stale tables, mimicking documented IACA bug classes (§7.2)
    legacy_bugs = {
        "MOVQ2DQ_X_X": {frozenset("5"): 2},            # wrong ports (llvm/IACA)
        "IMUL_R64_M64": {frozenset("1"): 1},           # missing load μop
        "BSWAP_R32": {frozenset("15"): 2},             # variant confusion
        "SAHF": {frozenset("0156"): 1},                # extra ports (IACA>=2.2)
    }
    machines = [SimMachine(ua, TEST_ISA) for ua in SIM_UARCHES.values()]
    res = Campaign().run(machines, TEST_ISA)
    print("\n== Table 1: characterized variants & legacy agreement ==")
    print(f"{'uarch':10s} {'#instr':>6s} {'runtime_s':>9s} "
          f"{'uops_agree%':>11s} {'ports_agree%':>12s} {'cache_hit%':>10s}")
    for name, model in res.models.items():
        n = len(model.instructions)
        uops_ok = ports_ok = total = 0
        for iname, im in model.instructions.items():
            legacy_usage = legacy_bugs.get(iname, im.port_usage.usage)
            legacy_uops = sum(legacy_usage.values())
            total += 1
            uops_ok += int(round(im.uops) == legacy_uops)
            ports_ok += int(im.port_usage.usage == legacy_usage)
        print(f"{name:10s} {n:6d} {res.uarch_seconds[name]:9.1f} "
              f"{100 * uops_ok / total:11.2f} {100 * ports_ok / total:12.2f} "
              f"{100 * res.stats[name]['hit_rate']:10.1f}")
        emit(f"table1_{name}", res.uarch_seconds[name] * 1e6, f"instr={n}")
    phases = {k: round(v, 1) for k, v in
              sorted(res.phase_seconds[machines[0].name].items())}
    print(f"(campaign wall {res.wall_seconds:.1f}s across "
          f"{len(machines)} uarches; phase seconds: {phases})")
    emit("table1_campaign", res.wall_seconds * 1e6,
         f"hit_rate={res.hit_rate:.3f}")


def table_legacy_versions():
    """§7.2 'Differences Between Different IACA Versions': two legacy-table
    versions disagree on the same instruction; sometimes the newer one is
    right (MOVQ2DQ fixed), sometimes the older one is (SAHF regressed in
    v2, as IACA >= 2.2 did on Haswell). Measurement adjudicates."""
    from repro.core.blocking import find_blocking_instructions
    from repro.core.isa import TEST_ISA
    from repro.core.port_usage import infer_port_usage
    from repro.core.simulator import SimMachine
    from repro.core.uarch import SIM_SKL

    legacy_v1 = {  # old version: MOVQ2DQ wrong, SAHF right
        "MOVQ2DQ_X_X": {frozenset("5"): 2},
        "SAHF": {frozenset("06"): 1},
    }
    legacy_v2 = {  # new version: MOVQ2DQ fixed, SAHF regressed
        "MOVQ2DQ_X_X": {frozenset("0"): 1, frozenset("015"): 1},
        "SAHF": {frozenset("0156"): 1},
    }
    m = SimMachine(SIM_SKL, TEST_ISA)

    def work():
        blk = find_blocking_instructions(m, TEST_ISA)
        out = {}
        for n in ("MOVQ2DQ_X_X", "SAHF"):
            out[n] = infer_port_usage(m, TEST_ISA, n, blk, 4).usage
        return out

    measured, us = _timed(work)
    print("\n== §7.2: legacy-analyzer version differences, adjudicated ==")
    print(f"{'instr':14s} {'v1':>14s} {'v2':>14s} {'measured':>16s} {'right':>6s}")

    def fmt(u):
        return "+".join(f"{c}*p{''.join(sorted(pc))}"
                        for pc, c in sorted(u.items(), key=lambda kv: sorted(kv[0])))

    for n in ("MOVQ2DQ_X_X", "SAHF"):
        right = ("v2" if legacy_v2[n] == measured[n] else
                 "v1" if legacy_v1[n] == measured[n] else "none")
        print(f"{n:14s} {fmt(legacy_v1[n]):>14s} {fmt(legacy_v2[n]):>14s} "
              f"{fmt(measured[n]):>16s} {right:>6s}")
    emit("table_legacy_versions", us)


def table_throughput_defs():
    """§4.2: Intel-definition (LP from ports) vs Fog-definition (measured)."""
    from repro.core.characterize import characterize
    from repro.core.isa import TEST_ISA
    from repro.core.simulator import SimMachine
    from repro.core.uarch import SIM_SKL

    names = ["ADD_R64_R64", "CMC", "ADC_R64_R64", "SHL_R64_I8", "PADDD_X_X",
             "MULPS_X_X", "DIV_R64"]
    m = SimMachine(SIM_SKL, TEST_ISA)
    model, us = _timed(lambda: characterize(m, TEST_ISA, names))
    print("\n== §4.2: throughput definitions ==")
    print(f"{'instr':16s} {'tp_measured':>11s} {'tp_LP':>8s} {'diverge':>8s}")
    for n in names:
        tp = model[n].throughput
        lp = tp.computed_from_ports
        div = "yes" if lp is not None and abs(tp.measured - lp) > 0.1 else ""
        print(f"{n:16s} {tp.measured:11.2f} "
              f"{lp if lp is not None else float('nan'):8.2f} {div:>8s}")
    emit("table_throughput_defs", us)


def _lat_table(uarch_name, instr):
    from repro.core.isa import TEST_ISA
    from repro.core.latency import LatencyAnalyzer
    from repro.core.simulator import SimMachine
    from repro.core.uarch import SIM_UARCHES

    m = SimMachine(SIM_UARCHES[uarch_name], TEST_ISA)
    r, us = _timed(lambda: LatencyAnalyzer(m, TEST_ISA).analyze(instr))
    return r, us


def fig_case_aesdec():
    print("\n== §7.3.1: AESDEC per-pair latency across microarchitectures ==")
    print(f"{'uarch':10s} {'lat(op1->op1)':>14s} {'lat(op2->op1)':>14s}")
    tot = 0.0
    for ua in ("sim_snb", "sim_hsw", "sim_skl"):
        r, us = _lat_table(ua, "AESDEC_X_X")
        tot += us
        print(f"{ua:10s} {r.get('op1', 'op1').value:14.2f} "
              f"{r.get('op2', 'op1').value:14.2f}")
    print("(single-scalar tools report only the max; the 1-cycle round-key"
          " path on sim_snb is invisible to them)")
    emit("fig_case_aesdec", tot)


def fig_case_shld():
    print("\n== §7.3.2: SHLD same-register effect ==")
    print(f"{'uarch':10s} {'lat(op1,op1)':>12s} {'lat(op2,op1)':>12s} "
          f"{'same_reg':>9s}")
    tot = 0.0
    for ua in ("sim_snb", "sim_skl"):
        r, us = _lat_table(ua, "SHLD_R64_R64_I8")
        tot += us
        e = r.get("op2", "op1")
        print(f"{ua:10s} {r.get('op1', 'op1').value:12.2f} {e.value:12.2f} "
              f"{e.same_reg:9.2f}")
    print("(explains Fog=3 vs manual=4 on NHM-like, and Granlund/AIDA64=1 "
          "vs Fog=3 on SKL-like: different operand scenarios)")
    emit("fig_case_shld", tot)


def fig_case_movq2dq():
    from repro.core.blocking import find_blocking_instructions
    from repro.core.isa import TEST_ISA
    from repro.core.machine import isolation_ports
    from repro.core.port_usage import infer_port_usage
    from repro.core.simulator import SimMachine
    from repro.core.uarch import SIM_SKL

    m = SimMachine(SIM_SKL, TEST_ISA)

    def work():
        iso = isolation_ports(m, TEST_ISA["MOVQ2DQ_X_X"])
        blk = find_blocking_instructions(m, TEST_ISA)
        pu = infer_port_usage(m, TEST_ISA, "MOVQ2DQ_X_X", blk, 4)
        return iso, pu

    (iso, pu), us = _timed(work)
    print("\n== §7.3.3: MOVQ2DQ isolation fallacy ==")
    print("isolation per-port counts:",
          {p: round(v, 2) for p, v in sorted(iso.items())})
    print("naive conclusion: 1*p0+1*p15   (wrong)")
    print(f"Algorithm 1:      {pu.notation()}   (matches hidden truth)")
    emit("fig_case_movq2dq", us, pu.notation())


def table_multi_latency():
    from repro.core.isa import TEST_ISA
    from repro.core.latency import LatencyAnalyzer
    from repro.core.simulator import SimMachine
    from repro.core.uarch import SIM_SKL

    m = SimMachine(SIM_SKL, TEST_ISA)
    la = LatencyAnalyzer(m, TEST_ISA)
    names = ["MUL_R64", "ADC_R64_R64", "SHLD_R64_R64_I8", "ADD_R64_M64",
             "IMUL_R64_M64", "AESDEC_X_M", "BSWAP_R64", "MOVQ2DQ_X_X"]

    def work():
        out = []
        for n in names:
            r = la.analyze(n)
            vals = {e.value for e in r.entries.values() if e.kind == "exact"}
            if len(vals) > 1:
                out.append((n, sorted(vals)))
        return out

    rows, us = _timed(work)
    print("\n== §7.3.5: instructions with pair-dependent latencies ==")
    for n, vals in rows:
        print(f"  {n:18s} distinct latencies: {vals}")
    emit("table_multi_latency", us, f"found={len(rows)}")


def table_zero_idioms():
    from repro.core.isa import TEST_ISA
    from repro.core.latency import LatencyAnalyzer
    from repro.core.simulator import SimMachine
    from repro.core.uarch import SIM_SKL

    m = SimMachine(SIM_SKL, TEST_ISA)
    la = LatencyAnalyzer(m, TEST_ISA)
    cands = ["XOR_R64_R64", "SUBZ_R64_R64", "PCMPGTQ_X_X", "ADD_R64_R64",
             "PADDD_X_X"]

    def work():
        found = []
        for n in cands:
            r = la.analyze(n)
            e = r.get("op2", "op1")
            if e is not None and e.same_reg is not None and e.same_reg < 0.5:
                found.append(n)
        return found

    found, us = _timed(work)
    print("\n== §7.3.6: dependency-breaking idioms detected ==")
    print("  ", found, " (PCMPGTQ-family undocumented in the manual)")
    emit("table_zero_idioms", us, ";".join(found))


def bench_lp():
    import random

    from repro.core.lp import throughput_lp

    rng = random.Random(0)
    ports = "01234567"
    cases = []
    for _ in range(200):
        n = rng.randint(1, 5)
        cases.append({frozenset(rng.sample(ports, rng.randint(1, 4))):
                      rng.randint(1, 6) for _ in range(n)})

    def work():
        return [throughput_lp(c) for c in cases]

    _, us = _timed(work)
    print(f"\n== LP solver: {len(cases)} solves in {us / 1e3:.1f} ms ==")
    emit("bench_lp", us / len(cases), f"solves={len(cases)}")


def bench_simulator():
    from repro.core.isa import TEST_ISA
    from repro.core.machine import RegPool, independent_seq
    from repro.core.simulator import SimMachine
    from repro.core.uarch import SIM_SKL

    m = SimMachine(SIM_SKL, TEST_ISA)
    seq = independent_seq(TEST_ISA["ADD_R64_R64"], RegPool(), 16) * 200

    def work():
        return m.run(seq)

    c, us = _timed(work)
    rate = c.total_uops / (us / 1e6)
    print(f"\n== simulator: {rate / 1e6:.2f} Mμops/s ==")
    emit("bench_simulator", us, f"uops_per_s={rate:.0f}")


def bench_hardware_corpus():
    from repro.core.hardware import characterize_corpus
    from repro.ops.corpus import build_corpus

    corpus = build_corpus(sizes=(128, 256))

    def work():
        return characterize_corpus(corpus)

    res, us = _timed(work)
    print("\n== §6.2-analogue: real-JAX op corpus (this backend) ==")
    print(f"{'op':22s} {'lat_us':>8s} {'tput_us':>8s} {'GFLOP/s':>8s}")
    for name, r in res.items():
        print(f"{name:22s} {r.latency_ns / 1e3:8.2f} "
              f"{r.throughput_ns / 1e3:8.2f} {r.achieved_gflops:8.2f}")
        emit(f"hw_{name}", r.throughput_ns / 1e3,
             f"gflops={r.achieved_gflops:.2f}")
    emit("bench_hardware_corpus", us, f"ops={len(res)}")


def bench_kernel_contention():
    import jax.numpy as jnp

    from repro.core.kernel_bench import profile_kernel
    from repro.kernels import ref

    q = jnp.ones((1, 2, 128, 32), jnp.float32) * 0.1

    def target():
        return ref.reference_attention(q, q, q, causal=True)

    # CPU stand-ins for the blockers (the Pallas blockers run on TPU)
    a = jnp.ones((128, 128), jnp.float32)
    v = jnp.ones((1 << 14,), jnp.float32)
    blockers = {
        "MXU": lambda: (a @ a) * 1e-3,
        "VPU": lambda: v * 1.0001 + 0.5,
    }

    def work():
        return profile_kernel("attention", target, blockers)

    prof, us = _timed(work)
    print("\n== kernel contention harness (CPU: everything serializes) ==")
    print(f"  alone={prof.alone_ns / 1e3:.1f}us overlap="
          f"{ {k: round(v, 2) for k, v in prof.overlap.items()} }")
    emit("bench_kernel_contention", us)


BATCH_SIM_STATS: dict = {}


def bench_batch_sim(smoke: bool = False):
    """Wave execution: scalar per-experiment loop vs the batched array
    backends, over a wave-size sweep. Each wave item is one Algorithm-2
    experiment (body * n_small plus body * n_large), exactly what
    ``MeasurementEngine.submit`` hands to ``run_batch``. Results are
    checked bit-identical while being timed."""
    import random
    import time as _time

    from repro.core.batch_sim import BatchSimMachine
    from repro.core.isa import TEST_ISA
    from repro.core.machine import RegPool, independent_seq
    from repro.core.simulator import SimMachine
    from repro.core.uarch import SIM_SKL

    try:
        import jax  # noqa: F401
        have_jax = True
    except ImportError:
        have_jax = False

    specs = ["ADD_R64_R64", "IMUL_R64_R64", "MOV_R64_R64",
             "SHLD_R64_R64_I8", "PADDD_X_X", "MOV_R64_M64", "ADC_R64_R64",
             "MULPS_X_X", "DIV_R64", "AESDEC_X_X"]
    scalar = SimMachine(SIM_SKL, TEST_ISA)
    sweep = (8,) if smoke else (32, 128, 256)
    rows = []
    print("\n== vectorized measurement substrate: wave-size sweep ==")
    print(f"{'wave':>6s} {'scalar_s':>9s} {'numpy_s':>8s} {'np_x':>6s} "
          f"{'jax_s':>8s} {'jax_x':>6s}")
    for wave in sweep:
        rng = random.Random(wave)
        codes = []
        for _ in range(wave):
            body = independent_seq(TEST_ISA[rng.choice(specs)], RegPool(),
                                   rng.randint(4, 12))
            codes.append(body * 10)
            codes.append(body * 110)
        t0 = _time.perf_counter()
        ref = [scalar.run(list(c)) for c in codes]
        t_scalar = _time.perf_counter() - t0

        def timed_backend(backend):
            m = BatchSimMachine(SIM_SKL, TEST_ISA, backend=backend)
            m.run_batch(codes)   # warm: recipe caches + jit shape buckets
            t0 = _time.perf_counter()
            got = m.run_batch(codes)
            dt = _time.perf_counter() - t0
            assert all(r.cycles == g.cycles and r.port_uops == g.port_uops
                       for r, g in zip(ref, got)), \
                f"{backend} backend diverged from the scalar oracle"
            return dt

        t_np = timed_backend("numpy")
        t_jax = timed_backend("jax") if have_jax else None
        np_x = t_scalar / t_np
        jax_x = (t_scalar / t_jax) if t_jax else None
        print(f"{wave:6d} {t_scalar:9.3f} {t_np:8.3f} {np_x:5.1f}x "
              f"{t_jax if t_jax is not None else float('nan'):8.3f} "
              f"{f'{jax_x:.1f}x' if jax_x else '---':>6s}")
        emit(f"batch_sim_w{wave}_numpy", t_np * 1e6 / (2 * wave),
             f"speedup={np_x:.1f}x")
        if t_jax is not None:
            emit(f"batch_sim_w{wave}_jax", t_jax * 1e6 / (2 * wave),
                 f"speedup={jax_x:.1f}x")
        rows.append({"wave": wave, "scalar_s": round(t_scalar, 4),
                     "numpy_s": round(t_np, 4),
                     "numpy_speedup": round(np_x, 2),
                     "jax_s": round(t_jax, 4) if t_jax else None,
                     "jax_speedup": round(jax_x, 2) if jax_x else None})
    best = max(r["numpy_speedup"] for r in rows)
    target_rows = [r for r in rows if r["wave"] >= 256]
    meets = all(r["numpy_speedup"] >= 5 for r in target_rows) \
        if target_rows else None
    if meets is not None:
        print(f"  wave>=256 numpy speedup "
              f"{'meets' if meets else 'MISSES'} the >=5x target")

    # thin-chunk crossover: smallest lane count where the batched kernel
    # beats the scalar oracle loop — the measured basis for the
    # SimMachine/BatchSimMachine ``min_lanes`` default
    from repro.core.batch_sim import DEFAULT_MIN_LANES
    cross_rows = []
    crossover = None
    widths = (2, 4) if smoke else (2, 4, 6, 8, 12, 16, 24)
    for lanes in widths:
        rng = random.Random(1000 + lanes)
        thin = [independent_seq(TEST_ISA[rng.choice(specs)], RegPool(),
                                rng.randint(4, 12)) * 10
                for _ in range(lanes)]
        t0 = _time.perf_counter()
        for _ in range(5):
            for c in thin:
                scalar.run(list(c))
        t_sc = (_time.perf_counter() - t0) / 5
        mb = BatchSimMachine(SIM_SKL, TEST_ISA, min_lanes=1)
        mb.run_batch(thin)
        t0 = _time.perf_counter()
        for _ in range(5):
            mb.run_batch(thin)
        t_b = (_time.perf_counter() - t0) / 5
        cross_rows.append({"lanes": lanes, "scalar_s": round(t_sc, 5),
                           "batched_s": round(t_b, 5)})
        if crossover is None and t_b < t_sc:
            crossover = lanes
    print(f"  thin-chunk crossover: batched kernel wins from "
          f"{crossover} lanes (min_lanes default {DEFAULT_MIN_LANES})")
    BATCH_SIM_STATS.update({"sweep": rows, "best_numpy_speedup": best,
                            "meets_5x_target_at_256": meets,
                            "jax_available": have_jax,
                            "min_lanes_sweep": cross_rows,
                            "min_lanes_crossover": crossover,
                            "min_lanes_default": DEFAULT_MIN_LANES})


BACKEND_MATRIX_STATS: dict = {}


def bench_backend_matrix(smoke: bool = False):
    """Device-resident wave execution: numpy vs jax (blocked AOT scan) vs
    pallas (interpret mode off-TPU) across wave widths, with a cold and a
    warm lowering-cache pass per cell.  Kernel compilation is shared
    module-wide per shape bucket, so the cold pass measures lowering +
    packing + execution (one pre-pass per backend absorbs compiles and
    feeds the recompile probe: a fresh machine over the same shapes must
    trigger zero new compilations).  Results are asserted bit-identical to
    the scalar ``SimMachine`` oracle while being timed."""
    import random
    import time as _time

    from repro.core.batch_sim import BatchSimMachine
    from repro.core.isa import TEST_ISA
    from repro.core.machine import RegPool, independent_seq
    from repro.core.simulator import SimMachine
    from repro.core.uarch import SIM_SKL

    try:
        import jax  # noqa: F401
        backends = ("numpy", "jax", "pallas")
    except ImportError:
        backends = ("numpy",)

    specs = ["ADD_R64_R64", "IMUL_R64_R64", "MOV_R64_R64",
             "SHLD_R64_R64_I8", "PADDD_X_X", "MOV_R64_M64", "ADC_R64_R64",
             "MULPS_X_X", "DIV_R64", "AESDEC_X_X"]
    scalar = SimMachine(SIM_SKL, TEST_ISA)
    waves = (8, 32) if smoke else (32, 128, 512)
    rows = []
    print("\n== backend matrix: numpy / jax scan / pallas, cold+warm "
          "lowering cache ==")
    print(f"{'wave':>6s} {'backend':>8s} {'cold_s':>8s} {'warm_s':>8s} "
          f"{'vs_numpy':>9s} {'compiles':>9s}")
    for wave in waves:
        rng = random.Random(wave)
        codes = []
        for _ in range(wave):
            body = independent_seq(TEST_ISA[rng.choice(specs)], RegPool(),
                                   rng.randint(4, 12))
            codes.append(body * 10)
            codes.append(body * 110)
        ref = [scalar.run(list(c)) for c in codes]
        numpy_warm = None
        for backend in backends:
            # pre-pass on a throwaway machine: absorbs kernel compilation
            # (module-wide per bucket) so cold isolates the lowering cache
            pre = BatchSimMachine(SIM_SKL, TEST_ISA, backend=backend)
            pre.run_batch(codes)
            pre_compiles = pre.device_stats().get("compiles", 0)
            m = BatchSimMachine(SIM_SKL, TEST_ISA, backend=backend)
            t0 = _time.perf_counter()
            got = m.run_batch(codes)
            cold = _time.perf_counter() - t0
            assert all(r.cycles == g.cycles and r.port_uops == g.port_uops
                       for r, g in zip(ref, got)), \
                f"{backend} backend diverged from the scalar oracle"
            warm = min(_timed(lambda: m.run_batch(codes))[1]
                       for _ in range(3)) / 1e6
            dstats = m.device_stats()
            recompiles = dstats.get("compiles", 0)
            buckets = len(dstats.get("buckets", ()))
            # recompile probe: the pre-pass compiled every bucket, so the
            # measured machine must not have triggered a single compile
            assert recompiles == 0, \
                f"{backend}: {recompiles} recompiles for already-" \
                f"compiled buckets (bucketing regressed)"
            assert pre_compiles <= max(buckets, 1), \
                f"{backend}: {pre_compiles} compiles for {buckets} " \
                f"shape buckets (more than one compile per bucket)"
            if backend == "numpy":
                numpy_warm = warm
            speed = numpy_warm / warm if numpy_warm else float("nan")
            print(f"{wave:6d} {backend:>8s} {cold:8.3f} {warm:8.4f} "
                  f"{speed:8.2f}x {pre_compiles:9d}")
            emit(f"backend_matrix_w{wave}_{backend}",
                 warm * 1e6 / (2 * wave), f"vs_numpy={speed:.2f}x")
            rows.append({"wave": wave, "backend": backend,
                         "cold_s": round(cold, 4),
                         "warm_s": round(warm, 4),
                         "warm_speedup_vs_numpy": round(speed, 2),
                         "compiles": pre_compiles, "buckets": buckets,
                         "lowering": dict(m.lowering_stats)})
    target = [r for r in rows if r["backend"] == "jax" and r["wave"] >= 128]
    meets = all(r["warm_speedup_vs_numpy"] >= 2 for r in target) \
        if target else None
    if meets is not None:
        print(f"  jax backend at wave>=128 "
              f"{'meets' if meets else 'MISSES'} the >=2x-vs-numpy target")
    BACKEND_MATRIX_STATS.update({
        "matrix": rows, "backends": list(backends),
        "meets_2x_target_at_128": meets})


TRACE_OVERHEAD_STATS: dict = {}


def bench_trace_overhead(smoke: bool = False):
    """Observability tax: the backend-matrix wave sweep on the numpy
    backend with tracing disabled vs enabled (repro.obs).  Two numbers
    matter:

    * the measured enabled/disabled wall ratio (spans are per-wave, not
      per-μop, so it should be within noise of 1.0);
    * the **analytic disabled-overhead bound** — spans-per-pass × the
      measured cost of one disabled span call, as a share of the
      disabled wall time.  This is the number the <2% gate asserts: it
      is deterministic, unlike the A/B ratio, which on a busy CI host
      can swing either way by more than the effect being measured.
    """
    import random
    import time as _time

    from repro.core.batch_sim import BatchSimMachine
    from repro.core.isa import TEST_ISA
    from repro.core.machine import RegPool, independent_seq
    from repro.core.uarch import SIM_SKL
    from repro.obs import tracer as obs
    from repro.obs.tracer import Tracer, set_tracer

    specs = ["ADD_R64_R64", "IMUL_R64_R64", "MOV_R64_R64",
             "SHLD_R64_R64_I8", "PADDD_X_X", "MOV_R64_M64", "ADC_R64_R64",
             "MULPS_X_X", "DIV_R64", "AESDEC_X_X"]
    wave = 32 if smoke else 128
    rng = random.Random(wave)   # same wave construction as backend matrix
    codes = []
    for _ in range(wave):
        body = independent_seq(TEST_ISA[rng.choice(specs)], RegPool(),
                               rng.randint(4, 12))
        codes.append(body * 10)
        codes.append(body * 110)
    m = BatchSimMachine(SIM_SKL, TEST_ISA, backend="numpy")
    m.run_batch(codes)          # absorb compiles + cold lowering

    reps = 3 if smoke else 5
    prev = set_tracer(Tracer(enabled=False))
    try:
        t_off = min(_timed(lambda: m.run_batch(codes))[1]
                    for _ in range(reps)) / 1e6
        tr = Tracer(enabled=True)
        set_tracer(tr)
        t_on = min(_timed(lambda: m.run_batch(codes))[1]
                   for _ in range(reps)) / 1e6
        spans_per_pass = len(tr.events()) / reps

        # cost of one disabled span call, measured on the real no-op path
        set_tracer(Tracer(enabled=False))
        n = 100_000
        t0 = _time.perf_counter_ns()
        for _ in range(n):
            with obs.span("bench.noop", probe=1):
                pass
        noop_ns = (_time.perf_counter_ns() - t0) / n
    finally:
        set_tracer(prev)

    ratio = t_on / t_off
    bound = spans_per_pass * noop_ns / (t_off * 1e9)
    print("\n== tracing overhead: numpy wave sweep, repro.obs on vs off ==")
    print(f"{'wave':>6s} {'off_s':>8s} {'on_s':>8s} {'on/off':>7s} "
          f"{'spans':>7s} {'noop_ns':>8s} {'bound%':>7s}")
    print(f"{wave:6d} {t_off:8.4f} {t_on:8.4f} {ratio:6.3f}x "
          f"{spans_per_pass:7.0f} {noop_ns:8.1f} {100 * bound:6.4f}%")
    assert bound < 0.02, \
        f"disabled-tracing overhead bound {100 * bound:.3f}% >= 2% " \
        f"({spans_per_pass:.0f} spans/pass x {noop_ns:.0f}ns noop over " \
        f"{t_off:.4f}s)"
    emit("trace_overhead_off", t_off * 1e6 / (2 * wave),
         f"bound={100 * bound:.4f}%")
    emit("trace_overhead_on", t_on * 1e6 / (2 * wave),
         f"on/off={ratio:.3f}x")
    TRACE_OVERHEAD_STATS.update({
        "wave": wave, "t_off_s": round(t_off, 4), "t_on_s": round(t_on, 4),
        "enabled_over_disabled": round(ratio, 4),
        "spans_per_pass": spans_per_pass,
        "disabled_span_ns": round(noop_ns, 1),
        "disabled_overhead_bound_pct": round(100 * bound, 4),
        "bound_ok": bound < 0.02})


FAULT_OVERHEAD_STATS: dict = {}


def bench_fault_overhead(smoke: bool = False):
    """Chaos tax: the numpy wave sweep with no fault plan installed vs an
    *armed-but-never-firing* plan (p=0 rules at every injection point).
    Same discipline as ``bench_trace_overhead``: the asserted <2% gate is
    the **analytic disabled-path bound** — injection checks per pass × the
    measured cost of one disabled ``faults.check`` call, as a share of the
    plan-free wall time — because the A/B ratio is noise-dominated on a
    busy host."""
    import random
    import time as _time

    from repro.core.batch_sim import BatchSimMachine
    from repro.core.isa import TEST_ISA
    from repro.core.machine import RegPool, independent_seq
    from repro.core.uarch import SIM_SKL
    from repro.faults import plan as faults
    from repro.faults.plan import POINTS, FaultPlan

    specs = ["ADD_R64_R64", "IMUL_R64_R64", "MOV_R64_R64",
             "SHLD_R64_R64_I8", "PADDD_X_X", "MOV_R64_M64", "ADC_R64_R64",
             "MULPS_X_X", "DIV_R64", "AESDEC_X_X"]
    wave = 32 if smoke else 128
    rng = random.Random(wave)   # same wave construction as backend matrix
    codes = []
    for _ in range(wave):
        body = independent_seq(TEST_ISA[rng.choice(specs)], RegPool(),
                               rng.randint(4, 12))
        codes.append(body * 10)
        codes.append(body * 110)
    m = BatchSimMachine(SIM_SKL, TEST_ISA, backend="numpy")
    m.run_batch(codes)          # absorb compiles + cold lowering

    reps = 3 if smoke else 5
    prev = faults.set_plan(None)
    try:
        t_off = min(_timed(lambda: m.run_batch(codes))[1]
                    for _ in range(reps)) / 1e6
        armed = FaultPlan.from_spec(
            ";".join(f"{p}:raise:p=0" for p in POINTS))
        faults.set_plan(armed)
        t_on = min(_timed(lambda: m.run_batch(codes))[1]
                   for _ in range(reps)) / 1e6
        checks_per_pass = armed.occurrences() / reps
        assert not armed.fired

        # cost of one disabled check, measured on the real fast path
        faults.set_plan(None)
        n = 100_000
        t0 = _time.perf_counter_ns()
        for _ in range(n):
            faults.check("wave.kernel", key="bench")
        noop_ns = (_time.perf_counter_ns() - t0) / n
    finally:
        faults.set_plan(prev)

    ratio = t_on / t_off
    bound = checks_per_pass * noop_ns / (t_off * 1e9)
    print("\n== fault-injection overhead: numpy wave sweep, plan off vs "
          "armed p=0 ==")
    print(f"{'wave':>6s} {'off_s':>8s} {'on_s':>8s} {'on/off':>7s} "
          f"{'checks':>7s} {'noop_ns':>8s} {'bound%':>7s}")
    print(f"{wave:6d} {t_off:8.4f} {t_on:8.4f} {ratio:6.3f}x "
          f"{checks_per_pass:7.0f} {noop_ns:8.1f} {100 * bound:6.4f}%")
    assert bound < 0.02, \
        f"disabled-injection overhead bound {100 * bound:.3f}% >= 2% " \
        f"({checks_per_pass:.0f} checks/pass x {noop_ns:.0f}ns noop over " \
        f"{t_off:.4f}s)"
    emit("fault_overhead_off", t_off * 1e6 / (2 * wave),
         f"bound={100 * bound:.4f}%")
    emit("fault_overhead_armed", t_on * 1e6 / (2 * wave),
         f"armed/off={ratio:.3f}x")
    FAULT_OVERHEAD_STATS.update({
        "wave": wave, "t_off_s": round(t_off, 4), "t_on_s": round(t_on, 4),
        "armed_over_disabled": round(ratio, 4),
        "checks_per_pass": checks_per_pass,
        "disabled_check_ns": round(noop_ns, 1),
        "disabled_overhead_bound_pct": round(100 * bound, 4),
        "bound_ok": bound < 0.02})


DEVICE_SCALING_STATS: dict = {}

# worker for bench_device_scaling: runs in a subprocess because
# XLA_FLAGS=--xla_force_host_platform_device_count must be set before jax
# is first imported, and the parent process has usually imported it
# already.  Prints one JSON document on the last stdout line.
_DEVICE_SCALING_WORKER = """
import json, os, random, time
from repro.core.batch_sim import BatchSimMachine
from repro.core.isa import TEST_ISA
from repro.core.machine import RegPool, independent_seq
from repro.core.uarch import SIM_SKL
import jax

smoke = os.environ.get("BENCH_SMOKE") == "1"
waves = (8, 32) if smoke else (32, 128, 512)
specs = ["ADD_R64_R64", "IMUL_R64_R64", "MOV_R64_R64", "SHLD_R64_R64_I8",
         "PADDD_X_X", "MOV_R64_M64", "ADC_R64_R64", "MULPS_X_X", "DIV_R64",
         "AESDEC_X_X"]
rows = []
for wave in waves:
    rng = random.Random(wave)   # same wave construction as backend matrix
    codes = []
    for _ in range(wave):
        body = independent_seq(TEST_ISA[rng.choice(specs)], RegPool(),
                               rng.randint(4, 12))
        codes.append(body * 10)
        codes.append(body * 110)
    ref = BatchSimMachine(SIM_SKL, TEST_ISA, backend="numpy").run_batch(codes)
    for nd in (1, 2, 4):
        m = BatchSimMachine(SIM_SKL, TEST_ISA, backend="jax", devices=nd)
        got = m.run_batch(codes)            # cold: compiles + lowering
        assert all(a.cycles == b.cycles and a.port_uops == b.port_uops
                   for a, b in zip(ref, got)), ("bit-identity", wave, nd)
        warm = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            m.run_batch(codes)
            warm = min(warm, time.perf_counter() - t0)
        st = m.device_stats()
        c0 = st["compiles"]
        m.run_batch(codes)                  # recompile probe
        c1 = m.device_stats()["compiles"]
        if c1 != c0:
            raise AssertionError(
                f"unexpected recompiles at wave={wave} devices={nd}: "
                f"{c1 - c0} new compiles on a warm wave")
        rows.append({"wave": wave, "devices": nd,
                     "warm_s": round(warm, 4),
                     "exps_per_s": round(2 * wave / warm, 1),
                     "compiles": c0, "mesh": st["mesh"],
                     "per_device_lanes": {
                         k: v["lanes"]
                         for k, v in st["per_device"].items()}})
print(json.dumps({"rows": rows, "cpu_count": os.cpu_count(),
                  "jax_devices": len(jax.devices())}))
"""


def bench_device_scaling(smoke: bool = False):
    """Mesh-parallel wave execution: warm wave throughput at 1, 2 and 4
    forced host devices on the backend-matrix wave widths, asserted
    bit-identical to the numpy backend and failing on any warm-wave
    recompile.  Runs in a subprocess so the forced host-device count can
    be injected before jax's first import.  NOTE: forced host devices
    share the machine's physical cores — wall-clock scaling tracks the
    spare core count (``cpu_count`` is recorded alongside), and on a
    single-core host the 4-device row measures sharding overhead, not
    speedup; real accelerators (or real cores) are where the mesh pays."""
    import json as _json
    import os
    import subprocess

    try:
        import jax  # noqa: F401
    except ImportError:
        print("\n== device scaling: jax unavailable, skipped ==")
        DEVICE_SCALING_STATS.update({"skipped": "jax not importable"})
        return
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        env["XLA_FLAGS"] = \
            (flags + " --xla_force_host_platform_device_count=4").strip()
    env["BENCH_SMOKE"] = "1" if smoke else "0"
    src = str(Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _DEVICE_SCALING_WORKER],
                          env=env, capture_output=True, text=True,
                          timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError("bench_device_scaling worker failed:\n"
                           + proc.stderr[-3000:])
    data = _json.loads(proc.stdout.strip().splitlines()[-1])
    rows = data["rows"]
    print(f"\n== device scaling: warm wave throughput vs forced host "
          f"devices (host cpu_count={data['cpu_count']}) ==")
    print(f"{'wave':>6s} {'devices':>8s} {'warm_s':>8s} {'exps/s':>10s} "
          f"{'vs_1dev':>8s}")
    speedups = {}
    for r in rows:
        base = next(b["warm_s"] for b in rows
                    if b["wave"] == r["wave"] and b["devices"] == 1)
        speed = base / r["warm_s"]
        if r["devices"] == 4:
            speedups[r["wave"]] = round(speed, 2)
        print(f"{r['wave']:6d} {r['devices']:8d} {r['warm_s']:8.4f} "
              f"{r['exps_per_s']:10.1f} {speed:7.2f}x")
        emit(f"device_scaling_w{r['wave']}_d{r['devices']}",
             r["warm_s"] * 1e6 / (2 * r["wave"]), f"vs_1dev={speed:.2f}x")
    best = max(speedups.values(), default=float("nan"))
    meets = best >= 1.6
    print(f"  4-device speedup {best:.2f}x "
          f"{'meets' if meets else 'MISSES'} the >=1.6x target "
          f"(host has {data['cpu_count']} cpu core(s); forced host "
          f"devices can only scale across spare cores)")
    DEVICE_SCALING_STATS.update({
        "rows": rows, "speedup_4v1_by_wave": speedups,
        "best_speedup_4v1": best, "meets_1p6x_target": meets,
        "cpu_count": data["cpu_count"],
        "jax_devices": data["jax_devices"]})


CHARACTERIZE_STATS: dict = {}

# representative subset for the CI smoke artifact: big enough that wave
# fusion is visible, small enough to stay in CI budget
SMOKE_SUBSET = ["ADD_R64_R64", "ADC_R64_R64", "MOVQ2DQ_X_X", "MUL_R64",
                "SHLD_R64_R64_I8", "MOV_M64_R64", "DIV_R64", "AESDEC_X_X",
                "IMUL_R64_M64", "CMC", "PADDD_X_X", "PSHUFD_X_X"]


def bench_characterize(smoke: bool = False):
    """Cold scheduler-fused characterization: wall-clock and wave-width
    telemetry. The smoke variant (CI) characterizes a fixed instruction
    subset and records cold wall-clock + mean fused-wave width into
    experiments/benchmarks.smoke.json, so wave-fusion regressions show up
    in the artifact diff; the full variant runs the whole μISA."""
    import time as _time

    from repro.core.characterize import characterize
    from repro.core.engine import MeasurementEngine
    from repro.core.isa import TEST_ISA
    from repro.core.simulator import SimMachine
    from repro.core.uarch import SIM_SKL

    names = SMOKE_SUBSET if smoke else None
    m = SimMachine(SIM_SKL, TEST_ISA)
    t0 = _time.perf_counter()
    model = characterize(MeasurementEngine(m), TEST_ISA, names)
    cold_s = _time.perf_counter() - t0
    ws = model.wave_stats
    print(f"\n== cold characterize ({'smoke subset' if smoke else 'full'}"
          f" μISA, scheduler-fused) ==")
    print(f"  {len(model.instructions)} variants in {cold_s:.2f}s: "
          f"{ws['waves']} fused waves, mean width "
          f"{ws['mean_wave_width']:.1f}, max {ws['max_wave_width']}")
    emit("bench_characterize_cold", cold_s * 1e6,
         f"mean_wave_width={ws['mean_wave_width']};waves={ws['waves']}")
    # same cold characterization on the device-resident jax backend: the
    # wave-execution speedup as seen by a whole inference pipeline
    jax_cold = None
    try:
        import jax  # noqa: F401
    except ImportError:
        pass
    else:
        # first-ever pass pays one XLA compile per shape bucket (shared
        # module-wide afterwards); the second fresh machine is the
        # steady-state story — kernels compiled, lowering cache cold
        mj0 = SimMachine(SIM_SKL, TEST_ISA, backend="jax")
        t0 = _time.perf_counter()
        characterize(MeasurementEngine(mj0), TEST_ISA, names)
        jax_first = _time.perf_counter() - t0
        mj = SimMachine(SIM_SKL, TEST_ISA, backend="jax")
        t0 = _time.perf_counter()
        mdl = characterize(MeasurementEngine(mj), TEST_ISA, names)
        jax_cold = _time.perf_counter() - t0
        n_buckets = len(mj0.device_stats().get("buckets", ()))
        print(f"  jax backend: {jax_cold:.2f}s cold "
              f"({cold_s / jax_cold:.2f}x vs numpy; first-ever run "
              f"{jax_first:.2f}s incl. {n_buckets}-bucket compilation; "
              f"lowering {mj.lowering_stats})")
        emit("bench_characterize_cold_jax", jax_cold * 1e6,
             f"vs_numpy={cold_s / jax_cold:.2f}x")
        es = mdl.engine_stats
        CHARACTERIZE_STATS["jax_backend"] = {
            "cold_seconds": round(jax_cold, 3),
            "first_run_with_compiles_seconds": round(jax_first, 3),
            "speedup_vs_numpy": round(cold_s / jax_cold, 2),
            "lowering_hits": es["lowering_hits"],
            "lowering_misses": es["lowering_misses"],
            "device": mj.device_stats()}
    CHARACTERIZE_STATS.update({
        "smoke": smoke, "instructions": len(model.instructions),
        "cold_seconds": round(cold_s, 3),
        "mean_wave_width": ws["mean_wave_width"],
        "max_wave_width": ws["max_wave_width"], "waves": ws["waves"],
        "experiments": ws["experiments"],
        "engine_hit_rate": model.engine_stats["hit_rate"]})


WAVE_FUSION_STATS: dict = {}


def bench_wave_fusion():
    """Measurement-plan scheduler: per-instruction (legacy sequential
    driver) vs scheduler-fused characterization — wave widths and cold
    wall-clock across SIM_UARCHES. Model XML asserted identical while
    being timed, so the speedup is measured on byte-equivalent work."""
    import time as _time

    from repro.core import model_io
    from repro.core.characterize import characterize
    from repro.core.engine import MeasurementEngine
    from repro.core.isa import TEST_ISA
    from repro.core.simulator import SimMachine
    from repro.core.uarch import SIM_UARCHES

    rows = []
    print("\n== wave fusion: per-instruction (legacy) vs scheduler-fused ==")
    print(f"{'uarch':10s} {'seq_s':>7s} {'fused_s':>8s} {'speedup':>8s} "
          f"{'seq_w':>6s} {'fused_w':>8s} {'width_x':>8s}")
    for name, ua in SIM_UARCHES.items():
        t0 = _time.perf_counter()
        seq = characterize(MeasurementEngine(SimMachine(ua, TEST_ISA)),
                           TEST_ISA, sequential=True)
        t_seq = _time.perf_counter() - t0
        t0 = _time.perf_counter()
        fused = characterize(MeasurementEngine(SimMachine(ua, TEST_ISA)),
                             TEST_ISA)
        t_fus = _time.perf_counter() - t0
        assert model_io.to_xml(fused, TEST_ISA) == \
            model_io.to_xml(seq, TEST_ISA), \
            f"{name}: fused characterization diverged from sequential"
        w_seq = seq.wave_stats["mean_wave_width"]
        w_fus = fused.wave_stats["mean_wave_width"]
        speed, width_x = t_seq / t_fus, w_fus / w_seq
        print(f"{name:10s} {t_seq:7.2f} {t_fus:8.2f} {speed:7.1f}x "
              f"{w_seq:6.2f} {w_fus:8.1f} {width_x:7.1f}x")
        emit(f"wave_fusion_{name}", t_fus * 1e6,
             f"speedup={speed:.1f}x;width_x={width_x:.1f}x")
        rows.append({"uarch": name, "sequential_s": round(t_seq, 3),
                     "fused_s": round(t_fus, 3),
                     "speedup": round(speed, 2),
                     "sequential_mean_wave_width": w_seq,
                     "fused_mean_wave_width": w_fus,
                     "wave_width_ratio": round(width_x, 1),
                     "fused_max_wave_width":
                         fused.wave_stats["max_wave_width"]})
    mean_speed = sum(r["speedup"] for r in rows) / len(rows)
    mean_width = sum(r["wave_width_ratio"] for r in rows) / len(rows)
    meets_w = all(r["wave_width_ratio"] >= 10 for r in rows)
    meets_t = all(r["speedup"] >= 2 for r in rows)
    print(f"  mean: {mean_speed:.1f}x wall-clock, {mean_width:.0f}x wave "
          f"width ({'meets' if meets_w else 'MISSES'} the >=10x width "
          f"target, {'meets' if meets_t else 'MISSES'} the >=2x cold "
          f"wall-clock target)")
    WAVE_FUSION_STATS.update({
        "per_uarch": rows, "mean_speedup": round(mean_speed, 2),
        "mean_wave_width_ratio": round(mean_width, 1),
        "meets_10x_width_target": meets_w,
        "meets_2x_speedup_target": meets_t})


CAMPAIGN_STATS: dict = {}


def bench_campaign_cache():
    """Measurement-engine cache: cold vs warm campaign over all uarches.

    The warm pass re-runs the identical campaign against the same machines
    (whose engines now hold every result), standing in for an incremental
    ``characterize()`` re-run from a persisted cache."""
    import time as _time

    from repro.core.engine import Campaign
    from repro.core.isa import TEST_ISA
    from repro.core.simulator import SimMachine
    from repro.core.uarch import SIM_UARCHES

    machines = [SimMachine(ua, TEST_ISA) for ua in SIM_UARCHES.values()]
    camp = Campaign()
    t0 = _time.perf_counter()
    cold = camp.run(machines, TEST_ISA)
    cold_s = _time.perf_counter() - t0
    t0 = _time.perf_counter()
    warm = camp.run(machines, TEST_ISA)
    warm_s = _time.perf_counter() - t0
    speedup = cold_s / max(warm_s, 1e-9)
    CAMPAIGN_STATS.update({
        "cold_seconds": round(cold_s, 3), "warm_seconds": round(warm_s, 3),
        "speedup_warm_vs_cold": round(speedup, 2),
        "cold_hit_rate": round(cold.hit_rate, 4),
        "warm_hit_rate": round(warm.hit_rate, 4),
        "per_uarch": {n: cold.stats[n] for n in cold.stats},
    })
    print("\n== measurement-engine cache: cold vs warm campaign ==")
    print(f"  cold {cold_s:.2f}s (hit rate {100 * cold.hit_rate:.1f}%)  "
          f"warm {warm_s:.2f}s (hit rate {100 * warm.hit_rate:.1f}%)  "
          f"speedup {speedup:.1f}x")
    emit("bench_campaign_cold", cold_s * 1e6,
         f"hit_rate={cold.hit_rate:.3f}")
    emit("bench_campaign_warm", warm_s * 1e6,
         f"speedup={speedup:.1f}x")


SERVICE_STATS: dict = {}


def bench_service_throughput():
    """uops-as-a-service: requests/sec over a batch-size sweep, cold vs
    warm cache, against the uncached single-block reference predictor.

    Two layers are measured: the *service* layer (registry + LRU cache +
    vectorized batch predictor, queried in-process — comparable to the
    baseline, which is also in-process) and the *wire* layer (full TCP +
    JSON round trip through the client). The >=50x warm-cache target is
    judged at the service layer; the wire numbers show the transport tax."""
    import tempfile
    import time as _time
    from pathlib import Path as _Path

    from repro.core import model_io
    from repro.core.engine import Campaign
    from repro.core.isa import TEST_ISA
    from repro.core.predictor import predict
    from repro.core.simulator import SimMachine
    from repro.core.uarch import SIM_SKL
    from repro.service.client import local_service
    from repro.service.registry import ModelRegistry
    from repro.service.server import PredictionService
    from repro.service.workload import random_blocks

    machine = SimMachine(SIM_SKL, TEST_ISA)
    names = ["ADD_R64_R64", "IMUL_R64_R64", "MUL_R64", "ADC_R64_R64", "CMC",
             "TEST_R64_R64", "SHLD_R64_R64_I8", "MOVQ2DQ_X_X", "AESDEC_X_X",
             "PSHUFD_X_X", "PADDD_X_X", "MOV_R64_M64"]
    res = Campaign(instr_names=names).run([machine], TEST_ISA)
    model = res.models[machine.name]
    tmpdir = tempfile.TemporaryDirectory(prefix="uops_service_bench_")
    tmp = _Path(tmpdir.name)
    (tmp / f"{machine.name}.xml").write_text(model_io.to_xml(model, TEST_ISA))
    ua = machine.name

    n_blocks = 256
    blocks = random_blocks(model, TEST_ISA, n_blocks, seed=17)

    # baseline: the uncached single-block reference path (min of 3 passes,
    # the noise-robust estimator; same estimator as the warm passes below)
    def baseline_pass():
        t0 = _time.perf_counter()
        for b in blocks:
            predict(model, TEST_ISA, b)
        return (_time.perf_counter() - t0) * 1e6 / n_blocks

    base_us = min(baseline_pass() for _ in range(3))
    emit("service_baseline_single", base_us, "reference predict()")

    def sweep_layer(layer, run_chunk, batch_sizes, make_ctx, close_ctx):
        rows = []
        print(f"{'layer':>8s} {'batch':>6s} {'cold_us/req':>12s} "
              f"{'warm_us/req':>12s} {'warm_rps':>9s} {'speedup':>8s}")
        for bs in batch_sizes:
            ctx = make_ctx()
            try:
                def run_pass():
                    t0 = _time.perf_counter()
                    for i in range(0, n_blocks, bs):
                        run_chunk(ctx, blocks[i:i + bs], bs)
                    return (_time.perf_counter() - t0) * 1e6 / n_blocks

                cold_us = run_pass()   # empty cache: every block computed
                # identical requests: pure cache hits (min of 3 passes)
                warm_us = min(run_pass() for _ in range(3))
            finally:
                close_ctx(ctx)
            speedup = base_us / warm_us
            print(f"{layer:>8s} {bs:6d} {cold_us:12.1f} {warm_us:12.1f} "
                  f"{1e6 / warm_us:9.0f} {speedup:7.1f}x")
            emit(f"service_{layer}_warm_b{bs}", warm_us,
                 f"rps={1e6 / warm_us:.0f};speedup={speedup:.1f}x")
            rows.append({"layer": layer, "batch": bs,
                         "cold_us_per_req": round(cold_us, 2),
                         "warm_us_per_req": round(warm_us, 2),
                         "warm_rps": round(1e6 / warm_us),
                         "warm_speedup_vs_single": round(speedup, 1)})
        return rows

    print("\n== uops-as-a-service throughput (batch-size sweep) ==")
    print(f"  baseline (uncached single-block predict): {base_us:.0f} us/req")

    def service_chunk(svc, chunk, bs):
        svc.predict_batch(ua, chunk)

    service_rows = sweep_layer(
        "service", service_chunk, (1, 8, 64, 256),
        lambda: PredictionService(ModelRegistry(tmp), start=False),
        lambda svc: svc.close())

    def wire_chunk(client, chunk, bs):
        if bs == 1:
            client.predict(ua, chunk[0])
        else:
            client.predict_batch(ua, chunk)

    wire_ctxs = []

    def make_wire():
        cm = local_service(tmp)
        client = cm.__enter__()
        wire_ctxs.append(cm)
        return client

    def close_wire(client):
        wire_ctxs.pop().__exit__(None, None, None)

    wire_rows = sweep_layer("wire", wire_chunk, (1, 64, 256),
                            make_wire, close_wire)

    # simulate-backed mode: ground-truth steady-state cycles for a
    # sub-wave of the workload, measured on the simulated core through
    # its batched backend, judged against the analytic predictions
    from repro.service.batch_predictor import BatchPredictor
    bp = BatchPredictor(model, TEST_ISA, machine=machine)
    sub = blocks[:64]
    t0 = _time.perf_counter()
    sim_cycles = bp.simulate_batch(sub)
    sim_s = _time.perf_counter() - t0
    preds = bp.predict_batch(sub)
    rel = [abs(p.cycles - s) / s
           for p, s in zip(preds, sim_cycles) if s > 0]
    mean_rel = sum(rel) / max(len(rel), 1)
    print(f"  simulate-backed check: {len(sub)} blocks measured in "
          f"{sim_s * 1e3:.0f} ms (batched), mean |pred-sim|/sim = "
          f"{100 * mean_rel:.1f}%")
    emit("service_simulate_backed", sim_s * 1e6 / len(sub),
         f"mean_rel_err={mean_rel:.3f}")
    SERVICE_STATS["simulate_backed"] = {
        "blocks": len(sub), "seconds": round(sim_s, 4),
        "mean_rel_error_vs_prediction": round(mean_rel, 4)}

    tmpdir.cleanup()
    best = max(r["warm_speedup_vs_single"] for r in service_rows)
    ok = best >= 50
    print(f"  best warm-cache service-layer speedup vs uncached "
          f"single-block path: {best:.0f}x "
          f"({'meets' if ok else 'MISSES'} the >=50x target)")
    SERVICE_STATS.update({
        "n_blocks": n_blocks,
        "baseline_single_us": round(base_us, 2),
        "sweep": service_rows + wire_rows,
        "best_warm_speedup": best,
        "meets_50x_target": ok,
    })


SERVICE_SATURATION_STATS: dict = {}


def bench_service_saturation(smoke: bool = False):
    """Concurrent-client saturation of the serving tier (the PR-8 rebuild).

    Four measurements, all on the same warm bulk-wave workload:

    * **sustained throughput under concurrency** — closed-loop load via
      the replayable generator (``service/loadgen.py``) at 1 and 8
      connections, against the PR-7 one-thread-per-connection JSON server
      (the baseline) and the asyncio front door on both wires. Target:
      the front door on the binary wire sustains >=5x the baseline's warm
      predictions/sec at 8 clients.
    * **open-loop saturation curve** — fixed arrival rates from 0.5x to
      4x measured capacity; latency is charged from the scheduled arrival
      (no coordinated omission), so p99 under overload is honest. The
      admission controller must shed (typed ``Overloaded``) instead of
      queueing unboundedly.
    * **wire-format ratio** — binary vs JSON end-to-end on bulk waves,
      plus a pure codec micro-bench (encode+decode round trip). Target:
      binary >=2x the JSON framing.
    * **device-resident port bounds** — ``BatchPredictor._port_bounds``
      numpy vs the jax kernel at widening waves, asserted bit-identical.

    Correctness is gated inline: served envelopes on both wires must be
    byte-identical (canonical JSON, trace ids stripped) to the in-memory
    ``BatchPredictor`` reference, and smoke-level closed-loop load must
    not shed — violations raise, which is what the CI smoke step wants."""
    import json as _json
    import tempfile
    import time as _time
    from pathlib import Path as _Path

    from repro.core import model_io
    from repro.core.engine import Campaign
    from repro.core.isa import TEST_ISA
    from repro.core.predictor import sum_usage
    from repro.core.simulator import SimMachine
    from repro.core.uarch import SIM_SKL
    from repro.service import protocol
    from repro.service.batch_predictor import BatchPredictor
    from repro.service.client import ServiceClient
    from repro.service.loadgen import run_load
    from repro.service.registry import ModelRegistry
    from repro.service.server import (PredictionServer, PredictionService,
                                      ThreadedPredictionServer)
    from repro.service.workload import random_blocks

    machine = SimMachine(SIM_SKL, TEST_ISA)
    names = ["ADD_R64_R64", "IMUL_R64_R64", "MUL_R64", "ADC_R64_R64", "CMC",
             "TEST_R64_R64", "SHLD_R64_R64_I8", "MOVQ2DQ_X_X", "AESDEC_X_X",
             "PSHUFD_X_X", "PADDD_X_X", "MOV_R64_M64"]
    model = Campaign(instr_names=names).run([machine],
                                            TEST_ISA).models[machine.name]
    tmpdir = tempfile.TemporaryDirectory(prefix="uops_service_sat_")
    tmp = _Path(tmpdir.name)
    (tmp / f"{machine.name}.xml").write_text(model_io.to_xml(model, TEST_ISA))
    ua = machine.name

    wave = 24 if smoke else 64
    dur = 0.5 if smoke else 2.0
    conns = 8
    blocks = random_blocks(model, TEST_ISA, wave, seed=17, max_len=8)
    rows: list[dict] = []

    def drive(server, kind, wire, n_conns, rate=None):
        r = run_load(server.host, server.port, ua, blocks, wire=wire,
                     conns=n_conns, duration_s=dur, rate_rps=rate)
        r["server"] = kind
        rows.append(r)
        offered = f"{rate:.0f}rps" if rate else "closed"
        print(f"  {kind:>9s} {wire:>6s} conns={n_conns} load={offered:>8s} "
              f"rps={r['rps']:>7.1f} pred/s={r['predictions_per_s']:>9.1f} "
              f"p50={r['p50_ms']:.2f}ms p99={r['p99_ms']:.2f}ms "
              f"shed={r['shed']} err={r['errors']}")
        return r

    print("\n== service saturation (concurrent clients, warm bulk waves) ==")
    print(f"  wave={wave} blocks/request, {dur}s per point")

    # ---- baseline: the PR-7 threaded JSON server ----
    with ThreadedPredictionServer(
            PredictionService(ModelRegistry(tmp))) as srv:
        with ServiceClient(srv.host, srv.port, wire="json") as c:
            c.predict_batch(ua, blocks)  # warm the cache
        legacy1 = drive(srv, "threaded", "json", 1)
        legacy8 = drive(srv, "threaded", "json", conns)

    # ---- the asyncio front door, both wires ----
    svc = PredictionService(ModelRegistry(tmp))
    with PredictionServer(svc, workers=4, max_queue=64) as srv:
        # byte-identity under the served path: both wires vs the in-memory
        # reference predictor
        bp_ref = BatchPredictor(model, TEST_ISA, backend="numpy")
        expected = [{"ok": True, "uarch": ua,
                     "result": protocol.prediction_to_dict(p)}
                    for p in bp_ref.predict_batch(blocks)]
        canon_ref = _json.dumps(expected, sort_keys=True)
        with ServiceClient(srv.host, srv.port, wire="json") as cj, \
                ServiceClient(srv.host, srv.port, wire="binary") as cb:
            for _ in range(2):  # cold then warm (cached-segment path)
                ej = cj.predict_batch(ua, blocks)
                eb = cb.predict_batch(ua, blocks)
                for e in ej + eb:
                    e.pop("trace_id", None)
                if not (_json.dumps(ej, sort_keys=True) == canon_ref
                        == _json.dumps(eb, sort_keys=True)):
                    raise AssertionError(
                        "served envelopes diverge from the in-memory "
                        "BatchPredictor reference")
        print("  byte-identity: json == binary == in-memory reference OK")

        front_j1 = drive(srv, "frontdoor", "json", 1)
        front_j8 = drive(srv, "frontdoor", "json", conns)
        front_b1 = drive(srv, "frontdoor", "binary", 1)
        front_b8 = drive(srv, "frontdoor", "binary", conns)
        closed_rows = [legacy1, legacy8, front_j1, front_j8, front_b1,
                       front_b8]

        # ---- open-loop saturation sweep (binary wire) ----
        cap = max(front_b8["rps"], 1.0)
        sat_rows = []
        for f in ((0.5, 2.0) if smoke else (0.5, 0.8, 1.2, 2.0, 4.0)):
            r = drive(srv, "frontdoor", "binary", conns, rate=cap * f)
            r["offered_factor"] = f
            sat_rows.append(r)
        admission = srv.admission.stats()
        wire_counts = dict(srv.wire_counts)
        wave_cache = svc.wave_cache.stats()

    # ---- load shedding: a deliberately undersized server must shed with
    # typed Overloaded errors (bounded queue) instead of queueing forever
    svc2 = PredictionService(ModelRegistry(tmp))
    with PredictionServer(svc2, workers=1, max_queue=2) as srv:
        with ServiceClient(srv.host, srv.port, wire="json") as c:
            c.predict_batch(ua, blocks)
        shed_row = drive(srv, "tiny(w1q2)", "json", conns)
        shed_admission = srv.admission.stats()
    if shed_row["shed"] == 0:
        raise AssertionError("undersized server (1 worker, queue 2) did "
                             "not shed under 8-way load")
    if shed_admission["peak_inflight"] > 1 + 2:
        raise AssertionError(f"queue grew past its bound: {shed_admission}")

    # queue growth is bounded by construction; assert the accounting agrees
    if admission["peak_inflight"] > admission["workers"] + \
            admission["max_queue"] + 1:
        raise AssertionError(f"unbounded queue growth: {admission}")
    overloaded = [r for r in sat_rows if r.get("offered_factor", 0) >= 2.0]
    if overloaded and not smoke:
        if all(r["shed"] == 0 and r["p99_ms"] > 10 * dur * 1e3
               for r in overloaded):
            raise AssertionError("overload neither shed nor kept latency "
                                 "bounded")
    shed_at_smoke_load = sum(r["shed"] + r["errors"] for r in closed_rows)
    if shed_at_smoke_load:
        raise AssertionError(f"closed-loop (smoke-level) load shed/errored "
                             f"{shed_at_smoke_load} requests")

    # ---- framing micro-bench: server-side decode + encode per bulk wave
    # (the work the wire format imposes per warm request; the binary
    # cached-segment response path is what the server actually runs)
    packed = [protocol.instrs_to_packed(b) for b in blocks]
    req_json = _json.dumps({"op": "predict_batch", "uarch": ua,
                            "blocks": [protocol.packed_to_wire(pb)
                                       for pb in packed]},
                           separators=(",", ":"))
    req_bin = protocol.encode_predict_batch(ua, packed)
    resp_envs = expected  # trace-id-free envelopes, as the server sends
    resp_json = _json.dumps({"ok": True, "result": resp_envs},
                            separators=(",", ":"))
    pidx = {p: i for i, p in enumerate(bp_ref.port_names)}
    chunks = [protocol.encode_pred_chunk(e, pidx) for e in resp_envs]
    reps = 30 if smoke else 200

    def _codec_pass(fn):
        t0 = _time.perf_counter()
        for _ in range(reps):
            fn()
        return (_time.perf_counter() - t0) * 1e6 / reps

    def json_framing():
        msg = _json.loads(req_json)
        tuple(protocol.wire_to_packed(b) for b in msg["blocks"])
        _json.dumps({"ok": True, "result": resp_envs},
                    separators=(",", ":"))

    def bin_framing():
        protocol.decode_predict_batch(req_bin)
        protocol.encode_predict_batch_resp("t" * 16, ua,
                                           bp_ref.port_names, chunks)

    json_us = min(_codec_pass(json_framing) for _ in range(3))
    bin_us = min(_codec_pass(bin_framing) for _ in range(3))
    codec_ratio = json_us / bin_us
    print(f"  server-side framing (wave={wave}): json={json_us:.0f}us "
          f"binary={bin_us:.0f}us ratio={codec_ratio:.1f}x")
    print(f"  request bytes: json={len(req_json)} "
          f"binary={len(protocol.encode_predict_batch(ua, packed))}; "
          f"response bytes: json={len(resp_json)} binary="
          f"{len(protocol.encode_predict_batch_resp('t' * 16, ua, bp_ref.port_names, chunks))}")

    # ---- device-resident port bounds: numpy vs jax, bit-identical ----
    dev_rows = []
    try:
        bp_jax = BatchPredictor(model, TEST_ISA, backend="jax",
                                min_device_blocks=1)
    except Exception:
        bp_jax = None
    for w in ((64,) if smoke else (64, 256, 1024)):
        wb = random_blocks(model, TEST_ISA, w, seed=23, max_len=8)
        codes = [list(b) for b in wb]
        sums = {i: sum_usage(model, c) for i, c in enumerate(codes)}
        bn, np_us = _timed(lambda: bp_ref._port_bounds(sums))
        np_us = min(np_us, _timed(lambda: bp_ref._port_bounds(sums))[1])
        row = {"wave": w, "numpy_us": round(np_us, 1)}
        if bp_jax is not None:
            bp_jax._port_bounds(sums)  # compile once
            bj, jax_us = _timed(lambda: bp_jax._port_bounds(sums))
            jax_us = min(jax_us,
                         _timed(lambda: bp_jax._port_bounds(sums))[1])
            if bn != bj:
                raise AssertionError(f"device port bounds diverge from "
                                     f"numpy at wave {w}")
            row.update(jax_us=round(jax_us, 1),
                       jax_vs_numpy=round(np_us / jax_us, 2))
        dev_rows.append(row)
        print(f"  port-bounds wave={w}: numpy={row['numpy_us']}us"
              + (f" jax={row['jax_us']}us ({row['jax_vs_numpy']}x)"
                 if "jax_us" in row else " (jax unavailable)"))

    tmpdir.cleanup()

    # ---- headline gates ----
    speedup = front_b8["predictions_per_s"] / max(
        legacy8["predictions_per_s"], 1e-9)
    wire_ratio = front_b8["predictions_per_s"] / max(
        front_j8["predictions_per_s"], 1e-9)
    meets_5x = speedup >= 5.0
    meets_2x = wire_ratio >= 2.0
    print(f"  front door (binary, {conns} conns) vs PR-7 threaded server: "
          f"{speedup:.1f}x warm predictions/sec "
          f"({'meets' if meets_5x else 'MISSES'} the >=5x target)")
    print(f"  binary vs JSON framing: e2e {wire_ratio:.1f}x, codec "
          f"{codec_ratio:.1f}x ({'meets' if meets_2x else 'MISSES'} "
          f"the >=2x target)")
    emit("service_saturation_frontdoor_b8",
         1e6 / max(front_b8["predictions_per_s"], 1e-9),
         f"pred/s={front_b8['predictions_per_s']:.0f};"
         f"speedup={speedup:.1f}x;p99_ms={front_b8['p99_ms']}")
    emit("service_saturation_codec", bin_us,
         f"json_us={json_us:.0f};ratio={codec_ratio:.1f}x")

    SERVICE_SATURATION_STATS.update({
        "smoke": smoke, "wave": wave, "duration_s": dur,
        "closed_loop": closed_rows, "open_loop": sat_rows,
        "shed_demo": {"row": shed_row, "admission": shed_admission},
        "admission_after": admission, "wire_conns": wire_counts,
        "wave_cache": wave_cache,
        "codec": {"wave": wave, "json_us": round(json_us, 1),
                  "binary_us": round(bin_us, 1),
                  "ratio": round(codec_ratio, 2)},
        "device_port_bounds": dev_rows,
        "speedup_vs_threaded_at_8": round(speedup, 2),
        "binary_vs_json_e2e": round(wire_ratio, 2),
        "meets_5x_target": meets_5x,
        "meets_2x_wire_target": meets_2x,
        "served_bit_identical": True,
    })


CORPUS_EVAL_STATS: dict = {}


def bench_corpus_eval(smoke: bool = False):
    """Corpus-evaluation throughput: a seeded block corpus streamed
    through ``BatchPredictor.simulate_batch`` as fused mega-waves.
    Sweeps wave width × wave backend (numpy vs jax); each cell runs
    twice in-process so the second run sees warm lowering/jit caches
    (the first jax cell pays the cold compile)."""
    import shutil
    import tempfile

    from repro.core.characterize import characterize
    from repro.core.isa import TEST_ISA
    from repro.core.simulator import SimMachine
    from repro.core.uarch import SIM_UARCHES
    from repro.corpus import CorpusSpec, evaluate_corpus, generate_corpus
    from repro.corpus.store import load_manifest, read_shard
    from repro.service.protocol import parse_block

    blocks = 128 if smoke else 2048
    widths = (32, 128) if smoke else (512, 2048, 8192)
    tmp = Path(tempfile.mkdtemp(prefix="bench_corpus_"))
    try:
        spec = CorpusSpec(seed=0, blocks_per_uarch=blocks,
                          uarches=("sim_skl",),
                          shard_size=max(16, blocks // 8))
        _, gen_us = _timed(lambda: generate_corpus(tmp / "corpus", spec))
        emit("corpus_generate", gen_us / blocks, f"blocks={blocks}")

        # characterize once (numpy oracle) so every cell measures wave
        # throughput, not model inference
        man = load_manifest(tmp / "corpus")
        used = sorted({ins.spec for s in man["shards"]
                       for r in read_shard(tmp / "corpus", s)
                       for ins in parse_block(r["block"])})
        model = characterize(SimMachine(SIM_UARCHES["sim_skl"], TEST_ISA),
                             TEST_ISA, used)
        models = {"sim_skl": model}

        print("\n== corpus evaluation: fused mega-wave throughput ==")
        print(f"{'backend':8s} {'wave':>6s} {'cold_s':>8s} {'warm_s':>8s} "
              f"{'waves':>6s} {'max_w':>6s} {'blk/s':>8s}")
        rows = []
        for backend in ("numpy", "jax"):
            for width in widths:
                runs = []
                for phase in ("cold", "warm"):
                    out = tmp / f"r_{backend}_{width}_{phase}"
                    res, us = _timed(lambda out=out: evaluate_corpus(
                        tmp / "corpus", backend=backend, wave_width=width,
                        out_dir=out, resume=False, models=models))
                    runs.append((us, res))
                (cold_us, res), (warm_us, _) = runs
                ws = res["wave_stats"]
                bps = blocks / (warm_us / 1e6)
                rows.append({"backend": backend, "wave_width": width,
                             "cold_s": round(cold_us / 1e6, 3),
                             "warm_s": round(warm_us / 1e6, 3),
                             "waves": ws["waves"],
                             "max_wave_width": ws["max_wave_width"],
                             "blocks_per_s_warm": round(bps, 1)})
                print(f"{backend:8s} {width:>6d} {cold_us / 1e6:>8.3f} "
                      f"{warm_us / 1e6:>8.3f} {ws['waves']:>6d} "
                      f"{ws['max_wave_width']:>6d} {bps:>8.1f}")
                emit(f"corpus_eval_{backend}_w{width}", warm_us / blocks,
                     f"blocks={blocks};waves={ws['waves']};"
                     f"cold_s={cold_us / 1e6:.3f}")
        CORPUS_EVAL_STATS.update({"smoke": smoke, "blocks": blocks,
                                  "widths": list(widths), "rows": rows})
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def table_roofline():
    from repro.analysis.roofline import full_table, markdown_table

    rows, us = _timed(lambda: full_table(variant="cost"))
    print("\n== §Roofline (from dry-run artifacts, single-pod) ==")
    if rows:
        print(markdown_table(rows))
    else:
        print("  (no cost-variant dry-run records found — run "
              "python -m repro.launch.dryrun --all --variant cost)")
    emit("table_roofline", us, f"cells={len(rows)}")


BENCHES = {
    "table1_characterization": table1_characterization,
    "table_legacy_versions": table_legacy_versions,
    "table_throughput_defs": table_throughput_defs,
    "fig_case_aesdec": fig_case_aesdec,
    "fig_case_shld": fig_case_shld,
    "fig_case_movq2dq": fig_case_movq2dq,
    "table_multi_latency": table_multi_latency,
    "table_zero_idioms": table_zero_idioms,
    "bench_lp": bench_lp,
    "bench_simulator": bench_simulator,
    "bench_batch_sim": bench_batch_sim,
    "bench_backend_matrix": bench_backend_matrix,
    "bench_trace_overhead": bench_trace_overhead,
    "bench_fault_overhead": bench_fault_overhead,
    "bench_device_scaling": bench_device_scaling,
    "bench_characterize": bench_characterize,
    "bench_wave_fusion": bench_wave_fusion,
    "bench_campaign_cache": bench_campaign_cache,
    "bench_service_throughput": bench_service_throughput,
    "bench_service_saturation": bench_service_saturation,
    "bench_corpus_eval": bench_corpus_eval,
    "bench_hardware_corpus": bench_hardware_corpus,
    "bench_kernel_contention": bench_kernel_contention,
    "table_roofline": table_roofline,
}


def main(argv=None) -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", action="append", choices=sorted(BENCHES),
                    help="run only the named benchmark(s); repeatable")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke mode: tiny wave for bench_batch_sim and "
                         "an instruction subset for bench_characterize "
                         "(other benchmarks run at full cost — combine "
                         "with --only) and results go to "
                         "benchmarks.smoke.json")
    args = ap.parse_args(argv)
    selected = args.only or list(BENCHES)

    print("name,us_per_call,derived")
    for name in selected:
        fn = BENCHES[name]
        if name in ("bench_batch_sim", "bench_backend_matrix",
                    "bench_trace_overhead", "bench_fault_overhead",
                    "bench_device_scaling", "bench_characterize",
                    "bench_service_saturation", "bench_corpus_eval"):
            fn(smoke=args.smoke)
        else:
            fn()
    print(f"\n{len(ROWS)} benchmark rows emitted.")

    out = Path(__file__).resolve().parents[1] / "experiments"
    out.mkdir(parents=True, exist_ok=True)
    payload = {
        "rows": [{"name": n, "us_per_call": round(us, 2), "derived": d}
                 for n, us, d in ROWS],
        "campaign_cache": CAMPAIGN_STATS,
        "service": SERVICE_STATS,
        "service_saturation": SERVICE_SATURATION_STATS,
        "batch_sim": BATCH_SIM_STATS,
        "backend_matrix": BACKEND_MATRIX_STATS,
        "trace_overhead": TRACE_OVERHEAD_STATS,
        "fault_overhead": FAULT_OVERHEAD_STATS,
        "device_scaling": DEVICE_SCALING_STATS,
        "characterize": CHARACTERIZE_STATS,
        "wave_fusion": WAVE_FUSION_STATS,
        "corpus_eval": CORPUS_EVAL_STATS,
    }
    if args.only or args.smoke:
        # partial/smoke runs must not clobber the full record
        path = out / "benchmarks.smoke.json"
    else:
        path = out / "benchmarks.json"
    path.write_text(json.dumps(payload, indent=1))
    print(f"JSON results (incl. cache hit-rate / speedup) -> {path}")

    # with REPRO_TRACE=1 the whole run was traced: drop the Perfetto-
    # loadable trace next to the JSON (feed it to
    # scripts/analyze.py --trace-report for the bottleneck table)
    from repro.obs import tracer as obs
    if obs.enabled():
        from repro.obs.export import write_chrome_trace
        tpath = path.parent / (path.stem + ".trace.json")
        write_chrome_trace(tpath)
        print(f"Chrome/Perfetto trace -> {tpath}")


if __name__ == "__main__":
    main()

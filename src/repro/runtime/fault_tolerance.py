"""Deprecated shim: this module moved to :mod:`repro.faults.tolerance`.

The seed-era fault-tolerance objects (StragglerDetector, FleetMonitor,
StepTimer) now live in the faults package, wired to real per-device
kernel timings (see README §Robustness). Import from ``repro.faults``.
"""
import warnings

from repro.faults.tolerance import (FleetMonitor, StepTimer,  # noqa: F401
                                    StragglerDetector)

warnings.warn("repro.runtime.fault_tolerance moved to "
              "repro.faults.tolerance; this shim will be removed",
              DeprecationWarning, stacklevel=2)

__all__ = ["FleetMonitor", "StepTimer", "StragglerDetector"]

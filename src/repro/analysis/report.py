"""Generate the EXPERIMENTS.md §Dry-run table from the memory-variant
records (both meshes) — per-cell HBM fit, collective schedule summary,
compile times. Run:  PYTHONPATH=src python -m repro.analysis.report
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.analysis.roofline import DRYRUN_DIR

HBM = 16e9  # v5e per-chip


def dryrun_table(mesh: str = "single", variant: str = "memory") -> str:
    rows = []
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("mesh") != mesh or rec.get("variant") != variant:
            continue
        if rec.get("tag"):
            continue
        ma = rec.get("memory_analysis", {})
        args_gb = ma.get("argument_size_in_bytes", 0) / 1e9
        temp_gb = ma.get("temp_size_in_bytes", 0) / 1e9
        tot = args_gb + temp_gb
        coll = rec.get("collectives", {}).get("count", {})
        coll_s = " ".join(f"{k.split('-')[0] if False else k}:{v}"
                          for k, v in sorted(coll.items()))
        fits = "yes" if tot < HBM / 1e9 else "**NO**"
        status = "ok" if rec.get("ok") else "FAIL"
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {status} | {args_gb:.2f} | "
            f"{temp_gb:.2f} | {fits} | {coll_s} | "
            f"{rec.get('compile_s', 0):.0f}s |")
    hdr = ("| arch | shape | compile | args GB/dev | temp GB/dev | fits 16GB "
           "| collectives (count) | compile time |\n"
           "|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


if __name__ == "__main__":
    import sys

    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    print(dryrun_table(mesh=mesh))

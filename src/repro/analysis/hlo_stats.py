"""Parse compiled/optimized HLO text for collective statistics.

``cost_analysis()`` does not report collective traffic, so the roofline's
collective term comes from summing operand/result sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
in the (SPMD-partitioned, optimized) HLO.

Wire-byte estimates per device use standard ring-algorithm formulas with the
replica-group size g parsed from the op:
    all-gather          R·(g-1)/g      (R = result bytes, per device)
    all-reduce          2·R·(g-1)/g
    reduce-scatter      R·(g-1)        (R is the scattered shard)
    all-to-all          R·(g-1)/g
    collective-permute  R
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-gather.3 = bf16[16,4096,256]{2,1,0} all-gather(...), ...
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_TUPLE_OP_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SOURCE_TARGET_RE = re.compile(r"source_target_pairs=")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    count: dict = field(default_factory=lambda: defaultdict(int))
    result_bytes: dict = field(default_factory=lambda: defaultdict(int))
    wire_bytes: dict = field(default_factory=lambda: defaultdict(float))

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    @property
    def total_result_bytes(self) -> int:
        return sum(self.result_bytes.values())

    def to_dict(self) -> dict:
        return {
            "count": dict(self.count),
            "result_bytes": dict(self.result_bytes),
            "wire_bytes": dict(self.wire_bytes),
            "total_wire_bytes": self.total_wire_bytes,
        }


def _group_size(line: str) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota form [num_groups,group_size]<=[total]
        return int(m.group(2))
    if _SOURCE_TARGET_RE.search(line):
        return 2
    return 1


def _wire(kind: str, result_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-gather":
        return result_bytes * (g - 1) / g
    if kind == "all-reduce":
        return 2 * result_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return result_bytes * (g - 1)
    if kind == "all-to-all":
        return result_bytes * (g - 1) / g
    return float(result_bytes)  # collective-permute


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    seen_done = set()
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        # skip -done ops (size counted at -start)
        if re.search(r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)-done", line):
            continue
        kind = None
        rbytes = 0
        m = _OP_RE.search(line)
        if m:
            kind = m.group(3)
            rbytes = _shape_bytes(m.group(1), m.group(2))
        else:
            mt = _TUPLE_OP_RE.search(line)
            if mt:
                kind = mt.group(2)
                # tuple result (e.g. variadic all-gather / -start): sum parts
                for sm in _SHAPE_RE.finditer(mt.group(1)):
                    rbytes += _shape_bytes(sm.group(1), sm.group(2))
            else:
                continue
        g = _group_size(line)
        stats.count[kind] += 1
        stats.result_bytes[kind] += rbytes
        stats.wire_bytes[kind] += _wire(kind, rbytes, g)
    return stats

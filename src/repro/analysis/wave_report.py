"""Per-wave bottleneck attribution from an execution trace.

Consumes a trace written by :mod:`repro.obs.export` (either the Chrome
trace-event JSON or the JSONL log — ``load_events`` normalizes both) and
attributes where wave time actually went:

* **stage shares** — busy µs summed per pipeline stage (lower / pack /
  kernel / dispatch / result-wait / extract / scalar / compile, plus the
  scheduler's fuse step and the engine's cache probe), as a share of all
  attributed stage time;
* **lock-wait share** — time spent *acquiring* the kernel-execute and
  dispatch locks (``wave.lock_wait`` / ``wave.dispatch_lock_wait``),
  measured separately from the work the locks guard;
* **device imbalance** — per-device busy time from the synthetic
  ``device:<id>`` kernel tracks; the imbalance ratio is max/mean busy
  (1.0 == perfectly balanced shards);
* **top-k slowest waves** — the widest/longest ``scheduler.execute``
  spans, with their wave widths.

The classification at the end names the dominant cost so a campaign run
can be read at a glance: ``kernel-bound``, ``lowering-bound``,
``lock-bound`` (lock wait above :data:`LOCK_BOUND_SHARE` of stage time),
or ``device-imbalanced`` (imbalance above :data:`IMBALANCE_BOUND`).

Usage::

    PYTHONPATH=src python scripts/analyze.py --trace-report run.trace.json
"""
from __future__ import annotations

from typing import Dict, List

#: span name -> report stage (order is the table's display order)
STAGE_OF = {
    "wave.lower": "lower",
    "wave.pack": "pack",
    "wave.kernel": "kernel",
    "wave.dispatch": "dispatch",
    "wave.result_wait": "result_wait",
    "wave.extract": "extract",
    "wave.scalar": "scalar",
    "wave.compile": "compile",
    "scheduler.fuse": "fuse",
    "engine.cache_probe": "cache_probe",
}

#: lock-acquisition spans, attributed separately from the guarded work
LOCK_SPANS = ("wave.lock_wait", "wave.dispatch_lock_wait")

#: lock-wait share of stage time above which the run is "lock-bound"
LOCK_BOUND_SHARE = 0.25
#: device busy max/mean ratio above which the run is "device-imbalanced"
IMBALANCE_BOUND = 1.5


def _is_device_track(ev: dict) -> bool:
    name = ev.get("tid_name") or ""
    return isinstance(name, str) and name.startswith("device:")


def wave_report(events: List[dict], top: int = 5) -> dict:
    """Aggregate a normalized event list (see
    :func:`repro.obs.export.load_events`) into the attribution report."""
    from repro.faults.tolerance import StragglerDetector  # noqa: PLC0415

    stages: Dict[str, dict] = {s: {"us": 0.0, "count": 0}
                               for s in dict.fromkeys(STAGE_OF.values())}
    lock_us = 0.0
    lock_count = 0
    devices: Dict[str, float] = {}
    straggler = StragglerDetector()
    waves: List[dict] = []
    run_batches = 0
    t_lo, t_hi = None, 0.0
    for ev in events:
        if ev.get("ph") != "X":
            continue
        ts = float(ev.get("ts_us", 0.0))
        dur = float(ev.get("dur_us", 0.0))
        t_lo = ts if t_lo is None else min(t_lo, ts)
        t_hi = max(t_hi, ts + dur)
        name = ev.get("name", "")
        if _is_device_track(ev):
            # device tracks carry only kernel spans; fold into the kernel
            # stage AND the per-device busy ledger
            devices[ev["tid_name"]] = devices.get(ev["tid_name"], 0.0) + dur
            if name == "wave.kernel":
                stages["kernel"]["us"] += dur
                stages["kernel"]["count"] += 1
                # the same per-device EWMA the device executor runs live
                straggler.observe(ev["tid_name"], dur / 1e6)
            continue
        if name in LOCK_SPANS:
            lock_us += dur
            lock_count += 1
            continue
        stage = STAGE_OF.get(name)
        if stage is not None:
            stages[stage]["us"] += dur
            stages[stage]["count"] += 1
        elif name == "wave.run_batch":
            run_batches += 1
        elif name == "scheduler.execute":
            args = ev.get("args", {}) or {}
            waves.append({"ts_us": ts, "dur_us": dur,
                          "wave": args.get("wave"),
                          "plans": args.get("plans")})
    stage_us = sum(s["us"] for s in stages.values())
    denom = stage_us + lock_us
    for s in stages.values():
        s["share"] = s["us"] / denom if denom else 0.0
    lock_share = lock_us / denom if denom else 0.0
    busy = sorted(devices.values())
    imbalance = (busy[-1] / (sum(busy) / len(busy))
                 if busy and sum(busy) else 0.0)
    waves.sort(key=lambda w: -w["dur_us"])
    bottleneck = _classify(stages, lock_share, imbalance)
    return {
        "wall_us": (t_hi - t_lo) if t_lo is not None else 0.0,
        "stage_us": stage_us,
        "stages": stages,
        "lock_wait": {"us": lock_us, "count": lock_count,
                      "share": lock_share},
        "devices": devices,
        "device_imbalance": imbalance,
        "stragglers": straggler.snapshot(),
        "waves": run_batches,
        "top_waves": waves[:top],
        "bottleneck": bottleneck,
    }


def _classify(stages: dict, lock_share: float, imbalance: float) -> str:
    if lock_share >= LOCK_BOUND_SHARE:
        return "lock-bound"
    if imbalance >= IMBALANCE_BOUND:
        return "device-imbalanced"
    best, best_us = "idle", 0.0
    for name, s in stages.items():
        if s["us"] > best_us:
            best, best_us = name, s["us"]
    if best == "idle":
        return "idle"
    if best in ("lower", "compile"):
        return "lowering-bound"
    return f"{best}-bound"


def format_wave_report(rep: dict) -> str:
    """Render the report as the CLI's fixed-width table."""
    lines = [
        f"trace: {rep['wall_us'] / 1e3:.1f} ms wall, "
        f"{rep['waves']} wave(s), bottleneck: {rep['bottleneck']}",
        "",
        f"{'stage':<12} {'time ms':>10} {'share':>7} {'spans':>7}",
    ]
    rows = sorted(rep["stages"].items(), key=lambda kv: -kv[1]["us"])
    for name, s in rows:
        if not s["count"]:
            continue
        lines.append(f"{name:<12} {s['us'] / 1e3:>10.2f} "
                     f"{s['share'] * 100:>6.1f}% {s['count']:>7}")
    lw = rep["lock_wait"]
    lines.append(f"{'lock_wait':<12} {lw['us'] / 1e3:>10.2f} "
                 f"{lw['share'] * 100:>6.1f}% {lw['count']:>7}")
    if rep["devices"]:
        lines.append("")
        lines.append(f"device busy (imbalance "
                     f"{rep['device_imbalance']:.2f}x max/mean):")
        for dev, us in sorted(rep["devices"].items()):
            lines.append(f"  {dev:<12} {us / 1e3:>10.2f} ms")
        flagged = (rep.get("stragglers") or {}).get("flagged") or []
        if flagged:
            st = rep["stragglers"]
            lines.append(f"stragglers (EWMA > {2.0:.1f}x fleet median "
                         f"{st['median_s'] * 1e3:.2f} ms):")
            for dev in flagged:
                lines.append(f"  {dev:<12} "
                             f"{st['ewma_s'][dev] * 1e3:>10.2f} ms EWMA")
    if rep["top_waves"]:
        lines.append("")
        lines.append(f"slowest waves (top {len(rep['top_waves'])}):")
        for w in rep["top_waves"]:
            lines.append(f"  t={w['ts_us'] / 1e3:>9.2f} ms "
                         f"dur={w['dur_us'] / 1e3:>8.2f} ms "
                         f"wave={w['wave']} plans={w['plans']}")
    return "\n".join(lines)


def report_from_file(path, top: int = 5) -> dict:
    """Load a trace file (either exporter format) and build the report."""
    from repro.obs.export import load_events  # noqa: PLC0415

    return wave_report(load_events(path), top=top)

"""Three-term roofline analysis from the dry-run's compiled artifacts.

Per (arch × shape × mesh), using TPU v5e constants (core/uarch.py):

    compute_term    = HLO_FLOPs_per_device / peak_bf16_flops
    memory_term     = HLO_bytes_per_device / hbm_bw
    collective_term = wire_bytes_per_device / ici_bw

(cost_analysis of the SPMD-partitioned module is already per device, so
dividing by per-chip peaks is the prompt's ``global / (chips × peak)``.)

Also reports MODEL_FLOPS (6·N·D analytic) / HLO_FLOPs — the useful-compute
ratio that exposes remat and redundancy overhead — and the dominant term.

Methodology caveats (documented in EXPERIMENTS.md): HLO "bytes accessed" on
the CPU backend counts unfused operand+result traffic, an upper bound on
real TPU HBM traffic after fusion; the collective term assumes a single ICI
link per chip (conservative — v5e has 4 links on the 2D torus).
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec, load_config
from repro.core.uarch import TPU_V5E

DRYRUN_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def attn_flops_forward(cfg: ModelConfig, batch: int, seq: int) -> float:
    """Full-attention score+value FLOPs, forward, causal-halved."""
    if cfg.num_heads == 0:
        return 0.0
    per_layer = 2 * 2 * batch * seq * seq * cfg.num_heads * cfg.head_dim / 2
    if cfg.family in ("dense", "vlm", "moe"):
        layers = cfg.num_layers
    elif cfg.family == "hybrid":
        layers = cfg.num_layers // cfg.attn_every
    elif cfg.family == "encdec":
        enc = 2 * 2 * batch * cfg.num_audio_frames ** 2 * cfg.num_heads * cfg.head_dim
        cross = 2 * 2 * batch * seq * cfg.num_audio_frames * cfg.num_heads * cfg.head_dim
        return cfg.num_layers * enc + cfg.num_decoder_layers * (per_layer + cross)
    else:
        layers = 0
    return layers * per_layer


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Analytic useful FLOPs per step (global): 6ND (train) / 2ND (prefill)
    / 2N per token (decode), plus the attention term."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6 * n * toks + 3 * attn_flops_forward(cfg, shape.global_batch,
                                                     shape.seq_len)
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2 * n * toks + attn_flops_forward(cfg, shape.global_batch,
                                                 shape.seq_len)
    # decode: one token per sequence; attention reads the whole cache
    flops = 2 * n * shape.global_batch
    if cfg.num_heads:
        stack = (cfg.num_layers if cfg.family in ("dense", "vlm", "moe")
                 else cfg.num_layers // cfg.attn_every
                 if cfg.family == "hybrid" else cfg.num_decoder_layers)
        flops += (2 * 2 * shape.global_batch * shape.seq_len *
                  cfg.num_kv_heads * cfg.head_dim * stack)
    return flops


def decode_state_bytes(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """KV-cache + SSM-state bytes (global) — the decode memory floor."""
    B, S = shape.global_batch, shape.seq_len
    total = 0.0
    if cfg.num_heads:
        stack = (cfg.num_layers if cfg.family in ("dense", "vlm", "moe")
                 else cfg.num_layers // cfg.attn_every
                 if cfg.family == "hybrid" else cfg.num_decoder_layers)
        total += 2 * stack * B * S * cfg.num_kv_heads * cfg.head_dim * 2
    if cfg.ssm_state:
        conv_ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        total += cfg.num_layers * B * (
            cfg.ssm_heads * cfg.ssm_headdim * cfg.ssm_state
            + (cfg.ssm_conv - 1) * conv_ch) * 2
    return total


def model_bytes(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Analytic HBM-traffic floor per step (global), for the fair roofline
    fraction of memory-bound cells:

      train:   6 × param bytes (fwd read + bwd read + grad/opt update)
               + 4 × activation-residual traffic (write + re-read, fwd+bwd)
      prefill: param read + KV write + 2 × activations
      decode:  active-param read + full decode-state read (the floor that
               dominates at long context)
    """
    p_bytes = cfg.param_count() * 4.0  # fp32 master weights
    d = cfg.d_model
    toks = shape.global_batch * shape.seq_len
    layers = cfg.num_layers + (cfg.num_decoder_layers or 0)
    act = toks * d * 2.0 * layers  # bf16 residual stream per layer
    if shape.kind == "train":
        return 6 * p_bytes + 4 * act
    if shape.kind == "prefill":
        kv = decode_state_bytes(cfg, shape)
        return cfg.active_param_count() * 2.0 + kv + 2 * act
    return (cfg.active_param_count() * 2.0 +
            decode_state_bytes(cfg, shape))


def load_records(dryrun_dir: Path = DRYRUN_DIR, variant: str = "cost",
                 mesh: str = "single", tag: str = "") -> dict:
    out = {}
    for f in sorted(dryrun_dir.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("variant") != variant or rec.get("mesh") != mesh:
            continue
        if rec.get("tag", "") != tag:
            continue
        out[(rec["arch"], rec["shape"])] = rec
    return out


def roofline(rec: dict, chips: int = 256) -> dict | None:
    """The three terms (seconds) + bottleneck for one dry-run record."""
    if not rec.get("ok"):
        return None
    ca = rec.get("cost_analysis", {})
    flops_dev = ca.get("flops", 0.0)
    bytes_dev = ca.get("bytes accessed", 0.0)
    wire_dev = rec.get("collectives", {}).get("total_wire_bytes", 0.0)
    hw = TPU_V5E
    compute_s = flops_dev / hw["peak_bf16_flops"]
    memory_s = bytes_dev / hw["hbm_bw"]
    coll_s = wire_dev / hw["ici_bw"]
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    cfg = load_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    mf = model_flops(cfg, shape)
    mb = model_bytes(cfg, shape)
    hlo_global = flops_dev * chips
    bound = max(terms.values())
    # ideal step time: the analytically-necessary work on the dominant
    # resource (compute floor OR traffic floor, whichever binds)
    ideal_s = max(mf / (chips * hw["peak_bf16_flops"]),
                  mb / (chips * hw["hbm_bw"]))
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "model_flops": mf, "model_bytes": mb,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "bound_s": bound,
        "ideal_s": ideal_s,
        # roofline fraction (the §Perf score): necessary-work time on the
        # binding resource vs the achieved bound
        "roofline_fraction": ideal_s / bound if bound else 0.0,
        "collectives": rec.get("collectives", {}).get("wire_bytes", {}),
        "memory_analysis": rec.get("memory_analysis", {}),
    }


def full_table(variant: str = "cost", mesh: str = "single", tag: str = "",
               chips: int = 256) -> list[dict]:
    recs = load_records(variant=variant, mesh=mesh, tag=tag)
    rows = []
    for (arch, shape), rec in sorted(recs.items()):
        r = roofline(rec, chips)
        if r:
            rows.append(r)
    return rows


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| useful FLOP ratio | roofline frac |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.3f} | "
            f"{r['roofline_fraction']:.4f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys

    variant = sys.argv[1] if len(sys.argv) > 1 else "cost"
    tag = sys.argv[2] if len(sys.argv) > 2 else ""
    print(markdown_table(full_table(variant=variant, tag=tag)))

"""Deterministic, shard-aware synthetic data pipeline.

Produces next-token-prediction batches from a synthetic corpus (a seeded
Markov-ish token stream with local structure, so small models actually have
something learnable). Properties a production loader must have:

  * deterministic given (seed, step) — restart-safe without state files,
  * shard-aware: each data shard draws a disjoint slice of the global batch,
  * O(1) state: the cursor IS the step number (checkpointable as one int),
  * modality stubs for the VLM / audio architectures per the assignment.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 512
    # synthetic structure: tok_{t+1} = (a * tok_t + drift_{block}) % V
    n_styles: int = 7


class SyntheticTokens:
    """Iterable over (step -> batch dict). Stateless between calls."""

    def __init__(self, cfg: ModelConfig, shape: ShapeSpec,
                 data_cfg: DataConfig | None = None,
                 shard_index: int = 0, num_shards: int = 1):
        self.cfg = cfg
        self.shape = shape
        self.dc = data_cfg or DataConfig(vocab_size=cfg.vocab_size)
        assert shape.global_batch % num_shards == 0
        self.local_batch = shape.global_batch // num_shards
        self.shard_index = shard_index
        self.num_shards = num_shards

    def batch_at(self, step: int) -> dict:
        """Global determinism: sequence i of step s is a pure function of
        (seed, s, global_index)."""
        cfg, dc = self.cfg, self.dc
        B, S = self.local_batch, self.shape.seq_len
        g0 = step * self.shape.global_batch + self.shard_index * B
        idx = np.arange(g0, g0 + B, dtype=np.uint64)
        V = min(dc.vocab_size, cfg.vocab_size)
        # deterministic integer hashing: sequence i is a pure fn of (seed, i)
        h = (idx * np.uint64(2654435761) + np.uint64(dc.seed * 97 + 13))
        h ^= h >> np.uint64(16)
        style = (h % np.uint64(dc.n_styles)).astype(np.int64)[:, None] + 1
        start = ((h >> np.uint64(8)) % np.uint64(V)).astype(np.int64)[:, None]
        t = np.arange(S + 1, dtype=np.int64)[None, :]
        toks = (start + style * t + (t // 17) * (style + 3)) % V
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        if cfg.family == "vlm":
            pt = cfg.num_patch_tokens
            key = jax.random.PRNGKey(dc.seed * 1000003 + step)
            batch["patch_embeds"] = (jax.random.normal(
                key, (B, pt, cfg.d_model)) * 0.02).astype(cfg.compute_dtype)
            batch["tokens"] = batch["tokens"][:, :S - pt]
            batch["labels"] = batch["labels"][:, :S - pt]
        elif cfg.family == "encdec":
            key = jax.random.PRNGKey(dc.seed * 1000003 + step)
            batch["audio_frames"] = (jax.random.normal(
                key, (B, cfg.num_audio_frames, cfg.d_model)) * 0.02
            ).astype(cfg.compute_dtype)
        return batch

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

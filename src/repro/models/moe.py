"""Mixture-of-Experts FFN with capacity-based routing (GShard-style) and
expert parallelism over the ``model`` mesh axis via ``shard_map``.

Design (DESIGN.md §6 EP): at the MoE boundary the hidden states are already
replicated over the model axis (the attention output all-reduce put them
there), so dispatch is *local masking + scatter into a capacity buffer* on
the device that owns the expert, and combine is a single psum over the model
axis — no all-to-all and no (T, E, C) one-hot dispatch tensor (which at
phi3.5-moe train_4k scale would be ~10 GB/device).

Capacity semantics: each expert accepts at most C = ceil(cf·k·T/E) tokens per
shard; overflow tokens are dropped for that expert (standard GShard). Slot
C is a scratch row that absorbs dropped tokens and is discarded.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.models.sharding import MeshAxes, sc


def moe_params(rng, cfg: ModelConfig, layers: int | None = None):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    pre = () if layers is None else (layers,)
    ks = jax.random.split(rng, 4)
    dt = cfg.param_dtype
    return {
        "router": dense_init(ks[0], (*pre, d, e), dtype=dt),
        "w_gate": dense_init(ks[1], (*pre, e, d, f), in_axis=-2, dtype=dt),
        "w_up": dense_init(ks[2], (*pre, e, d, f), in_axis=-2, dtype=dt),
        "w_down": dense_init(ks[3], (*pre, e, f, d), in_axis=-2, dtype=dt),
    }


def capacity(cfg: ModelConfig, tokens: int) -> int:
    return max(1, math.ceil(cfg.capacity_factor * cfg.experts_per_token *
                            tokens / cfg.num_experts))


def _moe_local(xf, router, w_gate, w_up, w_down, *, cfg: ModelConfig,
               model_axis: str | None, batch_axes: tuple):
    """Per-device MoE over local tokens ``xf`` (T, D) and local experts.

    Inside shard_map: ``w_*`` hold E_loc experts; xf is replicated over the
    model axis. Without shard_map (fallback/reference): all E experts local,
    ``model_axis`` is None.
    """
    T, D = xf.shape
    E = cfg.num_experts
    k = cfg.experts_per_token
    cd = cfg.compute_dtype
    E_loc = w_gate.shape[0]
    if model_axis is not None:
        shard = jax.lax.axis_index(model_axis)
    else:
        shard = jnp.int32(0)

    logits = (xf.astype(jnp.float32) @ router.astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    C = capacity(cfg, T)

    def per_expert(wg_e, wu_e, wd_e, e_local):
        e_id = shard * E_loc + e_local
        w_te = jnp.sum(jnp.where(gate_idx == e_id, gate_vals, 0.0), axis=-1)  # (T,)
        m = w_te > 0
        posn = jnp.cumsum(m.astype(jnp.int32)) - 1
        keep = m & (posn < C)
        slot = jnp.where(keep, posn, C)
        buf = jnp.zeros((C + 1, D), cd).at[slot].add(
            jnp.where(m[:, None], xf.astype(cd), 0))
        h = jax.nn.silu(buf @ wg_e.astype(cd)) * (buf @ wu_e.astype(cd))
        out = h @ wd_e.astype(cd)  # (C+1, D)
        y = out[slot] * jnp.where(keep, w_te, 0.0)[:, None].astype(cd)
        return y

    ys = jax.vmap(per_expert)(w_gate, w_up, w_down, jnp.arange(E_loc))
    y = jnp.sum(ys, axis=0)  # (T, D)
    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)

    # auxiliary losses (Switch/GShard load balancing + router z-loss)
    f_e = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32), axis=1), axis=0)
    p_e = jnp.mean(probs, axis=0)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    if batch_axes and model_axis is not None:
        f_e = jax.lax.pmean(f_e, batch_axes)
        p_e = jax.lax.pmean(p_e, batch_axes)
        z = jax.lax.pmean(z, batch_axes)
    load = E * jnp.sum(f_e * p_e)
    return y, jnp.stack([load, z])


def moe_ffn(x, p, cfg: ModelConfig, axes: MeshAxes, mesh=None):
    """MoE FFN. x: (B, S, D) -> (y: (B, S, D), aux: (2,) [load_balance, z]).

    With a mesh and sharding enabled, experts are sharded over ``axes.model``
    (EP); otherwise runs the dense local fallback (also the reference oracle
    for equivalence tests).
    """
    B, S, D = x.shape
    xf = x.reshape(B * S, D)
    cd = cfg.compute_dtype
    if axes.enabled and mesh is not None and axes.model is not None:
        tp = mesh.shape[axes.model]
        assert cfg.num_experts % tp == 0, (
            f"num_experts={cfg.num_experts} must divide model axis {tp}")
        fn = jax.shard_map(
            partial(_moe_local, cfg=cfg, model_axis=axes.model,
                    batch_axes=axes.batch),
            mesh=mesh,
            in_specs=(P(axes.bspec, None), P(None, None),
                      P(axes.model, None, None), P(axes.model, None, None),
                      P(axes.model, None, None)),
            out_specs=(P(axes.bspec, None), P()),
        )
        # cast experts to bf16 *before* the shard_map boundary: the ZeRO
        # (data-axis) gather of each expert then moves/holds half the bytes
        y, aux = fn(xf, p["router"], p["w_gate"].astype(cd),
                    p["w_up"].astype(cd), p["w_down"].astype(cd))
    else:
        y, aux = _moe_local(xf, p["router"], p["w_gate"], p["w_up"],
                            p["w_down"], cfg=cfg, model_axis=None,
                            batch_axes=())
    y = sc(y.reshape(B, S, D), axes, "batch", None, None)
    return y, aux


def moe_block(x, p, cfg: ModelConfig, axes: MeshAxes, angles, mesh=None, *,
              causal: bool = True):
    """Pre-norm attention + MoE-FFN block."""
    from repro.models.layers import full_attention, mlp_block, project_qkv, rms_norm  # noqa: PLC0415

    cd = cfg.compute_dtype
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = project_qkv(h, p["attn"], cfg, axes, angles)
    o = full_attention(q, k, v, cfg, axes, causal=causal)
    x = x + (o @ p["attn"]["wo"].astype(cd))
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    y, aux = moe_ffn(h, p["moe"], cfg, axes, mesh)
    return x + y, aux

"""Sharding descriptors and activation-constraint helpers.

Model code never references a concrete mesh; it gets a :class:`MeshAxes`
describing which *named axes* carry batch / model parallelism and applies
``with_sharding_constraint`` through :func:`sc`. With ``enabled=False`` (or
empty axes) every constraint is a no-op, so the same code runs on a single
CPU device in tests.

Conventions (see DESIGN.md §6):
  * batch dims of activations  -> ``axes.batch``  (e.g. ("pod","data"))
  * attention heads / d_ff / experts / vocab -> ``axes.model``
  * decode KV-cache sequence dim -> ``axes.model`` when kv-head sharding is
    impossible (GQA with kv_heads < |model|): flash-decoding style.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class MeshAxes:
    batch: tuple[str, ...] = ()  # axes batch dim is sharded over
    model: str | None = None     # tensor-parallel axis name
    enabled: bool = False
    # decode-time KV partitioning: "heads" | "seq" (flash-decoding)
    kv_partition: str = "heads"

    @property
    def bspec(self):
        """Partition entry for a batch dimension."""
        return self.batch if self.batch else None

    def replace(self, **kw) -> "MeshAxes":
        return replace(self, **kw)


SINGLE = MeshAxes()  # no sharding: unit tests / single-device smoke runs


def make_axes(mesh, *, batch_shardable: bool = True, kv_partition: str = "heads") -> MeshAxes:
    """Derive MeshAxes from a mesh built by launch.mesh.make_production_mesh."""
    names = mesh.axis_names
    batch = tuple(n for n in names if n in ("pod", "data")) if batch_shardable else ()
    model = "model" if "model" in names else None
    return MeshAxes(batch=batch, model=model, enabled=True, kv_partition=kv_partition)


def sc(x, axes: MeshAxes, *dims):
    """``with_sharding_constraint(x, P(*dims))`` if sharding is enabled.

    ``dims`` entries are either None, an axis name string, a tuple of axis
    names, or the sentinel strings "batch"/"model" resolved via ``axes``.
    """
    if not axes.enabled:
        return x
    resolved = []
    for d in dims:
        if d == "batch":
            resolved.append(axes.bspec)
        elif d == "model":
            resolved.append(axes.model)
        else:
            resolved.append(d)
    return jax.lax.with_sharding_constraint(x, P(*resolved))

"""Mamba2 (SSD — state-space duality) blocks in pure JAX [arXiv:2405.21060].

Implements the chunked SSD algorithm (matrix-transformer form within chunks,
linear recurrence across chunks) for training/prefill, and the O(1)-per-token
recurrent step for decode. The projection is split into separate matrices per
component (z, x, B, C, dt) so each shards cleanly over the model axis.

``kernels/ssd_scan.py`` provides the Pallas TPU kernel for the intra-chunk
part; this module is the XLA path used for dry-runs and the reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rms_norm
from repro.models.sharding import MeshAxes, sc


def segsum(x):
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k] (i>=j),
    -inf elsewhere. x: (..., T) -> (..., T, T)."""
    T = x.shape[-1]
    xc = jnp.cumsum(x, axis=-1)
    seg = xc[..., :, None] - xc[..., None, :]
    i = jnp.arange(T)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None):
    """Chunked SSD scan.

    x: (b, s, h, p)   head inputs
    dt: (b, s, h)     discretization steps (post-softplus)
    A: (h,)           negative decay rates
    B, C: (b, s, g, n) input/output projections (g groups broadcast to heads)
    Returns y: (b, s, h, p), final_state: (b, h, p, n).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:  # zero-pad tail: dt=0 => decay 1 and no state contribution
        zp = lambda a: jnp.pad(a, [(0, 0), (0, pad)] +
                               [(0, 0)] * (a.ndim - 2))
        x, dt, B, C = zp(x), zp(dt), zp(B), zp(C)
        s_orig, s = s, s + pad
    else:
        s_orig = s
    c = s // q

    xd = (x * dt[..., None]).reshape(b, c, q, h, p)
    dA = (dt * A).reshape(b, c, q, h)
    rep = h // g
    Bh = jnp.repeat(B.reshape(b, c, q, g, n), rep, axis=3)  # (b,c,q,h,n)
    Ch = jnp.repeat(C.reshape(b, c, q, g, n), rep, axis=3)

    dA_t = jnp.moveaxis(dA, -1, 1)  # (b, h, c, q)
    dA_cs = jnp.cumsum(dA_t, axis=-1)  # (b, h, c, q)

    # 1. intra-chunk (diagonal blocks)
    L = jnp.exp(segsum(dA_t))  # (b, h, c, q, q)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp", Ch, Bh, L, xd)

    # 2. per-chunk final states
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)  # (b, h, c, q)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", Bh, decay_states, xd)

    # 3. inter-chunk linear recurrence (sequential scan; c is small)
    chunk_decay = jnp.exp(dA_cs[..., -1])  # (b, h, c)
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), states.dtype)

    def step(carry, inp):
        st_c, dec_c = inp  # (b,h,p,n), (b,h)
        new = carry * dec_c[..., None, None] + st_c
        return new, carry  # emit state *entering* this chunk

    final_state, states_in = jax.lax.scan(
        step, initial_state,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, -1, 0)))
    states_in = jnp.moveaxis(states_in, 0, 1)  # (b, c, h, p, n)

    # 4. state -> output
    state_decay = jnp.exp(dA_cs)  # (b, h, c, q)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", Ch, states_in, state_decay)

    y = (y_diag + y_off).reshape(b, s, h, p)[:, :s_orig]
    return y, final_state


def ssd_step(state, x_t, dt_t, A, B_t, C_t):
    """Single-token SSD recurrence.

    state: (b, h, p, n); x_t: (b, h, p); dt_t: (b, h); B_t, C_t: (b, g, n).
    Returns (y_t: (b, h, p), new_state).
    """
    h, g = x_t.shape[1], B_t.shape[1]
    rep = h // g
    Bh = jnp.repeat(B_t, rep, axis=1)  # (b, h, n)
    Ch = jnp.repeat(C_t, rep, axis=1)
    dA = jnp.exp(dt_t * A)  # (b, h)
    upd = jnp.einsum("bhp,bhn->bhpn", x_t * dt_t[..., None], Bh)
    new_state = state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y, new_state


# ---------------------------------------------------------------------------
# full Mamba2 block
# ---------------------------------------------------------------------------


def mamba_params(rng, cfg: ModelConfig, layers: int | None = None):
    d, di = cfg.d_model, cfg.d_inner
    g, n, h, w = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv
    pre = () if layers is None else (layers,)
    ks = jax.random.split(rng, 8)
    dt = cfg.param_dtype
    return {
        "wz": dense_init(ks[0], (*pre, d, di), dtype=dt),
        "wx": dense_init(ks[1], (*pre, d, di), dtype=dt),
        "wB": dense_init(ks[2], (*pre, d, g * n), dtype=dt),
        "wC": dense_init(ks[3], (*pre, d, g * n), dtype=dt),
        "wdt": dense_init(ks[4], (*pre, d, h), dtype=dt),
        "conv": (jax.random.normal(ks[5], (*pre, w, di + 2 * g * n)) * 0.1).astype(dt),
        "A_log": jnp.zeros((*pre, h), dt),  # A = -exp(A_log) = -1
        "D": jnp.ones((*pre, h), dt),
        "dt_bias": jnp.full((*pre, h), -2.0, dt),  # softplus(-2) ~ 0.12
        "norm": jnp.ones((*pre, di), dt),
        "out": dense_init(ks[6], (*pre, di, d), dtype=dt),
        "ln": jnp.ones((*pre, d), dt),
    }


def causal_conv1d(x, kernel):
    """Depthwise causal conv. x: (B, S, ch); kernel: (w, ch)."""
    w = kernel.shape[0]
    pad = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    S = x.shape[1]
    out = jnp.zeros_like(x)
    for i in range(w):
        out = out + pad[:, i:i + S, :] * kernel[i]
    return out


def _project(x, p, cfg: ModelConfig, axes: MeshAxes):
    cd = cfg.compute_dtype
    z = x @ p["wz"].astype(cd)
    xin = x @ p["wx"].astype(cd)
    Bp = x @ p["wB"].astype(cd)
    Cp = x @ p["wC"].astype(cd)
    dt_raw = x @ p["wdt"].astype(cd)
    return z, xin, Bp, Cp, dt_raw


def mamba_block(x, p, cfg: ModelConfig, axes: MeshAxes):
    """Training/prefill Mamba2 block: (B, S, D) -> ((B, S, D), final_state).

    final_state: (ssm_state (B,h,p,n), conv_state (B, w-1, conv_ch)) so that
    prefill can hand off to the recurrent decode path.
    """
    B_, S, _ = x.shape
    cd = cfg.compute_dtype
    g, n, hh, pp, w = (cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads,
                       cfg.ssm_headdim, cfg.ssm_conv)
    res = x
    x = rms_norm(x, p["ln"], cfg.norm_eps)
    z, xin, Bp, Cp, dt_raw = _project(x, p, cfg, axes)
    xbc = jnp.concatenate([xin, Bp, Cp], axis=-1)
    xbc = sc(xbc, axes, "batch", None, "model")
    conv_state = xbc[:, S - (w - 1):, :] if S >= w else None
    xbc = jax.nn.silu(causal_conv1d(xbc, p["conv"].astype(cd)))
    di = cfg.d_inner
    xin = xbc[..., :di].reshape(B_, S, hh, pp)
    Bm = xbc[..., di:di + g * n].reshape(B_, S, g, n)
    Cm = xbc[..., di + g * n:].reshape(B_, S, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xin = sc(xin, axes, "batch", None, "model", None)
    # ssd_dtype="bf16": keep x/B/C operands in bf16 through the intra-chunk
    # matrix work (MXU-native); decay/statistics stay fp32 inside ssd_chunked
    sdt = jnp.float32 if cfg.ssd_dtype == "fp32" else cfg.compute_dtype
    y, ssm_state = ssd_chunked(xin.astype(sdt), dt, A,
                               Bm.astype(sdt), Cm.astype(sdt),
                               cfg.ssm_chunk)
    y = y.astype(jnp.float32) + (p["D"].astype(jnp.float32)[:, None]
                                 * xin.astype(jnp.float32))
    y = y.astype(cd).reshape(B_, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out"].astype(cd)
    return res + sc(out, axes, "batch", None, None), (ssm_state.astype(cd), conv_state)


def mamba_block_decode(x, p, cfg: ModelConfig, axes: MeshAxes, state):
    """Single-token Mamba2 step. x: (B, 1, D); state: (ssm, conv)."""
    ssm_state, conv_state = state  # (B,h,p,n), (B, w-1, conv_ch)
    B_, _, _ = x.shape
    cd = cfg.compute_dtype
    g, n, hh, pp, w = (cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads,
                       cfg.ssm_headdim, cfg.ssm_conv)
    res = x
    x = rms_norm(x, p["ln"], cfg.norm_eps)
    z, xin, Bp, Cp, dt_raw = _project(x[:, 0], p, cfg, axes)
    xbc_t = jnp.concatenate([xin, Bp, Cp], axis=-1)  # (B, conv_ch)
    window = jnp.concatenate([conv_state, xbc_t[:, None, :]], axis=1)  # (B,w,ch)
    conv_out = jnp.einsum("bwc,wc->bc", window, p["conv"].astype(cd))
    conv_out = jax.nn.silu(conv_out)
    new_conv_state = window[:, 1:, :]
    di = cfg.d_inner
    x_t = conv_out[:, :di].reshape(B_, hh, pp)
    B_t = conv_out[:, di:di + g * n].reshape(B_, g, n)
    C_t = conv_out[:, di + g * n:].reshape(B_, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, new_ssm = ssd_step(ssm_state.astype(jnp.float32),
                          x_t.astype(jnp.float32), dt, A,
                          B_t.astype(jnp.float32), C_t.astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32) [:, None] * x_t.astype(jnp.float32)
    y = y.astype(cd).reshape(B_, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = (y @ p["out"].astype(cd))[:, None, :]
    return res + out, (new_ssm.astype(cd), new_conv_state)


def mamba_state_specs(cfg: ModelConfig, batch: int, stacked: int | None = None):
    """ShapeDtypeStructs for decode state of one (or ``stacked``) blocks."""
    pre = () if stacked is None else (stacked,)
    cd = cfg.compute_dtype
    conv_ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return (
        jax.ShapeDtypeStruct((*pre, batch, cfg.ssm_heads, cfg.ssm_headdim,
                              cfg.ssm_state), cd),
        jax.ShapeDtypeStruct((*pre, batch, cfg.ssm_conv - 1, conv_ch), cd),
    )

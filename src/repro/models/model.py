"""Facade: build models, input specs (ShapeDtypeStructs) and sharding rules.

This is the single place that knows how parameter/state/input pytrees map to
PartitionSpecs (DESIGN.md §6). Rules are name-based on the *trailing* dims of
each leaf so the same table covers stacked (L, ...), double-stacked
(n_super, k, ...) and unstacked (shared-block) parameters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.sharding import MeshAxes, make_axes
from repro.models.transformer import build_model  # re-export  # noqa: F401

# trailing-dim partition entries per parameter name ("data" = ZeRO-3 shard,
# "model" = tensor/expert parallel). Leading stack dims are padded with None.
_PARAM_RULES = {
    "embed": ("model", "data"),
    "unembed": ("model", "data"),
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wo": ("model", "data"),
    "w_gate": ("data", "model"),
    "w_up": ("data", "model"),
    "w_down": ("model", "data"),
    # mamba2
    "wz": ("data", "model"),
    "wx": ("data", "model"),
    "wB": ("data", None),
    "wC": ("data", None),
    "wdt": ("data", "model"),
    "conv": (None, "model"),
    "A_log": ("model",),
    "D": ("model",),
    "dt_bias": ("model",),
    "norm": ("model",),  # gated-norm weight over d_inner
    "out": ("model", "data"),
}
_MOE_RULES = {  # under a "moe" subtree (trailing dims (E, D, F) / (E, F, D))
    "router": ("data", None),
    "w_gate": ("model", "data", None),
    "w_up": ("model", "data", None),
    "w_down": ("model", None, "data"),
}


def _path_names(path):
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(k.key)
        elif hasattr(k, "name"):
            out.append(k.name)
    return out


def param_pspecs(param_tree, cfg: ModelConfig | None = None):
    """PartitionSpec pytree for a params (or matching ShapeDtypeStruct) tree.

    ``cfg.embed_sharding == "model_only"`` drops the data-axis ZeRO shard of
    the embedding tables (required by vocab-parallel CE)."""
    embed_core = (("model", None)
                  if cfg is not None and cfg.embed_sharding == "model_only"
                  else ("model", "data"))

    def spec(path, leaf):
        names = _path_names(path)
        name = names[-1]
        if name in ("embed", "unembed"):
            core = embed_core
        else:
            rules = (_MOE_RULES if "moe" in names and name in _MOE_RULES
                     else _PARAM_RULES)
            core = rules.get(name)
        if core is None or leaf.ndim < len(core):
            return P()  # norms, scalars, biases: replicate
        pad = (None,) * (leaf.ndim - len(core))
        return P(*pad, *core)

    return jax.tree_util.tree_map_with_path(spec, param_tree)


def choose_kv_partition(cfg: ModelConfig, tp: int) -> str:
    """Shard decode KV caches by head when divisible, else by sequence
    (flash-decoding with softmax-stat reduction over the model axis)."""
    if cfg.num_kv_heads and cfg.num_kv_heads % tp == 0:
        return "heads"
    return "seq"


def state_pspecs(state_tree, axes: MeshAxes):
    """PartitionSpecs for a decode-state pytree from decode_state_specs()."""
    kv_core = ((axes.bspec, axes.model, None, None)
               if axes.kv_partition == "seq"
               else (axes.bspec, None, axes.model, None))
    rules = {
        "k": kv_core,
        "v": kv_core,
        "ssm": (axes.bspec, axes.model, None, None),
        "conv": (axes.bspec, None, axes.model),
    }

    def spec(path, leaf):
        names = _path_names(path)
        name = names[-1]
        if name == "pos":
            return P()
        if "enc_kv" in names:  # whisper cross-kv: heads always divisible
            core = (axes.bspec, None, axes.model, None)
        else:
            core = rules.get(name)
        if core is None:
            return P()
        pad = (None,) * (leaf.ndim - len(core))
        return P(*pad, *core)

    return jax.tree_util.tree_map_with_path(spec, state_tree)


# ---------------------------------------------------------------------------
# input specs per (config × shape)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill: token batch (+ stubbed modality embeddings).
    decode: current tokens (B,) — the cache/state specs come from
    ``model.decode_state_specs``.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    cd = cfg.compute_dtype
    d = {}
    if shape.kind == "decode":
        d["tokens"] = jax.ShapeDtypeStruct((B,), i32)
        return d
    if cfg.family == "vlm":
        pt = cfg.num_patch_tokens
        d["patch_embeds"] = jax.ShapeDtypeStruct((B, pt, cfg.d_model), cd)
        d["tokens"] = jax.ShapeDtypeStruct((B, S - pt), i32)
        if shape.kind == "train":
            d["labels"] = jax.ShapeDtypeStruct((B, S - pt), i32)
        return d
    if cfg.family == "encdec":
        d["audio_frames"] = jax.ShapeDtypeStruct(
            (B, cfg.num_audio_frames, cfg.d_model), cd)
    d["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    if shape.kind == "train":
        d["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    return d


def input_pspecs(cfg: ModelConfig, shape: ShapeSpec, axes: MeshAxes) -> dict:
    b = axes.bspec
    d = {}
    if shape.kind == "decode":
        return {"tokens": P(b)}
    if cfg.family == "vlm":
        d["patch_embeds"] = P(b, None, None)
    if cfg.family == "encdec":
        d["audio_frames"] = P(b, None, None)
    d["tokens"] = P(b, None)
    if shape.kind == "train":
        d["labels"] = P(b, None)
    return d


def batch_shardable(shape: ShapeSpec, mesh) -> bool:
    n = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            n *= mesh.shape[ax]
    return shape.global_batch % n == 0 and shape.global_batch >= n


def axes_for(cfg: ModelConfig, shape: ShapeSpec, mesh) -> MeshAxes:
    tp = mesh.shape.get("model", 1)
    return make_axes(mesh, batch_shardable=batch_shardable(shape, mesh),
                     kv_partition=choose_kv_partition(cfg, tp))


def to_shardings(mesh, pspec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def abstract_params(model):
    """Parameter ShapeDtypeStructs without allocating (eval_shape on init)."""
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))

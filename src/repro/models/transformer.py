"""Model families assembled from layers/moe/mamba, all config-driven.

Families: dense (+vlm), moe, ssm (mamba2), hybrid (zamba2), encdec (whisper).
Every family exposes the same interface (see :class:`BaseLM`):

    init(rng) -> params
    loss(params, batch) -> (scalar, metrics)           # training
    prefill(params, batch) -> (last_logits, state)     # inference prefill
    decode_step(params, state, tokens) -> (logits, state)  # 1 token, O(cache)
    decode_state_specs(batch, seq_len) -> ShapeDtypeStructs

Layer stacks are scanned (``jax.lax.scan`` over stacked params) so the HLO —
and therefore compile time on the 512-device dry-run mesh — stays O(1) in
depth. Activation checkpointing policy comes from ``cfg.remat``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models.sharding import MeshAxes, sc


def _remat(f, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(f)
    if cfg.remat == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if cfg.remat == "tp_out":
        # save the per-layer TP-psum outputs: the rematted backward skips
        # the forward model-axis all-reduces (Megatron-style selective
        # recompute; costs 2 x (B,S,D) bf16 saved per layer)
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.save_only_these_names(
                "attn_out", "mlp_out"))
    return f


def _positions(cfg: ModelConfig, B: int, S: int, offset=0):
    pos = jnp.arange(S, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (B, S))
    if cfg.mrope_sections:
        # frontend stub: text-like ids on all three M-RoPE streams
        return jnp.broadcast_to(pos[None], (3, B, S))
    return pos


def _angles(cfg: ModelConfig, positions):
    return L.rope_angles(positions, cfg.head_dim, cfg.rope_theta,
                         cfg.mrope_sections)


def _ce_chunk_gather(hc, lc, embed, axes: MeshAxes):
    """Baseline: take_along_axis over the vocab-sharded logits. GSPMD cannot
    shard the label gather and inserts a full (B, qc, V) all-gather — the
    measured baseline pathology the §Perf hillclimb removes."""
    logits = jnp.einsum("bqd,vd->bqv", hc.astype(jnp.float32),
                        embed.astype(jnp.float32))
    logits = sc(logits, axes, "batch", None, "model")
    lse = jax.nn.logsumexp(logits, axis=-1)
    mask = lc >= 0
    lbl = jnp.where(mask, lc, 0)
    gold = jnp.take_along_axis(logits, lbl[..., None], axis=-1)[..., 0]
    tok_loss = jnp.where(mask, lse - gold, 0.0)
    return jnp.sum(tok_loss), jnp.sum(mask.astype(jnp.float32))


def _ce_chunk_vocab_parallel(hc, lc, embed, axes: MeshAxes, mesh):
    """Vocab-parallel CE (Megatron-style) under shard_map: each model-rank
    computes its local logits shard, extracts the gold logit if the label
    falls in its shard, and only softmax *statistics* cross the wire
    (two scalars per token instead of the V-wide logits row)."""
    from jax.sharding import PartitionSpec as P  # noqa: PLC0415

    def local(hc, lc, emb):
        # emb: (V_loc, D) local shard; hc replicated over model
        shard = jax.lax.axis_index(axes.model)
        v_loc = emb.shape[0]
        logits = jnp.einsum("bqd,vd->bqv", hc.astype(jnp.float32),
                            emb.astype(jnp.float32))
        local_max = jnp.max(logits, axis=-1)
        # stop_gradient: the max is a numerical-stability shift (standard
        # logsumexp trick) and pmax has no differentiation rule
        gmax = jax.lax.pmax(jax.lax.stop_gradient(local_max), axes.model)
        sumexp = jnp.sum(jnp.exp(logits - gmax[..., None]), axis=-1)
        sumexp = jax.lax.psum(sumexp, axes.model)
        lse = jnp.log(sumexp) + gmax
        mask = lc >= 0
        lbl = jnp.where(mask, lc, 0)
        idx = lbl - shard * v_loc
        in_shard = (idx >= 0) & (idx < v_loc)
        gold_loc = jnp.take_along_axis(
            logits, jnp.clip(idx, 0, v_loc - 1)[..., None], axis=-1)[..., 0]
        gold = jax.lax.psum(jnp.where(in_shard, gold_loc, 0.0), axes.model)
        tok_loss = jnp.where(mask, lse - gold, 0.0)
        ls = jnp.sum(tok_loss)
        cnt = jnp.sum(mask.astype(jnp.float32))
        if axes.batch:
            ls = jax.lax.psum(ls, axes.batch)
            cnt = jax.lax.psum(cnt, axes.batch)
        return ls, cnt

    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(axes.bspec, None, None), P(axes.bspec, None),
                  P(axes.model, None)),
        out_specs=(P(), P()),
        check_vma=False,
    )
    return fn(hc, lc, embed)


def chunked_ce_loss(h, embed, labels, cfg: ModelConfig, axes: MeshAxes,
                    chunk: int = 512, mesh=None):
    """Cross-entropy computed in sequence chunks so the (B, S, V) logits are
    never materialized (V up to 256k: unchunked fp32 logits would be
    ~67 GB/device at minitron train_4k scale). Vocab stays sharded over the
    model axis inside each chunk; ``cfg.ce_impl`` picks the gold-logit
    extraction strategy (see the two _ce_chunk_* variants)."""
    B, S, D = h.shape
    qc = min(chunk, S)
    n = S // qc
    hr = h.reshape(B, n, qc, D)
    lr = labels.reshape(B, n, qc)
    use_vp = (cfg.ce_impl == "vocab_parallel" and axes.enabled
              and mesh is not None and axes.model is not None)

    def chunk_loss(hc, lc):
        if use_vp:
            return _ce_chunk_vocab_parallel(hc, lc, embed, axes, mesh)
        return _ce_chunk_gather(hc, lc, embed, axes)

    def body(carry, inp):
        hc, lc = inp
        ls, cnt = _remat(chunk_loss, cfg)(hc, lc)
        return (carry[0] + ls, carry[1] + cnt), None

    (tot, cnt), _ = L.xscan(
        cfg, body, (jnp.float32(0), jnp.float32(0)),
        (jnp.moveaxis(hr, 1, 0), jnp.moveaxis(lr, 1, 0)))
    return tot / jnp.maximum(cnt, 1)


class BaseLM:
    def __init__(self, cfg: ModelConfig, axes: MeshAxes, mesh=None):
        self.cfg = cfg
        self.axes = axes
        self.mesh = mesh

    # ---- embedding helpers ----
    def _embed_params(self, rng):
        cfg = self.cfg
        ks = jax.random.split(rng, 2)
        p = {"embed": L.embed_init(ks[0], (cfg.vocab_padded, cfg.d_model),
                                   cfg.param_dtype),
             "ln_f": jnp.ones((cfg.d_model,), cfg.param_dtype)}
        if not cfg.tie_embeddings:
            p["unembed"] = L.embed_init(ks[1], (cfg.vocab_padded, cfg.d_model),
                                        cfg.param_dtype)
        return p

    def _embed(self, params, tokens):
        cfg = self.cfg
        e = params["embed"].astype(cfg.compute_dtype)[tokens]
        return sc(e, self.axes, "batch", None, None)

    def _unembed_table(self, params):
        return params.get("unembed", params["embed"])

    def _logits(self, params, h):
        """Last-position logits (B, V) in fp32."""
        h = L.rms_norm(h, params["ln_f"], self.cfg.norm_eps)
        logits = jnp.einsum("bd,vd->bv", h.astype(jnp.float32),
                            self._unembed_table(params).astype(jnp.float32))
        return sc(logits, self.axes, "batch", "model")

    def _hidden_loss(self, params, h, labels):
        h = L.rms_norm(h, params["ln_f"], self.cfg.norm_eps)
        return chunked_ce_loss(h, self._unembed_table(params), labels,
                               self.cfg, self.axes, mesh=self.mesh)

    # interface stubs
    def init(self, rng):
        raise NotImplementedError

    def loss(self, params, batch):
        raise NotImplementedError

    def prefill(self, params, batch):
        raise NotImplementedError

    def decode_step(self, params, state, tokens):
        raise NotImplementedError

    def decode_state_specs(self, batch: int, seq_len: int):
        raise NotImplementedError

    @staticmethod
    def _pad_kv(kv, pad_to: int | None):
        """Pad prefill KV caches along the sequence dim to ``pad_to`` so
        subsequent decode steps have write headroom."""
        if pad_to is None:
            return kv
        def pad(a):
            s = a.shape[2]
            return (jnp.pad(a, [(0, 0), (0, 0), (0, pad_to - s)] +
                            [(0, 0)] * (a.ndim - 3)) if pad_to > s else a)
        return jax.tree.map(pad, kv)

    def kv_cache_specs(self, stack: int, batch: int, seq_len: int):
        cfg = self.cfg
        cd = cfg.compute_dtype
        shp = (stack, batch, seq_len, cfg.num_kv_heads, cfg.head_dim)
        return {"k": jax.ShapeDtypeStruct(shp, cd),
                "v": jax.ShapeDtypeStruct(shp, cd)}


# ---------------------------------------------------------------------------
# dense decoder LM (also VLM backbone: patch embeddings prepended)
# ---------------------------------------------------------------------------


class DenseLM(BaseLM):
    def init(self, rng):
        cfg = self.cfg
        k1, k2 = jax.random.split(rng)
        p = self._embed_params(k1)
        p["layers"] = L.block_params(k2, cfg, cfg.num_layers)
        return p

    def _trunk(self, params, h, angles, collect_kv: bool = False):
        cfg = self.cfg

        def body(x, lp):
            if collect_kv:
                hn = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
                q, k, v = L.project_qkv(hn, lp["attn"], cfg, self.axes, angles)
                o = L.full_attention(q, k, v, cfg, self.axes, causal=True)
                x = x + (o @ lp["attn"]["wo"].astype(cfg.compute_dtype))
                hn = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
                x = x + L.mlp_block(hn, lp["mlp"], cfg, self.axes)
                return x, {"k": k.astype(cfg.compute_dtype),
                           "v": v.astype(cfg.compute_dtype)}
            x = L.transformer_block(x, lp, cfg, self.axes, angles, causal=True)
            return x, None

        return L.xscan(cfg, _remat(body, cfg), h, params["layers"])

    def _inputs_to_h(self, params, batch):
        """Embed tokens; VLM prepends stubbed patch embeddings."""
        cfg = self.cfg
        h = self._embed(params, batch["tokens"])
        if cfg.family == "vlm" and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(cfg.compute_dtype)
            h = jnp.concatenate([sc(pe, self.axes, "batch", None, None), h],
                                axis=1)
        return h

    def loss(self, params, batch):
        cfg = self.cfg
        h = self._inputs_to_h(params, batch)
        B, S, _ = h.shape
        angles = _angles(cfg, _positions(cfg, B, S))
        h, _ = self._trunk(params, h, angles)
        labels = batch["labels"]
        if h.shape[1] != labels.shape[1]:  # vlm: no loss on patch positions
            pad = -jnp.ones((B, h.shape[1] - labels.shape[1]), labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        loss = self._hidden_loss(params, h, labels)
        return loss, {"ce": loss}

    def prefill(self, params, batch, pad_to: int | None = None):
        cfg = self.cfg
        h = self._inputs_to_h(params, batch)
        B, S, _ = h.shape
        angles = _angles(cfg, _positions(cfg, B, S))
        h, kv = self._trunk(params, h, angles, collect_kv=True)
        logits = self._logits(params, h[:, -1])
        state = {"kv": self._pad_kv(kv, pad_to), "pos": jnp.int32(S)}
        return logits, state

    def decode_step(self, params, state, tokens):
        cfg = self.cfg
        h = self._embed(params, tokens[:, None])
        B = h.shape[0]
        pos = state["pos"]
        Smax = state["kv"]["k"].shape[2]
        write_pos = jnp.minimum(pos, Smax - 1)
        angles = _angles(cfg, _positions(cfg, B, 1, offset=pos))

        if cfg.decode_loop == "fori":
            # full cache as loop carry: in-place updates, single buffer
            def fbody(i, carry):
                x, ck, cv = carry
                lp = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, False),
                    params["layers"])
                cache = {"k": jax.lax.dynamic_index_in_dim(ck, i, 0, False),
                         "v": jax.lax.dynamic_index_in_dim(cv, i, 0, False)}
                x, cache = L.transformer_block_decode(
                    x, lp, cfg, self.axes, angles, cache, write_pos)
                ck = jax.lax.dynamic_update_index_in_dim(
                    ck, cache["k"], i, 0)
                cv = jax.lax.dynamic_update_index_in_dim(
                    cv, cache["v"], i, 0)
                return (x, ck, cv)

            h, ck, cv = jax.lax.fori_loop(
                0, cfg.num_layers, fbody,
                (h, state["kv"]["k"], state["kv"]["v"]),
                unroll=True if cfg.unroll_scans else 1)
            logits = self._logits(params, h[:, 0])
            return logits, {"kv": {"k": ck, "v": cv}, "pos": pos + 1}

        def body(x, inp):
            lp, cache = inp
            x, cache = L.transformer_block_decode(x, lp, cfg, self.axes,
                                                  angles, cache, write_pos)
            return x, cache

        h, kv = L.xscan(cfg, body, h, (params["layers"], state["kv"]))
        logits = self._logits(params, h[:, 0])
        return logits, {"kv": kv, "pos": pos + 1}

    def decode_state_specs(self, batch: int, seq_len: int):
        return {"kv": self.kv_cache_specs(self.cfg.num_layers, batch, seq_len),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}


# ---------------------------------------------------------------------------
# MoE decoder LM
# ---------------------------------------------------------------------------


class MoeLM(BaseLM):
    def init(self, rng):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(rng, 3)
        p = self._embed_params(k1)
        nl = cfg.num_layers
        p["layers"] = {
            "attn": L.attn_params(k2, cfg, nl),
            "moe": MOE.moe_params(k3, cfg, nl),
            "ln1": jnp.ones((nl, cfg.d_model), cfg.param_dtype),
            "ln2": jnp.ones((nl, cfg.d_model), cfg.param_dtype),
        }
        return p

    def _trunk(self, params, h, angles, collect_kv: bool = False):
        cfg = self.cfg

        def body(carry, lp):
            x, aux = carry
            hn = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = L.project_qkv(hn, lp["attn"], cfg, self.axes, angles)
            o = L.full_attention(q, k, v, cfg, self.axes, causal=True)
            x = x + (o @ lp["attn"]["wo"].astype(cfg.compute_dtype))
            hn = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
            y, a = MOE.moe_ffn(hn, lp["moe"], cfg, self.axes, self.mesh)
            x = x + y
            out = ({"k": k.astype(cfg.compute_dtype),
                    "v": v.astype(cfg.compute_dtype)} if collect_kv else None)
            return (x, aux + a), out

        (h, aux), kv = L.xscan(cfg, _remat(body, cfg), (h, jnp.zeros(2)),
                                params["layers"])
        return h, aux / cfg.num_layers, kv

    def loss(self, params, batch):
        cfg = self.cfg
        h = self._embed(params, batch["tokens"])
        B, S, _ = h.shape
        angles = _angles(cfg, _positions(cfg, B, S))
        h, aux, _ = self._trunk(params, h, angles)
        ce = self._hidden_loss(params, h, batch["labels"])
        loss = ce + 0.01 * aux[0] + 1e-3 * aux[1]
        return loss, {"ce": ce, "load_balance": aux[0], "router_z": aux[1]}

    def prefill(self, params, batch, pad_to: int | None = None):
        cfg = self.cfg
        h = self._embed(params, batch["tokens"])
        B, S, _ = h.shape
        angles = _angles(cfg, _positions(cfg, B, S))
        h, _, kv = self._trunk(params, h, angles, collect_kv=True)
        return (self._logits(params, h[:, -1]),
                {"kv": self._pad_kv(kv, pad_to), "pos": jnp.int32(S)})

    def decode_step(self, params, state, tokens):
        cfg = self.cfg
        h = self._embed(params, tokens[:, None])
        B = h.shape[0]
        pos = state["pos"]
        Smax = state["kv"]["k"].shape[2]
        write_pos = jnp.minimum(pos, Smax - 1)
        angles = _angles(cfg, _positions(cfg, B, 1, offset=pos))

        def body(x, inp):
            lp, cache = inp
            hn = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = L.project_qkv(hn, lp["attn"], cfg, self.axes, angles)
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, write_pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, write_pos, 0, 0))
            o = L.decode_attention(q, ck, cv, write_pos + 1, cfg, self.axes)
            x = x + (o @ lp["attn"]["wo"].astype(cfg.compute_dtype))
            hn = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
            y, _ = MOE.moe_ffn(hn, lp["moe"], cfg, self.axes, self.mesh)
            return x + y, {"k": ck, "v": cv}

        h, kv = L.xscan(cfg, body, h, (params["layers"], state["kv"]))
        return self._logits(params, h[:, 0]), {"kv": kv, "pos": pos + 1}

    def decode_state_specs(self, batch: int, seq_len: int):
        return {"kv": self.kv_cache_specs(self.cfg.num_layers, batch, seq_len),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}


# ---------------------------------------------------------------------------
# Mamba2 LM (attention-free)
# ---------------------------------------------------------------------------


class MambaLM(BaseLM):
    def init(self, rng):
        cfg = self.cfg
        k1, k2 = jax.random.split(rng)
        p = self._embed_params(k1)
        p["layers"] = M.mamba_params(k2, cfg, cfg.num_layers)
        return p

    def _trunk(self, params, h, collect_state: bool = False):
        cfg = self.cfg

        def body(x, lp):
            x, st = M.mamba_block(x, lp, cfg, self.axes)
            return x, st if collect_state else None

        return L.xscan(cfg, _remat(body, cfg), h, params["layers"])

    def loss(self, params, batch):
        h = self._embed(params, batch["tokens"])
        h, _ = self._trunk(params, h)
        ce = self._hidden_loss(params, h, batch["labels"])
        return ce, {"ce": ce}

    def prefill(self, params, batch, pad_to: int | None = None):
        h = self._embed(params, batch["tokens"])
        S = h.shape[1]
        h, st = self._trunk(params, h, collect_state=True)
        logits = self._logits(params, h[:, -1])
        ssm, conv = st
        return logits, {"ssm": ssm, "conv": conv, "pos": jnp.int32(S)}

    def decode_step(self, params, state, tokens):
        cfg = self.cfg
        h = self._embed(params, tokens[:, None])

        def body(x, inp):
            lp, ssm, conv = inp
            x, (ssm, conv) = M.mamba_block_decode(x, lp, cfg, self.axes,
                                                  (ssm, conv))
            return x, (ssm, conv)

        h, (ssm, conv) = L.xscan(
            cfg, body, h, (params["layers"], state["ssm"], state["conv"]))
        logits = self._logits(params, h[:, 0])
        return logits, {"ssm": ssm, "conv": conv, "pos": state["pos"] + 1}

    def decode_state_specs(self, batch: int, seq_len: int):
        ssm, conv = M.mamba_state_specs(self.cfg, batch, self.cfg.num_layers)
        return {"ssm": ssm, "conv": conv,
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}


# ---------------------------------------------------------------------------
# Hybrid (zamba2): Mamba2 trunk + one weight-shared attention block
# ---------------------------------------------------------------------------


class HybridLM(BaseLM):
    """``num_layers`` Mamba2 blocks; after every ``attn_every`` of them the
    *same* (weight-shared) transformer block runs. Params for the SSM trunk
    are stacked (n_super, attn_every, ...) for a two-level scan."""

    @property
    def n_super(self) -> int:
        assert self.cfg.num_layers % self.cfg.attn_every == 0
        return self.cfg.num_layers // self.cfg.attn_every

    def init(self, rng):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(rng, 3)
        p = self._embed_params(k1)
        flat = M.mamba_params(k2, cfg, cfg.num_layers)
        p["ssm_layers"] = jax.tree.map(
            lambda a: a.reshape(self.n_super, cfg.attn_every, *a.shape[1:]),
            flat)
        p["shared"] = L.block_params(k3, cfg)  # unstacked = weight-shared
        return p

    def _super_block(self, x, sp, shared, angles, collect, kv_cache=None,
                     write_pos=None):
        """attn_every mamba layers then the shared attention block.

        Training/prefill: ``kv_cache`` is None -> full attention; returns
        (x, (ssm_states, conv_states, k, v)). Decode handled separately."""
        cfg = self.cfg

        def inner(x, lp):
            x, st = M.mamba_block(x, lp, cfg, self.axes)
            return x, st if collect else None

        x, states = L.xscan(cfg, inner, x, sp)
        if collect:
            hn = L.rms_norm(x, shared["ln1"], cfg.norm_eps)
            q, k, v = L.project_qkv(hn, shared["attn"], cfg, self.axes, angles)
            o = L.full_attention(q, k, v, cfg, self.axes, causal=True)
            x = x + (o @ shared["attn"]["wo"].astype(cfg.compute_dtype))
            hn = L.rms_norm(x, shared["ln2"], cfg.norm_eps)
            x = x + L.mlp_block(hn, shared["mlp"], cfg, self.axes)
            return x, (states, k.astype(cfg.compute_dtype),
                       v.astype(cfg.compute_dtype))
        x = L.transformer_block(x, shared, cfg, self.axes, angles, causal=True)
        return x, None

    def _trunk(self, params, h, angles, collect: bool = False):
        cfg = self.cfg
        shared = params["shared"]

        def body(x, sp):
            return _remat(
                partial(self._super_block, shared=shared, angles=angles,
                        collect=collect), cfg)(x, sp)

        return L.xscan(cfg, body, h, params["ssm_layers"])

    def loss(self, params, batch):
        cfg = self.cfg
        h = self._embed(params, batch["tokens"])
        B, S, _ = h.shape
        angles = _angles(cfg, _positions(cfg, B, S))
        h, _ = self._trunk(params, h, angles)
        ce = self._hidden_loss(params, h, batch["labels"])
        return ce, {"ce": ce}

    def prefill(self, params, batch, pad_to: int | None = None):
        cfg = self.cfg
        h = self._embed(params, batch["tokens"])
        B, S, _ = h.shape
        angles = _angles(cfg, _positions(cfg, B, S))
        h, (states, k, v) = self._trunk(params, h, angles, collect=True)
        ssm, conv = states
        logits = self._logits(params, h[:, -1])
        kv = self._pad_kv({"k": k, "v": v}, pad_to)
        return logits, {"ssm": ssm, "conv": conv, "kv": kv,
                        "pos": jnp.int32(S)}

    def decode_step(self, params, state, tokens):
        cfg = self.cfg
        h = self._embed(params, tokens[:, None])
        B = h.shape[0]
        pos = state["pos"]
        Smax = state["kv"]["k"].shape[2]
        write_pos = jnp.minimum(pos, Smax - 1)
        angles = _angles(cfg, _positions(cfg, B, 1, offset=pos))
        shared = params["shared"]

        def body(x, inp):
            sp, ssm, conv, cache = inp

            def inner(x, lpst):
                lp, s1, s2 = lpst
                x, (s1, s2) = M.mamba_block_decode(x, lp, cfg, self.axes,
                                                   (s1, s2))
                return x, (s1, s2)

            x, (ssm, conv) = L.xscan(cfg, inner, x, (sp, ssm, conv))
            x, cache = L.transformer_block_decode(x, shared, cfg, self.axes,
                                                  angles, cache, write_pos)
            return x, (ssm, conv, cache)

        h, (ssm, conv, kv) = L.xscan(
            cfg, body, h, (params["ssm_layers"], state["ssm"], state["conv"],
                           state["kv"]))
        logits = self._logits(params, h[:, 0])
        return logits, {"ssm": ssm, "conv": conv, "kv": kv, "pos": pos + 1}

    def decode_state_specs(self, batch: int, seq_len: int):
        cfg = self.cfg
        ssm, conv = M.mamba_state_specs(cfg, batch, cfg.num_layers)
        re = lambda s: jax.ShapeDtypeStruct(
            (self.n_super, cfg.attn_every, *s.shape[1:]), s.dtype)
        return {"ssm": re(ssm), "conv": re(conv),
                "kv": self.kv_cache_specs(self.n_super, batch, seq_len),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}


# ---------------------------------------------------------------------------
# Encoder-decoder (whisper): bidirectional encoder over stubbed audio frames,
# causal decoder with cross-attention.
# ---------------------------------------------------------------------------


class EncDecLM(BaseLM):
    def init(self, rng):
        cfg = self.cfg
        k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
        p = self._embed_params(k1)
        p["enc_layers"] = L.block_params(k2, cfg, cfg.num_layers)
        nd = cfg.num_decoder_layers
        p["dec_layers"] = L.block_params(k3, cfg, nd)
        p["dec_layers"]["cross"] = L.attn_params(k4, cfg, nd)
        p["dec_layers"]["ln_x"] = jnp.ones((nd, cfg.d_model), cfg.param_dtype)
        p["ln_enc"] = jnp.ones((cfg.d_model,), cfg.param_dtype)
        return p

    def encode(self, params, audio_frames):
        cfg = self.cfg
        h = audio_frames.astype(cfg.compute_dtype)
        h = sc(h, self.axes, "batch", None, None)
        B, S, _ = h.shape
        angles = _angles(cfg, _positions(cfg, B, S))

        def body(x, lp):
            return (L.transformer_block(x, lp, cfg, self.axes, angles,
                                        causal=False), None)

        h, _ = L.xscan(cfg, _remat(body, cfg), h, params["enc_layers"])
        return L.rms_norm(h, params["ln_enc"], cfg.norm_eps)

    def _decoder(self, params, h, enc_out, angles, collect_kv: bool = False):
        cfg = self.cfg

        def body(x, lp):
            hn = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = L.project_qkv(hn, lp["attn"], cfg, self.axes, angles)
            o = L.full_attention(q, k, v, cfg, self.axes, causal=True)
            x = x + (o @ lp["attn"]["wo"].astype(cfg.compute_dtype))
            x = L.cross_attn_sublock(x, lp["cross"], lp["ln_x"], cfg,
                                     self.axes, enc_out)
            hn = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
            x = x + L.mlp_block(hn, lp["mlp"], cfg, self.axes)
            out = ({"k": k.astype(cfg.compute_dtype),
                    "v": v.astype(cfg.compute_dtype)} if collect_kv else None)
            return x, out

        return L.xscan(cfg, _remat(body, cfg), h, params["dec_layers"])

    def loss(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["audio_frames"])
        h = self._embed(params, batch["tokens"])
        B, S, _ = h.shape
        angles = _angles(cfg, _positions(cfg, B, S))
        h, _ = self._decoder(params, h, enc_out, angles)
        ce = self._hidden_loss(params, h, batch["labels"])
        return ce, {"ce": ce}

    def _cross_kv(self, params, enc_out):
        """Precompute per-decoder-layer cross-attention k/v from enc_out."""
        cfg = self.cfg
        cd = cfg.compute_dtype
        B, S, _ = enc_out.shape

        def body(_, lp):
            k = (enc_out @ lp["wk"].astype(cd)).reshape(
                B, S, cfg.num_kv_heads, cfg.head_dim)
            v = (enc_out @ lp["wv"].astype(cd)).reshape(
                B, S, cfg.num_kv_heads, cfg.head_dim)
            return None, {"k": k, "v": v}

        _, enc_kv = L.xscan(cfg, body, None, params["dec_layers"]["cross"])
        return enc_kv

    def prefill(self, params, batch, pad_to: int | None = None):
        cfg = self.cfg
        enc_out = self.encode(params, batch["audio_frames"])
        h = self._embed(params, batch["tokens"])
        B, S, _ = h.shape
        angles = _angles(cfg, _positions(cfg, B, S))
        h, kv = self._decoder(params, h, enc_out, angles, collect_kv=True)
        logits = self._logits(params, h[:, -1])
        return logits, {"kv": self._pad_kv(kv, pad_to),
                        "enc_kv": self._cross_kv(params, enc_out),
                        "pos": jnp.int32(S)}

    def decode_step(self, params, state, tokens):
        cfg = self.cfg
        h = self._embed(params, tokens[:, None])
        B = h.shape[0]
        pos = state["pos"]
        Smax = state["kv"]["k"].shape[2]
        write_pos = jnp.minimum(pos, Smax - 1)
        angles = _angles(cfg, _positions(cfg, B, 1, offset=pos))

        def body(x, inp):
            lp, cache, enc_kv = inp
            hn = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = L.project_qkv(hn, lp["attn"], cfg, self.axes, angles)
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, write_pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, write_pos, 0, 0))
            o = L.decode_attention(q, ck, cv, write_pos + 1, cfg, self.axes)
            x = x + (o @ lp["attn"]["wo"].astype(cfg.compute_dtype))
            x = L.cross_block_decode(
                x, {"ln1": lp["ln_x"], "attn": lp["cross"]}, cfg, self.axes,
                enc_kv)
            hn = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
            x = x + L.mlp_block(hn, lp["mlp"], cfg, self.axes)
            return x, {"k": ck, "v": cv}

        dl = params["dec_layers"]
        lp_only = {k: dl[k] for k in ("attn", "mlp", "ln1", "ln2", "cross",
                                      "ln_x")}
        h, kv = L.xscan(cfg, body, h, (lp_only, state["kv"], state["enc_kv"]))
        logits = self._logits(params, h[:, 0])
        return logits, {"kv": kv, "enc_kv": state["enc_kv"], "pos": pos + 1}

    def decode_state_specs(self, batch: int, seq_len: int):
        cfg = self.cfg
        return {
            "kv": self.kv_cache_specs(cfg.num_decoder_layers, batch, seq_len),
            "enc_kv": self.kv_cache_specs(cfg.num_decoder_layers, batch,
                                          cfg.num_audio_frames),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }


FAMILIES = {
    "dense": DenseLM,
    "vlm": DenseLM,
    "moe": MoeLM,
    "ssm": MambaLM,
    "hybrid": HybridLM,
    "encdec": EncDecLM,
}


def build_model(cfg: ModelConfig, axes: MeshAxes | None = None, mesh=None):
    from repro.models.sharding import SINGLE  # noqa: PLC0415
    return FAMILIES[cfg.family](cfg, axes or SINGLE, mesh)

"""Core neural-net layers in pure JAX (no flax): norms, RoPE/M-RoPE,
GQA attention (train/prefill chunked + single-step decode), MLPs.

Conventions:
  * params are plain nested dicts of jnp arrays,
  * every function takes ``axes: MeshAxes`` and applies activation sharding
    constraints through :func:`repro.models.sharding.sc`,
  * compute happens in ``cfg.compute_dtype`` (bf16), softmax/norm statistics
    in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.sharding import MeshAxes, sc

def xscan(cfg: ModelConfig, body, carry, xs, length=None):
    """lax.scan honoring cfg.unroll_scans (see ModelConfig docstring)."""
    return jax.lax.scan(body, carry, xs, length=length,
                        unroll=True if cfg.unroll_scans else 1)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(rng, shape, in_axis: int = -2, dtype=jnp.float32):
    fan_in = shape[in_axis]
    return (jax.random.normal(rng, shape) * (fan_in**-0.5)).astype(dtype)


def embed_init(rng, shape, dtype=jnp.float32):
    return (jax.random.normal(rng, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps: float = 1e-5):
    """RMSNorm with fp32 *statistics* but a bf16 data path.

    The obvious form ``(x32 * rsqrt(var)).astype(bf16)`` materializes an
    fp32 (B, S, D) intermediate whose backward cotangent is fp32 — measured
    on minitron train_4k this doubles the TP gradient all-reduce wire bytes
    (§Perf cell 2). Keeping only the (B, S, 1) statistic in fp32 keeps the
    residual-stream cotangents in bf16."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(dt)  # (B, S, 1) statistic only
    return x * inv * w.astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------


def rope_angles(positions, head_dim: int, theta: float, sections=()):
    """Rotation angles, shape (..., S, head_dim//2).

    ``positions``: (B, S) int32 for plain RoPE, or (3, B, S) for M-RoPE where
    the three streams are (temporal, height, width) position ids. ``sections``
    partitions head_dim//2 among the three streams (Qwen2-VL).
    """
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    if sections:
        assert sum(sections) == half and positions.ndim == 3
        sec_id = jnp.repeat(
            jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=half
        )  # (half,) -> which position stream each freq uses
        pos = positions.astype(jnp.float32)[sec_id]  # (half, B, S)
        return jnp.moveaxis(pos, 0, -1) * inv_freq  # (B, S, half)
    return positions.astype(jnp.float32)[..., None] * inv_freq


def apply_rope(x, angles):
    """x: (B, S, H, Dh); angles: (B, S, Dh//2). Rotate-half convention."""
    half = x.shape[-1] // 2
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def attn_params(rng, cfg: ModelConfig, layers: int | None = None, dtype=None):
    """Stacked attention params; ``layers=None`` -> unstacked (shared block)."""
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    pre = () if layers is None else (layers,)
    ks = jax.random.split(rng, 5)
    dtype = dtype or cfg.param_dtype
    p = {
        "wq": dense_init(ks[0], (*pre, d, qd), dtype=dtype),
        "wk": dense_init(ks[1], (*pre, d, kvd), dtype=dtype),
        "wv": dense_init(ks[2], (*pre, d, kvd), dtype=dtype),
        "wo": dense_init(ks[3], (*pre, qd, d), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((*pre, cfg.head_dim), dtype)
        p["k_norm"] = jnp.ones((*pre, cfg.head_dim), dtype)
    return p


def project_qkv(x, p, cfg: ModelConfig, axes: MeshAxes, angles, kv_x=None):
    """Project to q/k/v heads, apply qk-norm and RoPE.

    ``kv_x``: source for k/v (cross-attention; no RoPE applied then);
    defaults to ``x``. Returns q (B,Sq,Hq,Dh), k,v (B,Skv,Hkv,Dh).
    """
    cd = cfg.compute_dtype
    cross = kv_x is not None
    kv_x = x if kv_x is None else kv_x
    B, Sq, _ = x.shape
    Skv = kv_x.shape[1]
    q = (x @ p["wq"].astype(cd)).reshape(B, Sq, cfg.num_heads, cfg.head_dim)
    k = (kv_x @ p["wk"].astype(cd)).reshape(B, Skv, cfg.num_kv_heads, cfg.head_dim)
    v = (kv_x @ p["wv"].astype(cd)).reshape(B, Skv, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if angles is not None and not cross:
        q = apply_rope(q, angles)
        k = apply_rope(k, angles)
    q = sc(q, axes, "batch", None, "model", None)
    k = sc(k, axes, "batch", None, None, None)
    v = sc(v, axes, "batch", None, None, None)
    return q, k, v


def _repeat_kv(k, num_heads: int):
    """(B,S,Hkv,Dh) -> (B,S,Hq,Dh) by repeating each kv head for its group."""
    return jnp.repeat(k, num_heads // k.shape[2], axis=2)


def full_attention(q, k, v, cfg: ModelConfig, axes: MeshAxes, *, causal: bool,
                   q_chunk: int | None = None):
    """Chunked-query full attention (flash-style blocking at the HLO level).

    Scanning over query chunks bounds peak score memory at
    (B, H, q_chunk, Skv) instead of (B, H, Sq, Skv) — required for 32k
    prefill, where unchunked scores would be ~17 GB/device.
    """
    B, Sq, Hq, Dh = q.shape
    Skv = k.shape[1]
    k = _repeat_kv(k, Hq)
    v = _repeat_kv(v, Hq)
    k = sc(k, axes, "batch", None, "model", None)
    v = sc(v, axes, "batch", None, "model", None)
    scale = Dh**-0.5
    qc = min(q_chunk or cfg.attn_q_chunk, Sq)
    n_chunks = Sq // qc
    k_idx = jnp.arange(Skv)

    def one_chunk(qb, q0):
        s = jnp.einsum("bqhd,bkhd->bhqk", qb, k) * scale
        s = sc(s, axes, "batch", "model", None, None)
        if causal:
            q_idx = q0 + jnp.arange(qc)
            mask = q_idx[:, None] >= k_idx[None, :]
            s = jnp.where(mask[None, None], s, -jnp.inf)
        a = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(qb.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", a, v)
        return sc(o, axes, "batch", None, "model", None)

    if n_chunks <= 1:
        out = one_chunk(q, jnp.int32(0))
    else:
        qr = q.reshape(B, n_chunks, qc, Hq, Dh)

        def body(_, inp):
            qb, c = inp  # qb: (B, qc, Hq, Dh)
            return None, one_chunk(qb, c * qc)

        _, out = xscan(
            cfg, body, None, (jnp.moveaxis(qr, 1, 0), jnp.arange(n_chunks))
        )  # out: (n_chunks, B, qc, Hq, Dh)
        out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, Hq, Dh)
    return out.reshape(B, Sq, Hq * Dh)


def decode_attention(q, k_cache, v_cache, pos, cfg: ModelConfig, axes: MeshAxes):
    """Single-token attention against a (possibly seq-sharded) KV cache.

    q: (B, 1, Hq, Dh); caches: (B, Smax, Hkv, Dh); ``pos``: scalar int32 —
    number of valid cache entries (positions >= pos are masked out).

    When ``axes.kv_partition == "seq"`` the cache's sequence dim is sharded
    over the model axis (flash-decoding); the softmax statistics and the
    weighted sum then reduce over the model axis (GSPMD inserts the
    all-reduces). Otherwise kv-heads are sharded and attention is local.
    """
    B, _, Hq, Dh = q.shape
    Smax, Hkv = k_cache.shape[1], k_cache.shape[2]
    if axes.kv_partition == "seq":
        cache_spec = ("batch", "model", None, None)
    else:
        cache_spec = ("batch", None, "model", None)
    k_cache = sc(k_cache, axes, *cache_spec)
    v_cache = sc(v_cache, axes, *cache_spec)
    group = Hq // Hkv
    # grouped form: avoid materializing a repeated (B,Smax,Hq,Dh) cache
    qg = q.reshape(B, Hkv, group, Dh)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache) * (Dh**-0.5)
    valid = (jnp.arange(Smax) < pos)[None, None, None, :]
    s = jnp.where(valid, s.astype(jnp.float32), -jnp.inf)
    if axes.kv_partition == "seq":
        s = sc(s, axes, "batch", None, None, "model")
    a = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhgk,bkhd->bhgd", a, v_cache)
    return o.reshape(B, 1, Hq * Dh)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_params(rng, cfg: ModelConfig, layers: int | None = None, dtype=None):
    d, f = cfg.d_model, cfg.d_ff
    pre = () if layers is None else (layers,)
    ks = jax.random.split(rng, 3)
    dtype = dtype or cfg.param_dtype
    if cfg.mlp_kind == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (*pre, d, f), dtype=dtype),
            "w_up": dense_init(ks[1], (*pre, d, f), dtype=dtype),
            "w_down": dense_init(ks[2], (*pre, f, d), dtype=dtype),
        }
    return {
        "w_up": dense_init(ks[0], (*pre, d, f), dtype=dtype),
        "w_down": dense_init(ks[1], (*pre, f, d), dtype=dtype),
    }


def _ar_boundary(x, cfg: ModelConfig):
    """Optional optimization barrier after TP matmuls: keeps the model-axis
    all-reduce in bf16 (XLA otherwise promotes it to fp32 when a downstream
    consumer upcasts — measured 2x collective wire; see EXPERIMENTS §Perf)."""
    if cfg.bf16_all_reduce:
        return jax.lax.optimization_barrier(x)
    return x


def _tp_out(x, name: str):
    """Tag the TP-psum outputs for the 'tp_out' remat policy: saving these
    two (B,S,D) tensors per layer lets the rematerialized backward skip
    re-running the forward model-axis all-reduces (-1/3 AR wire)."""
    from jax.ad_checkpoint import checkpoint_name  # noqa: PLC0415
    return checkpoint_name(x, name)


def mlp_block(x, p, cfg: ModelConfig, axes: MeshAxes):
    cd = cfg.compute_dtype
    if cfg.mlp_kind == "swiglu":
        g = x @ p["w_gate"].astype(cd)
        u = x @ p["w_up"].astype(cd)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(x @ p["w_up"].astype(cd))
    h = sc(h, axes, "batch", None, "model")
    out = _tp_out(_ar_boundary(h @ p["w_down"].astype(cd), cfg), "mlp_out")
    return sc(out, axes, "batch", None, None)


# ---------------------------------------------------------------------------
# transformer block (attention + MLP, pre-norm)
# ---------------------------------------------------------------------------


def block_params(rng, cfg: ModelConfig, layers: int | None = None):
    k1, k2, k3 = jax.random.split(rng, 3)
    pre = () if layers is None else (layers,)
    return {
        "attn": attn_params(k1, cfg, layers),
        "mlp": mlp_params(k2, cfg, layers),
        "ln1": jnp.ones((*pre, cfg.d_model), cfg.param_dtype),
        "ln2": jnp.ones((*pre, cfg.d_model), cfg.param_dtype),
    }


def transformer_block(x, p, cfg: ModelConfig, axes: MeshAxes, angles, *,
                      causal: bool = True):
    """Pre-norm self-attention + MLP residual block (training / prefill)."""
    cd = cfg.compute_dtype
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = project_qkv(h, p["attn"], cfg, axes, angles)
    o = full_attention(q, k, v, cfg, axes, causal=causal)
    x = x + _tp_out(_ar_boundary(o @ p["attn"]["wo"].astype(cd), cfg),
                    "attn_out")
    x = sc(x, axes, "batch", None, None)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + mlp_block(h, p["mlp"], cfg, axes)
    return sc(x, axes, "batch", None, None)


def cross_attn_sublock(x, p, ln, cfg: ModelConfig, axes: MeshAxes, enc_out):
    """Pre-norm cross-attention residual sub-block (enc-dec training path).

    ``p``: attention params; ``ln``: the norm weight; no RoPE on cross-attn.
    """
    cd = cfg.compute_dtype
    h = rms_norm(x, ln, cfg.norm_eps)
    q, k, v = project_qkv(h, p, cfg, axes, None, kv_x=enc_out)
    o = full_attention(q, k, v, cfg, axes, causal=False)
    return x + (o @ p["wo"].astype(cd))


def transformer_block_decode(x, p, cfg: ModelConfig, axes: MeshAxes, angles,
                             cache, pos):
    """Single-token decode block. ``cache``: {"k","v"} (B,Smax,Hkv,Dh).

    Writes this step's k/v at position ``pos`` then attends to positions
    ``< pos+1``. Returns (x, updated cache).
    """
    cd = cfg.compute_dtype
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = project_qkv(h, p["attn"], cfg, axes, angles)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
    o = decode_attention(q, ck, cv, pos + 1, cfg, axes)
    x = x + (o @ p["attn"]["wo"].astype(cd))
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + mlp_block(h, p["mlp"], cfg, axes)
    return x, {"k": ck, "v": cv}


def cross_block_decode(x, p, cfg: ModelConfig, axes: MeshAxes, enc_kv):
    """Cross-attention sub-block for enc-dec decode (k/v precomputed)."""
    cd = cfg.compute_dtype
    B = x.shape[0]
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = (h @ p["attn"]["wq"].astype(cd)).reshape(B, 1, cfg.num_heads, cfg.head_dim)
    S_enc = enc_kv["k"].shape[1]
    o = decode_attention(q, enc_kv["k"].astype(cd), enc_kv["v"].astype(cd),
                         jnp.int32(S_enc), cfg, axes)
    return x + (o @ p["attn"]["wo"].astype(cd))

"""Trace exporters: Chrome trace-event JSON and a compact JSONL log.

``chrome_trace`` renders a :class:`repro.obs.tracer.Tracer`'s events into
the `trace-event format`__ understood by Perfetto and ``chrome://tracing``:

* one track per real thread (named after ``threading.Thread.name``),
* one synthetic track per named track (``device:0`` … — per-device kernel
  timelines),
* counter tracks (``ph == "C"``) for sampled values such as cache
  hit-rate and wave width.

__ https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU

Timestamps are µs relative to the tracer's start (monotonic clock), which
is what the viewers expect.  ``write_jsonl`` dumps the raw internal events
one-JSON-object-per-line for cheap ad-hoc grepping; ``load_events``
re-reads either format for :mod:`repro.analysis.wave_report`.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.obs.tracer import Tracer, get_tracer

#: synthetic tid range for named tracks, far above real thread idents' use
#: as display sort keys once remapped
_TRACK_TID_BASE = 1 << 20


def _tid_map(tracer: Tracer) -> Dict[object, int]:
    """Stable mapping from event tid keys (thread idents and track names)
    to small integer tids for the viewer."""
    mapping: Dict[object, int] = {}
    for i, ident in enumerate(sorted(tracer.thread_names()), start=1):
        mapping[ident] = i
    for j, track in enumerate(sorted(tracer.tracks())):
        mapping[track] = _TRACK_TID_BASE + j
    return mapping


def chrome_trace(tracer: Optional[Tracer] = None, *,
                 process_name: str = "repro") -> dict:
    """Render the tracer's events as a trace-event JSON object."""
    tr = tracer if tracer is not None else get_tracer()
    tids = _tid_map(tr)
    pid = tr.pid
    t0 = tr.t0_ns
    events: List[dict] = [
        {"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
         "args": {"name": process_name}},
    ]
    for ident, name in sorted(tr.thread_names().items()):
        events.append({"ph": "M", "name": "thread_name", "pid": pid,
                       "tid": tids[ident], "args": {"name": name}})
    for track in sorted(tr.tracks()):
        events.append({"ph": "M", "name": "thread_name", "pid": pid,
                       "tid": tids[track], "args": {"name": track}})
    for ev in tr.events():
        tid = tids.get(ev["tid"], 0)
        ts = (ev["t0"] - t0) / 1000.0
        out = {"ph": ev["ph"], "name": ev["name"], "pid": pid, "tid": tid,
               "ts": ts, "args": ev["args"] or {}}
        if ev["ph"] == "X":
            out["dur"] = ev["dur"] / 1000.0
        elif ev["ph"] == "i":
            out["s"] = "t"  # thread-scoped instant
        events.append(out)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path, tracer: Optional[Tracer] = None, *,
                       process_name: str = "repro") -> str:
    """Write the Perfetto-loadable JSON; returns the path written."""
    doc = chrome_trace(tracer, process_name=process_name)
    with open(path, "w") as fh:
        json.dump(doc, fh)
    return str(path)


def write_jsonl(path, tracer: Optional[Tracer] = None) -> str:
    """Write the raw events as one JSON object per line."""
    tr = tracer if tracer is not None else get_tracer()
    t0 = tr.t0_ns
    with open(path, "w") as fh:
        for ev in tr.events():
            rec = {"ph": ev["ph"], "name": ev["name"],
                   "ts_us": (ev["t0"] - t0) / 1000.0,
                   "dur_us": ev["dur"] / 1000.0,
                   "tid": ev["tid"] if isinstance(ev["tid"], str)
                   else int(ev["tid"]),
                   "args": ev["args"] or {}}
            fh.write(json.dumps(rec) + "\n")
    return str(path)


def load_events(path) -> List[dict]:
    """Load events from either exporter's output into one normalized
    shape: ``{"ph", "name", "ts_us", "dur_us", "tid", "tid_name", "args"}``.

    For Chrome-trace files the thread_name metadata is folded into
    ``tid_name`` so reports can tell device tracks from worker threads."""
    with open(path) as fh:
        text = fh.read()
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        doc = None  # one JSON object per line -> the JSONL log
    if isinstance(doc, dict):
        raw = doc.get("traceEvents", [])
        names = {ev["tid"]: ev["args"].get("name", "")
                 for ev in raw
                 if ev.get("ph") == "M" and ev.get("name") == "thread_name"}
        out = []
        for ev in raw:
            if ev.get("ph") == "M":
                continue
            out.append({"ph": ev["ph"], "name": ev["name"],
                        "ts_us": ev.get("ts", 0.0),
                        "dur_us": ev.get("dur", 0.0),
                        "tid": ev.get("tid", 0),
                        "tid_name": names.get(ev.get("tid"), ""),
                        "args": ev.get("args", {})})
        return out
    out = []
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        rec = json.loads(line)
        tid = rec.get("tid", 0)
        rec.setdefault("tid_name", tid if isinstance(tid, str) else "")
        out.append(rec)
    return out

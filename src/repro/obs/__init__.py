"""Unified observability: structured tracing + metrics for every layer.

The measurement stack is five layers deep (plans -> WaveScheduler ->
MeasurementEngine -> BatchSimMachine -> device mesh -> service), and each
layer grew its own ad-hoc stats dict.  This package is the one substrate
they all report through:

* :mod:`repro.obs.tracer` — thread-safe hierarchical spans with monotonic
  clocks and a module-level no-op fast path (near-zero overhead when
  disabled; enable with ``REPRO_TRACE=1`` or ``Tracer(enabled=True)``).
* :mod:`repro.obs.metrics` — a counter/gauge/histogram registry with one
  ``snapshot()`` shape, absorbing the legacy stats dicts
  (``EngineStats.as_dict()``, ``device_stats()``, the server's per-endpoint
  summaries) behind canonical dotted instrument names.
* :mod:`repro.obs.export` — Chrome trace-event JSON (loads in Perfetto /
  ``chrome://tracing``: one track per thread, one per device, counter
  tracks) and a compact JSONL event log.

Per-wave bottleneck attribution over an exported trace lives in
:mod:`repro.analysis.wave_report` (``scripts/analyze.py --trace-report``).
"""
from repro.obs.tracer import (NULL_SPAN, Tracer, counter, enabled,
                              get_tracer, instant, set_tracer, span,
                              wait_lock)

__all__ = ["Tracer", "span", "instant", "counter", "wait_lock", "enabled",
           "get_tracer", "set_tracer", "NULL_SPAN"]

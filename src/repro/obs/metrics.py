"""Counter/gauge/histogram registry with one canonical ``snapshot()`` shape.

Before this module, each layer reported through its own ad-hoc dict with
its own naming convention: ``EngineStats.as_dict()`` (flat snake_case),
``BatchSimMachine.device_stats()`` (nested per-device), and the server's
per-endpoint reservoirs (``p50_us``/``p99_us``).  Those legacy shapes are
kept — benches and clients pin them — but each is now *derived from* a
:class:`MetricsRegistry`: the absorb helpers below map every legacy key to
a canonical dotted instrument name, and the legacy dicts are reconstructed
from the registry snapshot through the documented alias tables.

Canonical snapshot shape (``MetricsRegistry.snapshot()``)::

    {
      "engine.cache.hits":        {"type": "counter", "value": 42},
      "device.mesh.width":        {"type": "gauge",   "value": 4},
      "server.endpoint.predict":  {"type": "histogram", "count": 9,
                                   "sum": ..., "min": ..., "max": ...,
                                   "p50": ..., "p99": ...},
      ...
    }

Instruments are cheap, lock-protected, and dependency-free; histograms
keep a bounded sample reservoir (newest ``keep`` observations) plus exact
count/sum/min/max.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Iterable, List, Optional


class Counter:
    """Monotonic event count."""

    __slots__ = ("name", "_v", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._v = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1):
        with self._lock:
            self._v += n

    @property
    def value(self):
        return self._v

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self._v}


class Gauge:
    """Last-written value (may be any JSON-serialisable scalar)."""

    __slots__ = ("name", "_v", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._v = 0
        self._lock = threading.Lock()

    def set(self, v):
        with self._lock:
            self._v = v

    def add(self, n=1):
        with self._lock:
            self._v += n

    @property
    def value(self):
        return self._v

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": self._v}


class Histogram:
    """Bounded-reservoir distribution: exact count/sum/min/max, quantiles
    over the newest ``keep`` observations (the same recent-window
    semantics the server's endpoint reservoirs always had)."""

    __slots__ = ("name", "keep", "_vals", "_i", "_count", "_sum",
                 "_min", "_max", "_lock")

    def __init__(self, name: str, keep: int = 2048):
        self.name = name
        self.keep = keep
        self._vals: List[float] = []
        self._i = 0
        self._count = 0
        self._sum = 0.0
        self._min: Optional[float] = None
        self._max: Optional[float] = None
        self._lock = threading.Lock()

    def observe(self, v: float):
        with self._lock:
            self._count += 1
            self._sum += v
            if self._min is None or v < self._min:
                self._min = v
            if self._max is None or v > self._max:
                self._max = v
            if len(self._vals) < self.keep:
                self._vals.append(v)
            else:  # ring overwrite: keep the newest `keep` samples
                self._vals[self._i] = v
                self._i = (self._i + 1) % self.keep

    def observe_many(self, v: float, n: int):
        """n observations of the same value under one lock acquisition —
        the batched-endpoint hot path (a 512-block wave is one call, not
        512 lock round-trips)."""
        if n <= 0:
            return
        with self._lock:
            self._count += n
            self._sum += v * n
            if self._min is None or v < self._min:
                self._min = v
            if self._max is None or v > self._max:
                self._max = v
            room = self.keep - len(self._vals)
            fill = min(n, room)
            if fill > 0:
                self._vals.extend([v] * fill)
                n -= fill
            for _ in range(min(n, self.keep)):  # ring overwrite
                self._vals[self._i] = v
                self._i = (self._i + 1) % self.keep

    @property
    def count(self):
        return self._count

    def quantile(self, q: float) -> float:
        with self._lock:
            vals = sorted(self._vals)
        if not vals:
            return 0.0
        k = min(len(vals) - 1, max(0, int(round(q * (len(vals) - 1)))))
        return vals[k]

    def snapshot(self) -> dict:
        with self._lock:
            vals = sorted(self._vals)
            out = {"type": "histogram", "count": self._count,
                   "sum": self._sum,
                   "min": self._min if self._min is not None else 0.0,
                   "max": self._max if self._max is not None else 0.0}
        for q, key in ((0.5, "p50"), (0.99, "p99")):
            if vals:
                k = min(len(vals) - 1, max(0, int(round(q * (len(vals) - 1)))))
                out[key] = vals[k]
            else:
                out[key] = 0.0
        return out


class MetricsRegistry:
    """Get-or-create instrument registry; the single snapshot surface."""

    def __init__(self):
        self._lock = threading.Lock()
        self._inst: Dict[str, Any] = {}

    def _get(self, name: str, cls, **kw):
        with self._lock:
            inst = self._inst.get(name)
            if inst is None:
                inst = self._inst[name] = cls(name, **kw)
            elif not isinstance(inst, cls):
                raise TypeError(f"instrument {name!r} already registered "
                                f"as {type(inst).__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, keep: int = 2048) -> Histogram:
        return self._get(name, Histogram, keep=keep)

    def set_gauges(self, mapping: Dict[str, Any], prefix: str = ""):
        """Bulk-register a flat dict of scalars as gauges."""
        for k, v in mapping.items():
            self.gauge(prefix + k).set(v)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._inst)

    def get(self, name: str):
        with self._lock:
            return self._inst.get(name)

    def value(self, name: str):
        inst = self.get(name)
        return None if inst is None else inst.snapshot().get("value")

    def snapshot(self) -> Dict[str, dict]:
        with self._lock:
            items = list(self._inst.items())
        return {name: inst.snapshot() for name, inst in sorted(items)}


# ----------------------------------------------------------------------
# Legacy-shape adapters.  Each table maps `legacy key -> canonical
# instrument name`; the legacy dicts the rest of the repo exposes are
# reconstructed from a registry through these tables, so the registry is
# the single source of truth and the old keys are documented aliases.

#: ``EngineStats.as_dict()`` aliases (see :class:`repro.core.engine.EngineStats`)
ENGINE_ALIASES: Dict[str, str] = {
    "requests": "engine.requests",
    "cache_hits": "engine.cache.hits",
    "dedup_hits": "engine.cache.dedup_hits",
    "executions": "engine.executions",
    "machine_runs": "engine.machine_runs",
    "batches": "engine.batches",
    "evictions": "engine.cache.evictions",
    "lowering_hits": "engine.lowering.hits",
    "lowering_misses": "engine.lowering.misses",
    "lowering_evictions": "engine.lowering.evictions",
    "quarantined": "engine.quarantined",
    "bisect_retries": "engine.bisect_retries",
    "degraded_chunks": "engine.degraded_chunks",
    "hit_rate": "engine.cache.hit_rate",
}

#: top-level numeric keys of ``BatchSimMachine.device_stats()``
DEVICE_ALIASES: Dict[str, str] = {
    "compiles": "device.compiles",
    "kernel_calls": "device.kernel_calls",
    "mesh": "device.mesh.width",
    "devices": "device.count",
}

#: keys of each per-endpoint summary in ``PredictionService.stats()``
ENDPOINT_ALIASES: Dict[str, str] = {
    "requests": "count",
    "errors": "errors",
    "p50_us": "p50",
    "p99_us": "p99",
}


def absorb_engine_stats(reg: MetricsRegistry, stats: Dict[str, Any],
                        prefix: str = "") -> MetricsRegistry:
    """Register an ``EngineStats.as_dict()``-shaped dict as instruments."""
    for legacy, name in ENGINE_ALIASES.items():
        if legacy in stats:
            if legacy == "hit_rate":
                reg.gauge(prefix + name).set(stats[legacy])
            else:
                reg.gauge(prefix + name).set(stats[legacy])
    dev = stats.get("device")
    if isinstance(dev, dict):
        absorb_device_stats(reg, dev, prefix=prefix)
    return reg


def absorb_device_stats(reg: MetricsRegistry, dstats: Dict[str, Any],
                        prefix: str = "") -> MetricsRegistry:
    """Register a ``device_stats()``-shaped dict as instruments.

    Structural fields (``backend``, ``buckets``) become gauges holding the
    value verbatim; per-device counters land under
    ``device.<id>.<field>``."""
    for legacy, name in DEVICE_ALIASES.items():
        if legacy in dstats:
            reg.gauge(prefix + name).set(dstats[legacy])
    if "backend" in dstats:
        reg.gauge(prefix + "device.backend").set(dstats["backend"])
    if "buckets" in dstats:
        reg.gauge(prefix + "device.buckets").set(dstats["buckets"])
    for did, per in (dstats.get("per_device") or {}).items():
        base = f"{prefix}device.{did}."
        for k, v in per.items():
            reg.gauge(base + k).set(v)
    return reg


def absorb_server_stats(reg: MetricsRegistry, stats: Dict[str, Any],
                        prefix: str = "server.") -> MetricsRegistry:
    """Register a ``PredictionService.stats()``-shaped dict as instruments."""
    if "uptime_s" in stats:
        reg.gauge(prefix + "uptime_s").set(stats["uptime_s"])
    for ep, summ in (stats.get("endpoints") or {}).items():
        base = f"{prefix}endpoint.{ep}."
        for legacy, name in ENDPOINT_ALIASES.items():
            if legacy in summ:
                reg.gauge(base + name).set(summ[legacy])
    for section in ("cache", "coalescer", "registry", "admission", "wire",
                    "wave_cache", "predictor"):
        sub = stats.get(section)
        if isinstance(sub, dict):
            for k, v in sub.items():
                if isinstance(v, (int, float, bool)):
                    reg.gauge(f"{prefix}{section}.{k}").set(v)
    # per-shard cache hit rates (sharded result cache front door)
    for i, sh in enumerate((stats.get("cache") or {}).get("shards") or ()):
        for k, v in sh.items():
            if isinstance(v, (int, float, bool)):
                reg.gauge(f"{prefix}cache.shard.{i}.{k}").set(v)
    return reg


def legacy_engine_dict(reg: MetricsRegistry,
                       order: Iterable[str] = ENGINE_ALIASES) -> dict:
    """Reconstruct the legacy ``EngineStats.as_dict()`` shape from a
    registry populated with the canonical ``engine.*`` instruments."""
    return {legacy: reg.value(ENGINE_ALIASES[legacy]) for legacy in order}

"""Thread-safe hierarchical spans with a near-zero disabled fast path.

Every instrumented seam in the repo calls the *module-level* helpers::

    from repro import obs

    with obs.span("wave.execute", wave=len(codes)) as sp:
        ...
        sp.set(chunks=n)          # attach attributes mid-span

When tracing is disabled (the default) ``obs.span(...)`` returns a single
shared stateless no-op context manager — one attribute load, one truth
test, no allocation — so instrumentation costs a few tens of nanoseconds
per call site and nothing else.  Enable globally with ``REPRO_TRACE=1`` in
the environment, or programmatically::

    obs.set_tracer(obs.Tracer(enabled=True))
    ... traced work ...
    events = obs.get_tracer().events()

Design notes
------------
* Clocks are ``time.perf_counter_ns()`` (monotonic); export converts to
  the trace-event µs epoch relative to the tracer's start.
* Events append to one shared list — ``list.append`` is atomic under the
  GIL, so the hot path takes no lock.
* Each thread keeps its own span stack (``threading.local``) so nesting
  is tracked per thread and the tracer is reentrant across the Campaign
  pool.  A ``trace_id`` attribute set on an enclosing span is inherited
  by child spans on the same thread (how server request IDs flow into
  batch-predictor spans without threading them through every signature).
* ``track=`` pins an event to a named synthetic track (e.g. ``device:0``)
  instead of the calling thread — the export layer gives each track its
  own tid + thread-name metadata, which is how per-device kernel
  timelines appear in Perfetto.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Any, Dict, List, Optional

_now = time.perf_counter_ns


class _NullSpan:
    """Shared no-op span: returned by every helper while tracing is off."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


NULL_SPAN = _NullSpan()


class _Span:
    """A live span: records one complete ("X") event when it exits."""

    __slots__ = ("_tracer", "name", "args", "track", "_t0")

    def __init__(self, tracer: "Tracer", name: str,
                 args: Optional[Dict[str, Any]], track: Optional[str]):
        self._tracer = tracer
        self.name = name
        self.args = args
        self.track = track

    def set(self, **attrs) -> "_Span":
        """Attach (or overwrite) key/value attributes mid-span."""
        if self.args is None:
            self.args = attrs
        else:
            self.args.update(attrs)
        return self

    def __enter__(self):
        self._tracer._stack().append(self)
        self._t0 = _now()
        return self

    def __exit__(self, *exc):
        t1 = _now()
        tr = self._tracer
        stack = tr._stack()
        if stack and stack[-1] is self:
            stack.pop()
        args = self.args
        if (args is None or "trace_id" not in args):
            # inherit the nearest enclosing trace_id on this thread
            for sp in reversed(stack):
                a = sp.args
                if a is not None and "trace_id" in a:
                    args = dict(args) if args else {}
                    args["trace_id"] = a["trace_id"]
                    break
        tr._emit("X", self.name, self._t0, t1 - self._t0, args, self.track)
        return False


class _LockWait:
    """Context manager that times lock acquisition separately from the
    critical section, so contention shows up as its own span.

    Drives the lock through the context-manager protocol (not
    ``acquire``/``release``) so any ``with``-able lock the call sites
    already accepted keeps working; ``lock=None`` degrades to a pure
    no-op (the existing "no lock configured" behaviour is preserved
    bit-for-bit)."""

    __slots__ = ("_lock", "_name", "_tracer")

    def __init__(self, lock, name: str, tracer: Optional["Tracer"]):
        self._lock = lock
        self._name = name
        self._tracer = tracer

    def __enter__(self):
        if self._lock is None:
            return self
        tr = self._tracer
        if tr is None:
            self._lock.__enter__()
            return self
        t0 = _now()
        self._lock.__enter__()
        t1 = _now()
        tr._emit("X", self._name, t0, t1 - t0, None, None)
        return self

    def __exit__(self, *exc):
        if self._lock is not None:
            return self._lock.__exit__(*(exc or (None, None, None)))
        return False


class Tracer:
    """Collects trace events; one instance is installed globally.

    Thread-safe by construction: the event sink is a plain list (append is
    GIL-atomic) and span stacks are per-thread."""

    def __init__(self, enabled: bool = False):
        self.enabled = bool(enabled)
        self.t0_ns = _now()
        self.pid = os.getpid()
        self._events: List[dict] = []
        self._local = threading.local()
        self._threads: Dict[int, str] = {}
        self._tracks: Dict[str, None] = {}

    # -- hot path ------------------------------------------------------
    def span(self, name: str, *, track: Optional[str] = None, **attrs):
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, attrs or None, track)

    def instant(self, name: str, *, track: Optional[str] = None, **attrs):
        """A zero-duration marker event."""
        if self.enabled:
            self._emit("i", name, _now(), 0, attrs or None, track)

    def counter(self, name: str, value, *, track: Optional[str] = None):
        """A sampled counter value (a counter track in Perfetto)."""
        if self.enabled:
            self._emit("C", name, _now(), 0, {"value": value}, track)

    def wait_lock(self, lock, name: str = "lock.wait"):
        if not self.enabled:
            return _LockWait(lock, name, None)
        return _LockWait(lock, name, self)

    # -- plumbing ------------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _emit(self, ph: str, name: str, t0: int, dur: int,
              args: Optional[dict], track: Optional[str]):
        if track is None:
            tid = threading.get_ident()
            if tid not in self._threads:
                self._threads[tid] = threading.current_thread().name
        else:
            tid = track
            self._tracks[track] = None
        self._events.append({"ph": ph, "name": name, "t0": t0, "dur": dur,
                             "tid": tid, "args": args})

    def emit_span(self, name: str, t0_ns: int, dur_ns: int, *,
                  track: Optional[str] = None, **attrs):
        """Record an already-timed interval (used by pool workers that
        measure with raw clocks and attribute the span to a device track)."""
        if self.enabled:
            self._emit("X", name, t0_ns, dur_ns, attrs or None, track)

    def events(self) -> List[dict]:
        """The raw event list (internal schema; see export.py for the
        Chrome trace-event rendering)."""
        return list(self._events)

    def clear(self):
        self._events.clear()
        self._threads.clear()
        self._tracks.clear()
        self.t0_ns = _now()

    def thread_names(self) -> Dict[int, str]:
        return dict(self._threads)

    def tracks(self) -> List[str]:
        return list(self._tracks)


def _from_env() -> Tracer:
    flag = os.environ.get("REPRO_TRACE", "")
    return Tracer(enabled=flag not in ("", "0", "false", "off"))


_GLOBAL: Tracer = _from_env()


def get_tracer() -> Tracer:
    return _GLOBAL


def set_tracer(tracer: Tracer) -> Tracer:
    """Install ``tracer`` globally; returns the previous tracer so tests
    and benches can restore it."""
    global _GLOBAL
    prev = _GLOBAL
    _GLOBAL = tracer
    return prev


def enabled() -> bool:
    return _GLOBAL.enabled


# Module-level helpers: the instrumented call sites use these.  Each is a
# single global load + truth test when tracing is off.
def span(name: str, *, track: Optional[str] = None, **attrs):
    t = _GLOBAL
    if not t.enabled:
        return NULL_SPAN
    return _Span(t, name, attrs or None, track)


def instant(name: str, *, track: Optional[str] = None, **attrs):
    t = _GLOBAL
    if t.enabled:
        t._emit("i", name, _now(), 0, attrs or None, track)


def counter(name: str, value, *, track: Optional[str] = None):
    t = _GLOBAL
    if t.enabled:
        t._emit("C", name, _now(), 0, {"value": value}, track)


def wait_lock(lock, name: str = "lock.wait"):
    t = _GLOBAL
    if not t.enabled:
        return _LockWait(lock, name, None)
    return _LockWait(lock, name, t)


def emit_span(name: str, t0_ns: int, dur_ns: int, *,
              track: Optional[str] = None, **attrs):
    t = _GLOBAL
    if t.enabled:
        t._emit("X", name, t0_ns, dur_ns, attrs or None, track)

"""Deterministic fault injection + fault-tolerance policies.

``repro.faults.plan`` is the seeded injection plane (``REPRO_FAULTS``);
``repro.faults.tolerance`` holds the straggler/fleet policy objects the
resilience layers feed. See README §Robustness.
"""
from repro.faults.plan import (FaultPlan, FaultRule, FiredFault,
                               InjectedFault, MODES, POINTS, active, check,
                               check_wave, filter_bytes, get_plan,
                               plan_from_env, set_plan)
from repro.faults.tolerance import FleetMonitor, StepTimer, StragglerDetector

__all__ = [
    "FaultPlan", "FaultRule", "FiredFault", "InjectedFault", "MODES",
    "POINTS", "active", "check", "check_wave", "filter_bytes", "get_plan",
    "plan_from_env", "set_plan",
    "FleetMonitor", "StepTimer", "StragglerDetector",
]

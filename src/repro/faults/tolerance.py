"""Fault-tolerance policies: straggler detection, heartbeats,
restart/elastic decisions.

Folded in from the seed-era ``repro.runtime.fault_tolerance`` (a
deprecation shim remains at the old path). The :class:`StragglerDetector`
EWMA is wired to real data now: ``BatchSimMachine``'s device executor
feeds it per-device kernel wall times (see ``device_stats()``), and
``repro.analysis.wave_report`` runs one over the per-device
``wave.kernel`` spans of a trace so flagged stragglers show up in
``scripts/analyze.py --trace-report``. The *decisions* (restart from
checkpoint, drop to a smaller mesh, flag stragglers) are pure functions
so they are testable without hardware.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


@dataclass
class StragglerDetector:
    """Per-step wall-time EWMA + robust outlier flagging.

    A worker (a device in the mesh, or the single local process's step
    time) is a straggler when its step time exceeds ``threshold`` × the
    fleet median EWMA.
    """
    alpha: float = 0.2
    threshold: float = 2.0
    ewma: dict = field(default_factory=dict)  # worker -> ewma seconds

    def observe(self, worker: str, step_seconds: float) -> None:
        prev = self.ewma.get(worker)
        self.ewma[worker] = (step_seconds if prev is None
                             else (1 - self.alpha) * prev
                             + self.alpha * step_seconds)

    def median(self) -> float:
        vals = sorted(self.ewma.values())
        if not vals:
            return 0.0
        return vals[len(vals) // 2]

    def stragglers(self) -> list[str]:
        med = self.median()
        if med <= 0:
            return []
        return [w for w, v in self.ewma.items() if v > self.threshold * med]

    def snapshot(self) -> dict:
        """Flagging state for telemetry (``device_stats()`` / reports)."""
        med = self.median()
        return {"median_s": med,
                "ewma_s": {w: v for w, v in sorted(self.ewma.items())},
                "flagged": sorted(self.stragglers())}


@dataclass
class FleetMonitor:
    """Heartbeat bookkeeping + restart/elastic decisions."""
    heartbeat_timeout: float = 60.0
    last_seen: dict = field(default_factory=dict)
    now_fn: callable = time.monotonic

    def heartbeat(self, worker: str, t: float | None = None) -> None:
        self.last_seen[worker] = self.now_fn() if t is None else t

    def dead_workers(self) -> list[str]:
        now = self.now_fn()
        return [w for w, t in self.last_seen.items()
                if now - t > self.heartbeat_timeout]

    def plan(self, total_workers: int, min_workers: int) -> dict:
        """Decide: continue / restart_same / restart_elastic / halt.

        restart_same: dead workers expected back (spare capacity) — restore
        the latest checkpoint on the same mesh. restart_elastic: shrink the
        data-parallel axis to the largest feasible power-of-two and reshard
        (checkpoint.restore_checkpoint supports N->M). halt: below quorum.
        """
        dead = self.dead_workers()
        alive = total_workers - len(dead)
        if not dead:
            return {"action": "continue", "dead": []}
        if alive < min_workers:
            return {"action": "halt", "dead": dead}
        target = 1 << (alive.bit_length() - 1)  # largest power of two <= alive
        if target == total_workers:
            return {"action": "restart_same", "dead": dead}
        return {"action": "restart_elastic", "dead": dead,
                "new_data_parallel": target}


@dataclass
class StepTimer:
    """Context helper that feeds the detector one timed step."""
    detector: StragglerDetector
    worker: str = "local"
    _t0: float = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.detector.observe(self.worker, time.perf_counter() - self._t0)
        return False

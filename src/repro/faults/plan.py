"""Deterministic, seeded fault-injection plane (the chaos substrate).

A :class:`FaultPlan` is a set of :class:`FaultRule`\\ s evaluated at named
injection points threaded through the stack:

====================  ======================================================
point                 fires in
====================  ======================================================
engine.cache_io       persistent measurement-cache load/save
                      (``model_io`` / ``Campaign``)
wave.kernel           per-chunk kernel execution in ``BatchSimMachine``,
                      keyed by each code's content and tagged with the
                      executing backend — backend-restricted rules are
                      absorbed by the backend degradation chain, unkeyed
                      unrestricted ones propagate to the engine's
                      bisecting retry
wave.pack             host-side wave packing (``_pack_chunk`` callers)
device.dispatch       device-mesh kernel dispatch (``_DeviceExec``)
wire.frame            serialized wire messages — binary frame payloads and
                      JSON lines, corrupted *before* framing so length
                      headers/newlines stay consistent and decoders fail
                      typed instead of hanging
corpus.shard_write    corpus shard / per-shard result persistence
====================  ======================================================

Determinism: whether a rule fires for a given ``(point, key)`` is a pure
function of ``(seed, point, mode, key)`` — a crc32 hash mapped to
``[0, 1)`` and compared against the rule's probability.  Content-derived
keys make decisions independent of call order, retry count and wave
composition: the same poisoned experiment fails in *every* sub-wave
during bisection, which is what lets the engine converge on it.  Un-keyed
checks fall back to a per-point occurrence index (deterministic for a
fixed call sequence).  Every fired fault is recorded
(:class:`FiredFault`: point, mode, occurrence, key, seed) so any chaos
failure replays exactly from its spec.

Plans install via ``REPRO_FAULTS=<spec>`` (read once at import, like
``REPRO_TRACE``) or :func:`set_plan` in tests.  Spec grammar, clauses
joined by ``;``::

    seed=<int>
    <point>:<mode>[:p=<float>][:max=<int>][:after=<int>][:ms=<float>]
                  [:match=<substr>][:backend=<name>]

modes: ``raise`` (typed :class:`InjectedFault`), ``corrupt`` (byte
flips), ``torn`` (truncation — torn-write simulation), ``latency``
(sleep ``ms``).  Example::

    REPRO_FAULTS="seed=1337;wave.kernel:raise:p=0.02;engine.cache_io:torn"

Disabled cost: with no plan installed every hook is one module-global
load plus a ``None`` test (the same discipline as ``repro.obs.tracer``'s
``NULL_SPAN`` fast path); ``bench_fault_overhead`` gates the analytic
bound at <2% of wave wall time.
"""
from __future__ import annotations

import os
import threading
import time
import zlib
from dataclasses import dataclass, field

POINTS = ("engine.cache_io", "wave.kernel", "wave.pack", "device.dispatch",
          "wire.frame", "corpus.shard_write")
MODES = ("raise", "corrupt", "torn", "latency")


class InjectedFault(RuntimeError):
    """Typed fault raised by a ``raise``-mode rule. Carries enough to
    replay: the point, the per-point occurrence index, and the content
    key (if the check was keyed)."""

    def __init__(self, point: str, mode: str = "raise",
                 occurrence: int = 0, key=None):
        msg = f"injected {mode} fault at {point} #{occurrence}"
        if key is not None:
            msg += f" (key={str(key)[:80]!r})"
        super().__init__(msg)
        self.point = point
        self.mode = mode
        self.occurrence = occurrence
        self.key = key


@dataclass
class FiredFault:
    """One recorded firing — the replay token for a chaos failure."""
    point: str
    mode: str
    occurrence: int  # per-point check index at firing time
    seed: int
    key: str | None = None

    def as_dict(self) -> dict:
        return {"point": self.point, "mode": self.mode,
                "occurrence": self.occurrence, "seed": self.seed,
                "key": self.key}


@dataclass
class FaultRule:
    """One injection rule. ``p`` is the per-decision firing probability;
    ``match`` restricts to keys containing the substring; ``backend``
    restricts ``wave.kernel``-style checks to one executing backend;
    ``max_fires`` caps total firings (0 = unlimited — a capped rule
    models a *transient* fault that a retry survives, an uncapped one a
    *persistent* fault that bisection must quarantine); ``after`` skips
    the first N eligible occurrences; ``ms`` is the latency-mode sleep."""
    point: str
    mode: str = "raise"
    p: float = 1.0
    max_fires: int = 0
    after: int = 0
    ms: float = 0.0
    match: str = ""
    backend: str = ""
    fires: int = 0  # mutable: total firings so far

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"unknown fault mode {self.mode!r} "
                             f"(expected one of {MODES})")

    def wants(self, occurrence: int, key, backend) -> bool:
        """Static eligibility (probability decided separately)."""
        if self.max_fires and self.fires >= self.max_fires:
            return False
        if occurrence <= self.after:
            return False
        if self.backend and backend != self.backend:
            return False
        if self.match and (key is None or self.match not in str(key)):
            return False
        return True


class FaultPlan:
    """A seeded set of rules plus the record of everything that fired.

    Thread-safe: occurrence counters, fire caps and the fired-fault log
    are guarded by one lock (the plan is only consulted on the
    fault-enabled path, so the lock costs nothing when disabled)."""

    def __init__(self, rules=(), seed: int = 0):
        self.rules = list(rules)
        self.seed = seed
        self.fired: list[FiredFault] = []
        self._occ: dict[str, int] = {}
        self._lock = threading.Lock()

    # -- spec parsing --------------------------------------------------------

    @classmethod
    def from_spec(cls, spec: str) -> "FaultPlan":
        """Parse the ``REPRO_FAULTS`` grammar (see module docstring)."""
        seed = 0
        rules = []
        for clause in spec.split(";"):
            clause = clause.strip()
            if not clause:
                continue
            if clause.startswith("seed="):
                seed = int(clause[5:])
                continue
            parts = clause.split(":")
            if len(parts) < 2:
                raise ValueError(f"fault clause {clause!r} needs "
                                 f"<point>:<mode>")
            kw: dict = {"point": parts[0], "mode": parts[1]}
            for opt in parts[2:]:
                k, sep, v = opt.partition("=")
                if not sep:
                    raise ValueError(f"fault option {opt!r} in {clause!r} "
                                     f"is not key=value")
                if k == "p":
                    kw["p"] = float(v)
                elif k == "max":
                    kw["max_fires"] = int(v)
                elif k == "after":
                    kw["after"] = int(v)
                elif k == "ms":
                    kw["ms"] = float(v)
                elif k in ("match", "backend"):
                    kw[k] = v
                else:
                    raise ValueError(f"unknown fault option {k!r} in "
                                     f"{clause!r}")
            rules.append(FaultRule(**kw))
        return cls(rules, seed=seed)

    # -- deterministic decisions ---------------------------------------------

    def _hash01(self, rule: FaultRule, token) -> float:
        payload = f"{self.seed}:{rule.point}:{rule.mode}:{token}"
        return (zlib.crc32(payload.encode()) & 0xFFFFFFFF) / 2 ** 32

    def _decide(self, rule: FaultRule, token) -> bool:
        return rule.p >= 1.0 or self._hash01(rule, token) < rule.p

    def _record(self, rule: FaultRule, occurrence: int, key) -> None:
        # caller holds self._lock
        rule.fires += 1
        self.fired.append(FiredFault(rule.point, rule.mode, occurrence,
                                     self.seed,
                                     None if key is None else str(key)))

    def occurrences(self, point: str | None = None) -> int:
        with self._lock:
            if point is not None:
                return self._occ.get(point, 0)
            return sum(self._occ.values())

    # -- injection API -------------------------------------------------------

    def check(self, point: str, key=None, backend=None) -> None:
        """Evaluate ``raise`` and ``latency`` rules at ``point``. A firing
        ``raise`` rule raises :class:`InjectedFault`; ``latency`` sleeps.
        Keyed checks decide on the key's content hash (call-order
        independent), unkeyed ones on the occurrence index."""
        sleep_ms = 0.0
        boom = None
        with self._lock:
            occ = self._occ[point] = self._occ.get(point, 0) + 1
            for rule in self.rules:
                if rule.point != point or rule.mode not in ("raise",
                                                            "latency"):
                    continue
                if not rule.wants(occ, key, backend):
                    continue
                token = key if key is not None else occ
                if not self._decide(rule, token):
                    continue
                self._record(rule, occ, key)
                if rule.mode == "latency":
                    sleep_ms += rule.ms
                elif boom is None:
                    boom = InjectedFault(point, "raise", occ, key)
        if sleep_ms:
            time.sleep(sleep_ms / 1000.0)
        if boom is not None:
            raise boom

    def check_wave(self, point: str, keys, backend=None) -> None:
        """One check covering a whole wave/chunk of content keys: raises
        if *any* key's decision fires (the wave fails as a unit — exactly
        how a poisoned experiment takes down a fused kernel). Counted as
        a single occurrence."""
        boom = None
        sleep_ms = 0.0
        with self._lock:
            occ = self._occ[point] = self._occ.get(point, 0) + 1
            for rule in self.rules:
                if rule.point != point or rule.mode not in ("raise",
                                                            "latency"):
                    continue
                for key in keys:
                    if not rule.wants(occ, key, backend):
                        continue
                    if not self._decide(rule, key):
                        continue
                    self._record(rule, occ, key)
                    if rule.mode == "latency":
                        sleep_ms += rule.ms
                    elif boom is None:
                        boom = InjectedFault(point, "raise", occ, key)
                    break  # one firing per rule per wave
        if sleep_ms:
            time.sleep(sleep_ms / 1000.0)
        if boom is not None:
            raise boom

    def filter_bytes(self, point: str, data: bytes, key=None) -> bytes:
        """Pass ``data`` through ``corrupt``/``torn`` rules at ``point``:
        corrupt flips deterministically-chosen bytes, torn truncates at a
        deterministic cut (torn-write simulation). Returns the possibly
        mangled bytes; loaders must degrade typed (ValueError /
        BinaryProtocolError), never crash or hang."""
        with self._lock:
            occ = self._occ[point] = self._occ.get(point, 0) + 1
            for rule in self.rules:
                if rule.point != point or rule.mode not in ("corrupt",
                                                            "torn"):
                    continue
                if not rule.wants(occ, key, None):
                    continue
                token = key if key is not None else occ
                if not self._decide(rule, token):
                    continue
                if not data:
                    continue
                self._record(rule, occ, key)
                h = zlib.crc32(f"{self.seed}:{point}:pos:{token}".encode())
                if rule.mode == "torn":
                    data = data[:h % len(data)]
                else:
                    buf = bytearray(data)
                    for i in range(3):
                        buf[(h + 7919 * i) % len(buf)] ^= 0xFF
                    data = bytes(buf)
        return data

    def report(self) -> dict:
        with self._lock:
            return {"seed": self.seed,
                    "rules": len(self.rules),
                    "checks": dict(self._occ),
                    "fired": [f.as_dict() for f in self.fired]}


# ---------------------------------------------------------------------------
# module-level plan (same fast-path discipline as repro.obs.tracer._GLOBAL:
# every hook below is a global load + None test when no plan is installed)
# ---------------------------------------------------------------------------


def plan_from_env(env=None) -> FaultPlan | None:
    spec = (os.environ if env is None else env).get("REPRO_FAULTS", "")
    return FaultPlan.from_spec(spec) if spec.strip() else None


_PLAN: FaultPlan | None = plan_from_env()


def get_plan() -> FaultPlan | None:
    return _PLAN


def set_plan(plan: FaultPlan | None) -> FaultPlan | None:
    """Install ``plan`` (or ``None`` to disable); returns the previous
    plan so tests can restore it."""
    global _PLAN
    prev = _PLAN
    _PLAN = plan
    return prev


def active() -> bool:
    return _PLAN is not None


def check(point: str, key=None, backend=None) -> None:
    p = _PLAN
    if p is None:
        return
    p.check(point, key=key, backend=backend)


def check_wave(point: str, keys, backend=None) -> None:
    p = _PLAN
    if p is None:
        return
    p.check_wave(point, keys, backend=backend)


def filter_bytes(point: str, data: bytes, key=None) -> bytes:
    p = _PLAN
    if p is None:
        return data
    return p.filter_bytes(point, data, key=key)

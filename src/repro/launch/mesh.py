"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — required because the
dry-run overrides the host device count while tests must see 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over the locally available devices (tests / smoke runs)."""
    n = data * model
    assert n <= len(jax.devices()), (
        f"need {n} devices, have {len(jax.devices())}")
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

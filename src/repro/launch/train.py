"""Training driver: data pipeline → jitted train step → checkpoints →
fault-tolerance hooks. Works on a single CPU device (smoke configs) and on
the production mesh unchanged (mesh/axes are injected).

CLI (examples/train_100m.py wraps this):
    PYTHONPATH=src python -m repro.launch.train --arch smollm_360m \
        --smoke --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs.base import ShapeSpec, load_config
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models import model as MF
from repro.models.sharding import SINGLE
from repro.optim import adamw
from repro.runtime.fault_tolerance import StepTimer, StragglerDetector
from repro.train.train_loop import make_train_step


def train(cfg, shape: ShapeSpec, *, steps: int, opt_cfg=None, mesh=None,
          ckpt_dir=None, ckpt_interval: int = 100, microbatches: int = 1,
          log_every: int = 10, resume: bool = True, seed: int = 0,
          log_fn=print):
    opt_cfg = opt_cfg or adamw.AdamWConfig(total_steps=steps)
    axes = MF.axes_for(cfg, shape, mesh) if mesh is not None else SINGLE
    model = MF.build_model(cfg, axes, mesh)
    params = model.init(jax.random.PRNGKey(seed))
    opt_state = adamw.init_state(params)
    step_fn = make_train_step(model, opt_cfg, microbatches)
    if mesh is not None:
        p_sh = MF.to_shardings(mesh, MF.param_pspecs(params, cfg))
        o_sh = adamw.AdamWState(
            MF.to_shardings(mesh, jax.sharding.PartitionSpec()),
            MF.to_shardings(mesh, MF.param_pspecs(opt_state.mu, cfg)),
            MF.to_shardings(mesh, MF.param_pspecs(opt_state.nu, cfg)))
        params = jax.device_put(params, p_sh)
        opt_state = jax.device_put(opt_state, o_sh)
        step_fn = jax.jit(step_fn, in_shardings=(p_sh, o_sh, None),
                          donate_argnums=(0, 1))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    data = SyntheticTokens(cfg, shape, DataConfig(
        seed=seed, vocab_size=min(cfg.vocab_size, 512)))
    mgr = None
    start_step = 0
    if ckpt_dir is not None:
        mgr = CheckpointManager(ckpt_dir, interval=ckpt_interval)
        if resume:
            got = mgr.restore_latest((params, opt_state))
            if got is not None:
                start_step, (params, opt_state), _ = got
                log_fn(f"[train] resumed from step {start_step}")

    detector = StragglerDetector()
    losses = []
    for step in range(start_step, steps):
        batch = data.batch_at(step)
        with StepTimer(detector):
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (step + 1) % log_every == 0 or step == steps - 1:
            loss = float(metrics["loss"])
            losses.append((step + 1, loss))
            gn = float(metrics.get("grad_norm", np.nan))
            log_fn(f"[train] step {step + 1}/{steps} loss={loss:.4f} "
                   f"gnorm={gn:.3f} ewma={detector.median():.3f}s")
        if mgr is not None:
            mgr.maybe_save(step + 1, (params, opt_state),
                           metadata={"arch": cfg.name})
    if mgr is not None:
        mgr.maybe_save(steps, (params, opt_state), force=True,
                       metadata={"arch": cfg.name})
        mgr.wait()
    return params, opt_state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    cfg = load_config(args.arch, smoke=args.smoke)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    t0 = time.time()
    _, _, losses = train(
        cfg, shape, steps=args.steps,
        opt_cfg=adamw.AdamWConfig(lr=args.lr, warmup_steps=20,
                                  total_steps=args.steps),
        ckpt_dir=args.ckpt_dir, microbatches=args.microbatches)
    print(f"[train] done in {time.time() - t0:.1f}s; "
          f"loss {losses[0][1]:.3f} -> {losses[-1][1]:.3f}")


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two XLA_FLAGS lines above MUST stay the first statements of this module
(before any jax import) — jax locks the device count at first init. Do not
set this flag globally: tests and benchmarks must see 1 device.

Per cell this produces a JSON record under experiments/dryrun/ with:
  * memory_analysis (proves the program fits per-device HBM),
  * cost_analysis (HLO FLOPs / bytes for the roofline),
  * parsed collective statistics (wire bytes per collective kind),
  * compile/lower wall times.

Variants:
  memory — scans kept (fast compile), microbatched train step; used for the
           HBM-fit proof and for the multi-pod sharding-coherence pass.
  cost   — all scans unrolled, microbatches=1; exact HLO op counts for the
           roofline (XLA cost_analysis counts while-bodies once; verified).
"""  # noqa: E402

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs.base import SHAPES, load_config, runnable_cells
from repro.launch.mesh import make_production_mesh

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def build_cell(arch: str, shape_name: str, mesh, variant: str,
               extra_cfg: dict | None = None, alias_out: bool = False):
    """Returns (fn, example_args, in_shardings, out_shardings, donate).

    ``alias_out``: pin out_shardings to the input shardings for the donated
    arguments (params/opt state for train, decode state for serve) so XLA
    can alias the buffers — without this the decode caches are double-
    buffered (measured: phi3-mini decode_32k temp 16.6 GB -> exceeds HBM).
    """
    from repro.models import model as MF
    from repro.optim import adamw
    from repro.train.serve import make_serve_step
    from repro.train.train_loop import make_train_step

    shape = SHAPES[shape_name]
    cfg = load_config(arch)
    if variant == "cost":
        cfg = cfg.replace(unroll_scans=True)
    if extra_cfg:
        cfg = cfg.replace(**extra_cfg)
    axes = MF.axes_for(cfg, shape, mesh)
    model = MF.build_model(cfg, axes, mesh)

    params = MF.abstract_params(model)
    p_sh = MF.to_shardings(mesh, MF.param_pspecs(params, cfg))
    inputs = MF.input_specs(cfg, shape)
    in_sh = MF.to_shardings(mesh, MF.input_pspecs(cfg, shape, axes))

    if shape.kind == "train":
        micro = 1 if variant == "cost" else getattr(cfg, "train_microbatches", 4)
        opt = jax.eval_shape(adamw.init_state, params)
        o_sh = adamw.AdamWState(
            MF.to_shardings(mesh, jax.tree.map(lambda _: jax.sharding.PartitionSpec(), opt.step)),
            MF.to_shardings(mesh, MF.param_pspecs(opt.mu, cfg)),
            MF.to_shardings(mesh, MF.param_pspecs(opt.nu, cfg)))
        step_fn = make_train_step(model, adamw.AdamWConfig(), micro)
        out_sh = (p_sh, o_sh, None) if alias_out else None
        return (step_fn, (params, opt, inputs), (p_sh, o_sh, in_sh), out_sh,
                (0, 1))

    if shape.kind == "prefill":
        return (model.prefill, (params, inputs), (p_sh, in_sh), None, ())

    # decode: one new token against a cache of seq_len
    state = model.decode_state_specs(shape.global_batch, shape.seq_len)
    s_sh = MF.to_shardings(mesh, MF.state_pspecs(state, axes))
    serve = make_serve_step(model)

    def serve_fn(params, state, tokens):
        return serve(params, state, tokens, None)

    out_sh = (None, None, s_sh) if alias_out else None
    return (serve_fn, (params, state, inputs["tokens"]),
            (p_sh, s_sh, in_sh["tokens"]), out_sh, (1,))


def run_cell(arch: str, shape_name: str, multi_pod: bool, variant: str,
             out_dir: Path = OUT_DIR, extra_cfg: dict | None = None,
             tag: str = "", alias_out: bool = False) -> dict:
    from repro.analysis.hlo_stats import parse_collectives

    mesh_name = "multi" if multi_pod else "single"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "variant": variant, "tag": tag, "ok": False}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        fn, args, in_sh, out_sh, donate = build_cell(
            arch, shape_name, mesh, variant, extra_cfg, alias_out)
        with jax.set_mesh(mesh), jax.transfer_guard("disallow"):
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate or None)
            t1 = time.time()
            lowered = jitted.lower(*args)
            rec["lower_s"] = time.time() - t1
            t2 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = time.time() - t2
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(ma, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes")
            if hasattr(ma, k)
        }
        ca = compiled.cost_analysis() or {}
        rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                if isinstance(v, (int, float))}
        hlo = compiled.as_text()
        rec["collectives"] = parse_collectives(hlo).to_dict()
        rec["hlo_bytes"] = len(hlo)
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record failures per cell
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = time.time() - t0
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{arch}__{shape_name}__{mesh_name}__{variant}"
    if tag:
        name += f"__{tag}"
    (out_dir / f"{name}.json").write_text(json.dumps(rec, indent=1))
    status = "OK" if rec["ok"] else f"FAIL: {rec.get('error')}"
    print(f"[dryrun] {name}: {status} ({rec['total_s']:.1f}s)", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--variant", choices=["memory", "cost"], default="memory")
    ap.add_argument("--all", action="store_true",
                    help="run every runnable cell")
    ap.add_argument("--tag", default="", help="suffix for experiment files")
    ap.add_argument("--cfg", default="",
                    help="comma k=v ModelConfig overrides (perf experiments)")
    ap.add_argument("--alias-out", action="store_true",
                    help="pin out_shardings for donated args (buffer alias)")
    args = ap.parse_args()

    extra = {}
    for kv in args.cfg.split(","):
        if "=" in kv:
            k, v = kv.split("=", 1)
            try:
                v = json.loads(v)
            except json.JSONDecodeError:
                pass
            extra[k] = v

    cells = (runnable_cells() if args.all
             else [(args.arch, args.shape)])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    n_fail = 0
    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(arch, shape, mp, args.variant,
                           extra_cfg=extra or None, tag=args.tag,
                           alias_out=args.alias_out)
            n_fail += 0 if rec["ok"] else 1
    print(f"[dryrun] done, failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()

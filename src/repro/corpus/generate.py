"""Seeded, stratified basic-block generation.

Blocks are drawn per uarch from the variants that uarch actually
implements (``UArch.behaviors`` ∩ ISA, minus the paper-§8 exclusions) and
stratified into families chosen to stress different predictor terms:

* ``dep_chain`` — one serial dependency chain threaded through every
  instruction (latency-bound regime; register pool kept small so chains
  collide),
* ``port_pressure`` — independent instructions all drawn from one
  port-signature group of the uarch (port-bound regime; the narrower the
  signature, the hotter the contention),
* ``mixed`` — uniform sampling with random registers (the
  anything-can-happen regime the service sees),
* ``divider`` — divider-heavy blocks with ``!high`` operand-class hints
  mixed in (non-pipelined occupancy + value-dependent latency, §5.2.5),
* ``idiom`` — zero idioms and elimination-candidate moves woven into a
  chain (dependency-breaking detection, §7.3.6).

Everything is driven by one :class:`random.Random` seeded from a string
derived from ``(spec.seed, uarch)`` — Python seeds strings through
SHA-512, so the same spec yields byte-identical corpora on any host.
"""
from __future__ import annotations

import random
from dataclasses import dataclass

from repro.core.isa import FLAGS, GPR, IMM, ISA, MEM, TEST_ISA, VEC
from repro.core.simulator import Instr
from repro.core.uarch import SIM_UARCHES
from repro.obs import tracer as obs
from repro.service.protocol import format_block

FAMILIES = ("dep_chain", "port_pressure", "mixed", "divider", "idiom")

#: architectural register pools (the simulator's namespace, same as
#: repro.service.workload)
_POOLS = {
    GPR: [f"R{i}" for i in range(16)],
    VEC: [f"X{i}" for i in range(16)],
    MEM: [f"RB{i}" for i in range(8)],
}
#: small pools force chains/collisions in the dependency-heavy families
_TIGHT_POOLS = {
    GPR: [f"R{i}" for i in range(6)],
    VEC: [f"X{i}" for i in range(6)],
    MEM: [f"RB{i}" for i in range(4)],
}


@dataclass(frozen=True)
class CorpusSpec:
    """Everything that determines a corpus, and nothing else — the spec is
    embedded in the manifest, and (spec, ISA) → corpus is a pure
    function."""
    seed: int = 0
    blocks_per_uarch: int = 10_000
    uarches: tuple = tuple(sorted(SIM_UARCHES))
    min_len: int = 2
    max_len: int = 12
    shard_size: int = 2048
    family_mix: tuple = tuple((f, 1.0) for f in FAMILIES)

    def as_dict(self) -> dict:
        return {"seed": self.seed,
                "blocks_per_uarch": self.blocks_per_uarch,
                "uarches": list(self.uarches),
                "min_len": self.min_len, "max_len": self.max_len,
                "shard_size": self.shard_size,
                "family_mix": {f: w for f, w in self.family_mix}}


def _supported(spec) -> bool:
    return not (spec.system or spec.serializing or spec.control_flow
                or spec.is_nop)


def variant_pool(uarch_name: str, isa: ISA) -> list[str]:
    """Variant names this uarch implements and the tool characterizes."""
    ua = SIM_UARCHES[uarch_name]
    return sorted(n for n in isa.names()
                  if n in ua.behaviors and _supported(isa[n]))


def _regs_for(spec, rng: random.Random, pools) -> dict[str, str]:
    return {o.name: rng.choice(pools[o.otype])
            for o in spec.explicit_operands
            if o.otype not in (IMM, FLAGS)}


def _chainable(spec):
    """The operand a dependency chain can thread through: prefer a
    read+written register operand, else any read one."""
    rw = [o for o in spec.explicit_operands
          if o.otype in (GPR, VEC) and o.read and o.written]
    if rw:
        return rw[0]
    r = [o for o in spec.explicit_operands
         if o.otype in (GPR, VEC) and o.read]
    return r[0] if r else None


def _written(spec):
    for o in spec.explicit_operands:
        if o.otype in (GPR, VEC) and o.written:
            return o
    return None


def _gen_dep_chain(isa, pool, rng, length):
    names = [n for n in pool if _chainable(isa[n]) is not None]
    prev = {GPR: "R0", VEC: "X0"}
    code = []
    for _ in range(length):
        spec = isa[rng.choice(names)]
        link = _chainable(spec)
        regs = _regs_for(spec, rng, _TIGHT_POOLS)
        regs[link.name] = prev[link.otype]
        code.append(Instr(spec.name, regs, "low"))
        out = _written(spec)
        if out is not None:
            prev[out.otype] = regs.get(out.name, prev[out.otype])
    return code


def _port_sig(uarch, name) -> frozenset:
    return frozenset(p for u in uarch.behaviors[name].uops for p in u.ports)


def _gen_port_pressure(isa, pool, rng, length, uarch):
    """Independent instructions from one port-signature group: the wave
    lands entirely on a narrow port set, so the port bound dominates."""
    groups: dict[frozenset, list[str]] = {}
    for n in pool:
        groups.setdefault(_port_sig(uarch, n), []).append(n)
    # favor narrow signatures (hotter contention), but keep it random
    sigs = sorted(groups, key=lambda s: (len(s), sorted(s)))
    sig = sigs[min(int(rng.expovariate(0.7)), len(sigs) - 1)]
    names = groups[sig]
    code = []
    for i in range(length):
        spec = isa[rng.choice(names)]
        regs = {}
        for j, o in enumerate(spec.explicit_operands):
            if o.otype in (IMM, FLAGS):
                continue
            p = _POOLS[o.otype]
            # distinct destinations per lane, sources rotated off them:
            # no chains, pure throughput
            regs[o.name] = p[(2 * i + j) % len(p)]
        code.append(Instr(spec.name, regs, "low"))
    return code


def _gen_mixed(isa, pool, rng, length):
    code = []
    for _ in range(length):
        spec = isa[rng.choice(pool)]
        hint = ("high" if spec.uses_divider and rng.random() < 0.3
                else "low")
        code.append(Instr(spec.name, _regs_for(spec, rng, _POOLS), hint))
    return code


def _gen_divider(isa, pool, rng, length):
    divs = [n for n in pool if isa[n].uses_divider]
    if not divs:
        return _gen_mixed(isa, pool, rng, length)
    code = []
    for _ in range(length):
        if rng.random() < 0.6:
            spec = isa[rng.choice(divs)]
            hint = "high" if rng.random() < 0.5 else "low"
        else:
            spec = isa[rng.choice(pool)]
            hint = "low"
        code.append(Instr(spec.name, _regs_for(spec, rng, _TIGHT_POOLS),
                          hint))
    return code


def _gen_idiom(isa, pool, rng, length):
    """Zero idioms (same source and dest register) and elimination
    candidates inside a chain: the predictor only gets these right if the
    model captured the dependency-breaking behavior."""
    idioms = [n for n in pool if isa[n].zero_idiom]
    moves = [n for n in pool if isa[n].may_eliminate]
    if not idioms and not moves:
        return _gen_dep_chain(isa, pool, rng, length)
    code = _gen_dep_chain(isa, pool, rng, length)
    for i in range(len(code)):
        roll = rng.random()
        if idioms and roll < 0.3:
            spec = isa[rng.choice(idioms)]
            reg = rng.choice(_TIGHT_POOLS[
                spec.explicit_operands[0].otype])
            regs = {o.name: reg for o in spec.explicit_operands
                    if o.otype not in (IMM, FLAGS)}
            code[i] = Instr(spec.name, regs, "low")
        elif moves and roll < 0.5:
            spec = isa[rng.choice(moves)]
            code[i] = Instr(spec.name,
                            _regs_for(spec, rng, _TIGHT_POOLS), "low")
    return code


_GENERATORS = {
    "dep_chain": lambda isa, pool, rng, length, ua: _gen_dep_chain(
        isa, pool, rng, length),
    "port_pressure": _gen_port_pressure,
    "mixed": lambda isa, pool, rng, length, ua: _gen_mixed(
        isa, pool, rng, length),
    "divider": lambda isa, pool, rng, length, ua: _gen_divider(
        isa, pool, rng, length),
    "idiom": lambda isa, pool, rng, length, ua: _gen_idiom(
        isa, pool, rng, length),
}


def generate_blocks(uarch_name: str, spec: CorpusSpec,
                    isa: ISA | None = None) -> list[dict]:
    """All of one uarch's corpus records, in deterministic order. Each
    record is ``{"id", "uarch", "family", "block"}`` with the block in the
    textual format (``repro.service.protocol.parse_block`` inverts it)."""
    isa = isa if isa is not None else TEST_ISA
    ua = SIM_UARCHES[uarch_name]
    pool = variant_pool(uarch_name, isa)
    if not pool:
        raise ValueError(f"uarch {uarch_name!r} implements no ISA variant")
    # string seeding goes through SHA-512: stable across hosts/processes
    rng = random.Random(f"repro-corpus/{spec.seed}/{uarch_name}")
    fams = [f for f, _ in spec.family_mix]
    weights = [w for _, w in spec.family_mix]
    unknown = set(fams) - set(FAMILIES)
    if unknown:
        raise ValueError(f"unknown corpus families {sorted(unknown)}")
    records = []
    with obs.span("corpus.generate", uarch=uarch_name,
                  blocks=spec.blocks_per_uarch):
        for i in range(spec.blocks_per_uarch):
            fam = rng.choices(fams, weights)[0]
            length = rng.randint(spec.min_len, spec.max_len)
            code = _GENERATORS[fam](isa, pool, rng, length, ua)
            records.append({"id": f"{uarch_name}-{i:06d}",
                            "uarch": uarch_name, "family": fam,
                            "block": format_block(code)})
    return records


def generate_corpus(out_dir, spec: CorpusSpec | None = None,
                    isa: ISA | None = None) -> dict:
    """Generate and persist the full corpus; returns the manifest."""
    from repro.corpus.store import write_corpus  # noqa: PLC0415

    spec = spec if spec is not None else CorpusSpec()
    by_uarch = {ua: generate_blocks(ua, spec, isa) for ua in spec.uarches}
    return write_corpus(out_dir, by_uarch, spec)

"""BHive-style basic-block corpus: generation, ground truth, scoring.

The paper validates its inferred models instruction-by-instruction; the
tools it enables (uiCA, PALMED — see PAPERS.md) are judged on large
basic-block *corpora* with MAPE and Kendall-τ per microarchitecture. This
package is that workload, end to end:

* :mod:`repro.corpus.generate` — a seeded, stratified block generator
  (dependency-chain-heavy, port-pressure-heavy, mixed, divider-heavy and
  elimination/zero-idiom families, sampled per uarch from the variants the
  uarch actually implements). Deterministic: one seed → byte-identical
  corpus.
* :mod:`repro.corpus.store` — the sharded JSONL corpus format plus a
  content-addressed ``manifest.json`` (per-shard sha256, corpus id over
  the shard hashes) so any consumer can verify what it is reading.
* :mod:`repro.corpus.evaluate` — the ground-truth driver: corpus shards
  stream through ``BatchPredictor.simulate_batch`` as fused mega-waves
  (shards are packed until the wave-width target is met, the engine cache
  dedups across shards), with per-shard result files written atomically so
  a killed run resumes warm.
* :mod:`repro.corpus.score` — per-uarch MAPE, Kendall-τ (tau-b, exact)
  and relative-error bucket drill-downs of the closed-form predictor
  against the simulator ground truth.
* :mod:`repro.corpus.jit_ops` — the real-JAX jitted-op corpus (matmul
  tiles, elementwise, reductions, fused layers) that the hardware backend
  characterizes; folded in from the old ``repro.ops.corpus`` stub so
  "corpus" means one thing in the tree.

``python -m repro.corpus generate|evaluate|report`` drives the pipeline
from the command line; ``scripts/analyze.py --corpus-report`` renders the
accuracy artifact. The service's bulk ``predict_corpus`` op (see
``repro.service``) streams per-shard closed-form predictions so scoring
can run against a live server — byte-identical to the in-process path.
"""
from repro.corpus.evaluate import client_predict_fn, evaluate_corpus
from repro.corpus.generate import (FAMILIES, CorpusSpec, generate_blocks,
                                   generate_corpus)
from repro.corpus.score import (error_buckets, format_report, kendall_tau,
                                mape, score_pairs, score_results)
from repro.corpus.store import (corpus_id, iter_shard_blocks, load_manifest,
                                shard_records, write_corpus)

__all__ = [
    "FAMILIES", "CorpusSpec", "generate_blocks", "generate_corpus",
    "build_jit_corpus", "client_predict_fn", "error_buckets",
    "evaluate_corpus", "format_report",
    "kendall_tau", "mape", "score_pairs", "score_results", "corpus_id",
    "iter_shard_blocks", "load_manifest", "shard_records", "write_corpus",
]


def __getattr__(name):
    # the jitted-op corpus drags the jax import along — load it lazily so
    # block-corpus users (service, tests, CLI) stay light
    if name == "build_jit_corpus":
        from repro.corpus.jit_ops import build_jit_corpus
        return build_jit_corpus
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

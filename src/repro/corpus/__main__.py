"""Corpus pipeline CLI.

Usage (from the repo root)::

    PYTHONPATH=src python -m repro.corpus generate --out experiments/corpus \\
        --seed 0 --blocks 10000
    PYTHONPATH=src python -m repro.corpus evaluate --corpus experiments/corpus \\
        --wave-width 2048 --accuracy experiments/corpus_accuracy.json
    PYTHONPATH=src python -m repro.corpus report experiments/corpus_accuracy.json

``generate`` is deterministic under a seed; ``evaluate`` resumes per
shard (kill it, rerun it, finished shards are skipped); ``report``
renders the accuracy artifact (``scripts/analyze.py --corpus-report``
prints the same tables).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.corpus",
                                 description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    g = sub.add_parser("generate", help="generate a seeded corpus")
    g.add_argument("--out", default="experiments/corpus")
    g.add_argument("--seed", type=int, default=0)
    g.add_argument("--blocks", type=int, default=10_000,
                   help="blocks per uarch (default 10000)")
    g.add_argument("--uarch", action="append",
                   help="restrict to these uarches (repeatable)")
    g.add_argument("--shard-size", type=int, default=2048)
    g.add_argument("--min-len", type=int, default=2)
    g.add_argument("--max-len", type=int, default=12)

    e = sub.add_parser("evaluate", help="mega-wave ground truth + scoring")
    e.add_argument("--corpus", default="experiments/corpus")
    e.add_argument("--uarch", action="append")
    e.add_argument("--backend", default=None,
                   help="wave backend (default: REPRO_SIM_BACKEND)")
    e.add_argument("--wave-width", type=int, default=2048)
    e.add_argument("--no-resume", action="store_true",
                   help="ignore per-shard result files")
    e.add_argument("--accuracy", default="experiments/corpus_accuracy.json",
                   help="where to write the accuracy artifact")

    r = sub.add_parser("report", help="render an accuracy artifact")
    r.add_argument("accuracy", help="corpus_accuracy.json path")
    r.add_argument("--json", action="store_true", dest="as_json")

    args = ap.parse_args(argv)
    if args.cmd == "generate":
        from repro.corpus import CorpusSpec, generate_corpus
        from repro.core.uarch import SIM_UARCHES

        uarches = tuple(sorted(args.uarch or SIM_UARCHES))
        spec = CorpusSpec(seed=args.seed, blocks_per_uarch=args.blocks,
                          uarches=uarches, shard_size=args.shard_size,
                          min_len=args.min_len, max_len=args.max_len)
        manifest = generate_corpus(args.out, spec)
        print(f"corpus {manifest['corpus_id'][:12]}: "
              f"{manifest['total_blocks']} blocks in "
              f"{len(manifest['shards'])} shards -> {args.out}")
        return 0
    if args.cmd == "evaluate":
        from repro.corpus import evaluate_corpus, format_report, score_results

        results = evaluate_corpus(args.corpus, uarches=args.uarch,
                                  backend=args.backend,
                                  wave_width=args.wave_width,
                                  resume=not args.no_resume)
        report = score_results(results)
        out = Path(args.accuracy)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(report, sort_keys=True, indent=1))
        print(format_report(report))
        print(f"\naccuracy artifact -> {out}")
        return 0
    # report
    report = json.loads(Path(args.accuracy).read_text())
    if args.as_json:
        print(json.dumps(report, sort_keys=True, indent=1))
    else:
        from repro.corpus import format_report

        print(format_report(report))
    return 0


if __name__ == "__main__":
    sys.exit(main())

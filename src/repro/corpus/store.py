"""Sharded JSONL corpus storage with a content-addressed manifest.

Layout under a corpus directory::

    manifest.json                     # spec + per-shard sha256 + corpus id
    shards/<uarch>-00000.jsonl        # one JSON record per line
    shards/<uarch>-00001.jsonl
    ...

Every shard line is ``{"block", "family", "id", "uarch"}`` serialized with
sorted keys and compact separators, so a shard's bytes are a pure function
of its records. The manifest carries each shard's sha256 and a corpus id
(sha256 over the ordered shard hashes): two generation runs agree iff
their manifests are byte-identical, and an evaluator can verify a shard
before trusting cached results for it. Writes are atomic
(tmp + ``os.replace``, the checkpoint module's convention) so a killed
generation never leaves a torn shard behind.
"""
from __future__ import annotations

import hashlib
import json
import os
from pathlib import Path

MANIFEST = "manifest.json"
SHARD_DIR = "shards"
MANIFEST_VERSION = 1


def _dumps(obj) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def shard_records(records: list[dict]) -> bytes:
    """Canonical shard bytes for a record list."""
    return "".join(_dumps(r) + "\n" for r in records).encode()


def _atomic_write(path: Path, data: bytes) -> None:
    """tmp + rename; the ``corpus.shard_write`` injection point lives on
    the tmp-file bytes, so an injected torn write is caught by the
    manifest hash check (``read_shard``) instead of silently trusted."""
    from repro.faults import plan as faults  # noqa: PLC0415
    if faults.active():
        faults.check("corpus.shard_write", key=path.name)
        data = faults.filter_bytes("corpus.shard_write", data,
                                   key=path.name)
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_bytes(data)
    os.replace(tmp, path)


def corpus_id(shard_hashes: list[str]) -> str:
    h = hashlib.sha256()
    for s in shard_hashes:
        h.update(s.encode())
    return h.hexdigest()


def write_corpus(out_dir, by_uarch: dict, spec) -> dict:
    """Persist per-uarch record lists as fixed-size shards + manifest.
    Returns the manifest dict (what ``load_manifest`` reads back)."""
    out = Path(out_dir)
    (out / SHARD_DIR).mkdir(parents=True, exist_ok=True)
    shards = []
    for uarch in sorted(by_uarch):
        records = by_uarch[uarch]
        for si in range(0, max(1, len(records)), spec.shard_size):
            chunk = records[si:si + spec.shard_size]
            name = f"{uarch}-{si // spec.shard_size:05d}.jsonl"
            data = shard_records(chunk)
            _atomic_write(out / SHARD_DIR / name, data)
            fams: dict[str, int] = {}
            for r in chunk:
                fams[r["family"]] = fams.get(r["family"], 0) + 1
            shards.append({"name": name, "uarch": uarch,
                           "n_blocks": len(chunk), "families": fams,
                           "sha256": hashlib.sha256(data).hexdigest()})
    manifest = {"version": MANIFEST_VERSION, "spec": spec.as_dict(),
                "shards": shards,
                "total_blocks": sum(s["n_blocks"] for s in shards),
                "corpus_id": corpus_id([s["sha256"] for s in shards])}
    _atomic_write(out / MANIFEST,
                  json.dumps(manifest, sort_keys=True, indent=1).encode())
    return manifest


def load_manifest(corpus_dir) -> dict:
    path = Path(corpus_dir) / MANIFEST
    if not path.exists():
        raise FileNotFoundError(f"no corpus manifest at {path} — run "
                                f"python -m repro.corpus generate first")
    return json.loads(path.read_text())


def read_shard(corpus_dir, shard: dict, *, verify: bool = True) -> list[dict]:
    """One shard's records; with ``verify`` the bytes are checked against
    the manifest hash (a mismatch means the corpus was edited or torn)."""
    data = (Path(corpus_dir) / SHARD_DIR / shard["name"]).read_bytes()
    if verify:
        got = hashlib.sha256(data).hexdigest()
        if got != shard["sha256"]:
            raise ValueError(f"shard {shard['name']} content hash {got[:12]} "
                             f"does not match manifest "
                             f"{shard['sha256'][:12]}")
    return [json.loads(line) for line in data.splitlines() if line]


def iter_shard_blocks(corpus_dir, shard: dict, *, verify: bool = True):
    """Yield ``(record, parsed block)`` pairs for one shard."""
    from repro.service.protocol import parse_block  # noqa: PLC0415

    for rec in read_shard(corpus_dir, shard, verify=verify):
        yield rec, parse_block(rec["block"])

"""Ground truth at corpus scale: fused mega-waves with per-shard resume.

For each uarch in the corpus manifest this driver

1. builds the simulated machine (``REPRO_SIM_BACKEND`` selects the wave
   backend, ``devices`` the mesh placement) and characterizes exactly the
   variants the corpus uses — through the *same* measurement engine the
   ground-truth waves run on, so characterization experiments and corpus
   blocks share one content-addressed cache;
2. packs pending shards into **mega-waves**: shards accumulate until the
   wave-width target (default 2048 blocks) is met, then one
   ``BatchPredictor.simulate_batch`` call measures the whole wave — the
   engine dedups across shards and the batched backend executes the miss
   set device-resident, which is precisely the regime the bucketed
   kernels and the lowering cache were built for;
3. writes one result file per shard (atomic tmp+rename, keyed by the
   shard's manifest sha256) so a killed run resumes warm: shards with a
   matching result file are skipped entirely, and re-executed blocks hit
   the engine cache.

The returned results dict feeds :func:`repro.corpus.score.score_results`;
``wave_stats``/``engine_stats`` carry the fused-wave telemetry
(``max_wave_width`` is the acceptance probe that mega-waves actually
formed). Observability: ``corpus.evaluate`` → ``corpus.uarch`` →
``corpus.wave`` spans thread through generation → simulate → score when
``REPRO_TRACE=1``.
"""
from __future__ import annotations

import json
import os
import warnings
from pathlib import Path

from repro.core.characterize import characterize
from repro.faults import plan as faults
from repro.faults.plan import InjectedFault
from repro.core.engine import as_engine
from repro.core.isa import TEST_ISA
from repro.core.simulator import SimMachine
from repro.core.uarch import SIM_UARCHES
from repro.obs import tracer as obs
from repro.service.batch_predictor import BatchPredictor
from repro.corpus.store import load_manifest, read_shard

RESULT_DIR = "results"


def _result_path(results_dir: Path, shard: dict) -> Path:
    return results_dir / (shard["name"] + ".json")


def _load_resumed(results_dir: Path, shard: dict):
    """Previously-written rows for this shard, or None if absent/stale."""
    path = _result_path(results_dir, shard)
    if not path.exists():
        return None
    try:
        rec = json.loads(path.read_text())
    except ValueError:
        return None  # torn write from a kill without the atomic rename
    if rec.get("sha256") != shard["sha256"]:
        return None  # corpus regenerated under the results dir
    return rec["rows"]


def _write_rows(results_dir: Path, shard: dict, rows: list) -> None:
    """Atomic per-shard result write.  Failures — including injected
    ``corpus.shard_write`` faults — degrade to a warning: the rows are
    already in memory for scoring, so evaluation completes and only warm
    resume for this shard is lost; a *torn* write (injected or from a
    kill) is rejected by ``_load_resumed`` on the next run."""
    path = _result_path(results_dir, shard)
    data = json.dumps({"shard": shard["name"],
                       "sha256": shard["sha256"], "rows": rows},
                      sort_keys=True, separators=(",", ":")).encode()
    try:
        if faults.active():
            faults.check("corpus.shard_write",
                         key=f"rows:{shard['name']}")
            data = faults.filter_bytes("corpus.shard_write", data,
                                       key=f"rows:{shard['name']}")
        tmp = path.with_name(path.name + ".tmp")
        tmp.write_bytes(data)
        os.replace(tmp, path)
    except (OSError, InjectedFault) as e:
        warnings.warn(f"result write failed for shard {shard['name']} "
                      f"({type(e).__name__}: {e}); rows kept in memory, "
                      "resume for this shard is cold", stacklevel=2)


def _used_variants(shard_blocks) -> list[str]:
    return sorted({ins.spec for _, code in shard_blocks for ins in code})


class _WaveStats:
    def __init__(self):
        self.widths: list[int] = []

    def add(self, width: int) -> None:
        self.widths.append(width)

    def as_dict(self) -> dict:
        w = self.widths
        return {"waves": len(w), "blocks": sum(w),
                "mean_wave_width": round(sum(w) / max(1, len(w)), 2),
                "max_wave_width": max(w, default=0)}


def evaluate_corpus(corpus_dir, *, uarches=None, isa=None,
                    backend: str | None = None, devices=None,
                    wave_width: int = 2048, out_dir=None,
                    resume: bool = True, models: dict | None = None,
                    predict_fn=None, kernel_lock=None) -> dict:
    """Evaluate a generated corpus end to end; returns the results dict
    consumed by :func:`repro.corpus.score.score_results`.

    ``models`` optionally maps uarch name → :class:`PerfModel` (skip the
    in-driver characterization); ``predict_fn(uarch, blocks) -> cycles``
    overrides the in-process closed-form predictions — the served-corpus
    path passes a ``ServiceClient``-backed callable here, and the scores
    must come out byte-identical."""
    isa = isa if isa is not None else TEST_ISA
    manifest = load_manifest(corpus_dir)
    results_dir = Path(out_dir if out_dir is not None
                       else Path(corpus_dir) / RESULT_DIR)
    results_dir.mkdir(parents=True, exist_ok=True)
    wanted = set(uarches) if uarches is not None else None
    by_uarch: dict[str, list[dict]] = {}
    waves = _WaveStats()
    agg_engine: dict[str, int] = {}
    with obs.span("corpus.evaluate", corpus=manifest["corpus_id"][:12],
                  shards=len(manifest["shards"])):
        for ua in sorted({s["uarch"] for s in manifest["shards"]}):
            if wanted is not None and ua not in wanted:
                continue
            shards = [s for s in manifest["shards"] if s["uarch"] == ua]
            by_uarch[ua] = _evaluate_uarch(
                corpus_dir, ua, shards, isa, backend, devices, wave_width,
                results_dir, resume, (models or {}).get(ua), predict_fn,
                kernel_lock, waves, agg_engine)
    return {"corpus_id": manifest["corpus_id"], "spec": manifest["spec"],
            "uarches": by_uarch, "wave_stats": waves.as_dict(),
            "engine_stats": agg_engine}


def _evaluate_uarch(corpus_dir, ua, shards, isa, backend, devices,
                    wave_width, results_dir, resume, model, predict_fn,
                    kernel_lock, waves, agg_engine) -> list[dict]:
    from repro.service.protocol import parse_block  # noqa: PLC0415

    rows_by_shard: dict[str, list] = {}
    pending = []  # (shard, records, blocks) awaiting ground truth
    for shard in shards:
        cached = _load_resumed(results_dir, shard) if resume else None
        if cached is not None:
            rows_by_shard[shard["name"]] = cached
            continue
        records = read_shard(corpus_dir, shard)
        blocks = [parse_block(r["block"]) for r in records]
        pending.append((shard, records, blocks))
    if pending:
        machine = SimMachine(SIM_UARCHES[ua], isa, backend=backend,
                             devices=devices)
        engine = as_engine(machine)
        with obs.span("corpus.uarch", uarch=ua, shards=len(pending)):
            if model is None:
                used = sorted({ins.spec for _, _, blocks in pending
                               for code in blocks for ins in code})
                # same engine as the ground-truth waves: the cache is
                # shared, so characterization experiments never rerun
                model = characterize(engine, isa, used)
            bp = BatchPredictor(model, isa, machine=machine)
            stats0 = {k: v for k, v in engine.stats.as_dict().items()
                      if isinstance(v, (int, float)) and k != "hit_rate"}
            _run_waves(ua, bp, pending, wave_width, predict_fn,
                       kernel_lock, results_dir, rows_by_shard, waves)
            for k, v0 in stats0.items():
                d = engine.stats.as_dict()[k] - v0
                agg_engine[k] = agg_engine.get(k, 0) + d
    # submission order == manifest order, resumed or not
    return [row for shard in shards for row in rows_by_shard[shard["name"]]]


def _run_waves(ua, bp, pending, wave_width, predict_fn, kernel_lock,
               results_dir, rows_by_shard, waves) -> None:
    """Pack pending shards into ≥wave_width fused waves, measure + predict
    each wave once, then split results back per shard and persist."""
    group: list = []
    n_blocks = 0
    for item in pending:
        group.append(item)
        n_blocks += len(item[2])
        if n_blocks >= wave_width:
            _flush(ua, bp, group, predict_fn, kernel_lock, results_dir,
                   rows_by_shard, waves)
            group, n_blocks = [], 0
    if group:
        _flush(ua, bp, group, predict_fn, kernel_lock, results_dir,
               rows_by_shard, waves)


def client_predict_fn(client, *, shard_size: int = 512,
                      budget_us: float | None = None):
    """Adapt a :class:`repro.service.client.ServiceClient` into the
    ``predict_fn(uarch, blocks) -> cycles`` hook of
    :func:`evaluate_corpus`: each wave is cut into ``shard_size`` shards
    and pushed through the streaming bulk ``predict_corpus`` op, so
    corpus scoring runs against a live server — and, because the server
    answers from the same closed-form predictor, comes out byte-identical
    to the in-process path. A shed or failed shard raises (typed
    ``Overloaded``/``ServiceError``): corpus scoring needs every block."""
    from repro.service.client import ServiceError  # noqa: PLC0415
    from repro.service.protocol import format_block  # noqa: PLC0415

    def predict(uarch: str, blocks) -> list[float]:
        texts = [format_block(code) for code in blocks]
        shards = [texts[i:i + shard_size]
                  for i in range(0, len(texts), shard_size)]
        per_shard, _summary = client.predict_corpus(uarch, shards,
                                                    budget_us=budget_us)
        cycles: list[float] = []
        for envs in per_shard:
            for env in envs:
                if not env.get("ok", True):
                    err = env.get("error") or {}
                    if err.get("type") == "Overloaded":
                        from repro.service.client import (  # noqa: PLC0415
                            ServiceOverloaded)
                        raise ServiceOverloaded(err)
                    raise ServiceError(err)
                cycles.append(float(env["result"]["cycles"]))
        return cycles

    return predict


def _flush(ua, bp, group, predict_fn, kernel_lock, results_dir,
           rows_by_shard, waves) -> None:
    blocks = [code for _, _, shard_blocks in group for code in shard_blocks]
    waves.add(len(blocks))
    with obs.span("corpus.wave", uarch=ua, wave=len(blocks),
                  shards=len(group)):
        measured = bp.simulate_batch(blocks, kernel_lock=kernel_lock)
        if predict_fn is not None:
            predicted = list(predict_fn(ua, blocks))
        else:
            predicted = [p.cycles for p in bp.predict_batch(blocks)]
    if len(predicted) != len(blocks):
        raise ValueError(f"predict_fn returned {len(predicted)} cycles "
                         f"for a {len(blocks)}-block wave")
    off = 0
    for shard, records, shard_blocks in group:
        n = len(shard_blocks)
        rows = [{"id": r["id"], "family": r["family"], "block": r["block"],
                 "predicted": float(p), "measured": float(m)}
                for r, p, m in zip(records, predicted[off:off + n],
                                   measured[off:off + n])]
        off += n
        rows_by_shard[shard["name"]] = rows
        _write_rows(results_dir, shard, rows)

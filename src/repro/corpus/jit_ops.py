"""The real-JAX op corpus: jitted ops the hardware backend characterizes.

Folded in from the old ``repro.ops.corpus`` stub (which now re-exports
from here) so "corpus" lives in one package: this is the
hardware-instruction-set analogue of the basic-block corpus — a set of
jitted ops (matmul tiles, elementwise, reductions, layout ops, fused
layers) with analytic FLOP counts, consumed by ``bench_hardware_corpus``
and the hardware characterization path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def build_jit_corpus(sizes=(128, 256, 512)) -> dict:
    """name -> (shape-preserving fn, example arg, flops per application)."""
    corpus = {}
    for n in sizes:
        x = jnp.ones((n, n), jnp.float32) * 0.5

        def mm(v):
            return (v @ v) * (1.0 / n)  # normalized to stay finite

        corpus[f"matmul_{n}x{n}_f32"] = (mm, x, 2.0 * n * n * n)
        xb = x.astype(jnp.bfloat16)
        corpus[f"matmul_{n}x{n}_bf16"] = (mm, xb, 2.0 * n * n * n)
    v = jnp.linspace(0.1, 1.0, 1 << 16)
    corpus["add_vec_64k"] = (lambda t: t + 1.5, v, 1 << 16)
    corpus["mul_vec_64k"] = (lambda t: t * 1.0001, v, 1 << 16)
    corpus["fma_vec_64k"] = (lambda t: t * 0.999 + 0.01, v, 2 << 16)
    corpus["exp_vec_64k"] = (lambda t: jnp.exp(t) * 0.3, v, 1 << 16)
    corpus["rsqrt_vec_64k"] = (lambda t: jax.lax.rsqrt(t + 1.0), v, 1 << 16)
    m = jnp.ones((256, 256), jnp.float32)
    corpus["transpose_256"] = (lambda t: t.T + 0.0, m, 0.0)
    corpus["reduce_sum_256"] = (
        lambda t: t + jnp.sum(t, axis=1, keepdims=True) * 1e-6, m,
        256 * 256)
    corpus["softmax_256"] = (lambda t: jax.nn.softmax(t, axis=-1) + t * 0.5,
                             m, 5 * 256 * 256)
    idx = jnp.arange(256) % 128

    def gather_op(t):
        return t.at[idx].get() * 0.5 + t * 0.5

    corpus["gather_256"] = (gather_op, m, 0.0)
    w = jnp.ones((256,), jnp.float32)

    def rmsnorm_op(t):
        var = jnp.mean(t * t, axis=-1, keepdims=True)
        return t * jax.lax.rsqrt(var + 1e-5) * w

    corpus["rmsnorm_256"] = (rmsnorm_op, m, 4 * 256 * 256)
    return corpus


#: legacy name, kept for the repro.ops.corpus re-export
build_corpus = build_jit_corpus

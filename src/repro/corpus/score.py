"""Corpus accuracy scoring: MAPE, Kendall-τ and error buckets per uarch.

The quantities the downstream-tool literature reports (uiCA, PALMED,
BHive — see PAPERS.md): mean absolute percentage error of predicted vs
measured cycles, rank correlation (Kendall τ-b, the tie-aware variant —
exact, computed in chunked numpy so 10k-block corpora stay cheap), and a
relative-error histogram with per-family drill-downs plus the worst
offenders (the blocks a fidelity PR should look at first).

Everything here is deterministic and timestamp-free: scoring the same
results twice yields byte-identical JSON, which is what the CI
determinism gate and the served-vs-in-process byte-identity check rely
on.
"""
from __future__ import annotations

import numpy as np

#: relative-error histogram edges (fractions) and their report labels
BUCKET_EDGES = (0.01, 0.05, 0.10, 0.25)
BUCKET_LABELS = ("<1%", "1-5%", "5-10%", "10-25%", ">25%")


def mape(pred, true) -> float:
    """Mean absolute percentage error, skipping zero-measured entries."""
    p = np.asarray(pred, dtype=np.float64)
    t = np.asarray(true, dtype=np.float64)
    if p.shape != t.shape:
        raise ValueError(f"shape mismatch {p.shape} vs {t.shape}")
    ok = t != 0
    if not ok.any():
        return 0.0
    return float(np.mean(np.abs(p[ok] - t[ok]) / np.abs(t[ok])))


def kendall_tau(a, b, *, chunk: int = 256) -> float:
    """Exact Kendall τ-b (tie-aware) of two equal-length sequences.

    Chunked O(n²) in numpy: pairwise sign agreement is computed a few
    hundred rows at a time against the full vector, so memory stays at
    ``chunk × n`` while 10k-element corpora take seconds, not minutes."""
    x = np.asarray(a, dtype=np.float64)
    y = np.asarray(b, dtype=np.float64)
    if x.shape != y.shape:
        raise ValueError(f"shape mismatch {x.shape} vs {y.shape}")
    n = x.size
    if n < 2:
        return 1.0
    nc = nd = 0
    for i0 in range(0, n - 1, chunk):
        i1 = min(i0 + chunk, n - 1)
        rows = np.arange(i0, i1)
        dx = np.sign(x[None, :] - x[rows, None])
        dy = np.sign(y[None, :] - y[rows, None])
        upper = np.arange(n)[None, :] > rows[:, None]  # pairs with j > i
        s = dx * dy
        nc += int(np.count_nonzero((s > 0) & upper))
        nd += int(np.count_nonzero((s < 0) & upper))
    n0 = n * (n - 1) // 2

    def tie_term(v) -> int:
        _, counts = np.unique(v, return_counts=True)
        return int(np.sum(counts * (counts - 1) // 2))

    n1, n2 = tie_term(x), tie_term(y)
    denom = np.sqrt(float(n0 - n1) * float(n0 - n2))
    if denom == 0:
        return 1.0 if nc == nd else 0.0
    return float((nc - nd) / denom)


def error_buckets(pred, true) -> dict:
    """Relative-error histogram: label -> count (zero-measured entries are
    counted in the widest bucket only if the prediction is also
    nonzero)."""
    p = np.asarray(pred, dtype=np.float64)
    t = np.asarray(true, dtype=np.float64)
    rel = np.where(t != 0, np.abs(p - t) / np.maximum(np.abs(t), 1e-300),
                   np.where(p != 0, np.inf, 0.0))
    idx = np.searchsorted(np.asarray(BUCKET_EDGES), rel, side="right")
    return {lab: int(np.count_nonzero(idx == k))
            for k, lab in enumerate(BUCKET_LABELS)}


def score_pairs(pred, true, *, families=None, records=None,
                worst_k: int = 10) -> dict:
    """Full score dict for one uarch's (predicted, measured) pairs.

    ``families`` (one label per pair) adds the per-family drill-down;
    ``records`` (the corpus records, same order) adds the worst-offender
    list."""
    p = np.asarray(pred, dtype=np.float64)
    t = np.asarray(true, dtype=np.float64)
    out = {"n": int(p.size),
           "mape": round(mape(p, t), 6),
           "kendall_tau": round(kendall_tau(p, t), 6),
           "buckets": error_buckets(p, t)}
    if families is not None:
        fams: dict[str, dict] = {}
        labels = np.asarray(families)
        for fam in sorted(set(families)):
            m = labels == fam
            fams[fam] = {"n": int(np.count_nonzero(m)),
                         "mape": round(mape(p[m], t[m]), 6),
                         "kendall_tau": round(kendall_tau(p[m], t[m]), 6)}
        out["families"] = fams
    if records is not None and p.size:
        rel = np.where(t != 0, np.abs(p - t) / np.maximum(np.abs(t), 1e-300),
                       0.0)
        order = np.argsort(-rel, kind="stable")[:worst_k]
        out["worst"] = [
            {"id": records[i]["id"], "family": records[i]["family"],
             "block": records[i]["block"],
             "predicted": float(p[i]), "measured": float(t[i]),
             "rel_err": round(float(rel[i]), 6)} for i in order]
    return out


def score_results(results: dict, *, worst_k: int = 10) -> dict:
    """Score an evaluation-results dict (see ``repro.corpus.evaluate``)
    into the accuracy artifact: per-uarch scores + the corpus identity it
    was computed over. Purely a function of the results — no timestamps,
    no paths — so equal inputs give byte-identical JSON."""
    out = {"corpus_id": results["corpus_id"], "spec": results["spec"],
           "uarches": {}}
    for ua in sorted(results["uarches"]):
        rows = results["uarches"][ua]
        out["uarches"][ua] = score_pairs(
            [r["predicted"] for r in rows], [r["measured"] for r in rows],
            families=[r["family"] for r in rows], records=rows,
            worst_k=worst_k)
    if "wave_stats" in results:
        out["wave_stats"] = results["wave_stats"]
    if "engine_stats" in results:
        out["engine_stats"] = results["engine_stats"]
    return out


def format_report(report: dict) -> str:
    """Human-readable accuracy tables for an artifact from
    :func:`score_results` (what ``scripts/analyze.py --corpus-report``
    prints)."""
    lines = [f"corpus {report['corpus_id'][:12]} — "
             f"{sum(u['n'] for u in report['uarches'].values())} blocks "
             f"across {len(report['uarches'])} uarches"]
    hdr = (f"{'uarch':<10} {'n':>7} {'MAPE':>8} {'tau':>7}  "
           + "  ".join(f"{lab:>7}" for lab in BUCKET_LABELS))
    lines += ["", hdr, "-" * len(hdr)]
    for ua, sc in sorted(report["uarches"].items()):
        buck = "  ".join(f"{sc['buckets'][lab]:>7}" for lab in BUCKET_LABELS)
        lines.append(f"{ua:<10} {sc['n']:>7} {sc['mape'] * 100:>7.2f}% "
                     f"{sc['kendall_tau']:>7.4f}  {buck}")
    for ua, sc in sorted(report["uarches"].items()):
        fams = sc.get("families")
        if not fams:
            continue
        lines += ["", f"{ua} by family:"]
        for fam, fsc in sorted(fams.items()):
            lines.append(f"  {fam:<14} n={fsc['n']:<6} "
                         f"MAPE={fsc['mape'] * 100:6.2f}%  "
                         f"tau={fsc['kendall_tau']:.4f}")
        worst = sc.get("worst") or []
        if worst:
            lines.append("  worst: " + ", ".join(
                f"{w['id']} ({w['rel_err'] * 100:.1f}%)"
                for w in worst[:5]))
    ws = report.get("wave_stats")
    if ws:
        lines += ["", f"waves: {ws.get('waves')} fused, "
                      f"mean width {ws.get('mean_wave_width')}, "
                      f"max width {ws.get('max_wave_width')}"]
    return "\n".join(lines)

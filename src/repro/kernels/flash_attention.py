"""Flash attention (causal/bidirectional, GQA) as a Pallas TPU kernel.

TPU-native design (not a CUDA port): the grid is (batch·q_heads, q_blocks,
k_blocks) with the k dimension innermost — Pallas TPU executes the grid
sequentially per core, so the online-softmax statistics (m, l) and the
output accumulator live in VMEM scratch that persists across the k-block
iterations. Block shapes keep the MXU busy ((bq, d) x (d, bk) contractions
with d, bq, bk multiples of the 128-lane systolic width at production
sizes) and the working set in VMEM: q block + k/v blocks + accumulator
≈ (bq + 2·bk + bq)·d·4B ≪ VMEM.

Validated against ``ref.reference_attention`` in interpret mode (CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq: int, bk: int, nk: int, scale: float, causal: bool):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: skip blocks strictly above the diagonal
    compute = (qi + 1) * bq > kj * bk if causal else True

    @pl.when(compute)
    def _block():
        q = q_ref[0].astype(jnp.float32)           # (bq, d)
        k = k_ref[0].astype(jnp.float32)           # (bk, d)
        v = v_ref[0].astype(jnp.float32)           # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_scr[...] = l_prev * alpha + jnp.sum(p, axis=1)
        m_scr[...] = m_cur
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(kj == nk - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] /
                    jnp.where(l == 0, 1.0, l)[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Sk, D) with Hq % Hkv == 0.
    Returns (B, Hq, Sq, D)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    nq, nk = Sq // bq, Sk // bk
    qr = q.reshape(B * Hq, Sq, D)
    kr = k.reshape(B * Hkv, Sk, D)
    vr = v.reshape(B * Hkv, Sk, D)

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, nk=nk,
                               scale=D**-0.5, causal=causal)
    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, D),
                         lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bk, D),
                         lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, Hq, Sq, D)

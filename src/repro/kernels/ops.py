"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (Mosaic only lowers for real TPUs) and
False on TPU — the switch the model stack uses when ``cfg.attn_impl ==
"pallas"``. Flash attention gets a custom VJP whose backward pass is the
chunked XLA recomputation (fused forward + XLA backward is a standard
production pattern; a fused Pallas backward is a further optimization).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm_kernel
from repro.kernels.ssd_scan import ssd_scan as _ssd_kernel


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    return flash_attention_fwd(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k,
                               interpret=default_interpret())


def _fa_fwd(q, k, v, causal, block_q, block_k):
    o = flash_attention(q, k, v, causal, block_q, block_k)
    return o, (q, k, v)


def _fa_bwd(causal, block_q, block_k, res, g):
    q, k, v = res
    # XLA-recomputed backward through the reference (flash-equivalent math)
    _, vjp = jax.vjp(lambda q_, k_, v_:
                     ref.reference_attention(q_, k_, v_, causal=causal),
                     q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


def ssd_scan(x, dt, A, B, C, chunk: int = 256):
    return _ssd_kernel(x, dt, A, B, C, chunk, interpret=default_interpret())


def rmsnorm(x, w, eps: float = 1e-5):
    return _rmsnorm_kernel(x, w, eps, interpret=default_interpret())

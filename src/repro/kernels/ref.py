"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def reference_attention(q, k, v, *, causal: bool = True):
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Sk, D). fp32 softmax."""
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D**-0.5)
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask, s, -jnp.inf)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", a, v.astype(jnp.float32))
    return o.astype(q.dtype)


def reference_ssd(x, dt, A, B, C, chunk: int):
    """Delegates to the model-level chunked SSD (itself covered by decode-
    equivalence tests): x (b,s,h,p), dt (b,s,h), A (h,), B/C (b,s,g,n)."""
    from repro.models.mamba import ssd_chunked

    y, state = ssd_chunked(x.astype(jnp.float32), dt.astype(jnp.float32),
                           A.astype(jnp.float32), B.astype(jnp.float32),
                           C.astype(jnp.float32), chunk)
    return y.astype(x.dtype), state


def reference_ssd_sequential(x, dt, A, B, C):
    """Independent O(s·n·p) recurrent oracle (no chunking at all)."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    xf = x.astype(jnp.float32)

    def step(state, t):
        dA = jnp.exp(dtf[:, t] * A)  # (b, h)
        upd = jnp.einsum("bhp,bhn->bhpn", xf[:, t] * dtf[:, t][..., None],
                         Bh[:, t])
        state = state * dA[..., None, None] + upd
        y = jnp.einsum("bhpn,bhn->bhp", state, Ch[:, t])
        return state, y

    state0 = jnp.zeros((b, h, p, n), jnp.float32)
    state, ys = jax.lax.scan(step, state0, jnp.arange(s))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state


def reference_rmsnorm(x, w, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)

"""Fused RMSNorm Pallas kernel: one pass over rows, statistics in fp32.

Grid over row blocks; each program normalizes (block_rows, d) in VMEM. The
fusion saves one HBM round trip versus unfused mean-square + scale (the
memory-bound regime the roofline analysis flags for norm layers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x, w, eps: float = 1e-5, *, block_rows: int = 256,
            interpret: bool = False):
    """x: (..., d); w: (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    xr = x.reshape(-1, d)
    rows = xr.shape[0]
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(xr.shape[0] // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xr.shape, x.dtype),
        interpret=interpret,
    )(xr, w)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)

"""Mamba2 SSD (state-space duality) chunk scan as a Pallas TPU kernel.

Grid: (batch, heads, chunks) with the chunk dimension innermost; the SSM
state (headdim × dstate) persists in VMEM scratch across chunk iterations —
the TPU-idiomatic replacement for the CUDA kernel's cross-block shared-memory
recurrence. Within a chunk everything is matrix work for the MXU:

    y_diag = ((C Bᵀ) ⊙ L) X̄          (Q×N)(N×Q)->(Q×Q) then (Q×Q)(Q×P)
    y_off  = (C ⊙ decay_out) stateᵀ   (Q×N)(N×P)
    state' = decay_chunk·state + (B ⊙ decay_in)ᵀ X̄

Validated against ``ref.reference_ssd`` (and models/mamba.ssd_chunked) in
interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref,
                state_scr, *, nc: int):
    cj = pl.program_id(2)

    @pl.when(cj == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)      # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)    # (Q,)
    a = a_ref[0]                             # scalar A (negative)
    bm = b_ref[0, 0].astype(jnp.float32)     # (Q, N)
    cm = c_ref[0, 0].astype(jnp.float32)     # (Q, N)

    xbar = x * dt[:, None]
    dA = dt * a
    cum = jnp.cumsum(dA)
    # intra-chunk decay matrix L[i,j] = exp(cum_i - cum_j) for i >= j
    seg = cum[:, None] - cum[None, :]
    Q = x.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y_diag = jax.lax.dot_general(scores * L, xbar, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    state = state_scr[...]                   # (P, N)
    decay_out = jnp.exp(cum)                 # (Q,)
    y_off = jax.lax.dot_general(cm * decay_out[:, None], state,
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0, 0] = (y_diag + y_off).astype(y_ref.dtype)

    decay_in = jnp.exp(cum[-1] - cum)        # (Q,)
    upd = jax.lax.dot_general(xbar, bm * decay_in[:, None],
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    state_scr[...] = state * jnp.exp(cum[-1]) + upd

    @pl.when(cj == nc - 1)
    def _emit_state():
        st_ref[0, 0] = state_scr[...].astype(st_ref.dtype)


def ssd_scan(x, dt, A, B, C, chunk: int, *, interpret: bool = False):
    """x: (b, s, h, p); dt: (b, s, h) (post-softplus); A: (h,) negative;
    B, C: (b, s, g, n). Returns (y: (b, s, h, p), state: (b, h, p, n)).
    Groups are broadcast to heads via the BlockSpec index map."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    q = min(chunk, s)
    assert s % q == 0
    nc = s // q
    rep = h // g
    xt = jnp.moveaxis(x, 2, 1)               # (b, h, s, p)
    dtt = jnp.moveaxis(dt, 2, 1)             # (b, h, s)
    Bt = jnp.moveaxis(B, 2, 1)               # (b, g, s, n)
    Ct = jnp.moveaxis(C, 2, 1)

    kernel = functools.partial(_ssd_kernel, nc=nc)
    y, st = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, q, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, q), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, 1, q, n),
                         lambda bi, hi, ci, r=rep: (bi, hi // r, ci, 0)),
            pl.BlockSpec((1, 1, q, n),
                         lambda bi, hi, ci, r=rep: (bi, hi // r, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A.astype(jnp.float32), Bt, Ct)
    return jnp.moveaxis(y, 1, 2), st

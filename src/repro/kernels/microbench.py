"""Unit-blocking microbenchmark kernels — the TPU-native incarnation of the
paper's *blocking instructions* (§5.1.1).

On x86 a blocking instruction saturates one execution-port combination. The
TPU analogue is a Pallas kernel that saturates one functional-unit class:

    mxu_blocker   back-to-back 128×128 matmuls            -> MXU
    vpu_blocker   long elementwise FMA chains             -> VPU
    sfu_blocker   transcendental chains (exp/rsqrt)       -> VPU-transcendental
    lsu_blocker   streaming copy with trivial compute     -> LSU (HBM DMA)

``core/kernel_bench.py`` co-schedules a target kernel with each blocker and
attributes unit occupancy from the contention signature (the counter-free
variant of Algorithm 1: t(A‖B) ≈ max vs ≈ sum).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128


def _mxu_kernel(a_ref, b_ref, o_ref, *, iters: int):
    a = a_ref[...]
    b = b_ref[...]

    def body(_, acc):
        return jax.lax.dot_general(acc, b, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)

    o_ref[...] = jax.lax.fori_loop(0, iters, body, a)


def mxu_blocker(iters: int = 64, tile: int = TILE, *, interpret: bool = False):
    a = jnp.eye(tile, dtype=jnp.float32) * 1.0001
    return pl.pallas_call(
        functools.partial(_mxu_kernel, iters=iters),
        in_specs=[pl.BlockSpec((tile, tile), lambda: (0, 0))] * 2,
        out_specs=pl.BlockSpec((tile, tile), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((tile, tile), jnp.float32),
        interpret=interpret,
    )(a, a)


def _vpu_kernel(x_ref, o_ref, *, iters: int):
    x = x_ref[...]

    def body(_, acc):
        return acc * 1.000001 + 0.5

    o_ref[...] = jax.lax.fori_loop(0, iters, body, x)


def vpu_blocker(iters: int = 256, rows: int = 8, *, interpret: bool = False):
    x = jnp.ones((rows, TILE), jnp.float32)
    return pl.pallas_call(
        functools.partial(_vpu_kernel, iters=iters),
        in_specs=[pl.BlockSpec((rows, TILE), lambda: (0, 0))],
        out_specs=pl.BlockSpec((rows, TILE), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, TILE), jnp.float32),
        interpret=interpret,
    )(x)


def _sfu_kernel(x_ref, o_ref, *, iters: int):
    x = x_ref[...]

    def body(_, acc):
        return jax.lax.rsqrt(acc + 1.5)

    o_ref[...] = jax.lax.fori_loop(0, iters, body, x)


def sfu_blocker(iters: int = 128, rows: int = 8, *, interpret: bool = False):
    x = jnp.ones((rows, TILE), jnp.float32)
    return pl.pallas_call(
        functools.partial(_sfu_kernel, iters=iters),
        in_specs=[pl.BlockSpec((rows, TILE), lambda: (0, 0))],
        out_specs=pl.BlockSpec((rows, TILE), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, TILE), jnp.float32),
        interpret=interpret,
    )(x)


def _lsu_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] + 1.0


def lsu_blocker(rows: int = 4096, *, interpret: bool = False):
    """Streaming copy: bandwidth-bound, near-zero arithmetic intensity."""
    x = jnp.zeros((rows, TILE), jnp.float32)
    br = min(512, rows)
    return pl.pallas_call(
        _lsu_kernel,
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, TILE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, TILE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, TILE), jnp.float32),
        interpret=interpret,
    )(x)


BLOCKERS = {
    "MXU": mxu_blocker,
    "VPU": vpu_blocker,
    "SFU": sfu_blocker,
    "LSU": lsu_blocker,
}

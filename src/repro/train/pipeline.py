"""GPipe-style pipeline parallelism over a mesh axis (``pod`` by default).

``pipeline_apply`` runs a homogeneous layer stack split into S stages across
the axis: microbatches stream through stages with ``ppermute`` handoffs; the
bubble is the standard (S-1)/(S-1+M) fraction. Params come stacked as
(S, layers_per_stage, ...); inside shard_map each device holds one stage.

This is the composable PP building block (optional — the default multi-pod
config uses the pod axis for hierarchical data parallelism, DESIGN.md §6).
Correctness is asserted against the sequential stack in tests (multi-device
subprocess) for arbitrary microbatch counts.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_apply(stage_fn, stacked_params, xs, mesh, axis: str = "pod",
                   batch_axes: tuple = ()):
    """stage_fn(stage_params, x) -> x, applied as an S-stage pipeline.

    stacked_params leaves: (S, ...) — stage s uses leaf[s].
    xs: (n_micro, B, ...) microbatched inputs (replicated over ``axis``,
    batch possibly sharded over ``batch_axes``).
    Returns (n_micro, B, ...) outputs (replicated over ``axis``).
    """
    S = mesh.shape[axis]

    def local(params, xs_loc):
        params = jax.tree.map(lambda a: a[0], params)  # this stage's slice
        stage = jax.lax.axis_index(axis)
        n_micro = xs_loc.shape[0]
        T = n_micro + S - 1
        perm = [(i, i + 1) for i in range(S - 1)]

        def body(t, carry):
            recv, out = carry
            first = xs_loc[jnp.minimum(t, n_micro - 1)]
            inp = jnp.where(stage == 0, first, recv)
            y = stage_fn(params, inp)
            nxt = jax.lax.ppermute(y, axis, perm)
            widx = t - (S - 1)
            upd = jax.lax.dynamic_update_index_in_dim(
                out, y, jnp.maximum(widx, 0), 0)
            out = jnp.where((stage == S - 1) & (widx >= 0), upd, out)
            return (nxt, out)

        recv0 = jnp.zeros_like(xs_loc[0])
        out0 = jnp.zeros_like(xs_loc)
        _, out = jax.lax.fori_loop(0, T, body, (recv0, out0))
        # broadcast the last stage's outputs to every stage's copy
        out = jax.lax.psum(
            jnp.where(stage == S - 1, out, jnp.zeros_like(out)), axis)
        return out

    bspec = batch_axes if batch_axes else None
    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P(None, bspec)),
        out_specs=P(None, bspec),
        check_vma=False,
    )
    return fn(stacked_params, xs)


def split_stages(stacked_layers, n_stages: int):
    """(L, ...) stacked layer params -> (S, L/S, ...) stage-stacked."""
    def re(a):
        L = a.shape[0]
        assert L % n_stages == 0, f"{L} layers across {n_stages} stages"
        return a.reshape(n_stages, L // n_stages, *a.shape[1:])

    return jax.tree.map(re, stacked_layers)

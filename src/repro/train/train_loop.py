"""Training step factory: value_and_grad + microbatch gradient accumulation
+ AdamW, as a single jittable function.

Gradient accumulation (scan over microbatches) bounds per-layer activation
memory: at minitron-8b train_4k on the single-pod mesh, full-batch remat
residuals are ~16 GB/device (doesn't fit v5e HBM); 4 microbatches bring it
to ~4 GB. Collectives stay O(1) per step (grads reduced once).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.optim import adamw


def make_train_step(model, opt_cfg: adamw.AdamWConfig,
                    num_microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). ``batch`` leaves have leading dim global_batch; it must be
    divisible by num_microbatches."""

    def loss_fn(p, mb):
        return model.loss(p, mb)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if num_microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            n = num_microbatches

            def split(x):
                b = x.shape[0]
                assert b % n == 0, f"batch {b} % microbatches {n} != 0"
                return jnp.moveaxis(
                    x.reshape(b // n, n, *x.shape[1:]), 1, 0)

            mbs = jax.tree.map(split, batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)

            def body(carry, mb):
                acc, lsum = carry
                (l, _), g = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), acc, g)
                return (acc, lsum + l), None

            (grads, lsum), _ = jax.lax.scan(body, (g0, jnp.float32(0)), mbs)
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = lsum / n
            metrics = {"ce": loss}
        params, opt_state, om = adamw.apply_update(params, grads, opt_state,
                                                   opt_cfg)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics.update(om)
        return params, opt_state, metrics

    return train_step

"""Serving: prefill + single-token decode step + sampling.

``serve_step`` is the function lowered for the ``decode_*`` / ``long_*``
dry-run cells: one new token against a KV cache (or SSM state) of
``seq_len``. The decode state is donated so cache updates are in-place.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_serve_step(model, sample: str = "greedy", temperature: float = 1.0):
    """serve_step(params, state, tokens, rng) -> (next_tokens, logits, state).

    ``tokens``: (B,) int32 current tokens; state from prefill or
    decode_state_specs.
    """

    def serve_step(params, state, tokens, rng):
        logits, state = model.decode_step(params, state, tokens)
        if sample == "greedy":
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
            nxt = nxt.astype(jnp.int32)
        return nxt, logits, state

    return serve_step


def make_prefill(model):
    def prefill(params, batch):
        return model.prefill(params, batch)

    return prefill


def generate(model, params, batch, steps: int, rng=None, temperature=0.0):
    """Eager helper: prefill then decode ``steps`` tokens (small-scale use)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    serve_step = jax.jit(make_serve_step(
        model, "greedy" if temperature == 0 else "categorical", temperature))
    S = batch["tokens"].shape[1]
    logits, state = jax.jit(
        lambda p, b: model.prefill(p, b, pad_to=S + steps + 8))(params, batch)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    for _ in range(steps - 1):
        rng, k = jax.random.split(rng)
        tok, logits, state = serve_step(params, state, tok, k)
        out.append(tok)
    return jnp.stack(out, axis=1)  # (B, steps)

"""Gradient compression with error feedback for the cross-pod all-reduce.

At multi-pod scale the pod axis rides the slow DCN link, so the cross-pod
gradient reduction is the wire-dominant collective. ``compressed_psum``
performs int8 block-quantized summation over a mesh axis inside shard_map:
the int8 payload (plus one fp32 scale per block) cuts wire bytes ~3.6×
versus fp32. ``ErrorFeedback`` keeps the quantization residual and re-adds
it next step (EF-SGD/1-bit-Adam style), which restores convergence.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

BLOCK = 256


def quantize_int8(x, block: int = BLOCK):
    """Per-block symmetric int8 quantization. x: any shape -> (q, scales,
    meta) with q int8 of x.size padded to block multiple."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blk = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blk), axis=1, keepdims=True) / 127.0
    safe = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blk / safe), -127, 127).astype(jnp.int8)
    return q, scale, (n, x.shape, x.dtype)


def dequantize_int8(q, scale, meta):
    n, shape, dtype = meta
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return flat.reshape(shape).astype(dtype)


def psum_int8(x, axis: str):
    """Drop-in for ``jax.lax.psum`` *inside* shard_map: quantize, all-gather
    the int8 payload (+ fp32 per-block scales) over ``axis``, dequantize and
    sum locally. Wire: ~1.016 B/element vs 4 B for an fp32 ring psum."""
    q, scale, meta = quantize_int8(x)
    qs = jax.lax.all_gather(q, axis)          # (n_axis, blocks, BLOCK) int8
    ss = jax.lax.all_gather(scale, axis)
    total = jnp.sum(qs.astype(jnp.float32) * ss, axis=0)
    n, shape, dtype = meta
    return total.reshape(-1)[:n].reshape(shape).astype(dtype)


def compressed_psum_tree(tree, axis: str):
    """psum_int8 over every leaf of a pytree (inside shard_map)."""
    return jax.tree.map(lambda x: psum_int8(x, axis), tree)


class ErrorFeedback:
    """Residual accumulator: compress(g + e); e' = (g + e) - decompress."""

    @staticmethod
    def init(tree):
        return jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tree)

    @staticmethod
    def apply(grads, residual, compress_fn):
        """Returns (compressed-then-decompressed grads, new residual)."""
        corrected = jax.tree.map(
            lambda g, e: g.astype(jnp.float32) + e, grads, residual)
        rounded = jax.tree.map(
            lambda x: dequantize_int8(*quantize_int8(x)), corrected)
        transmitted = compress_fn(rounded)
        new_resid = jax.tree.map(lambda c, r: c - r, corrected, rounded)
        return transmitted, new_resid


def roundtrip_int8(x):
    """Quantize + dequantize (for tests and the EF convergence check)."""
    return dequantize_int8(*quantize_int8(x))

"""AdamW + global-norm clipping + schedules, pure JAX (no optax).

Optimizer state is a pytree mirroring params (sharded identically), so the
dry-run's in_shardings for (params, opt_state) are derived from one spec.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array  # int32 scalar
    mu: dict
    nu: dict


def init_state(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.int32(0), zeros,
                      jax.tree.map(jnp.copy, zeros))


def schedule(cfg: AdamWConfig, step):
    """Linear warmup then cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def apply_update(params, grads, state: AdamWState, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (delta + decay)
        return newp.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    newp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return newp, AdamWState(step, mu, nu), {"grad_norm": gn, "lr": lr}

"""Machine-readable ISA description (the paper's XED→XML analogue, §6.1).

The paper extracts a machine-readable description of the x86 instruction set
from Intel XED's configuration files because the microbenchmark *generators*
(§5.2) need to know, for every instruction: the explicit and implicit
operands, their types and widths, which are read / written / both, and
special semantics (zero idioms, move elimination candidates, divider usage,
serializing/system instructions, control flow).

Here the same information lives in :class:`InstrSpec` records. The registry
is the single source of truth used by

  * the microbenchmark generators (blocking/latency/throughput),
  * the simulated machine's ground-truth tables (core/uarch.py),
  * the XML/JSON export (core/model_io.py).

Register classes model the structure that drives the paper's case analysis
in §5.2: gpr / vec / flags / mem, plus operand widths (partial-register
handling) and read-modify-write flags (the "both read and written" case).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Iterable


# operand types
GPR = "gpr"
VEC = "vec"
FLAGS = "flags"
MEM = "mem"
IMM = "imm"


@dataclass(frozen=True)
class Operand:
    name: str          # "op1", "op2", "flags", "mem", ...
    otype: str         # gpr | vec | flags | mem | imm
    read: bool
    written: bool
    implicit: bool = False
    width: int = 64

    @property
    def rmw(self) -> bool:
        return self.read and self.written


def op(name, otype, mode, implicit=False, width=64) -> Operand:
    """mode: 'r' | 'w' | 'rw'."""
    return Operand(name, otype, "r" in mode, "w" in mode, implicit, width)


@dataclass(frozen=True)
class InstrSpec:
    name: str                      # unique variant name, e.g. "ADD_R64_R64"
    mnemonic: str
    operands: tuple[Operand, ...]
    uses_divider: bool = False
    serializing: bool = False
    system: bool = False
    control_flow: bool = False
    may_eliminate: bool = False    # reg-reg move elimination candidate
    zero_idiom: bool = False       # same-reg => breaks dependency
    is_nop: bool = False
    extension: str = "BASE"        # BASE | SSE | AVX  (§5.1.1 transition penalties)

    @property
    def sources(self) -> tuple[Operand, ...]:
        return tuple(o for o in self.operands if o.read)

    @property
    def dests(self) -> tuple[Operand, ...]:
        return tuple(o for o in self.operands if o.written)

    @property
    def explicit_operands(self) -> tuple[Operand, ...]:
        return tuple(o for o in self.operands if not o.implicit)

    def reads_flags(self) -> bool:
        return any(o.otype == FLAGS and o.read for o in self.operands)

    def writes_flags(self) -> bool:
        return any(o.otype == FLAGS and o.written for o in self.operands)

    def replace(self, **kw) -> "InstrSpec":
        return replace(self, **kw)


class ISA:
    """A registry of instruction variants (one x86-like μISA instance)."""

    def __init__(self, specs: Iterable[InstrSpec] = ()):  # noqa: D107
        self._specs: dict[str, InstrSpec] = {}
        for s in specs:
            self.add(s)

    def add(self, spec: InstrSpec) -> None:
        if spec.name in self._specs:
            raise ValueError(f"duplicate instruction {spec.name}")
        self._specs[spec.name] = spec

    def __getitem__(self, name: str) -> InstrSpec:
        return self._specs[name]

    def __contains__(self, name: str) -> bool:
        return name in self._specs

    def __iter__(self):
        return iter(self._specs.values())

    def __len__(self) -> int:
        return len(self._specs)

    def names(self) -> list[str]:
        return list(self._specs)


# ---------------------------------------------------------------------------
# the test μISA — an x86-flavored instruction set exercising every structural
# case from §5.2: implicit flags, RMW operands, type-crossing moves, loads,
# stores, dividers, zero idioms, eliminable movs, chain instructions.
# ---------------------------------------------------------------------------

_F = op("flags", FLAGS, "w", implicit=True)
_Frw = op("flags", FLAGS, "rw", implicit=True)
_Fr = op("flags", FLAGS, "r", implicit=True)


def _alu2(name, *, flags="w", zero_idiom=False, ext="BASE"):
    """Two-operand ALU: op1 rw, op2 r, writes (or rw) flags."""
    ops = [op("op1", GPR, "rw"), op("op2", GPR, "r")]
    if flags == "w":
        ops.append(_F)
    elif flags == "rw":
        ops.append(_Frw)
    return InstrSpec(name=f"{name}_R64_R64", mnemonic=name,
                     operands=tuple(ops), zero_idiom=zero_idiom, extension=ext)


def build_test_isa() -> ISA:
    isa = ISA()
    # --- integer ALU ---
    for nm in ("ADD", "SUB", "AND", "OR"):
        isa.add(_alu2(nm))
    isa.add(_alu2("XOR", zero_idiom=True))
    isa.add(_alu2("SUBZ", zero_idiom=True))  # second zero idiom (SUB-like)
    isa.add(_alu2("ADC", flags="rw"))        # reads+writes flags (carry)
    isa.add(_alu2("SBB", flags="rw"))
    isa.add(InstrSpec("CMP_R64_R64", "CMP",
                      (op("op1", GPR, "r"), op("op2", GPR, "r"), _F)))
    isa.add(InstrSpec("TEST_R64_R64", "TEST",
                      (op("op1", GPR, "r"), op("op2", GPR, "r"), _F)))
    isa.add(InstrSpec("INC_R64", "INC", (op("op1", GPR, "rw"), _F)))
    isa.add(InstrSpec("NOT_R64", "NOT", (op("op1", GPR, "rw"),)))
    isa.add(InstrSpec("LEA_R64", "LEA",
                      (op("op1", GPR, "w"), op("op2", GPR, "r"))))
    isa.add(InstrSpec("POPCNT_R64_R64", "POPCNT",
                      (op("op1", GPR, "w"), op("op2", GPR, "r"), _F)))
    isa.add(InstrSpec("BSWAP_R32", "BSWAP", (op("op1", GPR, "rw", width=32),)))
    isa.add(InstrSpec("BSWAP_R64", "BSWAP", (op("op1", GPR, "rw"),)))
    # --- moves ---
    isa.add(InstrSpec("MOV_R64_R64", "MOV",
                      (op("op1", GPR, "w"), op("op2", GPR, "r")),
                      may_eliminate=True))
    isa.add(InstrSpec("MOVSX_R64_R32", "MOVSX",
                      (op("op1", GPR, "w"), op("op2", GPR, "r", width=32))))
    isa.add(InstrSpec("MOVSX_R64_R8", "MOVSX",
                      (op("op1", GPR, "w"), op("op2", GPR, "r", width=8))))
    isa.add(InstrSpec("MOVZX_R64_R16", "MOVZX",
                      (op("op1", GPR, "w"), op("op2", GPR, "r", width=16)),
                      may_eliminate=True))
    # --- shifts / rotates (implicit flags RMW; SHLD same-reg special) ---
    for nm in ("SHL", "SHR", "SAR", "ROL", "ROR"):
        isa.add(InstrSpec(f"{nm}_R64_I8", nm,
                          (op("op1", GPR, "rw"), op("imm", IMM, "r"), _Frw)))
    isa.add(InstrSpec("SHLD_R64_R64_I8", "SHLD",
                      (op("op1", GPR, "rw"), op("op2", GPR, "r"),
                       op("imm", IMM, "r"), _F)))
    # --- multiply / divide ---
    isa.add(InstrSpec("IMUL_R64_R64", "IMUL",
                      (op("op1", GPR, "rw"), op("op2", GPR, "r"), _F)))
    isa.add(InstrSpec("MUL_R64", "MUL",
                      (op("op1", GPR, "rw"), op("op2", GPR, "r"),
                       op("hi", GPR, "w", implicit=True), _F)))
    isa.add(InstrSpec("DIV_R64", "DIV",
                      (op("op1", GPR, "rw"), op("op2", GPR, "r"),
                       op("hi", GPR, "rw", implicit=True), _F),
                      uses_divider=True))
    # --- condition-flag consumers ---
    isa.add(InstrSpec("SETC_R8", "SETC",
                      (op("op1", GPR, "w", width=8), _Fr)))
    isa.add(InstrSpec("CMOVBE_R64_R64", "CMOVBE",
                      (op("op1", GPR, "rw"), op("op2", GPR, "r"), _Fr)))
    isa.add(InstrSpec("CMC", "CMC", (_Frw,)))
    isa.add(InstrSpec("SAHF", "SAHF",
                      (op("op1", GPR, "r", width=8), _F)))
    # --- memory ---
    isa.add(InstrSpec("MOV_R64_M64", "MOV",
                      (op("op1", GPR, "w"), op("mem", MEM, "r"))))
    isa.add(InstrSpec("MOV_M64_R64", "MOV",
                      (op("mem", MEM, "w"), op("op1", GPR, "r"))))
    isa.add(InstrSpec("ADD_R64_M64", "ADD",
                      (op("op1", GPR, "rw"), op("mem", MEM, "r"), _F)))
    isa.add(InstrSpec("IMUL_R64_M64", "IMUL",
                      (op("op1", GPR, "rw"), op("mem", MEM, "r"), _F)))
    # --- vector (SSE-like and AVX-like for the two blocking sets) ---
    for ext, pre in (("SSE", "P"), ("AVX", "VP")):
        isa.add(InstrSpec(f"{pre}ADDD_X_X", f"{pre}ADDD",
                          (op("op1", VEC, "rw"), op("op2", VEC, "r")),
                          extension=ext))
        isa.add(InstrSpec(f"{pre}MULD_X_X", f"{pre}MULD",
                          (op("op1", VEC, "rw"), op("op2", VEC, "r")),
                          extension=ext))
        isa.add(InstrSpec(f"{pre}SHUFB_X_X", f"{pre}SHUFB",
                          (op("op1", VEC, "rw"), op("op2", VEC, "r")),
                          extension=ext))
        isa.add(InstrSpec(f"{pre}AND_X_X", f"{pre}AND",
                          (op("op1", VEC, "rw"), op("op2", VEC, "r")),
                          extension=ext))
        isa.add(InstrSpec(f"{pre}CMPGTQ_X_X", f"{pre}CMPGTQ",
                          (op("op1", VEC, "rw"), op("op2", VEC, "r")),
                          zero_idiom=True, extension=ext))
    isa.add(InstrSpec("SHUFPS_X_X", "SHUFPS",
                      (op("op1", VEC, "rw"), op("op2", VEC, "r")),
                      extension="SSE"))
    # non-destructive shuffles: the §5.2.1 SIMD chain instructions
    isa.add(InstrSpec("PSHUFD_X_X", "PSHUFD",
                      (op("op1", VEC, "w"), op("op2", VEC, "r")),
                      extension="SSE"))
    isa.add(InstrSpec("MOVSHDUP_X_X", "MOVSHDUP",
                      (op("op1", VEC, "w"), op("op2", VEC, "r")),
                      extension="SSE"))
    isa.add(InstrSpec("ADDPS_X_X", "ADDPS",
                      (op("op1", VEC, "rw"), op("op2", VEC, "r")),
                      extension="SSE"))
    isa.add(InstrSpec("MULPS_X_X", "MULPS",
                      (op("op1", VEC, "rw"), op("op2", VEC, "r")),
                      extension="SSE"))
    isa.add(InstrSpec("DIVPS_X_X", "DIVPS",
                      (op("op1", VEC, "rw"), op("op2", VEC, "r")),
                      uses_divider=True, extension="SSE"))
    isa.add(InstrSpec("AESDEC_X_X", "AESDEC",
                      (op("op1", VEC, "rw"), op("op2", VEC, "r")),
                      extension="SSE"))
    isa.add(InstrSpec("AESDEC_X_M", "AESDEC",
                      (op("op1", VEC, "rw"), op("mem", MEM, "r")),
                      extension="SSE"))
    isa.add(InstrSpec("MOVQ2DQ_X_X", "MOVQ2DQ",
                      (op("op1", VEC, "w"), op("op2", VEC, "r")),
                      extension="SSE"))
    isa.add(InstrSpec("MOVAPS_X_X", "MOVAPS",
                      (op("op1", VEC, "w"), op("op2", VEC, "r")),
                      may_eliminate=True, extension="SSE"))
    # --- type-crossing (vec <-> gpr): chain-instruction candidates §5.2.1 ---
    isa.add(InstrSpec("MOVD_R64_X", "MOVD",
                      (op("op1", GPR, "w"), op("op2", VEC, "r")),
                      extension="SSE"))
    isa.add(InstrSpec("MOVD_X_R64", "MOVD",
                      (op("op1", VEC, "w"), op("op2", GPR, "r")),
                      extension="SSE"))
    isa.add(InstrSpec("PEXTRQ_R64_X", "PEXTRQ",
                      (op("op1", GPR, "w"), op("op2", VEC, "r")),
                      extension="SSE"))
    # --- stores with data computation / vector store ---
    isa.add(InstrSpec("MOVAPS_M_X", "MOVAPS",
                      (op("mem", MEM, "w"), op("op1", VEC, "r")),
                      extension="SSE"))
    isa.add(InstrSpec("MOVAPS_X_M", "MOVAPS",
                      (op("op1", VEC, "w"), op("mem", MEM, "r")),
                      extension="SSE"))
    # --- excluded-by-the-algorithm classes (must exist to be excluded) ---
    isa.add(InstrSpec("NOP", "NOP", (), is_nop=True))
    isa.add(InstrSpec("PAUSE", "PAUSE", ()))
    isa.add(InstrSpec("LFENCE", "LFENCE", (), serializing=True))
    isa.add(InstrSpec("CPUID", "CPUID",
                      (op("op1", GPR, "rw", implicit=True),),
                      serializing=True, system=True))
    isa.add(InstrSpec("RDMSR", "RDMSR",
                      (op("op1", GPR, "w", implicit=True),), system=True))
    isa.add(InstrSpec("JMP_R64", "JMP", (op("op1", GPR, "r"),),
                      control_flow=True))
    return isa


TEST_ISA = build_test_isa()

"""Measurement protocol (Algorithm 2) and microbenchmark code generation.

``measure`` implements the paper's overhead-cancellation protocol: run the
benchmark body with n=10 and n=110 copies, difference the counters and divide
by 100. The machine's raw ``run`` includes harness overhead (serializing
instructions, counter reads — emulated by the simulator; real wall-clock
overhead on the hardware backend), so this differencing is doing real work.

``RegPool``/instance builders generate operand assignments with the
independence properties the paper's generators need: distinct registers per
operand, round-robin pools so repeated instances don't chain, and explicit
"avoid" sets so benchmark code never collides with the chain registers.

``run_batch`` is the wave-execution protocol between the measurement layer
and the machines: a machine may expose ``run_batch(codes) ->
list[Counters]`` to execute a whole wave of instruction sequences at once
(the compiled array backend in ``core/batch_sim.py`` — the default path
behind ``SimMachine``), and machines without it are driven by a scalar
per-sequence loop. ``MeasurementEngine.submit`` routes every deduplicated
miss-set through this protocol. Lock-aware machines additionally accept
``run_batch(codes, kernel_lock=...)``: the lock serializes GIL-bound
kernel execution (numpy backend, scalar fallback) while host
lowering/packing overlaps other workers' kernels; device backends ignore
it (their kernels release the GIL) and serialize dispatch on their own
per-device-subset lock instead, so machines placed on disjoint device
subsets overlap (see ``core/device_mesh.py``).
``machine_run_batch`` bridges machines that predate the parameter by
running them entirely under the lock.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass

from repro.core.engine import (N_LARGE, N_SMALL, Experiment, as_engine,
                               machine_run_batch as run_batch)
from repro.core.isa import FLAGS, GPR, IMM, MEM, VEC, InstrSpec
from repro.core.simulator import Counters, Instr


def measure(machine, seq: list[Instr], n_small: int = N_SMALL,
            n_large: int = N_LARGE) -> Counters:
    """Per-copy cycles and per-port μop counts for one copy of ``seq``.

    Routed through the machine's :class:`~repro.core.engine
    .MeasurementEngine`, so identical benchmarks are executed once per
    machine regardless of which inference algorithm requests them.
    ``machine`` may be a machine or an engine."""
    return as_engine(machine).measure(
        Experiment(tuple(seq), n_small, n_large))


@dataclass
class RegPool:
    """Round-robin architectural register pools per operand type."""
    n_gpr: int = 16
    n_vec: int = 16
    n_mem: int = 8

    def __post_init__(self):
        self._iters = {}

    def _names(self, otype: str):
        if otype == GPR:
            return [f"R{i}" for i in range(self.n_gpr)]
        if otype == VEC:
            return [f"X{i}" for i in range(self.n_vec)]
        if otype == MEM:
            return [f"RB{i}" for i in range(self.n_mem)]  # base registers
        if otype == FLAGS:
            return ["FLAGS"]
        return ["IMM"]

    def take(self, otype: str, avoid: set = frozenset()) -> str:
        it = self._iters.get(otype)
        if it is None:
            it = self._iters[otype] = itertools.cycle(self._names(otype))
        for _ in range(4 * len(self._names(otype))):
            r = next(it)
            if r not in avoid:
                return r
        raise RuntimeError(f"register pool exhausted for {otype}")


def fresh_instance(spec: InstrSpec, pool: RegPool,
                   avoid: set = frozenset(), value_hint: str = "low") -> Instr:
    """Instance with distinct registers per explicit operand (independent
    from ``avoid`` and, via round-robin, from recent instances)."""
    regs = {}
    used = set(avoid)
    for o in spec.explicit_operands:
        if o.otype == IMM:
            continue
        r = pool.take(o.otype, used)
        regs[o.name] = r
        used.add(r)
    return Instr(spec.name, regs, value_hint)


def independent_seq(spec: InstrSpec, pool: RegPool, n: int,
                    avoid: set = frozenset(),
                    value_hint: str = "low") -> list[Instr]:
    """n instances avoiding read-after-write dependencies as far as operand
    structure allows (§5.3.1): every instance gets fresh registers; implicit
    RMW operands (e.g. flags) cannot be decoupled — that is the point."""
    return [fresh_instance(spec, pool, avoid, value_hint) for _ in range(n)]


def flags_breaker(isa, pool: RegPool, avoid: set = frozenset()) -> Instr:
    """Dependency-breaking instruction for the status flags: overwrites all
    flags without reading them (TEST R, R on an independent register)."""
    spec = isa["TEST_R64_R64"]
    r = pool.take(GPR, avoid)
    return Instr(spec.name, {"op1": r, "op2": r})


def independent_experiment(spec: InstrSpec, n: int = 12,
                           value_hint: str = "low") -> Experiment:
    """Declarative experiment: ``n`` independent instances from a fresh
    register pool. Deterministic per (spec, n, hint) — which is exactly what
    makes μop counting and isolation measurement the *same* cache entry."""
    return Experiment.of(independent_seq(spec, RegPool(), n,
                                         value_hint=value_hint))


def uops_from_counters(c: Counters, n: int) -> float:
    return c.total_uops / n


def ports_from_counters(c: Counters, n: int,
                        eps: float = 0.05) -> dict[str, float]:
    return {p: v / n for p, v in c.port_uops.items() if v / n > eps}


def total_uops(machine, spec: InstrSpec, pool: RegPool | None = None,
               n: int = 12) -> float:
    """Average μop count of one instance, from independent repetitions."""
    if pool is None:
        c = as_engine(machine).measure(independent_experiment(spec, n))
    else:
        c = measure(machine, independent_seq(spec, pool, n))
    return uops_from_counters(c, n)


def _total_uops_gen(spec: InstrSpec, n: int):
    c = yield [independent_experiment(spec, n)]
    return uops_from_counters(c[0], n)


def total_uops_plan(spec: InstrSpec, n: int = 12):
    """:func:`total_uops` as a single-wave measurement plan — the same
    Experiment as the isolation run, so under a scheduler the μop count
    and Algorithm 1's isolation measurement share one execution."""
    from repro.core.plan import MeasurementPlan  # noqa: PLC0415
    return MeasurementPlan(_total_uops_gen(spec, n),
                           name=f"uops[{spec.name}]", phase="uops")


def isolation_ports(machine, spec: InstrSpec, n: int = 12,
                    eps: float = 0.05) -> dict[str, float]:
    """Per-port μop distribution when run in isolation (the naive signal
    that §5.1 shows is ambiguous). Returns per-instance averages."""
    c = as_engine(machine).measure(independent_experiment(spec, n))
    return ports_from_counters(c, n, eps)

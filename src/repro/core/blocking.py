"""Finding blocking instructions (§5.1.1).

A *blocking instruction* for a port combination P is an instruction whose
μops can use all ports in P but no other port sharing those functional
units. The algorithm:

1. take all 1-μop instructions, excluding system / serializing /
   zero-latency / PAUSE / register-dependent control flow (§5.1.1),
2. group them by the set of ports they use when run in isolation,
3. pick from each group the instruction with the highest throughput
   (lowest cycles/instr) — this naturally avoids candidates whose implicit
   read-modify-write operands (flags!) serialize their own instances,
4. the store-data / store-address combinations get the 2-μop register→memory
   MOV special case,
5. SSE and AVX get separate blocking sets to avoid transition penalties.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.isa import ISA, MEM, InstrSpec
from repro.core.machine import (RegPool, independent_seq, isolation_ports,
                                measure, total_uops)


@dataclass
class BlockingSet:
    """port combination -> (instr name, uops the instr puts on that combo)."""
    instrs: dict = field(default_factory=dict)     # frozenset -> str
    uops_on_pc: dict = field(default_factory=dict)  # frozenset -> int

    def combos(self) -> list[frozenset]:
        return list(self.instrs)


def _excluded(spec: InstrSpec) -> bool:
    return (spec.system or spec.serializing or spec.control_flow
            or spec.is_nop or spec.mnemonic == "PAUSE" or spec.uses_divider)


def measured_throughput(machine, spec: InstrSpec, n: int = 8) -> float:
    pool = RegPool()
    seq = independent_seq(spec, pool, n)
    return measure(machine, seq).cycles / n


def find_blocking_instructions(machine, isa: ISA,
                               extensions: tuple[str, ...] = ("BASE", "SSE"),
                               ) -> BlockingSet:
    """Discover one blocking instruction per observed port combination.

    ``extensions`` restricts candidates (separate SSE vs AVX sets, §5.1.1).
    """
    groups: dict[frozenset, list[tuple[float, str]]] = {}
    for spec in isa:
        if _excluded(spec) or spec.extension not in extensions:
            continue
        if any(o.otype == MEM and o.written for o in spec.operands):
            continue  # store combos handled below (2-μop MOV special case)
        u = total_uops(machine, spec)
        if abs(u - 1.0) > 0.1:
            continue  # not a 1-μop instruction (or partially eliminated)
        ports = frozenset(isolation_ports(machine, spec))
        if not ports:
            continue  # zero-latency / eliminated
        tput = measured_throughput(machine, spec)
        groups.setdefault(ports, []).append((tput, spec.name))

    bs = BlockingSet()
    for pc, cand in groups.items():
        cand.sort()
        bs.instrs[pc] = cand[0][1]
        bs.uops_on_pc[pc] = 1

    # store data / store address ports: use the reg->mem MOV (2 μops; one on
    # the store-data combo, one on the store-address combo).
    store = next((s for s in isa
                  if any(o.otype == MEM and o.written for o in s.operands)
                  and s.mnemonic == "MOV"), None)
    if store is not None and abs(total_uops(machine, store) - 2.0) < 0.1:
        dist = isolation_ports(machine, store)
        # the store-data μop pins one port (~1 μop/instance); the
        # store-address μop spreads over its AGU ports (fractional counts)
        data_pc = frozenset(p for p in dist if dist[p] > 0.9)
        addr_pc = frozenset(p for p in dist if 0.05 < dist[p] <= 0.9)
        for pc in (data_pc, addr_pc):
            if pc and pc not in bs.instrs:
                bs.instrs[pc] = store.name
                bs.uops_on_pc[pc] = 1
    return bs

"""Finding blocking instructions (§5.1.1).

A *blocking instruction* for a port combination P is an instruction whose
μops can use all ports in P but no other port sharing those functional
units. The algorithm:

1. take all 1-μop instructions, excluding system / serializing /
   zero-latency / PAUSE / register-dependent control flow (§5.1.1),
2. group them by the set of ports they use when run in isolation,
3. pick from each group the instruction with the highest throughput
   (lowest cycles/instr) — this naturally avoids candidates whose implicit
   read-modify-write operands (flags!) serialize their own instances,
4. the store-data / store-address combinations get the 2-μop register→memory
   MOV special case,
5. SSE and AVX get separate blocking sets to avoid transition penalties.

The algorithm is expressed as a :mod:`repro.core.plan` measurement plan
(:func:`blocking_plan`) with two waves: one isolation wave over all
candidates (μop count and port distribution come from the same experiment;
the store MOV's isolation run rides along), then one throughput wave over
the 1-μop survivors. :func:`find_blocking_instructions` remains the
run-to-completion wrapper over the plan.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.engine import as_engine
from repro.core.isa import ISA, MEM, InstrSpec
from repro.core.machine import (independent_experiment, ports_from_counters,
                                uops_from_counters)
from repro.core.plan import MeasurementPlan, run_plan


@dataclass
class BlockingSet:
    """port combination -> (instr name, uops the instr puts on that combo)."""
    instrs: dict = field(default_factory=dict)     # frozenset -> str
    uops_on_pc: dict = field(default_factory=dict)  # frozenset -> int

    def combos(self) -> list[frozenset]:
        return list(self.instrs)


def _excluded(spec: InstrSpec) -> bool:
    return (spec.system or spec.serializing or spec.control_flow
            or spec.is_nop or spec.mnemonic == "PAUSE" or spec.uses_divider)


def measured_throughput(machine, spec: InstrSpec, n: int = 8) -> float:
    engine = as_engine(machine)
    return engine.measure(independent_experiment(spec, n)).cycles / n


def _blocking_gen(isa: ISA, extensions: tuple[str, ...]):
    cands = [spec for spec in isa
             if not _excluded(spec) and spec.extension in extensions
             and not any(o.otype == MEM and o.written for o in spec.operands)]
    # store combos handled below (2-μop MOV special case); its isolation
    # experiment joins wave 1 so the special case costs no extra wave
    store = next((s for s in isa
                  if any(o.otype == MEM and o.written for o in s.operands)
                  and s.mnemonic == "MOV"), None)

    # wave 1: isolation runs — μop count and port distribution per candidate
    wave = [independent_experiment(s, 12) for s in cands]
    if store is not None:
        wave.append(independent_experiment(store, 12))
    iso = yield wave
    store_iso = iso[len(cands)] if store is not None else None
    one_uop = [(s, frozenset(ports_from_counters(c, 12)))
               for s, c in zip(cands, iso)
               if abs(uops_from_counters(c, 12) - 1.0) <= 0.1]
    # zero-latency / eliminated candidates have no ports — drop them before
    # spending throughput measurements on them
    one_uop = [(s, ports) for s, ports in one_uop if ports]

    # wave 2: throughput of the 1-μop survivors
    tputs = yield [independent_experiment(s, 8) for s, _ in one_uop]
    groups: dict[frozenset, list[tuple[float, str]]] = {}
    for (spec, ports), c_tp in zip(one_uop, tputs):
        groups.setdefault(ports, []).append((c_tp.cycles / 8, spec.name))

    bs = BlockingSet()
    for pc, cand in groups.items():
        cand.sort()
        bs.instrs[pc] = cand[0][1]
        bs.uops_on_pc[pc] = 1

    # store data / store address ports: use the reg->mem MOV (2 μops; one on
    # the store-data combo, one on the store-address combo).
    if store is not None and abs(uops_from_counters(store_iso, 12) - 2.0) < 0.1:
        dist = ports_from_counters(store_iso, 12)
        # the store-data μop pins one port (~1 μop/instance); the
        # store-address μop spreads over its AGU ports (fractional counts)
        data_pc = frozenset(p for p in dist if dist[p] > 0.9)
        addr_pc = frozenset(p for p in dist if 0.05 < dist[p] <= 0.9)
        for pc in (data_pc, addr_pc):
            if pc and pc not in bs.instrs:
                bs.instrs[pc] = store.name
                bs.uops_on_pc[pc] = 1
    return bs


def blocking_plan(isa: ISA, extensions: tuple[str, ...] = ("BASE", "SSE")):
    """Plan producing the :class:`BlockingSet` for ``extensions``."""
    return MeasurementPlan(_blocking_gen(isa, extensions),
                           name=f"blocking[{'/'.join(extensions)}]",
                           phase="blocking")


def find_blocking_instructions(machine, isa: ISA,
                               extensions: tuple[str, ...] = ("BASE", "SSE"),
                               ) -> BlockingSet:
    """Discover one blocking instruction per observed port combination.

    ``extensions`` restricts candidates (separate SSE vs AVX sets, §5.1.1).
    Run-to-completion wrapper over :func:`blocking_plan`."""
    return run_plan(machine, blocking_plan(isa, extensions))

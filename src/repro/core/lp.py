"""Throughput-from-port-usage LP (§5.3.2).

    minimize   max_p Σ_{(pc,μ)} f(p, pc)
    subject to f(p, pc) = 0             for p ∉ pc
               Σ_p f(p, pc) = μ         for every (pc, μ)

Linearized with z ≥ Σ f(p, pc) per port. Solved with scipy's HiGHS; a pure
bisection + max-flow feasibility fallback (networkx) covers environments
without scipy and doubles as an independent check in tests.

For prediction serving there is also a *closed form*: by max-flow/min-cut
(Hall's condition on the bipartite pc→port graph), a makespan z is feasible
iff for every port set S, the μops that can only run on S fit: demand(S) ≤
z·|S|, where demand(S) = Σ{μ(pc) : pc ⊆ S}. The binding S can always be
taken as a union of the port combinations present, so

    z* = max over unions S of the pcs of demand(S) / |S|

which is exact, needs no solver, and vectorizes across many blocks as one
matrix pass (see service/batch_predictor.py). ``port_bound_from_usage`` is
the shared entry point: closed form while the union closure stays small,
LP fallback beyond that — both the single-block reference predictor and the
batched service path route through it, so their numbers are identical.
"""
from __future__ import annotations

# Beyond this many distinct port combinations the union closure may blow up
# combinatorially; fall back to the LP. Both the reference predictor and the
# batch predictor apply the same rule per block, keeping them bit-identical.
CUT_COMBO_CAP = 12


def union_closure(combos, cap: int = 4096) -> list | None:
    """All distinct unions of the given port combinations, sorted smallest
    first — the candidate min-cut port sets. Returns None if the closure
    exceeds ``cap`` sets (caller should use the LP instead)."""
    closed: set = set()
    for pc in combos:
        pc = frozenset(pc)
        closed |= {pc} | {pc | s for s in closed}
        if len(closed) > cap:
            return None
    return sorted(closed, key=lambda s: (len(s), sorted(s)))


def cut_matrices(combos, candidates):
    """Integer matrices of the closed form, shared by the vectorized
    predictor backends (see ``service/batch_predictor.py``).

    Returns ``(mask, sizes)`` as numpy int32 arrays: ``mask[c, s] = 1``
    iff port combination ``combos[c]`` is contained in candidate cut set
    ``candidates[s]`` and ``sizes[s] = |candidates[s]|``.  With integer
    μop counts ``u`` (blocks × combos), ``demand = u @ mask`` is an exact
    integer matrix product, so the bound ``max_s demand[:, s]/sizes[s]``
    can be evaluated *exactly* on any backend: the winning candidate per
    block can be selected purely with integer cross-multiplication
    (``d1 * s2 > d2 * s1``) and only the final division performed in
    float64 — two candidates with equal rational ratios round to the same
    float, so the result is bit-identical to the scalar reference loop in
    :func:`cut_bound`."""
    import numpy as np

    mask = np.array([[1 if pc <= s else 0 for s in candidates]
                     for pc in combos], dtype=np.int32)
    sizes = np.array([len(s) for s in candidates], dtype=np.int32)
    return mask, sizes


def cut_bound(usage: dict, candidates=None) -> float:
    """Exact min-max port load via the min-cut closed form.

    ``candidates`` may be any superset of the unions of ``usage``'s port
    combinations (e.g. a model-wide closure shared across blocks): extra
    sets can never exceed the maximum, because shrinking a candidate to the
    union of the combinations it contains only increases its ratio."""
    usage = {pc: float(n) for pc, n in usage.items() if n > 0}
    if not usage:
        return 0.0
    if candidates is None:
        candidates = union_closure(usage)
        if candidates is None:  # pragma: no cover - guarded by caller's cap
            return throughput_lp(usage)
    best = 0.0
    for s in candidates:
        demand = 0.0
        for pc, n in usage.items():
            if pc <= s:
                demand += n
        best = max(best, demand / len(s))
    return best


def port_bound_from_usage(usage: dict, combo_cap: int = CUT_COMBO_CAP
                          ) -> float:
    """Port-pressure bound shared by the reference and batched predictors:
    the closed-form cut bound when few distinct combinations are involved
    (the common case), the LP otherwise."""
    distinct = [pc for pc, n in usage.items() if n > 0]
    if not distinct:
        return 0.0
    if len(distinct) > combo_cap:
        # canonical variable order: the LP result must not depend on dict
        # insertion order (in-memory vs artifact-loaded models)
        return throughput_lp(dict(sorted(usage.items(),
                                         key=lambda kv: sorted(kv[0]))))
    return cut_bound(usage)


def throughput_lp(usage: dict, ports=None) -> float:
    """``usage``: {frozenset(ports): uop_count}. Returns min-max port load
    (= Intel-definition throughput, Def. 1, for divider-free instructions)."""
    usage = {pc: float(n) for pc, n in usage.items() if n > 0}
    if not usage:
        return 0.0
    all_ports = sorted(set().union(*usage)) if ports is None else list(ports)
    try:
        return _scipy_lp(usage, all_ports)
    except ImportError:  # pragma: no cover
        return _bisect_flow(usage, all_ports)


def _scipy_lp(usage: dict, ports: list) -> float:
    import numpy as np
    from scipy.optimize import linprog

    pcs = list(usage)
    # variables: f(p, pc) for p in pc (flattened), then z
    var_idx = {}
    for pc in pcs:
        for p in pc:
            var_idx[(p, pc)] = len(var_idx)
    nz = len(var_idx)
    c = np.zeros(nz + 1)
    c[nz] = 1.0  # minimize z
    # equality: sum_p f(p,pc) = mu
    A_eq = np.zeros((len(pcs), nz + 1))
    b_eq = np.zeros(len(pcs))
    for i, pc in enumerate(pcs):
        for p in pc:
            A_eq[i, var_idx[(p, pc)]] = 1.0
        b_eq[i] = usage[pc]
    # inequality: sum_pc f(p,pc) - z <= 0
    A_ub = np.zeros((len(ports), nz + 1))
    for j, p in enumerate(ports):
        for pc in pcs:
            if p in pc:
                A_ub[j, var_idx[(p, pc)]] = 1.0
        A_ub[j, nz] = -1.0
    res = linprog(c, A_ub=A_ub, b_ub=np.zeros(len(ports)), A_eq=A_eq,
                  b_eq=b_eq, bounds=[(0, None)] * (nz + 1), method="highs")
    if not res.success:  # pragma: no cover
        raise RuntimeError(f"LP failed: {res.message}")
    return float(res.x[nz])


def _bisect_flow(usage: dict, ports: list, tol: float = 1e-6) -> float:
    """Feasibility of makespan z == max-flow saturation in the bipartite
    graph pc -> ports with port capacity z."""
    import networkx as nx

    total = sum(usage.values())
    lo, hi = 0.0, float(total)

    def feasible(z: float) -> bool:
        g = nx.DiGraph()
        for i, (pc, mu) in enumerate(usage.items()):
            g.add_edge("s", f"c{i}", capacity=mu)
            for p in pc:
                g.add_edge(f"c{i}", f"p{p}", capacity=mu)
        for p in ports:
            g.add_edge(f"p{p}", "t", capacity=z)
        val = nx.maximum_flow_value(g, "s", "t")
        return val >= total - 1e-9

    for _ in range(60):
        mid = (lo + hi) / 2
        if feasible(mid):
            hi = mid
        else:
            lo = mid
        if hi - lo < tol:
            break
    return hi

"""Static performance predictor — the open IACA/llvm-mca analogue the paper
ships on top of its measured models ("we have also implemented an
open-source performance-prediction tool similar to Intel's IACA", §9).

Given a :class:`PerfModel` and a loop body (list of Instr), predicts
steady-state cycles/iteration as the max of three bounds:

  * port bound      — LP over the summed port usage (§5.3.2),
  * latency bound   — loop-carried critical path through the per-operand-pair
                      latency map lat(s, d) (this is where the §4.1 latency
                      definition pays off: a scalar latency would overestimate
                      chains through fast operand pairs, e.g. AESDEC §7.3.1),
  * front-end bound — total μops / issue width.

``LegacyAnalyzer`` reproduces the *failure modes* the paper documents in
IACA (§7.2): it ignores status-flag and memory dependencies, models a single
scalar latency per instruction, and can carry stale port tables — used by
benchmarks to regenerate the paper's agreement-table methodology.

This module is the *single-block reference*: the batched service path
(service/batch_predictor.py) vectorizes the port and front-end bounds but
shares every scalar helper here (``sum_usage``, ``port_pressure``,
``classify_bottleneck``, ``_latency_bound``) and the port-bound entry point
in ``lp.py``, so batch and single-block predictions are bit-identical.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.characterize import PerfModel
from repro.core.isa import FLAGS, IMM, ISA, MEM
from repro.core.lp import port_bound_from_usage, throughput_lp
from repro.core.simulator import Instr


class UnknownInstructionError(KeyError):
    """A block references instruction variants absent from the model.

    This is an expected condition, not a bug: the paper's tool does not
    characterize every instruction (§8 — system, serializing, control-flow),
    and a model may come from a partial campaign. Carries the sorted list of
    ``missing`` variant names and the model's ``uarch`` so services can
    return it as a structured error instead of a bare KeyError."""

    def __init__(self, missing, uarch: str = ""):
        self.missing = sorted(set(missing))
        self.uarch = uarch
        super().__init__(f"model {uarch or '<unnamed>'} has no "
                         f"characterization for: {', '.join(self.missing)}")

    def __str__(self) -> str:  # KeyError.__str__ would re-quote the message
        return self.args[0]

    def __reduce__(self):  # KeyError's reduce would replay the message
        return (type(self), (self.missing, self.uarch))


def missing_specs(model: PerfModel, code, isa: ISA | None = None
                  ) -> list[str]:
    """Instruction variants used by ``code`` that cannot be predicted:
    absent from ``model``, or (with ``isa`` given) from the serving ISA —
    the latency bound needs the operand structure, not just measurements."""
    return sorted({i.spec for i in code
                   if i.spec not in model.instructions
                   or (isa is not None and i.spec not in isa)})


def check_block(model: PerfModel, code, isa: ISA | None = None) -> None:
    missing = missing_specs(model, code, isa)
    if missing:
        raise UnknownInstructionError(missing, model.uarch)


@dataclass
class Prediction:
    cycles: float
    port_bound: float
    latency_bound: float
    frontend_bound: float
    port_pressure: dict = field(default_factory=dict)
    bottleneck: str = ""


def sum_usage(model: PerfModel, code: list[Instr]):
    """Summed port-usage multiset and μop count of a block, in code order
    (the accumulation order is part of the reference semantics: the batch
    predictor reproduces it position by position)."""
    usage_sum: dict[frozenset, float] = {}
    uops = 0.0
    for ins in code:
        im = model[ins.spec]
        uops += im.uops
        if im.port_usage:
            for pc, n in im.port_usage.usage.items():
                usage_sum[pc] = usage_sum.get(pc, 0) + n
    return usage_sum, uops


def port_pressure(usage_sum: dict) -> dict:
    """Per-port pressure under an optimal balanced assignment.

    Combinations are visited in canonical order so the float accumulation
    is independent of dict insertion order — an in-memory model and its
    XML round trip must produce bit-identical pressures."""
    pressure: dict[str, float] = {}
    for pc, n in sorted(usage_sum.items(), key=lambda kv: sorted(kv[0])):
        for p in sorted(pc):
            pressure[p] = pressure.get(p, 0.0) + n / len(pc)
    return pressure


def classify_bottleneck(cycles: float, port_bound: float, lat_bound: float
                        ) -> str:
    """Deterministic tie-break: ports > latency > frontend."""
    if port_bound >= cycles - 1e-9:
        return "ports"
    if lat_bound >= cycles - 1e-9:
        return "latency"
    return "frontend"


def _resource_bounds(model: PerfModel, code: list[Instr], issue_width: int):
    usage_sum, uops = sum_usage(model, code)
    port_bound = port_bound_from_usage(usage_sum) if usage_sum else 0.0
    return port_bound, uops / issue_width, port_pressure(usage_sum)


def _latency_bound(model: PerfModel, isa: ISA, code: list[Instr],
                   iters: int = 24, *, track_flags: bool = True,
                   track_mem: bool = True, scalar_latency: bool = False):
    """Loop-carried dependency length per iteration: iterate the symbolic
    dataflow until the per-iteration increment stabilizes."""
    t: dict[str, float] = {}
    prev_max = 0.0
    inc = 0.0
    for it in range(iters):
        for ins in code:
            spec = isa[ins.spec]
            im = model[ins.spec]
            regs = dict(ins.regs)
            for o in spec.operands:
                regs.setdefault(o.name, "FLAGS" if o.otype == FLAGS
                                else f"IMPL_{o.name}")
            # measured dependency-breaking: some entry's same-register
            # cycles collapsed below latency => zero idiom (§7.3.6)
            ex = [regs[o.name] for o in spec.explicit_operands
                  if o.otype not in (IMM, MEM, FLAGS)]
            idiom = (not scalar_latency and len(ex) >= 2
                     and len(set(ex)) == 1 and im.latency is not None
                     and any(e.same_reg is not None and e.same_reg < 0.5
                             for e in im.latency.entries.values()))
            for d in spec.dests:
                if d.otype == FLAGS and not track_flags:
                    continue
                ready = 0.0
                for s in () if idiom else spec.sources:
                    if s.otype == IMM:
                        continue
                    if s.otype == FLAGS and not track_flags:
                        continue
                    if s.otype == MEM and not track_mem:
                        continue
                    e = im.latency.get(s.name, d.name) if im.latency else None
                    if e is None:
                        continue
                    if scalar_latency:
                        lat = im.latency.max_latency()
                    elif (e.same_reg is not None
                          and regs.get(s.name) == regs.get(d.name)
                          and s.name != d.name):
                        if e.same_reg < 0.5:  # measured dependency-breaking
                            continue
                        lat = e.same_reg
                    else:
                        lat = e.value
                    key = "MEM_" + regs[s.name] if s.otype == MEM else regs[s.name]
                    ready = max(ready, t.get(key, 0.0) + lat)
                key = "MEM_" + regs[d.name] if d.otype == MEM else regs[d.name]
                t[key] = ready
        cur_max = max(t.values(), default=0.0)
        inc = cur_max - prev_max
        prev_max = cur_max
    return inc


def predict(model: PerfModel, isa: ISA, code: list[Instr],
            issue_width: int = 4) -> Prediction:
    check_block(model, code, isa)
    port_bound, fe_bound, pressure = _resource_bounds(model, code, issue_width)
    lat_bound = _latency_bound(model, isa, code)
    cycles = max(port_bound, lat_bound, fe_bound)
    bn = classify_bottleneck(cycles, port_bound, lat_bound)
    return Prediction(cycles, port_bound, lat_bound, fe_bound, pressure, bn)


class LegacyAnalyzer:
    """IACA-with-its-documented-bugs (§7.2): ignores flag and memory
    dependencies, one scalar latency per instruction, optionally stale port
    tables (``port_overrides``: instr name -> {frozenset: count})."""

    def __init__(self, model: PerfModel, isa: ISA,
                 port_overrides: dict | None = None, issue_width: int = 4):
        self.model = model
        self.isa = isa
        self.port_overrides = port_overrides or {}
        self.issue_width = issue_width

    def predict(self, code: list[Instr]) -> Prediction:
        check_block(self.model, code, self.isa)
        usage_sum: dict[frozenset, float] = {}
        uops = 0.0
        for ins in code:
            im = self.model[ins.spec]
            usage = self.port_overrides.get(ins.spec,
                                            im.port_usage.usage
                                            if im.port_usage else {})
            uops += sum(usage.values())
            for pc, n in usage.items():
                usage_sum[pc] = usage_sum.get(pc, 0) + n
        port_bound = throughput_lp(usage_sum) if usage_sum else 0.0
        fe = uops / self.issue_width
        lat = _latency_bound(self.model, self.isa, code, track_flags=False,
                             track_mem=False, scalar_latency=True)
        cycles = max(port_bound, lat, fe)
        bn = ("ports" if port_bound >= cycles - 1e-9 else
              "latency" if lat >= cycles - 1e-9 else "frontend")
        return Prediction(cycles, port_bound, lat, fe, {}, bn)

    def port_usage_of(self, name: str):
        return self.port_overrides.get(
            name, self.model[name].port_usage.usage
            if self.model[name].port_usage else {})

"""Batched simulated machine: whole experiment waves as one array program.

The scalar :class:`~repro.core.simulator.SimMachine` interprets one μop per
Python-loop iteration — the hot path under every inference algorithm.  This
module executes a *wave* of experiments at once: each instruction sequence
is lowered to flat integer tensors (issue cycles, port-mask ids, latencies,
occupancies, dependency producers), the wave is padded to
``(n_experiments, n_uops)``, and the dispatch/dependency recurrence runs as
a vectorized kernel.  Three backends share the lowering and packing layers:

* ``numpy`` — the baseline: a Python loop over μop *positions* with one
  vectorized step across all experiment lanes (Python overhead is
  O(max μops), not O(total μops)).
* ``jax`` — the device-resident path: the recurrence is an AOT-compiled
  ``lax.scan`` executed per shape *bucket* (see below), with the μop
  ``mask_table`` LUT kept resident on device and chunk dispatch pipelined
  against host packing (double-buffered: pack chunk k+1 while chunk k
  executes).
* ``pallas`` — the same recurrence as a ``pl.pallas_call`` kernel: the grid
  runs over blocks of experiment lanes, a ``fori_loop`` walks μop positions
  with the per-lane state (``done`` history, port-free times, port counts)
  carried in on-chip values.  Off-TPU it executes in interpret mode — the
  correctness twin of the compiled TPU kernel, not a speed path.

Wave execution is amortized end-to-end:

* **Lowering cache** — ``_lower`` results (:class:`_Prog` tensors) are
  memoized under a content key (canonical body + unroll count), so a warm
  wave skips Python lowering entirely even when the measurement-engine
  cache missed (e.g. only the Algorithm-2 params changed).  LRU-bounded;
  hit/miss/eviction counters surface through ``engine_stats``.
* **Shape buckets** — device kernels are compiled for a small fixed set of
  ``(S, E, R)`` shapes (quarter-octave rounding: ``b`` or ``1.5b`` for
  powers of two ``b``), so the number of compilations is bounded and warm
  waves never re-trace; ``device_stats()`` exposes the compile count the
  CI probe asserts on.
* **Vectorized packing** — chunks are packed into (bucket-sized,
  double-buffered) host arrays with sliced NumPy scatters instead of a
  per-experiment Python loop, and Counters extraction is one gather per
  wave.

Bit-identity with the scalar oracle is by construction: every quantity in
the simulation (issue cycles, latencies, penalties, port-free times) is an
integer, so all kernels run in integer arithmetic and convert to the same
float values the scalar machine produces.  ``tests/test_batch_sim.py``
differential-tests every backend on all ``SIM_UARCHES`` and random ground
truths, including dispatch tie-breaks at port-count boundaries.

Lowering resolves the full dataflow up front: operand snapshots (with
partial-register stall deltas), intra-instruction temporaries, memory
cells, store-to-load forwarding, move elimination, and zero idioms all
reduce to per-μop producer row indices.  Because the measurement engine
submits ``body * n`` unrollings (Algorithm 2), lowering detects the
periodic steady state — once the machine state signature repeats at a copy
boundary, the remaining copies are *tiled* with shifted NumPy arrays
instead of per-μop Python work.
"""
from __future__ import annotations

import threading
import warnings

import numpy as np

from repro.core.isa import IMM, ISA
from repro.core.simulator import Counters, _implicit_reg
from repro.core.uarch import UArch
from repro.core.uarch_compile import (F_HAS_SR, F_PRESENT, TEMP_BASE,
                                      CompiledUArch, UopTableIndex,
                                      compile_uarch)
from repro.faults import plan as faults
from repro.faults.tolerance import StragglerDetector
from repro.obs import tracer as obs

# producer descriptor kinds (recipe-time)
_P_SNAP, _P_TMP, _P_MEM, _P_CUR = 0, 1, 2, 3
# write descriptor kinds
_W_TMP, _W_MEM, _W_CELL = 0, 1, 2
# recipe kinds
_K_NORMAL, _K_ZERO_NOUOP, _K_ELIM = 0, 1, 2

BACKENDS = ("numpy", "jax", "pallas")

# thin-chunk scalar-oracle crossover (lanes): below this many parallel
# lanes the array program's fixed per-step dispatch cost exceeds the
# scalar interpreter it replaces.  The default is the measured crossover
# from the ``bench_batch_sim`` thin-chunk sweep (the batched kernel wins
# from 4 lanes on the reference box; see experiments/benchmarks.json,
# ``batch_sim.min_lanes_crossover``); results are bit-identical either way.
DEFAULT_MIN_LANES = 4

# lowering-cache bound (distinct (body, unroll-count) programs).  A full
# characterization stays in the hundreds; the bound exists so service-backed
# machines fed unbounded query streams cannot grow without limit.
DEFAULT_LOWER_CACHE = 4096

# lane-block width for the pallas kernel grid (the TPU lane dimension)
_PALLAS_LANE_BLOCK = 128


def _fault_key(code) -> str:
    """Content key for ``wave.kernel`` fault rules: the sequence's spec
    string, so a seeded fault follows its poisoned sequence through every
    bisection sub-wave and every backend, and ``match=`` clauses can
    target instructions by name (see :mod:`repro.faults.plan`)."""
    return ";".join(ins.spec for ins in code)


class _Plan:
    """One executable μop of a lowered instruction recipe."""
    __slots__ = ("mask_id", "lat", "blk", "vis", "prods", "sf", "sf_cell",
                 "writes", "issue_off")

    def __init__(self, mask_id, lat, blk, vis, prods, sf, sf_cell, writes,
                 issue_off):
        self.mask_id = mask_id
        self.lat = lat
        self.blk = blk
        self.vis = vis
        self.prods = prods
        self.sf = sf
        self.sf_cell = sf_cell
        self.writes = writes
        self.issue_off = issue_off


class _Recipe:
    """Lowering recipe for one concrete instruction instance."""
    __slots__ = ("kind", "dest_cells", "period", "ekey", "src_cell",
                 "dst_cell", "advance", "snapshot", "plans", "ckey")

    def __init__(self, kind, advance, snapshot=(), plans=(), dest_cells=(),
                 period=0, ekey=None, src_cell=-1, dst_cell=-1, ckey=None):
        self.kind = kind
        self.advance = advance
        self.snapshot = snapshot
        self.plans = plans
        self.dest_cells = dest_cells
        self.period = period
        self.ekey = ekey
        self.src_cell = src_cell
        self.dst_cell = dst_cell
        self.ckey = ckey           # content key (spec, regs, value_hint)


class _Prog:
    """One experiment lowered to flat int32 tensors."""
    __slots__ = ("n_rows", "issue", "mask", "lat", "blk", "vis", "prod",
                 "delta", "finals", "max_r")

    def __init__(self, n_rows, issue, mask, lat, blk, vis, prod, delta,
                 finals, max_r):
        self.n_rows = n_rows
        self.issue = issue
        self.mask = mask
        self.lat = lat
        self.blk = blk
        self.vis = vis
        self.prod = prod
        self.delta = delta
        self.finals = finals
        self.max_r = max_r


def _body_period(ids) -> int:
    """Smallest p with ``ids == ids[:p] * k`` (object identities — the
    engine's ``body * n`` unrollings share instruction objects)."""
    n = len(ids)
    if n < 2:
        return n
    first = ids[0]
    for p in range(1, n // 2 + 1):
        if ids[p] == first and n % p == 0 and ids[p:] == ids[:-p]:
            return p
    return n


def _code_period(code) -> int:
    """:func:`_body_period` directly over the instruction list: the slice
    compare runs at C speed with CPython's identity short-circuit (the
    engine's ``body * n`` unrollings share objects), and a content-equal
    fallback is harmless — recipes key on content.  This runs per sequence
    on the wave hot path, ahead of every lowering-cache probe."""
    n = len(code)
    if n < 2:
        return n
    first = code[0]
    for p in range(1, n // 2 + 1):
        if code[p] is first and n % p == 0 and code[p:] == code[:-p]:
            return p
    return n


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 1).bit_length()


def _bucket(n: int, lo: int) -> int:
    """Smallest value >= n of the form ``lo * 2**k`` or ``1.5 * lo * 2**k``
    (quarter-octave shape buckets: at most ~33% padding, O(log n) distinct
    buckets, so device kernels compile a bounded number of times)."""
    b = lo
    while b < n:
        h = b + b // 2
        if h >= n:
            return h
        b *= 2
    return b


class _ChunkPack:
    """One packed chunk: bucket-shaped input tensors + extraction metadata.

    ``vis``/``valid`` live alongside the kernel inputs; only they (and the
    scatter targets) are re-zeroed when a device buffer set is reused —
    every other cell of a reused buffer is gated off by ``valid`` in the
    kernels, so stale data cannot perturb results."""
    __slots__ = ("chunk", "lane_progs", "S", "E", "R", "issue", "mask",
                 "lat", "blk", "valid", "prod", "delta", "vis")

    def __init__(self, chunk, lane_progs, S, E, R, issue, mask, lat, blk,
                 valid, prod, delta, vis):
        self.chunk = chunk
        self.lane_progs = lane_progs
        self.S = S
        self.E = E
        self.R = R
        self.issue = issue
        self.mask = mask
        self.lat = lat
        self.blk = blk
        self.valid = valid
        self.prod = prod
        self.delta = delta
        self.vis = vis


class BatchSimMachine:
    """Measurable black box executing waves of sequences as array programs.

    Same observable contract as :class:`~repro.core.simulator.SimMachine`
    (cycles + per-port μop counts, including harness overhead), plus
    :meth:`run_batch` — and bit-identical results to the scalar oracle on
    every backend (``numpy``, ``jax``, ``pallas``)."""

    counters_available = True

    def __init__(self, uarch: UArch, isa: ISA, backend: str = "numpy",
                 table_index: UopTableIndex | None = None,
                 min_lanes: int = DEFAULT_MIN_LANES,
                 lower_cache_entries: int | None = DEFAULT_LOWER_CACHE,
                 devices=None):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}")
        if backend != "numpy" and _jax() is None:
            raise RuntimeError(f"{backend} backend requested but jax is "
                               "not importable")
        self.uarch = uarch
        self.isa = isa
        self.name = uarch.name
        self.ports = uarch.ports
        self.backend = backend
        # device placement spec for the jax/pallas backends: None (the
        # REPRO_SIM_DEVICES env knob, default all available devices), an
        # integer count, "all", or an explicit jax device sequence —
        # resolved lazily by core/device_mesh when the device executor is
        # first built.  More than one resolved device shards every wave's
        # lanes across a 1-D ``lanes`` mesh; a single device (the normal
        # CPU case) keeps the single-device path, bit-identical either way
        self.devices = devices
        # a padded chunk with fewer lanes than this runs on the scalar
        # oracle instead: the array program's fixed per-step dispatch cost
        # only amortizes across enough parallel lanes (results are
        # bit-identical either way; set 1 to force the kernel)
        self.min_lanes = min_lanes
        self._comp: CompiledUArch = compile_uarch(uarch, isa, table_index)
        self._cells: dict = {}          # register name -> cell id
        self._recipes_by_key: dict = {}
        self._scalar = None             # lazy scalar fallback for thin chunks
        # lowering cache: (body content key, unroll count) -> _Prog (LRU)
        self._lower_cache: dict = {}
        self._lower_max = lower_cache_entries
        self.lowering_stats = {"hits": 0, "misses": 0, "evictions": 0}
        # backend degradation counters: "<from>-><to>" -> chunks rerouted
        # down the backend chain (pallas -> jax -> numpy -> scalar oracle)
        # after a kernel-path failure.  Results stay bit-identical (every
        # backend computes the same integers); the engine snapshots these
        # through degraded_stats() into EngineStats.degraded_chunks.
        self.degraded: dict = {}
        self._device = None             # lazy _DeviceExec (jax/pallas)
        self._device_fb: dict = {}      # degraded-backend executors
        # guards the machine's shared mutable host state (lowering-cache
        # LRU, recipe memo, lazy device/scalar init) across concurrent
        # run_batch callers; slot leasing has its own lock in _DeviceExec
        self._host_lock = threading.Lock()

    # ------------------------------------------------------------------
    def run(self, code) -> Counters:
        return self.run_batch([code])[0]

    def set_devices(self, devices) -> None:
        """Adopt a device placement (count, ``"all"``, or an explicit jax
        device sequence — see :mod:`repro.core.device_mesh`).  The device
        executor is rebuilt on the next wave; results are bit-identical
        for every placement.  ``Campaign.run`` uses this to place each
        machine on a disjoint device subset."""
        with self._host_lock:
            self.devices = devices
            self._device = None
            self._device_fb.clear()

    def device_stats(self) -> dict:
        """Device-kernel telemetry: compile count (the CI recompile probe
        asserts ``compiles <= len(buckets)``), kernel dispatches, the
        shape buckets seen so far, the resolved device placement, and
        per-device compile/kernel-call/lane counters (``per_device``,
        keyed by jax device id — cross-device recompiles show up here).
        Empty for the numpy backend."""
        if self._device is None:
            return {}
        return self._device.stats()

    def degraded_stats(self) -> dict:
        """Per-transition backend degradation counters
        (``{"jax->numpy": 2, ...}``) — empty when no chunk has ever been
        rerouted, which is the overwhelmingly common case."""
        with self._host_lock:
            return dict(self.degraded)

    def _note_degraded(self, frm: str, to: str, chunks: int,
                       exc: BaseException) -> None:
        key = f"{frm}->{to}"
        with self._host_lock:
            self.degraded[key] = self.degraded.get(key, 0) + chunks
        obs.instant("wave.degraded", transition=key, chunks=chunks,
                    error=f"{type(exc).__name__}: {exc}")
        warnings.warn(f"{self.name}: {chunks} chunk(s) degraded {key} "
                      f"after {type(exc).__name__}: {exc}", stacklevel=2)

    def run_batch(self, codes, kernel_lock=None) -> list:
        """Execute each sequence once; one :class:`Counters` per sequence,
        in submission order.

        ``kernel_lock`` (optional ``threading.Lock``) serializes the
        GIL-bound kernels — the numpy backend's Python-stepped loop and
        the scalar-oracle fallback — which thrash when interleaved across
        threads; host lowering and packing always run outside it.  The
        device backends do not take it: their compiled kernels release
        the GIL and are scheduled by the machine's device pool, and
        dispatch serializes on the executor's per-device-subset lock
        (:func:`repro.core.device_mesh.dispatch_lock`) so machines on
        disjoint device subsets overlap (see ``WaveScheduler``).

        Concurrent ``run_batch`` calls on one machine instance are safe —
        the lowering cache/recipe memo and the device buffer-slot leasing
        are mutex-guarded — but they serialize on host lowering; the
        intended topology is one caller per machine (campaign workers own
        distinct machines and overlap only *across* machines).

        With tracing on (``REPRO_TRACE=1``, see :mod:`repro.obs`) each
        wave emits a ``wave.run_batch`` span with per-phase children
        (``wave.lower`` / ``wave.pack`` / ``wave.kernel`` /
        ``wave.dispatch`` / ``wave.extract``), lock-wait spans
        (``wave.lock_wait`` / ``wave.dispatch_lock_wait``) measured
        separately from the work they guard, and per-device kernel spans
        on ``device:<id>`` tracks."""
        with obs.span("wave.run_batch", lanes=len(codes),
                      backend=self.backend):
            return self._run_batch(codes, kernel_lock)

    def _run_batch(self, codes, kernel_lock=None) -> list:
        codes = [list(c) for c in codes]
        out: list = [None] * len(codes)
        # chunk by similar length so short sequences don't pay for the
        # longest experiment's padded steps; thin chunks go scalar
        order = sorted(range(len(codes)), key=lambda i: -len(codes[i]))
        chunks: list = []
        chunk: list = []
        chunk_max = 0
        for i in order:
            if chunk and len(codes[i]) * 4 < chunk_max:
                chunks.append(chunk)
                chunk, chunk_max = [], 0
            if not chunk:
                chunk_max = max(len(codes[i]), 1)
            chunk.append(i)
        if chunk:
            chunks.append(chunk)
        batched = [c for c in chunks if len(c) >= self.min_lanes]
        thin = [i for c in chunks if len(c) < self.min_lanes for i in c]
        if thin:
            self._chunk_scalar(thin, codes, out, kernel_lock)
        if not batched:
            return out
        progs = self._lower_wave(codes, batched)
        if self.backend == "numpy":
            for c in batched:
                try:
                    self._chunk_numpy(c, codes, progs, out, kernel_lock)
                except Exception as exc:
                    self._note_degraded("numpy", "scalar", 1, exc)
                    self._chunk_scalar(c, codes, out, kernel_lock,
                                       span="wave.degraded")
        else:
            try:
                self._run_device(batched, codes, progs, out, kernel_lock,
                                 self.backend)
            except Exception as exc:
                self._degrade_device(batched, codes, progs, out,
                                     kernel_lock, exc)
        return out

    def _ensure_scalar(self):
        with self._host_lock:
            if self._scalar is None:
                from repro.core.simulator import SimMachine  # noqa: PLC0415
                self._scalar = SimMachine(self.uarch, self.isa)
            return self._scalar

    def _chunk_scalar(self, idxs, codes, out, kernel_lock,
                      span: str = "wave.scalar") -> None:
        """Run ``idxs`` on the scalar oracle — the thin-chunk path, and
        the terminal rung of the backend degradation chain."""
        sim = self._ensure_scalar()
        # wait_lock(None) degrades to a no-op, so both lock topologies
        # share one code path; acquisition wait is traced separately
        with obs.span(span, thin=len(idxs)), \
                obs.wait_lock(kernel_lock, "wave.lock_wait"):
            if faults.active():
                faults.check_wave("wave.kernel",
                                  [_fault_key(codes[i]) for i in idxs],
                                  backend="scalar")
            for i in idxs:
                out[i] = sim.run(codes[i])

    def _chunk_numpy(self, c, codes, progs, out, kernel_lock) -> None:
        """Pack + host-kernel + extract for one chunk (the numpy backend's
        per-chunk unit of work, also the numpy rung of the degradation
        chain for device-backend failures)."""
        with obs.span("wave.pack", lanes=len(c)):
            if faults.active():
                faults.check("wave.pack", backend="numpy")
            pk = self._pack_chunk(c, progs)
        if pk.S == 0:
            self._fill_empty(c, out)
            return
        with obs.wait_lock(kernel_lock, "wave.lock_wait"), \
                obs.span("wave.kernel", lanes=pk.E, steps=pk.S):
            if faults.active():
                faults.check_wave("wave.kernel",
                                  [_fault_key(codes[i]) for i in c],
                                  backend="numpy")
            done, counts = self._kernel_numpy(pk)
        with obs.span("wave.extract", lanes=len(c)):
            self._extract(pk, done.T, counts, out)

    def _degrade_device(self, batched, codes, progs, out, kernel_lock,
                        exc: BaseException) -> None:
        """Backend degradation chain: on a device-path failure, re-run
        every chunk that never produced results on the next backend down
        (pallas -> jax -> numpy -> scalar oracle).  Results stay
        bit-identical — each backend computes the same integers — so a
        degraded wave is correct, just slower; reroutes are counted per
        transition (``degraded_stats()``).  A wave that fails even the
        scalar oracle re-raises, handing the measurement engine's
        bisecting retry the job of isolating the poison experiment."""
        prev = self.backend
        for nxt in (("jax",) if self.backend == "pallas" else ()):
            remaining = [c for c in batched
                         if any(out[i] is None for i in c)]
            if not remaining:
                return
            self._note_degraded(prev, nxt, len(remaining), exc)
            try:
                self._run_device(remaining, codes, progs, out,
                                 kernel_lock, nxt)
                return
            except Exception as e2:
                exc, prev = e2, nxt
        remaining = [c for c in batched if any(out[i] is None for i in c)]
        if not remaining:
            return
        self._note_degraded(prev, "numpy", len(remaining), exc)
        for c in remaining:
            try:
                self._chunk_numpy(c, codes, progs, out, kernel_lock)
            except Exception as e3:
                self._note_degraded("numpy", "scalar", 1, e3)
                self._chunk_scalar(c, codes, out, kernel_lock,
                                   span="wave.degraded")

    # ------------------------------------------------------------------
    # lowering cache: content-addressed _Prog tensors
    # ------------------------------------------------------------------
    def _lower_wave(self, codes, batched) -> dict:
        """Lower every batched sequence, serving repeat bodies from the
        content-addressed lowering cache.  Sequences sharing one body
        (Algorithm 2 submits the same body at two unroll counts) lower the
        longest *missing* count once; shorter unrollings are prefix views
        of the same tensors (causality).  Holds the machine's host lock:
        the cache LRU (pop/reinsert/evict) and the recipe memo are shared
        mutable state across concurrent ``run_batch`` callers.  Traced as
        a ``wave.lower`` span carrying this wave's cache hit/miss delta."""
        stats = self.lowering_stats
        h0, m0 = stats["hits"], stats["misses"]
        with obs.span("wave.lower",
                      lanes=sum(len(c) for c in batched)) as sp, \
                self._host_lock:
            progs = self._lower_wave_locked(codes, batched)
            sp.set(hits=stats["hits"] - h0, misses=stats["misses"] - m0)
        return progs

    def _lower_wave_locked(self, codes, batched) -> dict:
        by_id: dict = {}
        groups: dict = {}
        for c in batched:
            for i in c:
                code = codes[i]
                if code:
                    p = _code_period(code)
                    body_ck = tuple(self._recipe(ins, by_id).ckey
                                    for ins in code[:p])
                    key = (p, body_ck)
                    nc = len(code) // p
                else:
                    key, nc = (0, ()), 0
                groups.setdefault(key, []).append((i, nc))
        progs: dict = {}
        cache = self._lower_cache
        stats = self.lowering_stats
        for (p, body_ck), members in groups.items():
            cuts = sorted({nc for _, nc in members})
            have: dict = {}
            missing: list = []
            for nc in cuts:
                hit = cache.pop((body_ck, nc), None)   # pop: LRU touch
                if hit is None:
                    missing.append(nc)
                else:
                    have[nc] = hit
            stats["hits"] += len(have)
            if missing:
                stats["misses"] += len(missing)
                rep_i = max(members, key=lambda t: t[1])[0]
                rep_code = codes[rep_i][:p * missing[-1]]
                made = self._lower(rep_code, by_id, missing, p)
                for nc in missing:
                    have[nc] = made[nc]
            for nc in cuts:                            # reinsert as newest
                cache[(body_ck, nc)] = have[nc]
            if self._lower_max is not None:
                while len(cache) > self._lower_max:
                    cache.pop(next(iter(cache)))       # oldest entry
                    stats["evictions"] += 1
            for i, nc in members:
                progs[i] = have[nc]
        return progs

    # ------------------------------------------------------------------
    # recipes: per concrete instruction instance, content-memoized
    # ------------------------------------------------------------------
    def _cell(self, name: str) -> int:
        c = self._cells.get(name)
        if c is None:
            c = self._cells[name] = len(self._cells)
        return c

    def _recipe(self, ins, by_id: dict) -> _Recipe:
        r = by_id.get(id(ins))
        if r is None:
            key = (ins.spec, tuple(sorted(ins.regs.items())), ins.value_hint)
            r = self._recipes_by_key.get(key)
            if r is None:
                r = self._build_recipe(ins)
                r.ckey = key
                self._recipes_by_key[key] = r
            by_id[id(ins)] = r
        return r

    def _build_recipe(self, ins) -> _Recipe:
        comp = self._comp
        idx = comp.index.idx[ins.spec]       # KeyError like isa[...]
        info = comp.index.specs[idx]
        if not comp.flags[idx] & F_PRESENT:  # KeyError like ua.behaviors[..]
            raise KeyError(ins.spec)
        regs = dict(ins.regs)
        for nm, ot in zip(info.op_names, info.op_otype):
            if nm not in regs and ot != IMM:
                regs[nm] = _implicit_reg(nm, ot)
        same = (len(info.same_reg_ops) >= 2
                and len({regs[n] for n in info.same_reg_ops}) == 1)
        use_sr = same and bool(comp.flags[idx] & F_HAS_SR)
        zero_nouop = bool(comp.sr_zero_nouop[idx] if use_sr
                          else comp.zero_nouop[idx])
        elim_period = int(comp.sr_elim_period[idx] if use_sr
                          else comp.elim_period[idx])
        div_extra = int(comp.sr_divider_extra[idx] if use_sr
                        else comp.divider_extra[idx])
        zero = info.zero_idiom and same
        if zero and zero_nouop:
            return _Recipe(_K_ZERO_NOUOP, 0, dest_cells=tuple(
                self._cell(regs[d]) for d in info.dest_names))
        off, cnt = comp.behavior_rows(idx, same)
        extra = div_extra if (ins.value_hint == "high" and not zero) else 0
        vis = 0 if zero else 1
        ignore_reads = zero
        snapshot = tuple((self._cell(regs.get(nm, nm)), chk, w)
                         for nm, chk, w in info.snapshot)
        snap_pos = {nm: i for i, (nm, _, _) in enumerate(info.snapshot)}
        syms = comp.syms[idx]
        plans = []
        issue_off = 0
        for j in range(cnt):
            row = off + j
            if comp.port_mask[row] == 0:   # 0-port μop: scalar skips it
                continue
            names = []
            for slot in comp.reads[row]:
                if slot < 0:
                    break
                names.append(info.op_names[slot] if slot < TEMP_BASE
                             else syms[slot - TEMP_BASE])
            prods = []
            if not ignore_reads:
                for nm in names:
                    if nm.startswith("%"):
                        prods.append((_P_TMP, nm))
                    elif nm in info.mem_read and info.mem_read[nm]:
                        prods.append((_P_MEM, self._cell(regs[nm])))
                    elif nm in snap_pos:
                        prods.append((_P_SNAP, snap_pos[nm]))
                    else:
                        prods.append((_P_CUR,
                                      self._cell(regs.get(nm, nm))))
            sf = any(nm in info.mem_read and info.mem_read[nm]
                     for nm in names)
            sf_cell = next((self._cell(regs[nm]) for nm in names
                            if nm in info.mem_read), -1)
            writes = []
            for slot in comp.writes[row]:
                if slot < 0:
                    break
                nm = (info.op_names[slot] if slot < TEMP_BASE
                      else syms[slot - TEMP_BASE])
                if nm.startswith("%"):
                    writes.append((_W_TMP, nm, None))
                elif nm in info.mem_read:
                    writes.append((_W_MEM, self._cell(regs[nm]), None))
                else:
                    try:
                        w = info.op_width[info.op_names.index(nm)]
                    except ValueError:
                        w = None
                    writes.append((_W_CELL, self._cell(regs.get(nm, nm)), w))
            occ = int(comp.occupancy[row]) + extra
            plans.append(_Plan(int(comp.mask_id[row]),
                               int(comp.latency[row]) + extra,
                               occ if occ > 1 else 1, vis, tuple(prods),
                               sf, sf_cell, tuple(writes), issue_off))
            issue_off += 1
        if info.may_eliminate and elim_period and not zero:
            return _Recipe(_K_ELIM, cnt, snapshot, tuple(plans),
                           period=elim_period, ekey=ins.spec,
                           src_cell=self._cell(regs[info.elim_src]),
                           dst_cell=self._cell(regs[info.dest_names[0]]))
        return _Recipe(_K_NORMAL, cnt, snapshot, tuple(plans))

    # ------------------------------------------------------------------
    # lowering: sequence -> flat tensors (with periodic-steady-state tiling)
    # ------------------------------------------------------------------
    def _lower(self, code, by_id: dict, cuts=None, period=None) -> dict:
        """Lower ``code`` (= body * ncopies) and materialize one
        :class:`_Prog` per requested copy count in ``cuts`` — shorter
        counts are prefix views of the full tensors."""
        comp = self._comp
        width = comp.issue_width
        penalty = comp.partial_stall_penalty
        sfl = comp.store_forward_latency
        n = len(code)
        p = period if period is not None else (
            _body_period([id(x) for x in code]) if n else 0)
        ncopies = n // p if p else 0
        if cuts is None:
            cuts = [ncopies]
        body = [self._recipe(ins, by_id) for ins in code[:p]]

        lw: dict = {}       # cell -> producing row
        wd: dict = {}       # cell -> width of last write
        ml: dict = {}       # mem cell -> producing (store) row
        ms: set = set()     # mem cells with a store seen
        ec: dict = {}       # elim spec key -> instance count
        ecp: dict = {}      # elim spec key -> period
        issue_l: list = []
        mask_l: list = []
        lat_l: list = []
        blk_l: list = []
        vis_l: list = []
        prods_l: list = []
        uop_counter = 0

        sig_map: dict = {}
        snaps: list = []    # per copy boundary: (rows, uops, lw, ml)
        tile = None

        def signature():
            nr = len(issue_l)
            return (uop_counter % width,
                    tuple(sorted((c, nr - r) for c, r in lw.items())),
                    tuple(sorted(wd.items())),
                    tuple(sorted((c, nr - r) for c, r in ml.items())),
                    tuple(sorted(ms)),
                    tuple(sorted((k, c % ecp[k]) for k, c in ec.items())))

        for i in range(ncopies):
            if ncopies > 1:
                sig = signature()
                c0 = sig_map.get(sig)
                if c0 is not None:
                    tile = (c0, i)
                    snaps.append((len(issue_l), uop_counter, dict(lw),
                                  dict(ml)))
                    break
                sig_map[sig] = i
            snaps.append((len(issue_l), uop_counter, dict(lw), dict(ml)))
            for r in body:
                k = r.kind
                if k == _K_ZERO_NOUOP:
                    for c in r.dest_cells:
                        lw.pop(c, None)
                    continue
                if k == _K_ELIM:
                    c = ec.get(r.ekey, 0)
                    ec[r.ekey] = c + 1
                    ecp[r.ekey] = r.period
                    if c % r.period:
                        s = lw.get(r.src_cell, -1)
                        if s < 0:
                            lw.pop(r.dst_cell, None)
                        else:
                            lw[r.dst_cell] = s
                        continue
                svals = [(lw.get(cell, -1),
                          penalty if (chk and w > wd.get(cell, 64)) else 0)
                         for cell, chk, w in r.snapshot]
                tmp: dict = {}
                for pl in r.plans:
                    row = len(issue_l)
                    prow = []
                    for kind, a in pl.prods:
                        if kind == _P_SNAP:
                            prow.append(svals[a])
                        elif kind == _P_TMP:
                            prow.append((tmp.get(a, -1), 0))
                        elif kind == _P_CUR:
                            prow.append((lw.get(a, -1), 0))
                        else:   # _P_MEM: reg base + memory value
                            prow.append((lw.get(a, -1), 0))
                            prow.append((ml.get(a, -1), 0))
                    lat = pl.lat
                    if pl.sf and pl.sf_cell in ms:
                        lat = min(lat, sfl)
                    issue_l.append((uop_counter + pl.issue_off) // width)
                    mask_l.append(pl.mask_id)
                    lat_l.append(lat)
                    blk_l.append(pl.blk)
                    vis_l.append(pl.vis)
                    prods_l.append(prow)
                    for wk, a, b in pl.writes:
                        if wk == _W_TMP:
                            tmp[a] = row
                        elif wk == _W_MEM:
                            ml[a] = row
                            ms.add(a)
                        else:
                            lw[a] = row
                            if b is not None:
                                wd[a] = b
                uop_counter += r.advance
        else:
            snaps.append((len(issue_l), uop_counter, dict(lw), dict(ml)))

        # native part -> arrays
        n_nat = len(issue_l)
        max_r = max((len(pr) for pr in prods_l), default=0)
        max_r = max(max_r, 1)
        issue = np.array(issue_l, np.int64) if n_nat else np.zeros(0, np.int64)
        mask = np.array(mask_l, np.int64) if n_nat else np.zeros(0, np.int64)
        lat = np.array(lat_l, np.int64) if n_nat else np.zeros(0, np.int64)
        blk = np.array(blk_l, np.int64) if n_nat else np.zeros(0, np.int64)
        vis = np.array(vis_l, np.int64) if n_nat else np.zeros(0, np.int64)
        prod = np.full((n_nat, max_r), -1, np.int64)
        delta = np.zeros((n_nat, max_r), np.int64)
        for j, pr in enumerate(prods_l):
            for kk, (pp, dd) in enumerate(pr):
                prod[j, kk] = pp
                delta[j, kk] = dd

        if tile is None:
            parts = [(issue, mask, lat, blk, vis, prod, delta)]
        else:
            c0, c1 = tile
            s0, u0 = snaps[c0][0], snaps[c0][1]
            s1, u1 = n_nat, uop_counter
            d_rows, d_uops = s1 - s0, u1 - u0
            assert d_uops % width == 0
            d_issue = d_uops // width
            per = c1 - c0
            rem = ncopies - c1
            full, left = divmod(rem, per)
            parts = [(issue, mask, lat, blk, vis, prod, delta)]
            if full:
                # all full periods in one broadcast: segment + q * shift
                q = np.arange(1, full + 1, dtype=np.int64)
                sl = slice(s0, s1)
                seg_p = prod[sl]
                pt = np.where(seg_p[None] >= 0,
                              seg_p[None] + (q * d_rows)[:, None, None], -1)
                parts.append((
                    (issue[sl][None] + (q * d_issue)[:, None]).reshape(-1),
                    np.tile(mask[sl], full), np.tile(lat[sl], full),
                    np.tile(blk[sl], full), np.tile(vis[sl], full),
                    pt.reshape(-1, max_r),
                    np.tile(delta[sl], (full, 1))))
            if left:
                sl = slice(s0, snaps[c0 + left][0])
                pr = prod[sl]
                qq = full + 1
                parts.append((issue[sl] + qq * d_issue, mask[sl], lat[sl],
                              blk[sl], vis[sl],
                              np.where(pr >= 0, pr + qq * d_rows, -1),
                              delta[sl]))
        if len(parts) > 1:
            issue = np.concatenate([x[0] for x in parts])
            mask = np.concatenate([x[1] for x in parts])
            lat = np.concatenate([x[2] for x in parts])
            blk = np.concatenate([x[3] for x in parts])
            vis = np.concatenate([x[4] for x in parts])
            prod = np.concatenate([x[5] for x in parts])
            delta = np.concatenate([x[6] for x in parts])
        # cached tensors are int32: every simulated quantity fits (cycles,
        # rows, counts < 2^31 - 1 — the device kernels reserve INT32_MAX
        # as the disallowed-port dispatch sentinel) and run int32 natively
        issue = issue.astype(np.int32)
        mask = mask.astype(np.int32)
        lat = lat.astype(np.int32)
        blk = blk.astype(np.int32)
        vis = vis.astype(np.int32)
        prod = prod.astype(np.int32)
        delta = delta.astype(np.int32)

        def boundary(b):
            """(rows, row shift, reg cells, mem cells) after ``b`` copies."""
            if tile is None or b <= tile[1]:
                rows_b, _, lwb, mlb = snaps[b]
                return rows_b, 0, lwb, mlb
            qb, rb = divmod(b - c0, per)
            rows_b = s0 + qb * d_rows + (snaps[c0 + rb][0] - s0)
            return rows_b, qb * d_rows, snaps[c0 + rb][2], snaps[c0 + rb][3]

        made: dict = {}
        for b in cuts:
            rows_b, sh, lwb, mlb = boundary(b)
            fin = sorted({r + sh for r in lwb.values()}
                         | {r + sh for r in mlb.values()})
            made[b] = _Prog(rows_b, issue[:rows_b], mask[:rows_b],
                            lat[:rows_b], blk[:rows_b], vis[:rows_b],
                            prod[:rows_b], delta[:rows_b],
                            np.array(fin, np.int64), max_r)
        return made

    # ------------------------------------------------------------------
    # packing: chunk -> bucket tensors (vectorized NumPy scatter)
    # ------------------------------------------------------------------
    def _pack_chunk(self, chunk, progs, bufs=None) -> _ChunkPack:
        """Pack a chunk's lowered programs into wave tensors with sliced
        scatters (one concatenate + one fancy-index assignment per tensor,
        not a per-experiment Python loop).

        ``bufs`` reuses a device bucket buffer set in *lane-major*
        ``(E, S)`` layout — the scatter then writes each lane's rows to
        consecutive addresses, and the device kernel transposes once on
        device instead of the host scattering strided.  Only ``valid`` and
        ``vis`` are re-zeroed on reuse; every other stale cell is gated
        off by ``valid`` in the device kernels.  ``None`` allocates fresh
        exact-shape ``(S, E)`` arrays for the numpy kernel (which walks μop
        rows and relies on zeroed padding)."""
        E0 = len(chunk)
        gs = [progs[i] for i in chunk]
        S0 = max(g.n_rows for g in gs)
        R0 = max(g.max_r for g in gs)
        lane_major = bufs is not None
        if bufs is None:
            S, E, R = S0, E0, max(R0, 1)
            issue = np.zeros((S, E), np.int32)
            mask = np.zeros((S, E), np.int32)
            lat = np.zeros((S, E), np.int32)
            blk = np.zeros((S, E), np.int32)
            valid = np.zeros((S, E), bool)
            prod = np.full((S, E, R), -1, np.int32)
            delta = np.zeros((S, E, R), np.int32)
            vis = np.zeros((E, S), np.int32)
        else:
            issue, mask, lat, blk, valid, prod, delta, vis = bufs
            E, S = issue.shape
            R = prod.shape[2]
            valid[:] = False
            vis[:] = 0
        pk = _ChunkPack(chunk, gs, S0, E0, R0, issue, mask, lat, blk,
                        valid, prod, delta, vis)
        if S0 == 0:
            return pk
        if lane_major:
            # lane-major: one contiguous slice copy per lane per tensor —
            # every write lands on consecutive addresses
            for e, g in enumerate(gs):
                m = g.n_rows
                if not m:
                    continue
                issue[e, :m] = g.issue
                mask[e, :m] = g.mask
                lat[e, :m] = g.lat
                blk[e, :m] = g.blk
                valid[e, :m] = True
                vis[e, :m] = g.vis
                r = g.max_r
                prod[e, :m, :r] = g.prod
                delta[e, :m, :r] = g.delta
                if r < R:
                    # the kernels read ALL R producer columns of a valid
                    # row — stale values from a previous occupant of this
                    # reused buffer are only row-gated, never column-gated
                    prod[e, :m, r:] = -1
                    delta[e, :m, r:] = 0
            return pk
        # row-major (numpy kernel): one concatenate + fancy scatter per
        # tensor instead of E strided per-lane column writes
        lens = np.fromiter((g.n_rows for g in gs), np.int64, E0)
        total = int(lens.sum())
        if not total:
            return pk
        cols = np.repeat(np.arange(E0), lens)
        starts = np.concatenate(([0], np.cumsum(lens)[:-1]))
        rows = np.arange(total) - np.repeat(starts, lens)
        issue[rows, cols] = np.concatenate([g.issue for g in gs])
        mask[rows, cols] = np.concatenate([g.mask for g in gs])
        lat[rows, cols] = np.concatenate([g.lat for g in gs])
        blk[rows, cols] = np.concatenate([g.blk for g in gs])
        valid[rows, cols] = True
        for e, g in enumerate(gs):          # vis is lane-major everywhere
            if g.n_rows:
                vis[e, :g.n_rows] = g.vis
        # producers: lanes grouped by their program's read width, one
        # sliced scatter per distinct width (few in practice)
        widths = {g.max_r for g in gs if g.n_rows}
        for r in sorted(widths):
            if len(widths) == 1:
                m = slice(None)
                sel = [bool(g.n_rows) for g in gs]
            else:
                sel = [g.max_r == r and g.n_rows > 0 for g in gs]
                m = np.asarray(sel, bool)[cols]
            prod[rows[m], cols[m], :r] = np.concatenate(
                [g.prod for g, s in zip(gs, sel) if s])
            delta[rows[m], cols[m], :r] = np.concatenate(
                [g.delta for g, s in zip(gs, sel) if s])
        return pk

    # ------------------------------------------------------------------
    # extraction: kernel outputs -> Counters (one gather per wave)
    # ------------------------------------------------------------------
    def _fill_empty(self, chunk, out) -> None:
        overhead = self._comp.overhead_cycles
        for i in chunk:
            out[i] = Counters(float(overhead),
                              {p: 0 for p in self.uarch.ports})

    def _extract(self, pk: _ChunkPack, done, counts, out) -> None:
        """Batched Counters extraction: per-lane end times via one masked
        max + one scatter-max over final-writer rows, port counts via one
        ``tolist`` gather.  ``done`` is lane-major ``(E, S)`` (the numpy
        kernel hands in a transposed view)."""
        comp = self._comp
        E0, S0 = pk.E, pk.S
        core = (done[:E0, :S0] * pk.vis[:E0, :S0]).max(axis=1)
        fins = [(e, g.finals) for e, g in enumerate(pk.lane_progs)
                if g.finals.size]
        if fins:
            lanes = np.concatenate(
                [np.full(f.size, e, np.int64) for e, f in fins])
            rows = np.concatenate([f for _, f in fins])
            np.maximum.at(core, lanes, done[lanes, rows])
        overhead = comp.overhead_cycles
        ports = list(self.uarch.ports)
        perm = [comp.port_pos[p] for p in ports]
        cnt = counts[:E0][:, perm].tolist()
        for e, i in enumerate(pk.chunk):
            out[i] = Counters(float(int(core[e]) + overhead),
                              dict(zip(ports, cnt[e])))

    # ------------------------------------------------------------------
    # kernels
    # ------------------------------------------------------------------
    def _kernel_numpy(self, pk: _ChunkPack):
        comp = self._comp
        S, E = pk.S, pk.E
        issue, mask, lat, blk = pk.issue, pk.mask, pk.lat, pk.blk
        valid, prod, delta = pk.valid, pk.prod, pk.delta
        P = len(comp.ports)
        rows = np.arange(E)
        rows1 = rows[:, None]
        done = np.zeros((S, E), np.int32)
        port_free = np.zeros((E, P), np.int32)
        # dispatch tie-break key low bits: μop count (shifted) | port axis,
        # so one argmin realizes the scalar's (time, load, port) ordering.
        # Field widths are sized per chunk: the port axis needs
        # ``idx_bits``, counts are bounded by S, and time gets the rest.
        idx_bits = max((P - 1).bit_length(), 1)
        cnt_shift = (S << idx_bits).bit_length()
        pc_key = np.tile(np.arange(P, dtype=np.int64), (E, 1))
        big = np.iinfo(np.int64).max
        allowed = comp.mask_table[mask]                         # (S, E, P)
        prod_neg = prod < 0
        prod_c = np.maximum(prod, 0)
        vinc = valid.astype(np.int64) << idx_bits  # gated count increments
        # padding rows sit *after* each lane's real rows, so their (gated
        # out of the counts) dispatches cannot perturb any real result
        for j in range(S):
            val = np.where(prod_neg[j],
                           0, done[prod_c[j], rows1]) + delta[j]   # (E, R)
            ready = np.maximum(issue[j], val.max(axis=1))
            t = np.maximum(ready[:, None], port_free)
            key = np.where(allowed[j],
                           (t.astype(np.int64) << cnt_shift) + pc_key, big)
            best = key.argmin(axis=1)
            tmin = t[rows, best]
            done[j] = tmin + lat[j]
            port_free[rows, best] = tmin + blk[j]
            pc_key[rows, best] += vinc[j]
        return done, (pc_key >> idx_bits).astype(np.int32)

    # -- device backends (jax scan / pallas) ---------------------------
    def _device_exec(self, kind: str):
        """Lazy per-backend device executor.  The machine's configured
        backend keeps the historical ``_device`` slot (``set_devices``
        drops it for rebuild); degraded-backend executors are cached
        separately and share the same resolved device placement."""
        from repro.core.device_mesh import resolve_devices  # noqa: PLC0415
        with self._host_lock:
            if kind == self.backend:
                if self._device is None:
                    self._device = _DeviceExec(
                        self._comp, kind,
                        devices=resolve_devices(self.devices),
                        min_lanes=self.min_lanes)
                return self._device
            dev = self._device_fb.get(kind)
            if dev is None:
                dev = self._device_fb[kind] = _DeviceExec(
                    self._comp, kind,
                    devices=resolve_devices(self.devices),
                    min_lanes=self.min_lanes)
            return dev

    def _run_device(self, batched, codes, progs, out, kernel_lock,
                    kind: str) -> None:
        """Pipelined, lane-sharded device execution: each chunk is split
        into per-core lane shards whose kernels run concurrently on the
        device pool (the kernels release the GIL), and chunk k+1 is packed
        on the host while chunk k executes (double-buffered bucket slots —
        a slot is only reused once its chunk's results have been
        *extracted*, not merely once its kernel finished: host buffers may
        be aliased zero-copy onto the device, and extraction still reads
        the slot's ``vis`` plane through the :class:`_ChunkPack` views).
        ``kernel_lock`` is held only around kernel dispatch, never around
        host packing or result waits."""
        from collections import deque  # noqa: PLC0415
        dev = self._device_exec(kind)
        pending: deque = deque()
        jobs: list = []
        try:
            for c in batched:
                if max(progs[i].n_rows for i in c) == 0:
                    self._fill_empty(c, out)
                    continue
                if faults.active():
                    faults.check_wave("wave.kernel",
                                      [_fault_key(codes[i]) for i in c],
                                      backend=kind)
                jobs = []
                with obs.span("wave.pack", lanes=len(c)) as psp:
                    if faults.active():
                        faults.check("wave.pack", backend=kind)
                    for sc in dev.shard(c, progs):
                        S0 = max(progs[i].n_rows for i in sc)
                        if S0 == 0:    # a shard of all-zero-μop programs
                            self._fill_empty(sc, out)
                            continue
                        R0 = max(max(progs[i].max_r for i in sc), 1)
                        slot = dev.acquire(S0, len(sc), R0)
                        pk = self._pack_chunk(sc, progs, bufs=slot.bufs)
                        jobs.append((pk, slot))
                    psp.set(shards=len(jobs))
                if not jobs:
                    continue
                with obs.span("wave.dispatch", shards=len(jobs)):
                    futs = dev.dispatch(jobs, kernel_lock)
                pending.append((jobs, futs))
                while len(pending) > 1:
                    self._finalize_device(*pending.popleft(), out)
            while pending:
                self._finalize_device(*pending.popleft(), out)
        except BaseException:
            # error path: slots must not stay leased forever (a transient
            # kernel failure would otherwise leak pinned buffers on every
            # wave).  The current chunk's slots have no dispatched
            # kernels if it never reached pending; dispatched chunks go
            # through _abort_jobs, which waits out in-flight kernels
            if not pending or pending[-1][0] is not jobs:
                for _, slot in jobs:
                    slot.release()
            while pending:
                _abort_jobs(*pending.popleft())
            raise

    def _finalize_device(self, jobs, futs, out) -> None:
        try:
            for (pk, slot), fut in zip(jobs, futs):
                # result_wait is kernel flight (device time the host spends
                # blocked on), extract is host gather work — trace them
                # apart so the report can tell device-bound from host-bound
                with obs.span("wave.result_wait", lanes=pk.E):
                    done, counts = fut.result()  # blocks until shard ends
                with obs.span("wave.extract", lanes=pk.E):
                    self._extract(pk, done, counts, out)
                # only now is the slot reusable: _extract read pk.vis,
                # which aliases the slot's vis buffer — releasing at
                # dispatch would let a fast same-bucket chunk k+1 re-zero
                # it mid-extraction
                slot.release()
        except BaseException:
            _abort_jobs(jobs, futs)
            raise


class _DeviceExec:
    """Per-machine device execution state: AOT-compiled kernels per shape
    bucket, the device-resident μop mask LUT, a small kernel thread pool
    (lane shards execute concurrently — the compiled kernels release the
    GIL), and recycled per-bucket packing-buffer slots whose lease lasts
    until their chunk's results are extracted (host buffers can be
    zero-copy aliases on device, and extraction reads the slot's ``vis``
    plane).

    With more than one resolved device the executor runs in **mesh
    mode**: each chunk's lanes are sharded across a 1-D ``lanes`` mesh
    (``shard_map`` over the bucketed kernel, lane-axis
    ``PartitionSpec``), with the chunk padded to a lanes-divisible bucket
    width so every device runs one equal lane block of the same
    executable.  Buffer slots are pooled per ``(bucket, mesh width)`` —
    the per-device pools of the lease protocol — and kernel dispatch is
    serialized by the executor's **per-device-subset lock** (see
    :func:`repro.core.device_mesh.dispatch_lock`) instead of the
    campaign-wide execute lock, so machines placed on disjoint device
    subsets never serialize each other's kernels."""

    _BUCKETS_MAX = 8     # bucket slot-ring pool bound (LRU)
    _SHARD_MIN_LANES = 64

    def __init__(self, comp: CompiledUArch, kind: str, devices=(),
                 min_lanes: int = DEFAULT_MIN_LANES):
        import os  # noqa: PLC0415
        from repro.core.device_mesh import (  # noqa: PLC0415
            dispatch_lock, jax_devices)
        self.comp = comp
        self.kind = kind
        self.devices = tuple(devices)
        self.min_lanes = max(min_lanes, 1)
        all_devs = jax_devices()
        default = all_devs[0] if all_devs else None
        # mesh mode whenever the placement is not simply "the default
        # device": >1 device shards lanes; a single non-default device
        # (campaign placement with more machines than devices) still needs
        # the mesh wrapper to pin its kernels to that device
        self.mesh_mode = bool(self.devices) and (
            len(self.devices) > 1
            or (default is not None and self.devices[0].id != default.id))
        self.n_mesh = len(self.devices) if self.mesh_mode else 1
        self.lut = None if self.mesh_mode else comp.device_mask_table()
        self._luts: dict = {}    # mesh width -> replicated device LUT
        # per-subset dispatch lock (module-wide): machines sharing this
        # device subset serialize host-side dispatch on it; disjoint
        # subsets dispatch concurrently
        self.dispatch_lock = dispatch_lock(
            self.devices or ((default,) if default is not None else ()))
        self.compiles = 0
        self.kernel_calls = 0
        self.buckets: set = set()
        # per-device telemetry: device id -> counters (a mesh dispatch
        # counts on every participating device)
        self.per_device: dict = {
            d.id: {"compiles": 0, "kernel_calls": 0, "lanes": 0,
                   "buckets": set()}
            for d in (self.devices or ((default,) if default else ()))}
        self.n_workers = max(1, os.cpu_count() or 1)
        self._pool = None
        self._lock = threading.Lock()   # guards slot leasing / ring LRU
        self._rings: dict = {}   # bucket -> slot list (LRU by bucket)
        # per-device kernel wall time + straggler EWMA, fed by the traced
        # kernel path (the observe hook on _run_kernel); flagged outliers
        # surface through stats() and the analyze.py wave report
        self.kernel_ns: dict = {}      # "device:<id>" -> total kernel ns
        self.straggler = StragglerDetector()

    def _observe(self, tracks, dur_ns: int) -> None:
        """Traced-kernel callback (runs on a pool thread): accumulate
        per-device kernel time and feed the straggler EWMA with each
        device's share of the shard interval."""
        with self._lock:
            for label, _ in tracks:
                self.kernel_ns[label] = self.kernel_ns.get(label, 0) \
                    + dur_ns
                self.straggler.observe(label, dur_ns / 1e9)

    def stats(self) -> dict:
        out = {"backend": self.kind, "compiles": self.compiles,
               "kernel_calls": self.kernel_calls,
               "buckets": sorted(self.buckets),
               "mesh": self.mesh_mode,
               "devices": [d.id for d in self.devices],
               "per_device": {
                   did: {"compiles": c["compiles"],
                         "kernel_calls": c["kernel_calls"],
                         "lanes": c["lanes"],
                         "buckets": sorted(c["buckets"])}
                   for did, c in self.per_device.items()}}
        with self._lock:
            if self.kernel_ns:   # only traced waves populate these
                out["kernel_ns"] = dict(sorted(self.kernel_ns.items()))
                out["stragglers"] = self.straggler.snapshot()
        return out

    # -- lane sharding --------------------------------------------------
    def shard(self, chunk, progs) -> list:
        """Split a chunk into contiguous per-core lane shards (the chunk
        arrives sorted by descending length, so later shards pad to a
        smaller S bucket).  In mesh mode the chunk stays whole: per-device
        subdivision happens through the lane-axis sharding of one fused
        kernel, not through separate host-dispatched shards."""
        if self.mesh_mode:
            return [chunk]
        E0 = len(chunk)
        n = min(self.n_workers, E0 // self._SHARD_MIN_LANES)
        if n <= 1:
            return [chunk]
        per = (E0 + n - 1) // n
        return [chunk[k:k + per] for k in range(0, E0, per)]

    def mesh_width(self, E0: int) -> int:
        """Devices used for an ``E0``-lane chunk: capped so every
        per-device lane shard keeps at least ``min_lanes`` lanes — the
        thin-chunk scalar crossover applies to the *per-device shard
        width*, not the whole wave (a wave wide enough in total but thin
        per device runs on fewer devices instead of paying kernel
        overhead on sub-crossover shards)."""
        return max(1, min(self.n_mesh, E0 // self.min_lanes))

    # -- buckets / buffer slots ----------------------------------------
    @staticmethod
    def bucket_shape(S0: int, E0: int, R0: int) -> tuple:
        return (_bucket(S0, 32), _bucket(E0, 8), _next_pow2(R0))

    def _mesh_bucket(self, S0: int, E0: int, R0: int, n_use: int) -> tuple:
        """Mesh-mode bucket: lane width padded per device and multiplied
        back up, so the global width is lanes-divisible (every device gets
        one equal ``E_dev`` block of the same bucketed executable)."""
        e_dev = _bucket((E0 + n_use - 1) // n_use, 8)
        return (_bucket(S0, 32), e_dev * n_use, _next_pow2(R0))

    def acquire(self, S0: int, E0: int, R0: int) -> "_BufSlot":
        """Lease a packing-buffer slot for one shard.  A slot stays leased
        from here until :meth:`~_BufSlot.release` in ``_finalize_device``
        — through packing, kernel flight, AND extraction (two shards of
        one chunk often share a bucket and must never share buffers; the
        kernel may read the buffers as zero-copy device aliases; and
        extraction still reads the slot's ``vis`` plane).  If every slot
        is leased a new one is allocated: live slots are bounded by the
        lease discipline itself (pipeline depth x shards per chunk), so
        the ring never grows past warm steady state.  Mutex-guarded so
        concurrent ``run_batch`` callers can never double-lease a slot.

        In mesh mode the slot pool is keyed by ``(bucket, mesh width)`` —
        per-device buffer pools: a slot's buffers are sharded onto the
        first ``n_use`` devices at dispatch, so slots of different mesh
        widths never alias and a reused slot always re-shards onto the
        same device subset."""
        if self.mesh_mode:
            n_use = self.mesh_width(E0)
            shape = self._mesh_bucket(S0, E0, R0, n_use)
            key = shape + (n_use,)
        else:
            n_use = None
            shape = self.bucket_shape(S0, E0, R0)
            key = shape
        with self._lock:
            ring = self._rings.get(key)
            if ring is None:
                while len(self._rings) >= self._BUCKETS_MAX:
                    self._rings.pop(next(iter(self._rings)))
                ring = self._rings[key] = []
            else:
                self._rings[key] = self._rings.pop(key)   # LRU touch
            for slot in ring:   # a released slot has been fully extracted
                if not slot.leased:
                    slot.leased = True
                    return slot
            slot = _BufSlot(self._alloc(*shape), n_use)
            ring.append(slot)
            slot.leased = True
            return slot

    @staticmethod
    def _alloc(S, E, R):
        return (np.zeros((E, S), np.int32), np.zeros((E, S), np.int32),
                np.zeros((E, S), np.int32), np.zeros((E, S), np.int32),
                np.zeros((E, S), bool), np.full((E, S, R), -1, np.int32),
                np.zeros((E, S, R), np.int32), np.zeros((E, S), np.int32))

    # -- dispatch -------------------------------------------------------
    def _get_pool(self):
        with self._lock:   # concurrent callers must not each build a pool
            if self._pool is None:
                from concurrent.futures import (  # noqa: PLC0415
                    ThreadPoolExecutor)
                self._pool = ThreadPoolExecutor(
                    max_workers=self.n_workers,
                    thread_name_prefix="batch-sim-kernel")
            return self._pool

    def _mesh_lut(self, n_use: int):
        """The μop port-mask LUT replicated across the first ``n_use``
        mesh devices (resident per mesh width, transferred once)."""
        lut = self._luts.get(n_use)
        if lut is None:
            import jax  # noqa: PLC0415
            from repro.core.device_mesh import lane_mesh  # noqa: PLC0415
            mesh = lane_mesh(self.devices[:n_use])
            lut = jax.device_put(self.comp.mask_table, mesh.replicated)
            self._luts[n_use] = lut
        return lut

    def _record(self, devs, bucket, compiled_now, E0, e_dev) -> None:
        """Per-device telemetry for one dispatch: every participating
        device counts the call; real (non-padding) lanes are attributed
        by their contiguous block position."""
        for k, d in enumerate(devs):
            c = self.per_device.setdefault(
                d.id, {"compiles": 0, "kernel_calls": 0, "lanes": 0,
                       "buckets": set()})
            c["kernel_calls"] += 1
            c["compiles"] += 1 if compiled_now else 0
            c["buckets"].add(bucket)
            c["lanes"] += max(0, min(E0 - k * e_dev, e_dev))

    def dispatch(self, jobs, kernel_lock=None) -> list:
        """Enqueue one kernel call per shard on the device pool; returns
        one future per job yielding host ``(done, counts)`` arrays.
        Dispatch is guarded by the executor's per-device-subset lock —
        NOT the campaign-wide ``kernel_lock`` (accepted for protocol
        compatibility, unused here): only the enqueue is host-side Python,
        execution parallelism is the pool's and the devices' (compiled
        kernels release the GIL), and machines placed on disjoint device
        subsets must never serialize each other's kernels."""
        if faults.active():
            faults.check("device.dispatch", backend=self.kind)
        pool = self._get_pool()
        M, P = self.comp.mask_table.shape
        traced = obs.enabled()
        calls = []
        for pk, slot in jobs:
            E, S = pk.issue.shape
            R = pk.prod.shape[2]
            if slot.n_use is not None:          # mesh-mode shard
                from repro.core.device_mesh import (  # noqa: PLC0415
                    lane_mesh)
                n_use = slot.n_use
                e_dev = E // n_use
                mesh = lane_mesh(self.devices[:n_use])
                fn, compiled_now = _compiled_kernel(
                    self.kind, S, e_dev, R, M, P, mesh=mesh)
                lut = self._mesh_lut(n_use)
                devs = mesh.devices
                self._record(devs, (S, e_dev, R), compiled_now,
                             pk.E, e_dev)
            else:
                n_use, e_dev = 1, E
                fn, compiled_now = _compiled_kernel(self.kind, S, E, R,
                                                    M, P)
                lut = self.lut
                devs = self.devices[:1]
                self._record(devs, (S, E, R), compiled_now, pk.E, E)
            if compiled_now:
                self.compiles += 1
            self.buckets.add((S, E, R))
            self.kernel_calls += 1
            # per-device kernel spans: each participating device's track
            # gets the shard's kernel interval with its real lane share
            tracks = tuple(
                (f"device:{d.id}",
                 max(0, min(pk.E - k * e_dev, e_dev)))
                for k, d in enumerate(devs)) if traced else ()
            calls.append((fn, (pk.issue, pk.mask, pk.lat, pk.blk, pk.valid,
                               pk.prod, pk.delta, lut), tracks))
        with obs.wait_lock(self.dispatch_lock, "wave.dispatch_lock_wait"):
            # untraced waves keep the legacy 2-arg call (tests monkeypatch
            # _run_kernel with that signature to inject kernel failures)
            if traced:
                futs = [pool.submit(_run_kernel, fn, args, tracks,
                                    self._observe)
                        for fn, args, tracks in calls]
            else:
                futs = [pool.submit(_run_kernel, fn, args)
                        for fn, args, _ in calls]
        # the slots stay leased: ``_finalize_device`` releases them only
        # after extraction, which reads the slots' vis buffers
        return futs


class _BufSlot:
    """One recycled packing-buffer set.  ``leased`` is True from
    ``_DeviceExec.acquire`` until :meth:`release` after the chunk's
    results are *extracted* — kernel completion alone does not free the
    slot, because extraction reads the slot's ``vis`` plane through the
    :class:`_ChunkPack` views (and the kernel may have read the buffers
    as zero-copy device aliases).  ``n_use`` records the mesh width the
    slot was bucketed for (``None`` on the single-device path): the
    dispatcher shards the slot's buffers across exactly that many
    devices, so slots are effectively pooled per device subset."""
    __slots__ = ("bufs", "leased", "n_use")

    def __init__(self, bufs, n_use=None):
        self.bufs = bufs
        self.leased = False
        self.n_use = n_use

    def release(self) -> None:
        self.leased = False


def _abort_jobs(jobs, futs) -> None:
    """Error-path slot cleanup: wait for every dispatched shard kernel to
    settle (a still-running kernel may be reading the slot's buffers,
    possibly as zero-copy device aliases) and release every slot —
    idempotent, so jobs already released by the success path are fine."""
    for (_, slot), fut in zip(jobs, futs):
        try:
            fut.exception()          # blocks until the kernel settles
        except BaseException:        # cancelled: the kernel never ran
            pass
        slot.release()


def _run_kernel(fn, args, tracks=(), observe=None):
    """Pool worker: execute one compiled shard kernel and realize its
    outputs on the host (so finalization only touches host arrays; the
    packing buffers themselves stay leased until extraction).

    ``tracks`` — when tracing is on — attributes the kernel interval to
    every participating device's ``device:<id>`` trace track with that
    device's real lane share (how per-device timelines and imbalance
    appear in the wave report); ``observe`` additionally feeds the
    executor's per-device kernel-time counters and straggler EWMA."""
    if not tracks:
        done, counts = fn(*args)
        return np.asarray(done), np.asarray(counts)
    import time  # noqa: PLC0415
    t0 = time.perf_counter_ns()
    done, counts = fn(*args)
    out = np.asarray(done), np.asarray(counts)
    dur = time.perf_counter_ns() - t0
    for label, lanes in tracks:
        obs.emit_span("wave.kernel", t0, dur, track=label, lanes=lanes)
    if observe is not None:
        observe(tracks, dur)
    return out


# ---------------------------------------------------------------------------
# compiled device kernels (module-wide: shared across machines per shape)
# ---------------------------------------------------------------------------

_JAX = ()


def _jax():
    global _JAX
    if _JAX == ():
        try:
            import jax  # noqa: F401
            _JAX = jax
        except ImportError:
            _JAX = None
    return _JAX


_EXEC_CACHE: dict = {}
_EXEC_CACHE_MAX = 128
_EXEC_LOCK = threading.Lock()


def _compiled_kernel(kind: str, S: int, E: int, R: int, M: int, P: int,
                     mesh=None):
    """AOT-compiled dispatch kernel for one shape bucket.  Returns
    ``(callable, compiled_now)``; the executable cache is module-wide, so
    machines sharing bucket shapes share compilations — and a module lock
    keeps concurrent campaign workers from paying for the same multi-
    second XLA compile twice.

    With ``mesh`` (a :class:`~repro.core.device_mesh.LaneMesh`) the
    bucketed kernel is wrapped in ``shard_map`` over the mesh's ``lanes``
    axis: ``E`` is then the *per-device* lane width and the executable
    takes ``(E * mesh.n, S)``-shaped operands whose lane blocks land one
    per device.  Executables are device-bound, so the mesh's device-id
    tuple is part of the cache key."""
    jax = _jax()
    key = (kind, S, E, R, M, P) + ((mesh.key,) if mesh is not None else ())
    hit = _EXEC_CACHE.get(key)
    if hit is not None:
        return hit, False
    with _EXEC_LOCK:
        hit = _EXEC_CACHE.get(key)      # double-check under the lock
        if hit is not None:
            return hit, False
        with obs.span("wave.compile", backend=kind, bucket=list(key[1:6])):
            return _compile_kernel(jax, kind, key, mesh), True


def _compile_kernel(jax, kind, key, mesh=None):
    S, E, R, M, P = key[1:6]
    import jax.numpy as jnp

    fn = (_build_pallas_fn(S, E, R, M, P) if kind == "pallas"
          else _build_scan_fn())
    if mesh is None:
        shapes = (jax.ShapeDtypeStruct((E, S), jnp.int32),
                  jax.ShapeDtypeStruct((E, S), jnp.int32),
                  jax.ShapeDtypeStruct((E, S), jnp.int32),
                  jax.ShapeDtypeStruct((E, S), jnp.int32),
                  jax.ShapeDtypeStruct((E, S), jnp.bool_),
                  jax.ShapeDtypeStruct((E, S, R), jnp.int32),
                  jax.ShapeDtypeStruct((E, S, R), jnp.int32),
                  jax.ShapeDtypeStruct((M, P), jnp.bool_))
    else:
        from jax.experimental.shard_map import shard_map  # noqa: PLC0415
        # the per-shard fn sees (E, S) blocks; lanes are independent, so
        # no collectives and no replication to check
        fn = shard_map(
            fn, mesh=mesh.mesh,
            in_specs=(mesh.spec2,) * 5 + (mesh.spec3,) * 2
            + (mesh.repl_spec,),
            out_specs=(mesh.spec2, mesh.spec2), check_rep=False)
        Eg = E * mesh.n
        sd = jax.ShapeDtypeStruct
        shapes = (sd((Eg, S), jnp.int32, sharding=mesh.shard2),
                  sd((Eg, S), jnp.int32, sharding=mesh.shard2),
                  sd((Eg, S), jnp.int32, sharding=mesh.shard2),
                  sd((Eg, S), jnp.int32, sharding=mesh.shard2),
                  sd((Eg, S), jnp.bool_, sharding=mesh.shard2),
                  sd((Eg, S, R), jnp.int32, sharding=mesh.shard3),
                  sd((Eg, S, R), jnp.int32, sharding=mesh.shard3),
                  sd((M, P), jnp.bool_, sharding=mesh.replicated))
    # donation lets XLA alias the bucket input buffers for outputs; it is
    # unimplemented on the CPU backend (emits warnings), so gate on device
    donate = tuple(range(7)) if jax.default_backend() != "cpu" else ()
    compiled = jax.jit(fn, donate_argnums=donate).lower(*shapes).compile()
    while len(_EXEC_CACHE) >= _EXEC_CACHE_MAX:
        _EXEC_CACHE.pop(next(iter(_EXEC_CACHE)))
    _EXEC_CACHE[key] = compiled
    return compiled


def _scan_block(S: int) -> int:
    """Inner scan-block length: the history buffer is updated once per
    block (one contiguous ``(K, E)`` write), so the per-step loop carries
    only a small block of ``done`` values — carrying (and copying) the
    whole ``(S, E)`` history every step is what made the naive scan lose
    to the numpy kernel.  Shape buckets are ``32*2^k`` or ``48*2^k``, so
    one of these always divides S exactly."""
    for k in (32, 48, 16, 8, 4, 2):
        if S % k == 0:
            return k
    return 1


def _build_scan_fn():
    """The ``lax.scan`` dispatch kernel: one step per μop position, all
    experiment lanes advancing in lockstep.  Two-level structure: an outer
    scan over blocks of K μop positions gathers every finished-block
    producer value in one pass and writes the block's ``done`` values back
    to the history with one contiguous update; the inner scan resolves
    intra-block producers from its small ``(K, E)`` carry.  The dispatch
    tie-break is the two-pass min (earliest time, then least load, then
    lowest port index on the *sorted* port axis) — pinned equivalent to
    the numpy kernel's packed-key argmin by the tie-break differential
    tests."""
    import jax.numpy as jnp
    from jax import lax

    def run(issue_l, mask_l, lat_l, blk_l, valid_l, prod_l, delta_l, lut):
        # inputs arrive lane-major (E, S) — the host packs each lane's
        # rows contiguously; one on-device transpose beats a strided
        # host scatter into row-major buffers
        issue = issue_l.T
        mask_id = mask_l.T
        lat = lat_l.T
        blk = blk_l.T
        valid = valid_l.T
        prod = prod_l.transpose(1, 0, 2)
        delta = delta_l.transpose(1, 0, 2)
        S, E = issue.shape
        R = prod.shape[2]
        K = _scan_block(S)
        nb = S // K
        # disallowed-port sentinel: INT32_MAX, matching the numpy kernel's
        # int64-max (real candidate times stay below it over the whole
        # documented cycles < 2^31 - 1 envelope; count keys are far
        # smaller), so a disallowed port can never win either min pass
        big = jnp.int32(2**31 - 1)
        P = lut.shape[1]
        # the (count << idx_bits | port) dispatch key: one int32 per port,
        # so the tie-break needs a single min+argmin pass (the numpy
        # kernel's packed-key ordering, realized in two int32 fields)
        idx_bits = max((P - 1).bit_length(), 1)
        pcp0 = jnp.arange(P, dtype=jnp.int32)
        lanes = jnp.arange(E, dtype=jnp.int32)
        # per-μop allowed-port rows, expanded once outside the loop (one
        # vectorized LUT gather instead of one per step)
        allowed = lut[mask_id]                                  # (S,E,P)
        # producer indices flattened for one-gather resolution: in-block
        # rows resolve against the running block, finished rows against
        # the history — both masks precomputed for the whole program
        in_block = prod >= (jnp.arange(S, dtype=jnp.int32)
                            // K * K)[:, None, None]            # (S,E,R)
        rel_flat = (jnp.clip(prod % jnp.int32(K), 0, K - 1) * E
                    + lanes[None, :, None])                     # (S,E,R)
        hist_flat = jnp.clip(prod, 0, S - 1) * E + lanes[None, :, None]
        prod_neg = prod < 0

        def per_block(a):
            return a.reshape((nb, K) + a.shape[1:])

        def block(carry, xsb):
            hist, pf, pcp = carry           # (S,E), (E,P), (E,P)
            b, isu, la, bl, va, de, alw, inb_m, relf, histf, png = xsb
            # producers in finished blocks: one gather for the whole block
            old = jnp.where(png, 0, jnp.take(hist.reshape(-1), histf))

            def step(icarry, xs):
                bdone, pf, pcp = icarry     # (K,E), (E,P), (E,P)
                (j, isuj, laj, blj, vaj, dej, alwj, inbj, relj,
                 oldj) = xs
                inb = jnp.take(bdone.reshape(-1), relj)      # (E,R)
                val = jnp.where(inbj, inb, oldj) + dej
                ready = jnp.maximum(isuj, val.max(axis=1))
                t = jnp.maximum(ready[:, None], pf)
                ta = jnp.where(alwj, t, big)
                tmin = ta.min(axis=1)
                key = jnp.where(ta == tmin[:, None], pcp, big)
                best = jnp.argmin(key, axis=1)
                hit = (pcp0[None, :] == best[:, None]) & vaj[:, None]
                bdone = lax.dynamic_update_slice(
                    bdone, jnp.where(vaj, tmin + laj, 0)[None, :], (j, 0))
                pf = jnp.where(hit, (tmin + blj)[:, None], pf)
                pcp = pcp + (hit.astype(jnp.int32) << idx_bits)
                return (bdone, pf, pcp), None

            ixs = (jnp.arange(K), isu, la, bl, va, de, alw, inb_m, relf,
                   old)
            (bdone, pf, pcp), _ = lax.scan(
                step, (jnp.zeros((K, E), jnp.int32), pf, pcp), ixs)
            hist = lax.dynamic_update_slice(hist, bdone, (b * K, 0))
            return (hist, pf, pcp), None

        xs = (jnp.arange(nb), per_block(issue), per_block(lat),
              per_block(blk), per_block(valid), per_block(delta),
              per_block(allowed), per_block(in_block),
              per_block(rel_flat), per_block(hist_flat),
              per_block(prod_neg))
        carry = (jnp.zeros((S, E), jnp.int32),
                 jnp.zeros((E, P), jnp.int32),
                 jnp.tile(pcp0, (E, 1)))
        (hist, _, pcp), _ = lax.scan(block, carry, xs)
        return hist.T, pcp >> idx_bits

    return run


def _build_pallas_fn(S: int, E: int, R: int, M: int, P: int):
    """The dispatch recurrence as a ``pl.pallas_call`` kernel: grid over
    blocks of experiment lanes, ``fori_loop`` over μop positions, per-lane
    state (``done`` history, port-free times, port counts) carried in
    on-chip values.  Off-TPU it runs in interpret mode (the lax.scan
    kernel above is the performance fallback there); the tie-break is the
    same two-pass min as the scan kernel."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    B = _PALLAS_LANE_BLOCK
    while E % B:
        B //= 2
    grid = (E // B,)
    big = 2**31 - 1   # disallowed-port sentinel (see the scan kernel)

    def kernel(issue_ref, mask_ref, lat_ref, blk_ref, valid_ref, prod_ref,
               delta_ref, lut_ref, done_ref, counts_ref):
        lut = lut_ref[:]
        issue = issue_ref[:]               # (B, S) — one block of lanes
        mask_id = mask_ref[:]
        lat = lat_ref[:]
        blk = blk_ref[:]
        valid = valid_ref[:]
        prod = prod_ref[:]                 # (B, S, R)
        delta = delta_ref[:]

        def step(j, carry):
            done, pf, pc = carry           # (B,S), (B,P), (B,P)
            pr = jax.lax.dynamic_index_in_dim(prod, j, 1, False)
            de = jax.lax.dynamic_index_in_dim(delta, j, 1, False)
            val = jnp.where(
                pr >= 0,
                jnp.take_along_axis(done, jnp.maximum(pr, 0), axis=1),
                0) + de
            isu = jax.lax.dynamic_index_in_dim(issue, j, 1, False)
            ready = jnp.maximum(isu, val.max(axis=1))
            mid = jax.lax.dynamic_index_in_dim(mask_id, j, 1, False)
            allowed = lut[mid]
            t = jnp.maximum(ready[:, None], pf)
            ta = jnp.where(allowed, t, big)
            tmin = ta.min(axis=1)
            cnt = jnp.where(ta == tmin[:, None], pc, big)
            cmin = cnt.min(axis=1)
            best = jnp.argmax(cnt == cmin[:, None], axis=1)
            va = jax.lax.dynamic_index_in_dim(valid, j, 1, False)
            la = jax.lax.dynamic_index_in_dim(lat, j, 1, False)
            bl = jax.lax.dynamic_index_in_dim(blk, j, 1, False)
            done = jax.lax.dynamic_update_index_in_dim(
                done, jnp.where(va, tmin + la, 0), j, 1)
            hit = (jnp.arange(P)[None, :] == best[:, None]) & va[:, None]
            pf = jnp.where(hit, (tmin + bl)[:, None], pf)
            pc = pc + hit.astype(jnp.int32)
            return done, pf, pc

        done0 = jnp.zeros((B, S), jnp.int32)
        pf0 = jnp.zeros((B, P), jnp.int32)
        pc0 = jnp.zeros((B, P), jnp.int32)
        done, _, pc = jax.lax.fori_loop(0, S, step, (done0, pf0, pc0))
        done_ref[:] = done
        counts_ref[:] = pc

    lane2 = pl.BlockSpec((B, S), lambda i: (i, 0))
    call = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[lane2, lane2, lane2, lane2, lane2,
                  pl.BlockSpec((B, S, R), lambda i: (i, 0, 0)),
                  pl.BlockSpec((B, S, R), lambda i: (i, 0, 0)),
                  pl.BlockSpec((M, P), lambda i: (0, 0))],
        out_specs=[lane2,
                   pl.BlockSpec((B, P), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((E, S), jnp.int32),
                   jax.ShapeDtypeStruct((E, P), jnp.int32)],
        interpret=jax.default_backend() != "tpu",
    )

    def run(issue, mask_id, lat, blk, valid, prod, delta, lut):
        return tuple(call(issue, mask_id, lat, blk, valid, prod, delta,
                          lut))

    return run

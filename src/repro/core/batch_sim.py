"""Batched simulated machine: whole experiment waves as one array program.

The scalar :class:`~repro.core.simulator.SimMachine` interprets one μop per
Python-loop iteration — the hot path under every inference algorithm.  This
module executes a *wave* of experiments at once: each instruction sequence
is lowered to flat integer tensors (issue cycles, port-mask ids, latencies,
occupancies, dependency producers), the wave is padded to
``(n_experiments, n_uops)``, and the dispatch/dependency recurrence runs as
a vectorized kernel — a NumPy baseline and an optional ``jax.jit``/scan
backend.  The inner loop is over μop *positions*; all experiments advance
one μop per step in lockstep, so Python overhead is O(max μops), not
O(total μops).

Bit-identity with the scalar oracle is by construction: every quantity in
the simulation (issue cycles, latencies, penalties, port-free times) is an
integer, so the kernel runs in integer arithmetic and converts to the same
float values the scalar machine produces.  ``tests/test_batch_sim.py``
differential-tests the two on all ``SIM_UARCHES`` and random ground truths.

Lowering resolves the full dataflow up front: operand snapshots (with
partial-register stall deltas), intra-instruction temporaries, memory
cells, store-to-load forwarding, move elimination, and zero idioms all
reduce to per-μop producer row indices.  Because the measurement engine
submits ``body * n`` unrollings (Algorithm 2), lowering detects the
periodic steady state — once the machine state signature repeats at a copy
boundary, the remaining copies are *tiled* with shifted NumPy arrays
instead of per-μop Python work.
"""
from __future__ import annotations

import numpy as np

from repro.core.isa import IMM, ISA
from repro.core.simulator import Counters, _implicit_reg
from repro.core.uarch import UArch
from repro.core.uarch_compile import (F_HAS_SR, F_PRESENT, TEMP_BASE,
                                      CompiledUArch, UopTableIndex,
                                      compile_uarch)

# producer descriptor kinds (recipe-time)
_P_SNAP, _P_TMP, _P_MEM, _P_CUR = 0, 1, 2, 3
# write descriptor kinds
_W_TMP, _W_MEM, _W_CELL = 0, 1, 2
# recipe kinds
_K_NORMAL, _K_ZERO_NOUOP, _K_ELIM = 0, 1, 2


class _Plan:
    """One executable μop of a lowered instruction recipe."""
    __slots__ = ("mask_id", "lat", "blk", "vis", "prods", "sf", "sf_cell",
                 "writes", "issue_off")

    def __init__(self, mask_id, lat, blk, vis, prods, sf, sf_cell, writes,
                 issue_off):
        self.mask_id = mask_id
        self.lat = lat
        self.blk = blk
        self.vis = vis
        self.prods = prods
        self.sf = sf
        self.sf_cell = sf_cell
        self.writes = writes
        self.issue_off = issue_off


class _Recipe:
    """Lowering recipe for one concrete instruction instance."""
    __slots__ = ("kind", "dest_cells", "period", "ekey", "src_cell",
                 "dst_cell", "advance", "snapshot", "plans")

    def __init__(self, kind, advance, snapshot=(), plans=(), dest_cells=(),
                 period=0, ekey=None, src_cell=-1, dst_cell=-1):
        self.kind = kind
        self.advance = advance
        self.snapshot = snapshot
        self.plans = plans
        self.dest_cells = dest_cells
        self.period = period
        self.ekey = ekey
        self.src_cell = src_cell
        self.dst_cell = dst_cell


class _Prog:
    """One experiment lowered to flat tensors."""
    __slots__ = ("n_rows", "issue", "mask", "lat", "blk", "vis", "prod",
                 "delta", "finals", "max_r")

    def __init__(self, n_rows, issue, mask, lat, blk, vis, prod, delta,
                 finals, max_r):
        self.n_rows = n_rows
        self.issue = issue
        self.mask = mask
        self.lat = lat
        self.blk = blk
        self.vis = vis
        self.prod = prod
        self.delta = delta
        self.finals = finals
        self.max_r = max_r


def _body_period(ids) -> int:
    """Smallest p with ``ids == ids[:p] * k`` (object identities — the
    engine's ``body * n`` unrollings share instruction objects)."""
    n = len(ids)
    if n < 2:
        return n
    first = ids[0]
    for p in range(1, n // 2 + 1):
        if ids[p] == first and n % p == 0 and ids[p:] == ids[:-p]:
            return p
    return n


class BatchSimMachine:
    """Measurable black box executing waves of sequences as array programs.

    Same observable contract as :class:`~repro.core.simulator.SimMachine`
    (cycles + per-port μop counts, including harness overhead), plus
    :meth:`run_batch` — and bit-identical results to the scalar oracle.
    """

    counters_available = True

    def __init__(self, uarch: UArch, isa: ISA, backend: str = "numpy",
                 table_index: UopTableIndex | None = None,
                 min_lanes: int = 8):
        if backend not in ("numpy", "jax"):
            raise ValueError(f"unknown backend {backend!r}")
        if backend == "jax" and _jax_fn() is None:
            raise RuntimeError("jax backend requested but jax is not "
                               "importable")
        self.uarch = uarch
        self.isa = isa
        self.name = uarch.name
        self.ports = uarch.ports
        self.backend = backend
        # a padded chunk with fewer lanes than this runs on the scalar
        # oracle instead: the array program's fixed per-step dispatch cost
        # only amortizes across enough parallel lanes (results are
        # bit-identical either way; set 1 to force the kernel)
        self.min_lanes = min_lanes
        self._comp: CompiledUArch = compile_uarch(uarch, isa, table_index)
        self._cells: dict = {}          # register name -> cell id
        self._recipes_by_key: dict = {}
        self._scalar = None             # lazy scalar fallback for thin chunks

    # ------------------------------------------------------------------
    def run(self, code) -> Counters:
        return self.run_batch([code])[0]

    def run_batch(self, codes) -> list:
        """Execute each sequence once; one :class:`Counters` per sequence,
        in submission order."""
        codes = [list(c) for c in codes]
        out: list = [None] * len(codes)
        # chunk by similar length so short sequences don't pay for the
        # longest experiment's padded steps; thin chunks go scalar
        order = sorted(range(len(codes)), key=lambda i: -len(codes[i]))
        chunks: list = []
        chunk: list = []
        chunk_max = 0
        for i in order:
            if chunk and len(codes[i]) * 4 < chunk_max:
                chunks.append(chunk)
                chunk, chunk_max = [], 0
            if not chunk:
                chunk_max = max(len(codes[i]), 1)
            chunk.append(i)
        if chunk:
            chunks.append(chunk)
        batched = [c for c in chunks if len(c) >= self.min_lanes]
        for c in chunks:
            if len(c) < self.min_lanes:
                if self._scalar is None:
                    from repro.core.simulator import SimMachine  # noqa: PLC0415
                    self._scalar = SimMachine(self.uarch, self.isa)
                for i in c:
                    out[i] = self._scalar.run(codes[i])
        if not batched:
            return out
        # group sequences sharing one body (Algorithm 2 submits the same
        # body at two unroll counts): lower the longest once, shorter
        # unrollings are prefix views of the same tensors (causality)
        by_id: dict = {}
        groups: dict = {}
        for c in batched:
            for i in c:
                code = codes[i]
                if code:
                    ids = [id(x) for x in code]
                    p = _body_period(ids)
                    key = (p, tuple(ids[:p]))
                    nc = len(code) // p
                else:
                    key, nc = (0, ()), 0
                groups.setdefault(key, []).append((i, nc))
        progs: dict = {}
        for (p, _), members in groups.items():
            cuts = sorted({nc for _, nc in members})
            rep_i, _ = max(members, key=lambda t: t[1])
            made = self._lower(codes[rep_i], by_id, cuts, p)
            for i, nc in members:
                progs[i] = made[nc]
        for c in batched:
            self._run_chunk(c, progs, out)
        return out

    # ------------------------------------------------------------------
    # recipes: per concrete instruction instance, content-memoized
    # ------------------------------------------------------------------
    def _cell(self, name: str) -> int:
        c = self._cells.get(name)
        if c is None:
            c = self._cells[name] = len(self._cells)
        return c

    def _recipe(self, ins, by_id: dict) -> _Recipe:
        r = by_id.get(id(ins))
        if r is None:
            key = (ins.spec, tuple(sorted(ins.regs.items())), ins.value_hint)
            r = self._recipes_by_key.get(key)
            if r is None:
                r = self._build_recipe(ins)
                self._recipes_by_key[key] = r
            by_id[id(ins)] = r
        return r

    def _build_recipe(self, ins) -> _Recipe:
        comp = self._comp
        idx = comp.index.idx[ins.spec]       # KeyError like isa[...]
        info = comp.index.specs[idx]
        if not comp.flags[idx] & F_PRESENT:  # KeyError like ua.behaviors[..]
            raise KeyError(ins.spec)
        regs = dict(ins.regs)
        for nm, ot in zip(info.op_names, info.op_otype):
            if nm not in regs and ot != IMM:
                regs[nm] = _implicit_reg(nm, ot)
        same = (len(info.same_reg_ops) >= 2
                and len({regs[n] for n in info.same_reg_ops}) == 1)
        use_sr = same and bool(comp.flags[idx] & F_HAS_SR)
        zero_nouop = bool(comp.sr_zero_nouop[idx] if use_sr
                          else comp.zero_nouop[idx])
        elim_period = int(comp.sr_elim_period[idx] if use_sr
                          else comp.elim_period[idx])
        div_extra = int(comp.sr_divider_extra[idx] if use_sr
                        else comp.divider_extra[idx])
        zero = info.zero_idiom and same
        if zero and zero_nouop:
            return _Recipe(_K_ZERO_NOUOP, 0, dest_cells=tuple(
                self._cell(regs[d]) for d in info.dest_names))
        off, cnt = comp.behavior_rows(idx, same)
        extra = div_extra if (ins.value_hint == "high" and not zero) else 0
        vis = 0 if zero else 1
        ignore_reads = zero
        snapshot = tuple((self._cell(regs.get(nm, nm)), chk, w)
                         for nm, chk, w in info.snapshot)
        snap_pos = {nm: i for i, (nm, _, _) in enumerate(info.snapshot)}
        syms = comp.syms[idx]
        plans = []
        issue_off = 0
        for j in range(cnt):
            row = off + j
            if comp.port_mask[row] == 0:   # 0-port μop: scalar skips it
                continue
            names = []
            for slot in comp.reads[row]:
                if slot < 0:
                    break
                names.append(info.op_names[slot] if slot < TEMP_BASE
                             else syms[slot - TEMP_BASE])
            prods = []
            if not ignore_reads:
                for nm in names:
                    if nm.startswith("%"):
                        prods.append((_P_TMP, nm))
                    elif nm in info.mem_read and info.mem_read[nm]:
                        prods.append((_P_MEM, self._cell(regs[nm])))
                    elif nm in snap_pos:
                        prods.append((_P_SNAP, snap_pos[nm]))
                    else:
                        prods.append((_P_CUR,
                                      self._cell(regs.get(nm, nm))))
            sf = any(nm in info.mem_read and info.mem_read[nm]
                     for nm in names)
            sf_cell = next((self._cell(regs[nm]) for nm in names
                            if nm in info.mem_read), -1)
            writes = []
            for slot in comp.writes[row]:
                if slot < 0:
                    break
                nm = (info.op_names[slot] if slot < TEMP_BASE
                      else syms[slot - TEMP_BASE])
                if nm.startswith("%"):
                    writes.append((_W_TMP, nm, None))
                elif nm in info.mem_read:
                    writes.append((_W_MEM, self._cell(regs[nm]), None))
                else:
                    try:
                        w = info.op_width[info.op_names.index(nm)]
                    except ValueError:
                        w = None
                    writes.append((_W_CELL, self._cell(regs.get(nm, nm)), w))
            occ = int(comp.occupancy[row]) + extra
            plans.append(_Plan(int(comp.mask_id[row]),
                               int(comp.latency[row]) + extra,
                               occ if occ > 1 else 1, vis, tuple(prods),
                               sf, sf_cell, tuple(writes), issue_off))
            issue_off += 1
        if info.may_eliminate and elim_period and not zero:
            return _Recipe(_K_ELIM, cnt, snapshot, tuple(plans),
                           period=elim_period, ekey=ins.spec,
                           src_cell=self._cell(regs[info.elim_src]),
                           dst_cell=self._cell(regs[info.dest_names[0]]))
        return _Recipe(_K_NORMAL, cnt, snapshot, tuple(plans))

    # ------------------------------------------------------------------
    # lowering: sequence -> flat tensors (with periodic-steady-state tiling)
    # ------------------------------------------------------------------
    def _lower(self, code, by_id: dict, cuts=None, period=None) -> dict:
        """Lower ``code`` (= body * ncopies) and materialize one
        :class:`_Prog` per requested copy count in ``cuts`` — shorter
        counts are prefix views of the full tensors."""
        comp = self._comp
        width = comp.issue_width
        penalty = comp.partial_stall_penalty
        sfl = comp.store_forward_latency
        n = len(code)
        p = period if period is not None else (
            _body_period([id(x) for x in code]) if n else 0)
        ncopies = n // p if p else 0
        if cuts is None:
            cuts = [ncopies]
        body = [self._recipe(ins, by_id) for ins in code[:p]]

        lw: dict = {}       # cell -> producing row
        wd: dict = {}       # cell -> width of last write
        ml: dict = {}       # mem cell -> producing (store) row
        ms: set = set()     # mem cells with a store seen
        ec: dict = {}       # elim spec key -> instance count
        ecp: dict = {}      # elim spec key -> period
        issue_l: list = []
        mask_l: list = []
        lat_l: list = []
        blk_l: list = []
        vis_l: list = []
        prods_l: list = []
        uop_counter = 0

        sig_map: dict = {}
        snaps: list = []    # per copy boundary: (rows, uops, lw, ml)
        tile = None

        def signature():
            nr = len(issue_l)
            return (uop_counter % width,
                    tuple(sorted((c, nr - r) for c, r in lw.items())),
                    tuple(sorted(wd.items())),
                    tuple(sorted((c, nr - r) for c, r in ml.items())),
                    tuple(sorted(ms)),
                    tuple(sorted((k, c % ecp[k]) for k, c in ec.items())))

        for i in range(ncopies):
            if ncopies > 1:
                sig = signature()
                c0 = sig_map.get(sig)
                if c0 is not None:
                    tile = (c0, i)
                    snaps.append((len(issue_l), uop_counter, dict(lw),
                                  dict(ml)))
                    break
                sig_map[sig] = i
            snaps.append((len(issue_l), uop_counter, dict(lw), dict(ml)))
            for r in body:
                k = r.kind
                if k == _K_ZERO_NOUOP:
                    for c in r.dest_cells:
                        lw.pop(c, None)
                    continue
                if k == _K_ELIM:
                    c = ec.get(r.ekey, 0)
                    ec[r.ekey] = c + 1
                    ecp[r.ekey] = r.period
                    if c % r.period:
                        s = lw.get(r.src_cell, -1)
                        if s < 0:
                            lw.pop(r.dst_cell, None)
                        else:
                            lw[r.dst_cell] = s
                        continue
                svals = [(lw.get(cell, -1),
                          penalty if (chk and w > wd.get(cell, 64)) else 0)
                         for cell, chk, w in r.snapshot]
                tmp: dict = {}
                for pl in r.plans:
                    row = len(issue_l)
                    prow = []
                    for kind, a in pl.prods:
                        if kind == _P_SNAP:
                            prow.append(svals[a])
                        elif kind == _P_TMP:
                            prow.append((tmp.get(a, -1), 0))
                        elif kind == _P_CUR:
                            prow.append((lw.get(a, -1), 0))
                        else:   # _P_MEM: reg base + memory value
                            prow.append((lw.get(a, -1), 0))
                            prow.append((ml.get(a, -1), 0))
                    lat = pl.lat
                    if pl.sf and pl.sf_cell in ms:
                        lat = min(lat, sfl)
                    issue_l.append((uop_counter + pl.issue_off) // width)
                    mask_l.append(pl.mask_id)
                    lat_l.append(lat)
                    blk_l.append(pl.blk)
                    vis_l.append(pl.vis)
                    prods_l.append(prow)
                    for wk, a, b in pl.writes:
                        if wk == _W_TMP:
                            tmp[a] = row
                        elif wk == _W_MEM:
                            ml[a] = row
                            ms.add(a)
                        else:
                            lw[a] = row
                            if b is not None:
                                wd[a] = b
                uop_counter += r.advance
        else:
            snaps.append((len(issue_l), uop_counter, dict(lw), dict(ml)))

        # native part -> arrays
        n_nat = len(issue_l)
        max_r = max((len(pr) for pr in prods_l), default=0)
        max_r = max(max_r, 1)
        issue = np.array(issue_l, np.int64) if n_nat else np.zeros(0, np.int64)
        mask = np.array(mask_l, np.int64) if n_nat else np.zeros(0, np.int64)
        lat = np.array(lat_l, np.int64) if n_nat else np.zeros(0, np.int64)
        blk = np.array(blk_l, np.int64) if n_nat else np.zeros(0, np.int64)
        vis = np.array(vis_l, np.int64) if n_nat else np.zeros(0, np.int64)
        prod = np.full((n_nat, max_r), -1, np.int64)
        delta = np.zeros((n_nat, max_r), np.int64)
        for j, pr in enumerate(prods_l):
            for kk, (pp, dd) in enumerate(pr):
                prod[j, kk] = pp
                delta[j, kk] = dd

        if tile is None:
            parts = [(issue, mask, lat, blk, vis, prod, delta)]
        else:
            c0, c1 = tile
            s0, u0 = snaps[c0][0], snaps[c0][1]
            s1, u1 = n_nat, uop_counter
            d_rows, d_uops = s1 - s0, u1 - u0
            assert d_uops % width == 0
            d_issue = d_uops // width
            per = c1 - c0
            rem = ncopies - c1
            full, left = divmod(rem, per)
            parts = [(issue, mask, lat, blk, vis, prod, delta)]
            if full:
                # all full periods in one broadcast: segment + q * shift
                q = np.arange(1, full + 1, dtype=np.int64)
                sl = slice(s0, s1)
                seg_p = prod[sl]
                pt = np.where(seg_p[None] >= 0,
                              seg_p[None] + (q * d_rows)[:, None, None], -1)
                parts.append((
                    (issue[sl][None] + (q * d_issue)[:, None]).reshape(-1),
                    np.tile(mask[sl], full), np.tile(lat[sl], full),
                    np.tile(blk[sl], full), np.tile(vis[sl], full),
                    pt.reshape(-1, max_r),
                    np.tile(delta[sl], (full, 1))))
            if left:
                sl = slice(s0, snaps[c0 + left][0])
                pr = prod[sl]
                qq = full + 1
                parts.append((issue[sl] + qq * d_issue, mask[sl], lat[sl],
                              blk[sl], vis[sl],
                              np.where(pr >= 0, pr + qq * d_rows, -1),
                              delta[sl]))
        if len(parts) > 1:
            issue = np.concatenate([x[0] for x in parts])
            mask = np.concatenate([x[1] for x in parts])
            lat = np.concatenate([x[2] for x in parts])
            blk = np.concatenate([x[3] for x in parts])
            vis = np.concatenate([x[4] for x in parts])
            prod = np.concatenate([x[5] for x in parts])
            delta = np.concatenate([x[6] for x in parts])

        def boundary(b):
            """(rows, row shift, reg cells, mem cells) after ``b`` copies."""
            if tile is None or b <= tile[1]:
                rows_b, _, lwb, mlb = snaps[b]
                return rows_b, 0, lwb, mlb
            qb, rb = divmod(b - c0, per)
            rows_b = s0 + qb * d_rows + (snaps[c0 + rb][0] - s0)
            return rows_b, qb * d_rows, snaps[c0 + rb][2], snaps[c0 + rb][3]

        made: dict = {}
        for b in cuts:
            rows_b, sh, lwb, mlb = boundary(b)
            fin = sorted({r + sh for r in lwb.values()}
                         | {r + sh for r in mlb.values()})
            made[b] = _Prog(rows_b, issue[:rows_b], mask[:rows_b],
                            lat[:rows_b], blk[:rows_b], vis[:rows_b],
                            prod[:rows_b], delta[:rows_b],
                            np.array(fin, np.int64), max_r)
        return made

    # ------------------------------------------------------------------
    # kernels
    # ------------------------------------------------------------------
    def _run_chunk(self, chunk, progs, out):
        comp = self._comp
        E = len(chunk)
        S = max(progs[i].n_rows for i in chunk)
        R = max(progs[i].max_r for i in chunk)
        overhead = comp.overhead_cycles
        if S == 0:
            for i in chunk:
                out[i] = Counters(float(overhead),
                                  {p: 0 for p in self.uarch.ports})
            return
        issue = np.zeros((S, E), np.int64)
        mask = np.zeros((S, E), np.int64)
        lat = np.zeros((S, E), np.int64)
        blk = np.zeros((S, E), np.int64)
        vis = np.zeros((E, S), np.int64)
        valid = np.zeros((S, E), bool)
        prod = np.full((S, E, R), -1, np.int64)
        delta = np.zeros((S, E, R), np.int64)
        for e, i in enumerate(chunk):
            g = progs[i]
            m = g.n_rows
            if not m:
                continue
            issue[:m, e] = g.issue
            mask[:m, e] = g.mask
            lat[:m, e] = g.lat
            blk[:m, e] = g.blk
            vis[e, :m] = g.vis
            valid[:m, e] = True
            prod[:m, e, :g.max_r] = g.prod
            delta[:m, e, :g.max_r] = g.delta
        if self.backend == "jax":
            done, counts = self._kernel_jax(issue, mask, lat, blk, valid,
                                            prod, delta)
        else:
            done, counts = self._kernel_numpy(issue, mask, lat, blk, valid,
                                              prod, delta)
        core = (done * vis).max(axis=1)
        pos = comp.port_pos
        for e, i in enumerate(chunk):
            g = progs[i]
            t_end = int(core[e])
            if g.finals.size:
                t_end = max(t_end, int(done[e, g.finals].max()))
            out[i] = Counters(float(t_end + overhead),
                              {p: int(counts[e, pos[p]])
                               for p in self.uarch.ports})

    def _kernel_numpy(self, issue, mask, lat, blk, valid, prod, delta):
        comp = self._comp
        S, E = issue.shape
        P = len(comp.ports)
        rows = np.arange(E)
        rows1 = rows[:, None]
        done = np.zeros((E, S), np.int64)
        port_free = np.zeros((E, P), np.int64)
        # dispatch tie-break key low bits: μop count (shifted) | port axis,
        # so one argmin realizes the scalar's (time, load, port) ordering.
        # Field widths are sized per chunk: the port axis needs
        # ``idx_bits``, counts are bounded by S, and time gets the rest.
        idx_bits = max((P - 1).bit_length(), 1)
        cnt_shift = (S << idx_bits).bit_length()
        pc_key = np.tile(np.arange(P, dtype=np.int64), (E, 1))
        big = np.iinfo(np.int64).max
        allowed = comp.mask_table[mask]                         # (S, E, P)
        prod_neg = prod < 0
        prod_c = np.maximum(prod, 0)
        vinc = valid.astype(np.int64) << idx_bits  # gated count increments
        # padding rows sit *after* each lane's real rows, so their (gated
        # out of the counts) dispatches cannot perturb any real result
        for j in range(S):
            val = np.where(prod_neg[j], 0,
                           done[rows1, prod_c[j]]) + delta[j]   # (E, R)
            ready = np.maximum(issue[j], val.max(axis=1))
            t = np.maximum(ready[:, None], port_free)
            key = np.where(allowed[j], (t << cnt_shift) + pc_key, big)
            best = key.argmin(axis=1)
            tmin = t[rows, best]
            done[:, j] = tmin + lat[j]
            port_free[rows, best] = tmin + blk[j]
            pc_key[rows, best] += vinc[j]
        return done, pc_key >> idx_bits

    def _kernel_jax(self, issue, mask, lat, blk, valid, prod, delta):
        fn = _jax_fn()
        S, E = issue.shape
        Sp, Ep = _next_pow2(S), _next_pow2(E)

        def pad(a, fill=0):
            shape = (Sp, Ep) + a.shape[2:]
            o = np.full(shape, fill, a.dtype)
            o[:S, :E] = a
            return o

        done, counts = fn(pad(issue).astype(np.int32),
                          pad(mask).astype(np.int32),
                          pad(lat).astype(np.int32),
                          pad(blk).astype(np.int32),
                          pad(valid),
                          pad(prod, -1).astype(np.int32),
                          pad(delta).astype(np.int32),
                          self._comp.mask_table)
        return (np.asarray(done)[:E, :S].astype(np.int64),
                np.asarray(counts)[:E].astype(np.int64))


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 1).bit_length()


_JAX_FN = ()


def _jax_fn():
    """The jitted scan kernel, or None when jax is unavailable."""
    global _JAX_FN
    if _JAX_FN == ():
        try:
            import jax
            import jax.numpy as jnp
            from jax import lax
        except ImportError:
            _JAX_FN = None
            return None

        def run(issue, mask_id, lat, blk, valid, prod, delta, lut):
            S, E = issue.shape
            rows = jnp.arange(E)
            big = jnp.int32(1 << 30)

            def step(carry, xs):
                done, pf, pc = carry
                j, isu, mid, la, bl, va, pr, de = xs
                val = jnp.where(
                    pr >= 0,
                    jnp.take_along_axis(done, jnp.maximum(pr, 0), axis=1),
                    0) + de
                ready = jnp.maximum(isu, val.max(axis=1))
                allowed = lut[mid]
                t = jnp.maximum(ready[:, None], pf)
                ta = jnp.where(allowed, t, big)
                tmin = ta.min(axis=1)
                cnt = jnp.where(ta == tmin[:, None], pc, big)
                cmin = cnt.min(axis=1)
                best = jnp.argmax(cnt == cmin[:, None], axis=1)
                done = lax.dynamic_update_slice(
                    done, jnp.where(va, tmin + la, 0)[:, None], (0, j))
                pf = pf.at[rows, best].set(
                    jnp.where(va, tmin + bl, pf[rows, best]))
                pc = pc.at[rows, best].add(va.astype(jnp.int32))
                return (done, pf, pc), None

            P = lut.shape[1]
            carry = (jnp.zeros((E, S), jnp.int32),
                     jnp.zeros((E, P), jnp.int32),
                     jnp.zeros((E, P), jnp.int32))
            xs = (jnp.arange(S), issue, mask_id, lat, blk, valid, prod,
                  delta)
            (done, _, pc), _ = lax.scan(step, carry, xs)
            return done, pc

        _JAX_FN = jax.jit(run)
    return _JAX_FN

"""Batched experiment engine: the measurement layer behind Algorithm 2.

The paper's tool (§3.3, Algorithm 2) treats the processor as a black box
queried by thousands of auto-generated microbenchmarks: serialize the
benchmark body n times, read the performance counters before and after, and
difference two run lengths (n=10 vs n=110) to cancel the constant harness
overhead. This module reifies that protocol as data instead of control flow:

* :class:`Experiment` — one microbenchmark *described declaratively*: the
  instruction sequence (Algorithm 2's benchmark body) plus the protocol
  parameters (the two unroll counts). An Experiment says *what* to measure,
  never *how* or *where*; the same object can be executed on any machine.

* :class:`MeasurementEngine` — executes Experiments against one machine
  through a content-addressed result cache. The cache key is
  ``uarch name + canonicalized instruction sequence + run params``, so two
  inference algorithms that independently generate the same microbenchmark
  (e.g. μop counting in ``characterize`` and in Algorithm 1's setup) share
  one execution. ``submit`` takes a whole wave of independent Experiments,
  dedups identical requests, and hands the unique miss-set to the machine
  *as one wave* through the ``run_batch`` protocol (see ``machine.py``):
  machines with a compiled batched backend (``batch_sim.BatchSimMachine``,
  the default behind ``SimMachine.run_batch``) execute the whole wave as a
  single vectorized array program; machines without one fall back to a
  per-experiment scalar loop. Either way the results are bit-identical —
  the batch backend is differential-tested against the scalar oracle.
  The in-memory cache is LRU-bounded (``max_entries``, eviction count in
  ``stats``) so long service-backed campaigns cannot grow without limit;
  persisted caches are unaffected.

* :class:`Campaign` — a full characterization run over *several* machines
  (microarchitectures) at once: the paper's per-uarch tool invocations,
  sharded across a thread pool, with per-uarch engines whose caches can be
  persisted (via ``model_io``) so re-runs are incremental. Each worker
  drives the composite characterization plan through one
  :class:`~repro.core.plan.WaveScheduler`, and a shared cancellation event
  makes the first worker failure cancel its siblings cleanly.

The inference algorithms (blocking / port_usage / latency / throughput /
characterize) are expressed as *measurement plans* (see ``core/plan.py``):
resumable coroutines that yield batches of Experiments and receive their
Counters; none of them calls ``machine.run`` directly anymore. A
``WaveScheduler`` drains many plans' pending yields into fused super-waves
through ``submit``, so dedup/cache sharing happens *across* concurrently
scheduled plans, not just within one algorithm's batch. ``engine.stats``
counts requests, hits, and executions — the invariant that no duplicate
simulator execution ever happens is testable, not aspirational.
"""
from __future__ import annotations

import hashlib
import threading
import time
import warnings
from concurrent.futures import ThreadPoolExecutor, as_completed
from dataclasses import dataclass, field

from repro.core.simulator import Counters, Instr
from repro.faults import plan as faults
from repro.faults.plan import InjectedFault
from repro.obs import tracer as obs

# Algorithm 2 protocol defaults: the two unroll counts whose difference
# cancels the constant measurement-harness overhead.
N_SMALL = 10
N_LARGE = 110

# in-memory cache bound (entries). Characterization campaigns stay far
# below this; it exists so service-backed engines fed unbounded query
# streams cannot grow without limit.
DEFAULT_CACHE_ENTRIES = 1 << 18


# ---------------------------------------------------------------------------
# canonical form / content addressing
# ---------------------------------------------------------------------------


def _canon_uop(u) -> tuple:
    return (sorted(u.ports), u.reads, u.writes, u.latency, u.occupancy)


def _canon_behavior(b) -> tuple:
    return (tuple(_canon_uop(u) for u in b.uops),
            _canon_behavior(b.same_reg) if b.same_reg else None,
            b.elim_period, b.dep_breaking_same_reg, b.zero_uop_same_reg,
            b.divider_extra)


def machine_fingerprint(machine) -> str:
    """Content hash of the machine's hidden parameters (uarch tables).

    Persisted caches carry this fingerprint: measurements are only valid
    for the exact machine that produced them, so an edit to a uarch
    definition (or a machine without ground-truth tables) invalidates the
    cache instead of silently replaying stale counters."""
    ua = getattr(machine, "uarch", None)
    if ua is None:
        payload = f"opaque:{machine.name}"
    else:
        payload = repr((ua.name, sorted(ua.ports), ua.issue_width,
                        ua.load_latency, ua.store_forward_latency,
                        ua.overhead_cycles, ua.partial_stall_penalty,
                        sorted((n, _canon_behavior(b))
                               for n, b in ua.behaviors.items())))
    return hashlib.sha256(payload.encode()).hexdigest()


def canonical_instr(ins: Instr) -> str:
    """Stable text form of one instruction instance (operand order-free)."""
    regs = ",".join(f"{k}={v}" for k, v in sorted(ins.regs.items()))
    return f"{ins.spec}({regs})#{ins.value_hint}"


def canonical_code(code) -> str:
    return ";".join(canonical_instr(i) for i in code)


@dataclass(frozen=True)
class Experiment:
    """One declarative microbenchmark: body + Algorithm 2 run parameters."""
    code: tuple  # tuple[Instr, ...]
    n_small: int = N_SMALL
    n_large: int = N_LARGE

    @classmethod
    def of(cls, code, n_small: int = N_SMALL,
           n_large: int = N_LARGE) -> "Experiment":
        return cls(tuple(code), n_small, n_large)

    def cache_key(self, uarch: str) -> str:
        """Content-addressed key: uarch + canonical sequence + run params."""
        payload = f"{uarch}|{self.n_small}/{self.n_large}|" \
                  f"{canonical_code(self.code)}"
        return hashlib.sha256(payload.encode()).hexdigest()


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


@dataclass
class QuarantinedExperiment:
    """One experiment isolated by bisecting retry: its wave failed, the
    engine split until the failure pinned to this experiment alone, and
    the campaign carried on without it. The record is the postmortem
    handle — the cache key replays the exact microbenchmark, ``error``
    names the terminal exception (for injected faults that includes the
    fault point + occurrence, which replays the chaos schedule)."""
    uarch: str
    cache_key: str
    code: str    # canonical body (truncated for reporting)
    error: str   # "ExcType: message"

    def as_dict(self) -> dict:
        return {"uarch": self.uarch, "cache_key": self.cache_key,
                "code": self.code, "error": self.error}


class QuarantinedResult(Counters):
    """Sentinel Counters returned for a quarantined experiment: NaN
    cycles, no port uops. Never persisted to the engine cache — a later
    submit of the same experiment re-executes it."""


def is_quarantined(c: Counters) -> bool:
    return isinstance(c, QuarantinedResult)


@dataclass
class EngineStats:
    requests: int = 0      # Experiments submitted
    cache_hits: int = 0    # served from a previously executed result
    dedup_hits: int = 0    # duplicates within a single submitted wave
    executions: int = 0    # unique Experiments actually executed
    machine_runs: int = 0  # raw machine runs (2 per execution)
    batches: int = 0
    evictions: int = 0     # cache entries dropped by the LRU bound
    # machine-side lowering-cache counters: this engine's share of the
    # batched backend's totals (deltas against a baseline snapshot taken
    # before the engine's first executed wave, so a machine reused across
    # engines/campaigns does not leak prior runs' counts), refreshed
    # after every executed wave.  Warm waves skip Python lowering
    # entirely when these hit
    lowering_hits: int = 0
    lowering_misses: int = 0
    lowering_evictions: int = 0
    # resilience counters: experiments isolated + dropped by bisecting
    # retry, sub-wave retry rounds spent isolating them, and chunks the
    # machine degraded to a lower backend after a kernel fault (snapshot
    # of the backend's per-transition counters in ``degraded``)
    quarantined: int = 0
    bisect_retries: int = 0
    degraded_chunks: int = 0
    # the typed records behind ``quarantined`` (QuarantinedExperiment);
    # non-numeric, surfaced via ``as_dict()["quarantine"]`` only when
    # non-empty so clean runs keep the legacy shape byte-identical
    quarantine: list = field(default_factory=list)
    degraded: dict = field(default_factory=dict)
    # machine-side device-kernel telemetry: the batched backend's
    # ``device_stats()`` snapshot (compile/kernel-call totals plus the
    # ``per_device`` counters, keyed by jax device id), refreshed after
    # every executed wave.  Non-numeric — delta consumers (characterize's
    # engine_stats) skip it; ``bench_backend_matrix`` and the CI recompile
    # probe read it for cross-device recompiles
    device: dict = field(default_factory=dict)

    @property
    def hit_rate(self) -> float:
        return (self.cache_hits + self.dedup_hits) / max(1, self.requests)

    def to_registry(self, reg=None):
        """Publish these stats as canonical ``engine.*`` instruments on a
        :class:`repro.obs.metrics.MetricsRegistry` (see
        ``repro.obs.metrics.ENGINE_ALIASES`` for the legacy-key mapping)."""
        from repro.obs import metrics as obs_metrics  # noqa: PLC0415
        if reg is None:
            reg = obs_metrics.MetricsRegistry()
        reg.gauge("engine.requests").set(self.requests)
        reg.gauge("engine.cache.hits").set(self.cache_hits)
        reg.gauge("engine.cache.dedup_hits").set(self.dedup_hits)
        reg.gauge("engine.executions").set(self.executions)
        reg.gauge("engine.machine_runs").set(self.machine_runs)
        reg.gauge("engine.batches").set(self.batches)
        reg.gauge("engine.cache.evictions").set(self.evictions)
        reg.gauge("engine.lowering.hits").set(self.lowering_hits)
        reg.gauge("engine.lowering.misses").set(self.lowering_misses)
        reg.gauge("engine.lowering.evictions").set(self.lowering_evictions)
        reg.gauge("engine.quarantined").set(self.quarantined)
        reg.gauge("engine.bisect_retries").set(self.bisect_retries)
        reg.gauge("engine.degraded_chunks").set(self.degraded_chunks)
        reg.gauge("engine.cache.hit_rate").set(round(self.hit_rate, 4))
        if self.device:
            obs_metrics.absorb_device_stats(reg, self.device)
        return reg

    def as_dict(self) -> dict:
        """The legacy flat stats shape, now *derived from* the canonical
        metrics registry: every key here is a documented alias of an
        ``engine.*`` instrument (``repro.obs.metrics.ENGINE_ALIASES``);
        ``device`` carries the backend's nested telemetry verbatim."""
        from repro.obs import metrics as obs_metrics  # noqa: PLC0415
        out = obs_metrics.legacy_engine_dict(self.to_registry())
        out["device"] = dict(self.device)
        # resilience details only when something actually happened, so
        # clean runs keep the historical shape exactly
        if self.quarantine:
            out["quarantine"] = [q.as_dict() for q in self.quarantine]
        if self.degraded:
            out["degraded"] = dict(self.degraded)
        return out


def _takes_kernel_lock(fn) -> bool:
    """Does this ``run_batch`` speak the kernel-lock protocol?  Cached per
    underlying function (custom machines in tests often define a bare
    ``run_batch(codes)``)."""
    probe = getattr(fn, "__func__", fn)
    hit = _LOCK_SIG_CACHE.get(probe)
    if hit is None:
        import inspect  # noqa: PLC0415
        try:
            hit = "kernel_lock" in inspect.signature(probe).parameters
        except (TypeError, ValueError):
            hit = False
        _LOCK_SIG_CACHE[probe] = hit
    return hit


_LOCK_SIG_CACHE: dict = {}


def machine_run_batch(machine, codes, kernel_lock=None) -> list[Counters]:
    """The wave-execution protocol: machines exposing ``run_batch`` get the
    whole wave at once (vectorized backends); plain machines fall back to
    a per-sequence scalar loop. Re-exported by ``machine.py``.

    ``kernel_lock`` serializes GIL-bound kernel execution across callers
    that share it: lock-aware machines hold it while a Python-stepped
    kernel runs but only around *dispatch* for GIL-releasing device
    kernels (host lowering/packing always overlaps); machines that
    predate the protocol — or the scalar loop — are executed entirely
    under the lock."""
    run_batch = getattr(machine, "run_batch", None)
    if run_batch is not None:
        if kernel_lock is not None and not _takes_kernel_lock(run_batch):
            # legacy machine: whole wave under the lock (wait time traced
            # separately so cross-engine contention is visible)
            with obs.wait_lock(kernel_lock, "wave.lock_wait"):
                return run_batch(codes)
        if kernel_lock is not None:
            return run_batch(codes, kernel_lock=kernel_lock)
        return run_batch(codes)
    if kernel_lock is not None:
        with obs.wait_lock(kernel_lock, "wave.lock_wait"):
            return [machine.run(list(c)) for c in codes]
    return [machine.run(list(c)) for c in codes]


class MeasurementEngine:
    """Cached, deduplicating, wave-batching executor of Experiments on one
    machine. ``max_entries`` bounds the in-memory cache (LRU); ``None``
    disables the bound."""

    def __init__(self, machine, cache: dict | None = None, *,
                 enabled: bool = True,
                 max_entries: int | None = DEFAULT_CACHE_ENTRIES):
        self.machine = machine
        self.cache: dict[str, Counters] = {} if cache is None else cache
        self.enabled = enabled
        self.max_entries = max_entries
        # bisecting retry gives up (re-raises) past this many quarantined
        # experiments: a failure that survives hundreds of isolations is a
        # broken backend, not poisoned experiments
        self.max_quarantine = 256
        self.stats = EngineStats()
        self._lock = threading.Lock()
        # lowering-counter baseline: the backend stats dict we snapshotted
        # (identity-tracked — set_table_index rebuilds the machine's
        # batched backend, restarting its counters, so a stale baseline
        # would report negative deltas) and its totals at snapshot time
        self._lowering_src = None
        self._lowering_base: dict = {}

    # -- single experiment -------------------------------------------------
    def measure(self, exp: Experiment) -> Counters:
        return self.submit([exp])[0]

    # -- batched wave ------------------------------------------------------
    def submit(self, experiments, kernel_lock=None) -> list[Counters]:
        """Execute a wave of independent Experiments; identical requests are
        deduplicated and cached results reused; the unique miss-set runs as
        one batch through the machine's ``run_batch`` protocol. Returns one
        Counters per submitted Experiment, in submission order.
        ``kernel_lock`` serializes kernel execution across engines sharing
        it (host lowering/packing stays concurrent, see
        :func:`machine_run_batch`)."""
        experiments = list(experiments)
        uarch = self.machine.name
        with obs.span("engine.submit", uarch=uarch,
                      wave=len(experiments)) as sp:
            keys = [e.cache_key(uarch) for e in experiments]
            with self._lock:
                self.stats.requests += len(experiments)
                self.stats.batches += 1
                if not self.enabled:
                    with obs.span("engine.miss_wave",
                                  misses=len(experiments)):
                        return self._execute_wave(experiments, kernel_lock)
                todo: dict[str, Experiment] = {}
                resolved: dict[str, Counters] = {}
                with obs.span("engine.cache_probe", wave=len(experiments)):
                    for e, k in zip(experiments, keys):
                        if k in self.cache:
                            self.stats.cache_hits += 1
                            resolved[k] = self.cache[k] = \
                                self.cache.pop(k)  # touch
                        elif k in todo:
                            self.stats.dedup_hits += 1
                        else:
                            todo[k] = e
                sp.set(hits=len(experiments) - len(todo), misses=len(todo))
                if todo:
                    with obs.span("engine.miss_wave", misses=len(todo)):
                        for k, c in zip(todo,
                                        self._execute_wave(todo.values(),
                                                           kernel_lock)):
                            resolved[k] = c
                            if not is_quarantined(c):
                                self._store(k, c)
                obs.counter("engine.hit_rate",
                            round(self.stats.hit_rate, 4))
                return [self._copy(resolved[k]) for k in keys]

    def _store(self, key: str, c: Counters) -> None:
        self.cache[key] = c
        if self.max_entries is not None:
            while len(self.cache) > self.max_entries:
                self.cache.pop(next(iter(self.cache)))  # oldest entry
                self.stats.evictions += 1

    # -- Algorithm 2: overhead-cancelling differenced runs, one wave -------
    def _execute_wave(self, experiments, kernel_lock=None) -> list[Counters]:
        """Execute a miss-wave with bisecting-retry resilience: if the
        fused wave fails, split it and retry the halves until the
        failure pins to single experiments, which are quarantined
        (typed :class:`QuarantinedExperiment` records on ``stats``,
        :class:`QuarantinedResult` sentinels in the result slots — never
        cached) instead of aborting the campaign. A clean wave pays
        nothing: the try/except only costs when a kernel actually
        raises."""
        experiments = list(experiments)
        try:
            return self._run_experiments(experiments, kernel_lock)
        except Exception as exc:
            return self._bisect_wave(experiments, kernel_lock, exc)

    def _bisect_wave(self, experiments, kernel_lock, exc) -> list[Counters]:
        if len(experiments) == 1:
            return [self._quarantine(experiments[0], exc)]
        self.stats.bisect_retries += 1
        mid = len(experiments) // 2
        out: list[Counters] = []
        for half in (experiments[:mid], experiments[mid:]):
            try:
                with obs.span("engine.bisect_retry", wave=len(half)):
                    out.extend(self._run_experiments(half, kernel_lock))
            except Exception as e2:
                out.extend(self._bisect_wave(half, kernel_lock, e2))
        return out

    def _quarantine(self, e: Experiment, exc: BaseException) -> Counters:
        if self.stats.quarantined >= self.max_quarantine:
            # a failure that survives this many isolations is systemic
            # (broken backend, not poisoned experiments): stop eating it
            raise exc
        rec = QuarantinedExperiment(
            uarch=self.machine.name,
            cache_key=e.cache_key(self.machine.name),
            code=canonical_code(e.code)[:200],
            error=f"{type(exc).__name__}: {exc}")
        self.stats.quarantined += 1
        self.stats.quarantine.append(rec)
        obs.instant("engine.quarantine", uarch=rec.uarch, error=rec.error)
        warnings.warn(f"quarantined experiment on {rec.uarch} "
                      f"({rec.code[:60]}...): {rec.error}", stacklevel=2)
        return QuarantinedResult(float("nan"), {})

    def _run_experiments(self, experiments, kernel_lock=None) \
            -> list[Counters]:
        experiments = list(experiments)
        ls0 = getattr(self.machine, "lowering_stats", None)
        if ls0 and ls0 is not self._lowering_src:
            # first sight of this backend's counter dict (machine warmed
            # by prior engines, or its backend rebuilt since our last
            # wave): snapshot a baseline — work counted before this
            # engine's next wave is not this engine's
            self._lowering_src = ls0
            self._lowering_base = dict(ls0)
        codes: list = []
        for e in experiments:
            codes.append(list(e.code) * e.n_small)
            codes.append(list(e.code) * e.n_large)
        raw = machine_run_batch(self.machine, codes, kernel_lock)
        self.stats.machine_runs += len(codes)
        self.stats.executions += len(experiments)
        ls = getattr(self.machine, "lowering_stats", None)
        if ls:   # this engine's share of the backend's lifetime totals
            if ls is not self._lowering_src:
                # the backend materialized (or was rebuilt) during this
                # wave: everything it counted happened in this wave
                self._lowering_src = ls
                self._lowering_base = {}
            base = self._lowering_base
            self.stats.lowering_hits = ls["hits"] - base.get("hits", 0)
            self.stats.lowering_misses = (ls["misses"]
                                          - base.get("misses", 0))
            self.stats.lowering_evictions = (ls["evictions"]
                                             - base.get("evictions", 0))
        ds = getattr(self.machine, "device_stats", None)
        if ds is not None:   # device-kernel telemetry snapshot (see stats)
            self.stats.device = ds() or {}
        dg = getattr(self.machine, "degraded_stats", None)
        if dg is not None:   # backend-degradation counters snapshot
            self.stats.degraded = dg() or {}
            self.stats.degraded_chunks = sum(self.stats.degraded.values())
        out = []
        for i, e in enumerate(experiments):
            c1, c2 = raw[2 * i], raw[2 * i + 1]
            d = e.n_large - e.n_small
            ports = {p: (c2.port_uops.get(p, 0) - c1.port_uops.get(p, 0)) / d
                     for p in set(c1.port_uops) | set(c2.port_uops)}
            out.append(Counters((c2.cycles - c1.cycles) / d, ports))
        return out

    @staticmethod
    def _copy(c: Counters) -> Counters:
        # type(c), not Counters: quarantined sentinels stay identifiable
        # through the copy callers receive
        return type(c)(c.cycles, dict(c.port_uops))


def as_engine(machine_or_engine) -> MeasurementEngine:
    """Adapt either a machine or an engine to an engine.

    A machine gets one persistent engine attached on first use, so every
    code path measuring on that machine — including legacy ``measure()``
    callers — shares a single cache."""
    if isinstance(machine_or_engine, MeasurementEngine):
        return machine_or_engine
    eng = getattr(machine_or_engine, "_engine", None)
    if eng is None:
        eng = MeasurementEngine(machine_or_engine)
        machine_or_engine._engine = eng
    return eng


# ---------------------------------------------------------------------------
# campaigns: multi-uarch characterization
# ---------------------------------------------------------------------------


@dataclass
class CampaignResult:
    models: dict = field(default_factory=dict)         # uarch -> PerfModel
    stats: dict = field(default_factory=dict)          # uarch -> stats dict
    phase_seconds: dict = field(default_factory=dict)  # uarch -> phase -> s
    uarch_seconds: dict = field(default_factory=dict)  # uarch -> CPU s
    wave_stats: dict = field(default_factory=dict)     # uarch -> wave widths
    # uarch -> [QuarantinedExperiment.as_dict()] for experiments isolated
    # by bisecting retry (only uarches that quarantined anything appear)
    quarantine: dict = field(default_factory=dict)
    wall_seconds: float = 0.0  # campaign wall; per-uarch values are
    # thread CPU seconds (comparable across runs regardless of sharding)

    @property
    def quarantined(self) -> int:
        return sum(len(v) for v in self.quarantine.values())

    @property
    def mean_wave_width(self) -> float:
        """Campaign-wide mean fused-wave width (experiments per submit)."""
        exps = sum(w.get("experiments", 0) for w in self.wave_stats.values())
        waves = sum(w.get("waves", 0) for w in self.wave_stats.values())
        return exps / max(1, waves)

    @property
    def hit_rate(self) -> float:
        req = sum(s["requests"] for s in self.stats.values())
        hit = sum(s["cache_hits"] + s["dedup_hits"]
                  for s in self.stats.values())
        return hit / max(1, req)

    def report(self) -> str:
        lines = [f"{'uarch':10s} {'#instr':>6s} {'cpu_s':>7s} "
                 f"{'hit%':>6s} {'execs':>6s}"]
        for name, model in sorted(self.models.items()):
            s = self.stats[name]
            lines.append(
                f"{name:10s} {len(model.instructions):6d} "
                f"{self.uarch_seconds[name]:7.1f} "
                f"{100 * s['hit_rate']:6.1f} {s['executions']:6d}")
        lines.append(f"total wall: {self.wall_seconds:.1f}s, "
                     f"overall hit rate {100 * self.hit_rate:.1f}%")
        if self.quarantine:
            lines.append(f"quarantined experiments: {self.quarantined} "
                         f"({', '.join(sorted(self.quarantine))})")
        return "\n".join(lines)


class Campaign:
    """Characterize several machines concurrently through cached engines.

    Each machine's worker drives the composite characterization plan
    through its own :class:`~repro.core.plan.WaveScheduler`, so every
    uarch's experiments fuse into campaign-wide super-waves (wave-width
    telemetry lands in ``CampaignResult.wave_stats``). Workers share one
    cancellation event: the first failure cancels the sibling schedulers at
    their next wave boundary and the original exception (with its
    traceback) propagates from :meth:`run` instead of a hung pool or a
    partially populated result.

    ``cache_dir`` enables the persistent cache: each machine's engine cache
    is loaded before and saved after its characterization (serialized by
    ``model_io``), making ``characterize`` re-runs incremental across
    processes."""

    def __init__(self, instr_names=None, cache_dir=None,
                 max_workers: int | None = None):
        self.instr_names = instr_names
        self.cache_dir = cache_dir
        self.max_workers = max_workers

    def _cache_path(self, uarch: str):
        from pathlib import Path  # noqa: PLC0415
        return Path(self.cache_dir) / f"{uarch}.meas.json"

    def _run_one(self, machine, isa, cancel, execute_lock):
        from repro.core import model_io  # noqa: PLC0415
        from repro.core.characterize import characterize  # noqa: PLC0415

        engine = as_engine(machine)
        with obs.span("campaign.worker", uarch=machine.name) as sp:
            if self.cache_dir is not None:
                path = self._cache_path(machine.name)
                if path.exists():
                    try:
                        with obs.span("campaign.cache_load",
                                      uarch=machine.name):
                            faults.check("engine.cache_io",
                                         key=f"load:{path.name}")
                            engine.cache.update(
                                model_io.load_measurement_cache(
                                    path, expect_fingerprint=
                                    machine_fingerprint(machine)))
                    except (ValueError, KeyError, OSError,
                            InjectedFault) as e:
                        # a cache is disposable: corruption (incl. a torn
                        # write from a previous crash) or a changed
                        # machine means cold, not dead (the save below
                        # rewrites it)
                        warnings.warn(f"ignoring unusable measurement cache "
                                      f"{path}: {e}", stacklevel=2)
            # thread CPU time: under the GIL the machines' threads
            # interleave, so wall clock per uarch would just re-measure the
            # whole campaign
            t0 = time.thread_time()
            model = characterize(engine, isa, self.instr_names, cancel=cancel,
                                 execute_lock=execute_lock)
            dt = time.thread_time() - t0
            sp.set(cpu_s=round(dt, 3),
                   instructions=len(model.instructions))
            if self.cache_dir is not None:
                try:
                    with obs.span("campaign.cache_save", uarch=machine.name):
                        model_io.save_measurement_cache(
                            self._cache_path(machine.name), engine)
                except (OSError, InjectedFault) as e:
                    # losing the persistent cache costs the next run
                    # warmth, never this run's model
                    warnings.warn(f"measurement cache save failed for "
                                  f"{machine.name}: {e}", stacklevel=2)
        return model, engine, dt

    def run(self, machines, isa) -> CampaignResult:
        """Top-level entry point: one characterization per machine, sharded
        across a thread pool (the machines are independent black boxes).

        Machines that support it share one compiled μop-table index, so
        every uarch's batched backend uses the same instruction numbering
        (one table set per campaign, not per machine) — and are placed on
        **disjoint device subsets** when the host has more than one jax
        device (see :func:`repro.core.device_mesh.partition`): each uarch's
        waves then execute on its own devices under its own dispatch lock,
        so a multi-uarch campaign is wall-clock-bound by one uarch rather
        than serialized behind a shared device.  With one (or no) device
        every machine keeps the default placement; results are
        bit-identical either way."""
        machines = list(machines)
        try:
            from repro.core.uarch_compile import UopTableIndex  # noqa: PLC0415
            index = UopTableIndex.for_isa(isa)
        except ImportError:   # no numpy: machines fall back to scalar runs
            index = None
        if index is not None:
            for m in machines:
                setter = getattr(m, "set_table_index", None)
                if setter is not None:
                    setter(index)
        from repro.core.device_mesh import (  # noqa: PLC0415
            partition, resolve_devices)
        placement = partition(resolve_devices(), len(machines))
        for m, group in zip(machines, placement):
            setter = getattr(m, "set_devices", None)
            if setter is not None and group:
                setter(group)
        res = CampaignResult()
        t0 = time.perf_counter()
        workers = self.max_workers or max(1, len(machines))
        # per-run cancel event and wave-execution lock (a Campaign object is
        # just config; one instance may serve concurrent run() calls). The
        # lock serializes the workers' GIL-bound fused array kernels (numpy
        # backend, scalar fallback): under the GIL, concurrently
        # interleaving them only thrashes (wave execution is the CPU-bound
        # part; plan stepping stays concurrent).  Device backends ignore it
        # for dispatch — they serialize on their own per-device-subset
        # locks instead, so the disjoint placement above actually overlaps
        cancel = threading.Event()
        execute_lock = threading.Lock()
        with obs.span("campaign.run", machines=len(machines),
                      workers=workers), \
                ThreadPoolExecutor(max_workers=workers) as pool:
            futures = {pool.submit(self._run_one, m, isa, cancel,
                                   execute_lock): m.name
                       for m in machines}
            try:
                for fut in as_completed(futures):
                    name = futures[fut]
                    # a worker failure re-raises here with the original
                    # traceback attached (concurrent.futures preserves it)
                    model, engine, dt = fut.result()
                    res.models[name] = model
                    # per-run delta (the engine may carry state from prior
                    # campaigns on the same machine), as recorded by
                    # characterize
                    res.stats[name] = dict(model.engine_stats)
                    res.phase_seconds[name] = dict(model.phase_seconds)
                    res.wave_stats[name] = dict(model.wave_stats)
                    res.uarch_seconds[name] = dt
                    if engine.stats.quarantine:
                        res.quarantine[name] = [
                            q.as_dict() for q in engine.stats.quarantine]
            except BaseException:
                # cancel the sibling workers' schedulers at their next wave
                # boundary, drop queued work, and surface the first failure
                # instead of hanging or returning a partial CampaignResult
                cancel.set()
                for f in futures:
                    f.cancel()
                raise
        res.wall_seconds = time.perf_counter() - t0
        return res

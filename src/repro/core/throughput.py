"""Throughput (§4.2 definitions, §5.3 measurement + computation).

Two notions are produced for every instruction:

* ``measured`` (Fog/Granlund, Def. 2): cycles/instr over sequences of
  independent instances, for sequence lengths 1, 2, 4 and 8 (longer
  sequences can be *slower* — the paper's observation — so we keep the
  minimum and record the per-length values). For instructions with implicit
  read-modify-write operands an additional variant interleaves
  dependency-breaking instructions (which consume execution resources
  themselves, so it does not always help — both variants are recorded).

* ``computed`` (Intel, Def. 1): from the inferred port usage via the LP of
  §5.3.2 — the minimal achievable maximum port load. Not valid for divider
  instructions (the divider is not fully pipelined), which keep the measured
  value annotated instead.

All sequence lengths (and the divider high-operand variants) are independent
experiments, requested as one wave by a single-yield measurement plan
(:func:`throughput_plan`); under a :class:`~repro.core.plan.WaveScheduler`
many instructions' throughput waves fuse into one. ``measure_throughput``
remains the run-to-completion wrapper.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.engine import Experiment, as_engine
from repro.core.isa import FLAGS, ISA, InstrSpec
from repro.core.lp import throughput_lp
from repro.core.machine import (RegPool, flags_breaker, independent_experiment,
                                independent_seq)
from repro.core.plan import MeasurementPlan, run_plan
from repro.core.port_usage import PortUsage

SEQ_LENS = (1, 2, 4, 8)


@dataclass
class ThroughputResult:
    instr: str
    measured: float = 0.0
    by_seq_len: dict = field(default_factory=dict)
    with_breakers: float | None = None
    computed_from_ports: float | None = None
    high_value: float | None = None  # divider worst-case operand class


def _throughput_gen(spec: InstrSpec, isa: ISA, value_hint: str):
    res = ThroughputResult(spec.name)

    wave = [independent_experiment(spec, n, value_hint) for n in SEQ_LENS]
    lens = list(SEQ_LENS)
    # implicit RMW operands: variant with dependency-breaking instructions
    rmw_flags = any(o.rmw and o.implicit and o.otype == FLAGS
                    for o in spec.operands)
    if rmw_flags:
        pool = RegPool()
        seq = []
        for ins in independent_seq(spec, pool, 4):
            seq.append(ins)
            seq.append(flags_breaker(isa, pool))
        wave.append(Experiment.of(seq))
    if spec.uses_divider:
        wave += [independent_experiment(spec, n, "high") for n in SEQ_LENS]

    counters = yield wave

    best = None
    for n, c in zip(lens, counters[:len(lens)]):
        cyc = c.cycles / n
        res.by_seq_len[n] = cyc
        best = cyc if best is None else min(best, cyc)
    res.measured = best
    rest = counters[len(lens):]
    if rmw_flags:
        # per-instr cycles of the *measured* instruction (breakers add μops
        # and execution resources, which is why this does not always help —
        # §5.3.1). Recorded separately; ``measured`` stays the canonical
        # breaker-free Def.-2 number (the paper reports CMC = 1, not 0.5).
        res.with_breakers = rest[0].cycles / 4
        rest = rest[1:]
    if spec.uses_divider:
        hi = None
        for n, c in zip(lens, rest):
            cyc = c.cycles / n
            hi = cyc if hi is None else min(hi, cyc)
        res.high_value = hi
    return res


def throughput_plan(spec: InstrSpec, isa: ISA,
                    value_hint: str = "low") -> MeasurementPlan:
    """§5.3.1 measured throughput as a single-wave plan."""
    return MeasurementPlan(_throughput_gen(spec, isa, value_hint),
                           name=f"throughput[{spec.name}]",
                           phase="throughput")


def measure_throughput(machine, isa: ISA, instr: InstrSpec | str,
                       value_hint: str = "low") -> ThroughputResult:
    """Run-to-completion wrapper over :func:`throughput_plan`."""
    spec = isa[instr] if isinstance(instr, str) else instr
    return run_plan(as_engine(machine), throughput_plan(spec, isa,
                                                        value_hint))


def computed_throughput(usage: PortUsage, spec: InstrSpec) -> float | None:
    """Intel-definition throughput from port usage (invalid for dividers)."""
    if spec.uses_divider or not usage.usage:
        return None
    return throughput_lp(usage.usage)

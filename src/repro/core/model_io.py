"""Machine-readable output (§6.4): XML (uops.info-style) and JSON.

The XML schema mirrors uops.info's: one <instruction> element per variant
with <operand> children and per-architecture <architecture><measurement>
elements carrying ports=, uops=, plus <latency> edges per (src,dst) operand
pair. Round-trips losslessly through ``load_xml`` (used by the predictor and
by tests).

Also serializes the measurement engine's content-addressed result cache
(``save_measurement_cache`` / ``load_measurement_cache``), making
characterization campaigns incremental across processes.
"""
from __future__ import annotations

import json
import os
import xml.etree.ElementTree as ET
from pathlib import Path
from xml.dom import minidom

from repro.core.characterize import InstrModel, PerfModel
from repro.core.latency import LatencyEntry, LatencyResult
from repro.core.port_usage import PortUsage
from repro.core.simulator import Counters
from repro.core.throughput import ThroughputResult


def _fmt(x) -> str:
    return f"{x:.6f}".rstrip("0").rstrip(".") if isinstance(x, float) else str(x)


def to_xml(model: PerfModel, isa=None) -> str:
    root = ET.Element("root")
    arch = ET.SubElement(root, "architecture", name=model.uarch)
    if model.fingerprint:
        arch.set("fingerprint", model.fingerprint)
    blk = ET.SubElement(arch, "blockingInstructions")
    for pc, nm in sorted(model.blocking.items()):
        ET.SubElement(blk, "blocking", ports=pc, instr=nm)
    for name, im in sorted(model.instructions.items()):
        el = ET.SubElement(root, "instruction", name=name)
        if isa is not None and name in isa:
            spec = isa[name]
            el.set("mnemonic", spec.mnemonic)
            el.set("extension", spec.extension)
            for o in spec.operands:
                ET.SubElement(el, "operand", name=o.name, type=o.otype,
                              r=str(int(o.read)), w=str(int(o.written)),
                              implicit=str(int(o.implicit)),
                              width=str(o.width))
        m = ET.SubElement(el, "measurement", arch=model.uarch,
                          uops=_fmt(im.uops))
        if im.port_usage is not None:
            m.set("ports", im.port_usage.notation())
        tp = im.throughput
        if tp is not None:
            m.set("tp_measured", _fmt(tp.measured))
            if tp.computed_from_ports is not None:
                m.set("tp_ports", _fmt(tp.computed_from_ports))
            if tp.high_value is not None:
                m.set("tp_high", _fmt(tp.high_value))
        if im.latency is not None:
            for (s, d), e in sorted(im.latency.entries.items()):
                le = ET.SubElement(m, "latency", src=s, dst=d,
                                   cycles=_fmt(e.value), kind=e.kind)
                if e.same_reg is not None:
                    le.set("same_reg", _fmt(e.same_reg))
                if e.high_value is not None:
                    le.set("high", _fmt(e.high_value))
    return minidom.parseString(ET.tostring(root)).toprettyxml(indent=" ")


def load_xml(text: str) -> PerfModel:
    root = ET.fromstring(text)
    arch = root.find("architecture")
    model = PerfModel(arch.get("name"))
    model.fingerprint = arch.get("fingerprint", "") or ""
    blk = arch.find("blockingInstructions")
    for b in (blk if blk is not None else []):
        model.blocking[b.get("ports")] = b.get("instr")
    for el in root.findall("instruction"):
        name = el.get("name")
        im = InstrModel(name)
        m = el.find("measurement")
        im.uops = float(m.get("uops"))
        pu = _parse_ports(m.get("ports"))
        pu.total_uops = im.uops
        im.port_usage = pu
        tp = ThroughputResult(name)
        tp.measured = float(m.get("tp_measured", 0))
        if m.get("tp_ports"):
            tp.computed_from_ports = float(m.get("tp_ports"))
        if m.get("tp_high"):
            tp.high_value = float(m.get("tp_high"))
        im.throughput = tp
        lat = LatencyResult(name)
        for le in m.findall("latency"):
            e = LatencyEntry(le.get("src"), le.get("dst"),
                             float(le.get("cycles")), le.get("kind"))
            if le.get("same_reg"):
                e.same_reg = float(le.get("same_reg"))
            if le.get("high"):
                e.high_value = float(le.get("high"))
            lat.entries[(e.src, e.dst)] = e
        im.latency = lat
        model.instructions[name] = im
    return model


def to_json(model: PerfModel) -> str:
    out = {"uarch": model.uarch, "blocking": model.blocking,
           "fingerprint": model.fingerprint,
           "run_seconds": model.run_seconds, "instructions": {}}
    for name, im in model.instructions.items():
        rec = {"uops": im.uops,
               "ports": im.port_usage.notation() if im.port_usage else None,
               "throughput": None, "latency": {}}
        if im.throughput:
            rec["throughput"] = {
                "measured": im.throughput.measured,
                "by_seq_len": im.throughput.by_seq_len,
                "with_breakers": im.throughput.with_breakers,
                "computed_from_ports": im.throughput.computed_from_ports,
                "high_value": im.throughput.high_value,
            }
        if im.latency:
            for (s, d), e in im.latency.entries.items():
                rec["latency"][f"{s}->{d}"] = {
                    "cycles": e.value, "kind": e.kind,
                    "same_reg": e.same_reg, "high": e.high_value,
                }
        out["instructions"][name] = rec
    return json.dumps(out, indent=1)


def _parse_ports(notation: str | None) -> PortUsage:
    pu = PortUsage()
    if notation and notation != "0":
        for part in notation.split("+"):
            n, pc = part.split("*p")
            pu.usage[frozenset(pc)] = int(n)
    return pu


def load_json(text: str) -> PerfModel:
    """Inverse of :func:`to_json` (JSON floats round-trip exactly, so a
    JSON-loaded model predicts identically to the in-memory one)."""
    data = json.loads(text)
    model = PerfModel(data["uarch"])
    model.blocking = dict(data.get("blocking") or {})
    model.fingerprint = data.get("fingerprint", "") or ""
    model.run_seconds = data.get("run_seconds", 0.0)
    for name, rec in data.get("instructions", {}).items():
        im = InstrModel(name)
        im.uops = float(rec["uops"])
        im.port_usage = _parse_ports(rec.get("ports"))
        im.port_usage.total_uops = im.uops
        tp = ThroughputResult(name)
        if rec.get("throughput"):
            t = rec["throughput"]
            tp.measured = t.get("measured", 0.0)
            tp.by_seq_len = {int(k): v
                             for k, v in (t.get("by_seq_len") or {}).items()}
            tp.with_breakers = t.get("with_breakers")
            tp.computed_from_ports = t.get("computed_from_ports")
            tp.high_value = t.get("high_value")
        im.throughput = tp
        lat = LatencyResult(name)
        for pair, e in (rec.get("latency") or {}).items():
            src, _, dst = pair.partition("->")
            entry = LatencyEntry(src, dst, e["cycles"],
                                 e.get("kind") or "exact")
            entry.same_reg = e.get("same_reg")
            entry.high_value = e.get("high")
            lat.entries[(src, dst)] = entry
        im.latency = lat
        model.instructions[name] = im
    return model


# ---------------------------------------------------------------------------
# persistent measurement cache (engine): key -> Counters
# ---------------------------------------------------------------------------


def save_measurement_cache(path, engine_or_cache, uarch: str | None = None
                           ) -> None:
    """Serialize an engine's content-addressed result cache to JSON.

    The machine's parameter fingerprint is stored alongside, so a cache can
    never be replayed against an edited uarch definition.  The write is
    atomic (tmp + ``os.replace``, the checkpoint/corpus convention): a
    crash — or an injected ``engine.cache_io`` torn write — leaves either
    the previous cache or the new one, never a truncated file."""
    from repro.core.engine import machine_fingerprint  # noqa: PLC0415
    from repro.faults import plan as faults  # noqa: PLC0415

    cache = getattr(engine_or_cache, "cache", engine_or_cache)
    machine = getattr(engine_or_cache, "machine", None)
    if uarch is None:
        uarch = machine.name if machine is not None else ""
    fp = machine_fingerprint(machine) if machine is not None else ""
    entries = {k: [c.cycles, c.port_uops] for k, c in cache.items()}
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    data = json.dumps({"uarch": uarch, "fingerprint": fp,
                       "entries": entries}).encode()
    if faults.active():
        faults.check("engine.cache_io", key=f"save:{path.name}")
        data = faults.filter_bytes("engine.cache_io", data,
                                   key=f"save:{path.name}")
    tmp = path.with_suffix(path.suffix + ".tmp")
    tmp.write_bytes(data)
    os.replace(tmp, path)


def load_measurement_cache(path, expect_fingerprint: str | None = None
                           ) -> dict:
    """Load a cache written by :func:`save_measurement_cache`.

    With ``expect_fingerprint`` set, a cache written for a machine with
    different hidden parameters raises ValueError (stale measurements must
    never be replayed as fresh ones)."""
    data = json.loads(Path(path).read_text())
    if (expect_fingerprint is not None
            and data.get("fingerprint") != expect_fingerprint):
        raise ValueError("machine fingerprint mismatch (uarch definition or "
                         "simulator changed since this cache was written)")
    return {k: Counters(cycles, dict(ports))
            for k, (cycles, ports) in data["entries"].items()}

"""Full-ISA characterization: the paper's tool pipeline.

For every supported instruction variant:
  1. per-operand-pair latencies (§5.2)  — also provides maxLatency for 2.,
  2. port usage via Algorithm 1 (§5.1)  — needs the blocking instructions,
  3. measured throughput (§5.3.1) and LP throughput from port usage (§5.3.2).

The result (:class:`PerfModel`) is the machine-readable artifact (§6.4)
consumed by the predictor and exported to XML/JSON by ``model_io``.

The pipeline is a composite :mod:`repro.core.plan` measurement plan
(:func:`characterize_plan`): blocking discovery and the latency boot fork
first, then one sub-plan per instruction fans out — each itself forking
latency / μop-count / throughput (port usage follows once the instruction's
maxLatency is known). Driven by a :class:`~repro.core.plan.WaveScheduler`
(the default in :func:`characterize` and in ``Campaign``), a full-ISA run
interleaves *hundreds of instructions' experiments into each fused wave*
instead of one instruction's handful — the wave widths land in
``PerfModel.wave_stats``. Driven by :func:`~repro.core.plan.run_plan`
(``sequential=True``), it reproduces the legacy per-instruction behavior
exactly; either way the measured results are identical, because experiments
are deterministic and the engine's cache/dedup semantics make execution
order invisible.

All measurement goes through the machine's :class:`MeasurementEngine`
(``machine`` may be a machine or an engine), so a characterization issues
no duplicate simulator executions: benchmarks shared between phases (μop
counting, isolation, Algorithm 1 setup) or repeated across runs are served
from the content-addressed cache. Per-phase wall time and the engine's
cache statistics are recorded on the model.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from repro.core.blocking import BlockingSet, blocking_plan
from repro.core.engine import as_engine, machine_fingerprint
from repro.core.isa import ISA, InstrSpec
from repro.core.latency import LatencyPlans, LatencyResult
from repro.core.machine import total_uops_plan
from repro.core.plan import (Fork, MeasurementPlan, SchedulerStats,
                             WaveScheduler, run_plan)
from repro.core.port_usage import PortUsage, port_usage_plan
from repro.core.throughput import (ThroughputResult, computed_throughput,
                                   throughput_plan)
from repro.obs import tracer as obs


@dataclass
class InstrModel:
    name: str
    uops: float = 0.0
    port_usage: PortUsage | None = None
    latency: LatencyResult | None = None
    throughput: ThroughputResult | None = None

    @property
    def max_latency(self) -> int:
        return self.latency.max_latency() if self.latency else 1


@dataclass
class PerfModel:
    uarch: str
    instructions: dict = field(default_factory=dict)  # name -> InstrModel
    blocking: dict = field(default_factory=dict)      # "p05" -> instr name
    run_seconds: float = 0.0
    phase_seconds: dict = field(default_factory=dict)  # phase -> seconds
    engine_stats: dict = field(default_factory=dict)   # cache/dedup counters
    wave_stats: dict = field(default_factory=dict)     # scheduler wave widths
    # content hash of the machine's hidden parameters at measurement time;
    # exported with the artifact so a registry can refuse to serve a model
    # measured on a different uarch definition (see service/registry.py)
    fingerprint: str = ""

    def __getitem__(self, name: str) -> InstrModel:
        return self.instructions[name]


def _supported(spec: InstrSpec) -> bool:
    """Paper §8 limitations: system / serializing / control-flow
    instructions are not characterized."""
    return not (spec.system or spec.serializing or spec.control_flow
                or spec.is_nop)


def _instruction_gen(spec: InstrSpec, isa: ISA, blocking: BlockingSet,
                     lat: LatencyPlans, n_ports: int):
    im = InstrModel(spec.name)
    # latency / μop count / throughput are mutually independent: fork them
    # so a scheduler fuses their waves (μop counting reuses Algorithm 1's
    # isolation experiment via the engine cache)
    im.latency, uops, im.throughput = yield Fork([
        lat.analyze_plan(spec),
        total_uops_plan(spec),
        throughput_plan(spec, isa),
    ])
    im.uops = round(uops, 2)
    # port usage needs maxLatency (blockRep sizing), hence runs after
    [im.port_usage] = yield Fork([
        port_usage_plan(spec, isa, blocking, im.max_latency,
                        n_ports=n_ports)])
    im.throughput.computed_from_ports = computed_throughput(
        im.port_usage, spec)
    return im


def instruction_plan(spec: InstrSpec, isa: ISA, blocking: BlockingSet,
                     lat: LatencyPlans, *, n_ports: int) -> MeasurementPlan:
    """Characterize one instruction (latency, μops, ports, throughput)."""
    return MeasurementPlan(_instruction_gen(spec, isa, blocking, lat,
                                            n_ports),
                           name=f"instr[{spec.name}]")


def _characterize_gen(isa: ISA, instr_names, blocking, n_ports: int):
    model = PerfModel("")
    lat = LatencyPlans(isa)
    if blocking is None:
        # separate SSE / AVX blocking sets (transition penalties, §5.1.1);
        # merged here since the simulated core has no penalty — the split
        # code path is exercised by dedicated tests. The latency boot rides
        # in the same fused wave as blocking discovery.
        blocking, _ = yield Fork([blocking_plan(isa, ("BASE", "SSE")),
                                  lat.boot_plan()])
    else:
        yield from lat.boot_gen()
    model.blocking = {"p" + "".join(sorted(pc)): nm
                      for pc, nm in blocking.instrs.items()}
    names = instr_names if instr_names is not None else isa.names()
    specs = [isa[n] for n in names if _supported(isa[n])]
    ims = yield Fork([instruction_plan(spec, isa, blocking, lat,
                                       n_ports=n_ports) for spec in specs])
    for im in ims:
        model.instructions[im.name] = im
    return model


def characterize_plan(isa: ISA, instr_names=None,
                      blocking: BlockingSet | None = None, *,
                      n_ports: int) -> MeasurementPlan:
    """The full pipeline as a composite plan (result: a :class:`PerfModel`
    whose machine-dependent fields — uarch name, fingerprint, stats — are
    filled in by the driver's wrapper). ``n_ports`` is the target machine's
    port count, threaded to Algorithm 1's blockRep sizing."""
    return MeasurementPlan(_characterize_gen(isa, instr_names, blocking,
                                             n_ports),
                           name="characterize")


def characterize(machine, isa: ISA, instr_names=None,
                 blocking: BlockingSet | None = None, *,
                 scheduler: WaveScheduler | None = None,
                 sequential: bool = False, cancel=None,
                 execute_lock=None) -> PerfModel:
    """Run-to-completion characterization of one machine.

    By default the composite plan is driven by a :class:`WaveScheduler`
    (pass ``scheduler`` to share one, e.g. per-campaign-worker; ``cancel``
    and ``execute_lock`` thread a cancellation event and a cross-worker
    wave-execution lock into a new scheduler). With ``sequential=True``
    the plan runs under :func:`run_plan` — the legacy per-instruction
    wave shape, kept as the reference/benchmark baseline."""
    if scheduler is not None and (cancel is not None
                                  or execute_lock is not None):
        raise ValueError("pass cancel/execute_lock when constructing the "
                         "shared scheduler, not alongside it (they would "
                         "be silently ignored)")
    if sequential and (scheduler is not None or cancel is not None
                       or execute_lock is not None):
        raise ValueError("sequential=True runs under run_plan, which "
                         "supports neither a scheduler nor "
                         "cancel/execute_lock")
    engine = as_engine(machine)
    if scheduler is not None and scheduler.engine is not engine:
        raise ValueError("the shared scheduler drives a different engine "
                         "than the machine being characterized (the model "
                         "would carry the wrong uarch/fingerprint)")
    stats0 = engine.stats.as_dict()
    t0 = time.time()
    with obs.span("characterize", uarch=engine.machine.name,
                  sequential=sequential) as span:
        return _run_characterize(engine, isa, instr_names, blocking,
                                 scheduler, sequential, cancel,
                                 execute_lock, stats0, t0, span)


def _run_characterize(engine, isa, instr_names, blocking, scheduler,
                      sequential, cancel, execute_lock, stats0, t0,
                      span) -> PerfModel:
    plan = characterize_plan(isa, instr_names, blocking,
                             n_ports=len(engine.machine.ports))
    if sequential:
        st = SchedulerStats()
        phases: dict = {}
        model = run_plan(engine, plan, stats=st, phase_seconds=phases)
        model.phase_seconds = {k: round(v, 6) for k, v in phases.items()}
        model.wave_stats = st.as_dict()
    else:
        sched = scheduler or WaveScheduler(engine, cancel=cancel,
                                           execute_lock=execute_lock)
        # the scheduler may be shared across characterize calls: report
        # this run's deltas, not scheduler-lifetime totals
        phases0 = dict(sched.phase_seconds)
        waves0, exps0, plans0 = (sched.stats.waves, sched.stats.experiments,
                                 sched.stats.plans_completed)
        model = sched.run_one(plan)
        model.phase_seconds = {
            k: round(v - phases0.get(k, 0.0), 6)
            for k, v in sched.phase_seconds.items()}
        d_waves = sched.stats.waves - waves0
        d_exps = sched.stats.experiments - exps0
        run_widths = sched.stats.wave_widths[waves0:]
        model.wave_stats = {
            "waves": d_waves, "experiments": d_exps,
            "plans_completed": sched.stats.plans_completed - plans0,
            "mean_wave_width": round(d_exps / max(1, d_waves), 2),
            "max_wave_width": max(run_widths, default=0)}
    model.uarch = engine.machine.name
    model.fingerprint = machine_fingerprint(engine.machine)
    model.run_seconds = time.time() - t0
    s1 = engine.stats.as_dict()
    # numeric counters delta against the run's baseline; non-numeric
    # telemetry (the "device" snapshot) is cumulative, carried as-is
    model.engine_stats = {
        k: (s1[k] - stats0.get(k, 0)
            if isinstance(s1[k], (int, float)) else s1[k])
        for k in s1 if k != "hit_rate"}
    req = model.engine_stats["requests"]
    hits = (model.engine_stats["cache_hits"]
            + model.engine_stats["dedup_hits"])
    model.engine_stats["hit_rate"] = round(hits / max(1, req), 4)
    span.set(instructions=len(model.instructions),
             waves=model.wave_stats.get("waves", 0),
             hit_rate=model.engine_stats["hit_rate"])
    return model

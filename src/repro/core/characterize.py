"""Full-ISA characterization: the paper's tool pipeline.

For every supported instruction variant:
  1. per-operand-pair latencies (§5.2)  — also provides maxLatency for 2.,
  2. port usage via Algorithm 1 (§5.1)  — needs the blocking instructions,
  3. measured throughput (§5.3.1) and LP throughput from port usage (§5.3.2).

The result (:class:`PerfModel`) is the machine-readable artifact (§6.4)
consumed by the predictor and exported to XML/JSON by ``model_io``.

All measurement goes through the machine's :class:`MeasurementEngine`
(``machine`` may be a machine or an engine), so a characterization issues
no duplicate simulator executions: benchmarks shared between phases (μop
counting, isolation, Algorithm 1 setup) or repeated across runs are served
from the content-addressed cache. Per-phase wall time and the engine's
cache statistics are recorded on the model.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from repro.core.blocking import BlockingSet, find_blocking_instructions
from repro.core.engine import as_engine, machine_fingerprint
from repro.core.isa import ISA, InstrSpec
from repro.core.latency import LatencyAnalyzer, LatencyResult
from repro.core.machine import total_uops
from repro.core.port_usage import PortUsage, infer_port_usage
from repro.core.throughput import (ThroughputResult, computed_throughput,
                                   measure_throughput)


@dataclass
class InstrModel:
    name: str
    uops: float = 0.0
    port_usage: PortUsage | None = None
    latency: LatencyResult | None = None
    throughput: ThroughputResult | None = None

    @property
    def max_latency(self) -> int:
        return self.latency.max_latency() if self.latency else 1


@dataclass
class PerfModel:
    uarch: str
    instructions: dict = field(default_factory=dict)  # name -> InstrModel
    blocking: dict = field(default_factory=dict)      # "p05" -> instr name
    run_seconds: float = 0.0
    phase_seconds: dict = field(default_factory=dict)  # phase -> seconds
    engine_stats: dict = field(default_factory=dict)   # cache/dedup counters
    # content hash of the machine's hidden parameters at measurement time;
    # exported with the artifact so a registry can refuse to serve a model
    # measured on a different uarch definition (see service/registry.py)
    fingerprint: str = ""

    def __getitem__(self, name: str) -> InstrModel:
        return self.instructions[name]


def _supported(spec: InstrSpec) -> bool:
    """Paper §8 limitations: system / serializing / control-flow
    instructions are not characterized."""
    return not (spec.system or spec.serializing or spec.control_flow
                or spec.is_nop)


class _PhaseClock:
    def __init__(self, sink: dict):
        self.sink = sink

    def __call__(self, phase: str, fn, *args, **kw):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        self.sink[phase] = self.sink.get(phase, 0.0) + (
            time.perf_counter() - t0)
        return out


def characterize(machine, isa: ISA, instr_names=None,
                 blocking: BlockingSet | None = None) -> PerfModel:
    engine = as_engine(machine)
    stats0 = engine.stats.as_dict()
    t0 = time.time()
    model = PerfModel(engine.machine.name)
    model.fingerprint = machine_fingerprint(engine.machine)
    clock = _PhaseClock(model.phase_seconds)
    if blocking is None:
        # separate SSE / AVX blocking sets (transition penalties, §5.1.1);
        # merged here since the simulated core has no penalty — the split
        # code path is exercised by dedicated tests.
        blocking = clock("blocking", find_blocking_instructions, engine, isa,
                         extensions=("BASE", "SSE"))
    model.blocking = {"p" + "".join(sorted(pc)): nm
                      for pc, nm in blocking.instrs.items()}
    lat_an = LatencyAnalyzer(engine, isa)
    names = instr_names if instr_names is not None else isa.names()
    for name in names:
        spec = isa[name]
        if not _supported(spec):
            continue
        im = InstrModel(name)
        im.latency = clock("latency", lat_an.analyze, spec)
        im.uops = round(clock("uops", total_uops, engine, spec), 2)
        im.port_usage = clock("ports", infer_port_usage, engine, isa, spec,
                              blocking, im.max_latency)
        im.throughput = clock("throughput", measure_throughput, engine, isa,
                              spec)
        im.throughput.computed_from_ports = computed_throughput(
            im.port_usage, spec)
        model.instructions[name] = im
    model.run_seconds = time.time() - t0
    s1 = engine.stats.as_dict()
    model.engine_stats = {k: s1[k] - stats0[k] for k in s1
                          if k != "hit_rate"}
    req = model.engine_stats["requests"]
    hits = (model.engine_stats["cache_hits"]
            + model.engine_stats["dedup_hits"])
    model.engine_stats["hit_rate"] = round(hits / max(1, req), 4)
    return model

"""Measurement plans: inference algorithms as resumable experiment generators.

The paper's algorithms (§5.1–§5.3) are naturally *experiment generators*:
each phase derives a set of microbenchmarks, runs them, and decides the next
set from the counters. This module makes that shape the public API of the
inference layer — the separation of experiment *selection* from experiment
*execution* that PALMED and Ritter & Reineke's explainable port-mapping work
use to scale throughput characterization.

The plan protocol
-----------------

A **plan** is a generator-based coroutine (optionally wrapped in
:class:`MeasurementPlan` for a name and a phase label). It communicates with
its driver exclusively through ``yield``:

* ``counters = yield [Experiment, ...]`` — request a batch of measurements;
  the driver resumes the plan with one :class:`Counters` per Experiment, in
  request order.
* ``results = yield Fork([plan, ...])`` — fan out sub-plans; the driver
  resumes the parent with the sub-plans' return values, in order. Sub-plans
  are themselves plans, so fan-out nests (characterize → instruction →
  latency pairs).
* ``return value`` — the plan's result (``StopIteration.value``).

Plans never touch a machine or an engine: they are pure descriptions of
*what to measure next given what was measured so far*. The same plan object
can therefore be driven two ways:

* :func:`run_plan` — the sequential reference driver: every yielded batch
  executes immediately, forked sub-plans run one after another. This is
  byte-for-byte the legacy (pre-plan) behavior of the inference modules, and
  it is what the thin compatibility wrappers (``infer_port_usage``,
  ``LatencyAnalyzer.analyze``, …) use.

* :class:`WaveScheduler` — the campaign driver: it steps *many* plans
  concurrently, drains every pending yield across all runnable plans, and
  executes the union as **one fused super-wave** through
  ``MeasurementEngine.submit`` (cache-first, deduplicated across plans,
  vectorized via the machine's ``run_batch`` backend). Because every
  runnable plan is stepped before any wave executes, no plan can starve —
  fairness is structural, not scheduled. A full-ISA characterization driven
  this way interleaves hundreds of instructions' experiments into each wave
  instead of one instruction's handful.

Results are identical under both drivers: experiments are deterministic
declarative objects, the engine's cache/dedup semantics make execution
order invisible, and the batched backend is bit-identical to the scalar
oracle — so regrouping experiments into wider waves can only change *when*
a benchmark runs, never what the inference concludes.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

from repro.core.engine import as_engine
from repro.obs import tracer as obs


class PlanCancelled(RuntimeError):
    """Raised inside a driver when its cancel event is set (e.g. a sibling
    campaign worker failed); outstanding plans are closed first."""


class Fork:
    """Fan-out request: run these sub-plans concurrently (WaveScheduler) or
    sequentially (run_plan); the yield resumes with their results in order."""

    __slots__ = ("plans",)

    def __init__(self, plans):
        self.plans = list(plans)


class MeasurementPlan:
    """A named, phase-tagged resumable measurement computation.

    Thin wrapper around the underlying generator: ``iter(plan)`` returns the
    generator itself, so a plan composes into another plan with plain
    ``yield from`` and drives identically to a bare generator. ``phase``
    labels the plan for the scheduler's per-phase time attribution
    (inherited by forked children that don't carry their own)."""

    __slots__ = ("gen", "name", "phase")

    def __init__(self, gen, name: str = "", phase: str = ""):
        self.gen = gen
        self.name = name
        self.phase = phase

    def __iter__(self):
        return self.gen

    def __repr__(self):
        tag = f" phase={self.phase}" if self.phase else ""
        return f"<MeasurementPlan {self.name or 'anonymous'}{tag}>"


@dataclass
class SchedulerStats:
    """Wave-fusion telemetry: how wide the executed waves actually were."""

    waves: int = 0              # engine.submit calls issued
    experiments: int = 0        # Experiments across all waves (pre-dedup)
    plans_completed: int = 0    # plans (incl. forked children) run to return
    max_wave_width: int = 0
    wave_widths: list = field(default_factory=list)  # per-wave widths, in
    # order — lets a caller sharing one scheduler slice out its own run's
    # widths (see characterize()'s delta bookkeeping)

    def record(self, width: int) -> None:
        self.waves += 1
        self.experiments += width
        self.max_wave_width = max(self.max_wave_width, width)
        self.wave_widths.append(width)

    @property
    def mean_wave_width(self) -> float:
        return self.experiments / max(1, self.waves)

    def as_dict(self) -> dict:
        return {"waves": self.waves, "experiments": self.experiments,
                "plans_completed": self.plans_completed,
                "mean_wave_width": round(self.mean_wave_width, 2),
                "max_wave_width": self.max_wave_width}


class _Task:
    """One live plan inside a scheduler run."""

    __slots__ = ("gen", "phase", "parent", "index", "pending", "results",
                 "send")

    def __init__(self, plan, parent, index, phase):
        self.gen = iter(plan)      # the generator (plans return themselves)
        self.phase = phase
        self.parent = parent       # _Task waiting on this one, or None
        self.index = index         # slot in the parent's (or root) results
        self.pending = 0           # outstanding forked children
        self.results = None        # collected fork results
        self.send = None           # value to inject at the next step


class WaveScheduler:
    """Drive many measurement plans concurrently, fusing their experiment
    requests into campaign-wide super-waves.

    Each round: (1) *drain* — step every runnable plan until it blocks on a
    batch of experiments, forks sub-plans (children become runnable), or
    returns; (2) *execute* — concatenate all blocked plans' batches into one
    wave, run it through ``engine.submit`` (cache-first, deduplicated across
    plans, batched through the machine's ``run_batch``), and resume every
    blocked plan with its slice. Draining everything before executing
    anything is the fairness guarantee: a plan is never left behind while
    others consume measurements.

    ``cancel`` (a ``threading.Event``) aborts the run at the next round
    boundary with :class:`PlanCancelled` — campaign workers share one event
    so a failure on one machine stops the others promptly. Per-phase wall
    time lands in ``phase_seconds``: stepping time is attributed to the
    running plan's phase, wave-execution time proportionally to the number
    of experiments each phase contributed.

    ``execute_lock`` (a ``threading.Lock``) travels down to the machine's
    batched backend as a kernel lock (see ``machine_run_batch``) and
    serializes the *GIL-bound* kernels across schedulers that share it: a
    fused numpy super-wave is one large Python-stepped array program that
    already saturates the interpreter, so two campaign workers' kernels
    interleaving under the GIL just thrash each other (measured ~8x CPU
    inflation).  Host lowering and wave packing always run outside the
    lock, and plan stepping stays concurrent throughout.  Device backends
    (jax/pallas) do not take this lock at all: their compiled kernels
    release the GIL, and dispatch serializes on the backend's own
    *per-device-subset* lock (:func:`repro.core.device_mesh
    .dispatch_lock`) instead — machines placed on disjoint device subsets
    by ``Campaign.run`` dispatch and execute concurrently, which is
    compute on distinct devices, not GIL thrash.
    """

    def __init__(self, machine_or_engine, *, cancel=None, execute_lock=None):
        self.engine = as_engine(machine_or_engine)
        self.cancel = cancel
        self.execute_lock = execute_lock
        self.stats = SchedulerStats()
        self.phase_seconds: dict[str, float] = {}

    # -- public entry points -----------------------------------------------
    def run(self, plans) -> list:
        """Drive ``plans`` to completion; returns their results in order.

        Each scheduler round emits two trace spans when tracing is on
        (``REPRO_TRACE=1``): ``scheduler.drain`` around the plan-stepping
        sweep and ``scheduler.execute`` around the fused wave (see
        :mod:`repro.obs`)."""
        plans = list(plans)
        results: list = [None] * len(plans)
        ready: deque[_Task] = deque(
            _Task(p, None, i, getattr(p, "phase", ""))
            for i, p in enumerate(plans))
        blocked: list[tuple[_Task, list]] = []
        live: set[_Task] = set(ready)
        rounds = 0
        try:
            with obs.span("scheduler.run", plans=len(plans)) as sp:
                while ready or blocked:
                    if self.cancel is not None and self.cancel.is_set():
                        raise PlanCancelled("measurement campaign cancelled")
                    rounds += 1
                    with obs.span("scheduler.drain", plans=len(ready)):
                        while ready:
                            self._step(ready.popleft(), ready, blocked,
                                       results, live)
                    if blocked:
                        self._execute(blocked, ready)
                        blocked = []
                sp.set(rounds=rounds, waves=self.stats.waves)
        except BaseException:
            for t in live:
                try:
                    t.gen.close()
                except Exception:   # noqa: BLE001 - best-effort cleanup
                    pass
            raise
        return results

    def run_one(self, plan):
        return self.run([plan])[0]

    # -- internals ----------------------------------------------------------
    def _charge(self, phase: str, seconds: float) -> None:
        if phase:
            self.phase_seconds[phase] = (
                self.phase_seconds.get(phase, 0.0) + seconds)

    def _step(self, t: _Task, ready, blocked, results, live) -> None:
        send, t.send = t.send, None
        t0 = time.perf_counter()
        try:
            req = t.gen.send(send)
        except StopIteration as stop:
            self._charge(t.phase, time.perf_counter() - t0)
            self.stats.plans_completed += 1
            live.remove(t)
            self._deliver(t, stop.value, ready, results)
            return
        # other exceptions from inside a plan propagate to run(), which
        # closes every live generator before re-raising
        self._charge(t.phase, time.perf_counter() - t0)
        if isinstance(req, Fork):
            if not req.plans:
                t.send = []
                ready.append(t)
                return
            t.pending = len(req.plans)
            t.results = [None] * len(req.plans)
            for i, sub in enumerate(req.plans):
                child = _Task(sub, t, i, getattr(sub, "phase", "") or t.phase)
                live.add(child)
                ready.append(child)
            return
        batch = list(req)
        if not batch:
            t.send = []
            ready.append(t)
            return
        blocked.append((t, batch))

    def _deliver(self, t: _Task, value, ready, results) -> None:
        if t.parent is None:
            results[t.index] = value
            return
        parent = t.parent
        parent.results[t.index] = value
        parent.pending -= 1
        if parent.pending == 0:
            parent.send, parent.results = parent.results, None
            ready.append(parent)

    def _execute(self, blocked, ready) -> None:
        with obs.span("scheduler.fuse", plans=len(blocked)):
            wave: list = []
            for _, batch in blocked:
                wave.extend(batch)
        obs.counter("scheduler.wave_width", len(wave))
        t0 = time.perf_counter()
        # the shared lock travels down to the machine as a *kernel* lock:
        # only kernel execution serializes across schedulers; this
        # scheduler's host lowering/packing overlaps a sibling's kernel
        # (double-buffered async dispatch in the batched backend)
        with obs.span("scheduler.execute", wave=len(wave),
                      plans=len(blocked)):
            counters = self.engine.submit(wave,
                                          kernel_lock=self.execute_lock)
        dt = time.perf_counter() - t0
        self.stats.record(len(wave))
        off = 0
        for t, batch in blocked:
            n = len(batch)
            t.send = counters[off:off + n]
            off += n
            self._charge(t.phase, dt * n / len(wave))
            ready.append(t)


def run_plan(machine_or_engine, plan, stats: SchedulerStats | None = None,
             phase_seconds: dict | None = None):
    """Sequential reference driver: run one plan to completion.

    Every yielded batch executes immediately as its own wave; forked
    sub-plans run one after another, depth-first. This reproduces the
    legacy per-instruction behavior exactly (phase-local waves, no fusion
    across plans) and is what the compatibility wrappers use. ``stats``
    optionally records the executed wave widths for comparison against a
    :class:`WaveScheduler` run; ``phase_seconds`` optionally accumulates
    per-phase wall time (phase labels inherit into forked children, as in
    the scheduler)."""
    engine = as_engine(machine_or_engine)

    def charge(phase: str, seconds: float) -> None:
        if phase_seconds is not None and phase:
            phase_seconds[phase] = phase_seconds.get(phase, 0.0) + seconds

    def drive(p, phase: str = ""):
        gen = iter(p)
        phase = getattr(p, "phase", "") or phase
        send = None
        while True:
            t0 = time.perf_counter()
            try:
                req = gen.send(send)
            except StopIteration as stop:
                charge(phase, time.perf_counter() - t0)
                if stats is not None:
                    stats.plans_completed += 1
                return stop.value
            charge(phase, time.perf_counter() - t0)
            if isinstance(req, Fork):
                send = [drive(sub, phase) for sub in req.plans]
            else:
                batch = list(req)
                t0 = time.perf_counter()
                send = engine.submit(batch) if batch else []
                charge(phase, time.perf_counter() - t0)
                if stats is not None and batch:
                    stats.record(len(batch))

    return drive(plan)

"""Per-operand-pair latency inference (§4.1 definition, §5.2 algorithms).

lat: S × D → ℕ maps every (source operand, destination operand) pair to its
own latency — the paper's central definitional contribution. Inference
builds cyclic dependency chains per pair:

  * gpr→gpr: MOVSX chain (avoids move elimination and partial-register
    stalls — the reasons the paper rejects MOV/MOVZX, §5.2.1),
  * vec→vec: both an integer (PSHUFD) and an fp (MOVSHDUP) non-destructive
    shuffle, to expose bypass-delay differences,
  * type-crossing pairs: compositions with every candidate chain instruction;
    min composite − 1 reported as an upper bound,
  * flags→reg: TEST R,R closes the loop (§5.2.3); reg→flags via SETC,
  * mem→reg: the double-XOR address trick (§5.2.2),
  * reg→mem: store→load round trip (store-to-load forwarding caveat, §5.2.4),
  * dividers: operand values pinned with AND/OR idempotent masking (§5.2.5).

Unwanted implicit dependencies (status flags, read-modify-write operands not
under test) are cut with dependency-breaking instructions: TEST on an
independent register for flags, a zero idiom for registers.

Each register→register pair with two explicit same-type operands is also
measured with *the same register* for both operands — the scenario that
explains the SHLD discrepancies between published numbers (§7.3.2).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.engine import Experiment, as_engine
from repro.core.isa import FLAGS, GPR, IMM, ISA, MEM, VEC, InstrSpec
from repro.core.simulator import Instr

# dedicated registers (never handed out by pools sized 16/16/8)
CHAIN_GPR = ("R24", "R25")
CHAIN_VEC = ("X24", "X25")
BREAK_GPR = "R26"
AUX_GPR = ("R27", "R28")
AUX_VEC = ("X27", "X28")
MEM_BASE = "R23"


@dataclass
class LatencyEntry:
    src: str
    dst: str
    value: float
    kind: str = "exact"  # exact | upper_bound | roundtrip
    chain: str = ""
    same_reg: float | None = None
    high_value: float | None = None  # divider high-latency operand class
    per_chain: dict = field(default_factory=dict)


@dataclass
class LatencyResult:
    instr: str
    entries: dict = field(default_factory=dict)  # (src,dst) -> LatencyEntry

    def get(self, src: str, dst: str) -> LatencyEntry | None:
        return self.entries.get((src, dst))

    def max_latency(self) -> int:
        vals = [e.value for e in self.entries.values()]
        vals += [e.high_value for e in self.entries.values()
                 if e.high_value is not None]
        return max(1, round(max(vals))) if vals else 1


class LatencyAnalyzer:
    """Per-pair latency inference through the measurement engine.

    ``machine`` may be a machine or a :class:`MeasurementEngine`; every
    dependency-chain benchmark is submitted as a declarative Experiment, so
    chains shared between pairs (or re-run across analyses) execute once."""

    def __init__(self, machine, isa: ISA):
        self.engine = as_engine(machine)
        self.machine = self.engine.machine
        self.isa = isa
        self._boot()

    # -- low-level helpers --------------------------------------------------
    def _cycles(self, seq: list[Instr]) -> float:
        return self.engine.measure(Experiment.of(seq)).cycles

    def _cycles_wave(self, seqs: list[list[Instr]]) -> list[float]:
        """Batched submission of independent chain benchmarks."""
        return [c.cycles for c in
                self.engine.submit([Experiment.of(s) for s in seqs])]

    def _flags_break(self) -> Instr:
        return Instr("TEST_R64_R64", {"op1": BREAK_GPR, "op2": BREAK_GPR})

    def _reg_break(self, reg: str, otype: str) -> Instr:
        """Overwrite ``reg`` without depending on it and — crucial for the
        flags→reg chains — without touching the status flags (a zero-idiom
        XOR would overwrite FLAGS and cut the dependency under test)."""
        if otype == GPR:
            return Instr("MOV_R64_R64", {"op1": reg, "op2": BREAK_GPR})
        return Instr("PCMPGTQ_X_X", {"op1": reg, "op2": reg})

    def _chain_instr(self, name: str, dst: str, src: str) -> Instr:
        return Instr(name, {"op1": dst, "op2": src})

    def _boot(self):
        """Measure the chain-instruction latencies (§5.2: 'known or easy to
        determine in isolation'). TEST's reg→flags latency is the single
        bootstrap assumption (= 1 cycle), as in the paper's methodology."""
        self.lat_test = 1.0
        a, b = CHAIN_GPR
        # MOVSX self-chain: MOVSX a,b ; MOVSX b,a
        self.lat_movsx = self._cycles([
            self._chain_instr("MOVSX_R64_R32", a, b),
            self._chain_instr("MOVSX_R64_R32", b, a)]) / 2
        va, vb = CHAIN_VEC
        self.vec_chains = {}
        for nm in ("PSHUFD_X_X", "MOVSHDUP_X_X"):
            if nm in self.isa:
                self.vec_chains[nm] = self._cycles([
                    self._chain_instr(nm, va, vb),
                    self._chain_instr(nm, vb, va)]) / 2
        # XOR lat(op1,op1): XOR a, aux (RMW self-chain; flags written only)
        self.lat_xor = (self._cycles([
            Instr("XOR_R64_R64", {"op1": a, "op2": AUX_GPR[0]})])
            if "XOR_R64_R64" in self.isa else 1.0)
        # SETC via TEST+SETC+MOVSX loop
        if "TEST_R64_R64" in self.isa and "SETC_R8" in self.isa:
            mv = ("MOVSX_R64_R8" if "MOVSX_R64_R8" in self.isa
                  else "MOVSX_R64_R32")
            comp = self._cycles([
                Instr("TEST_R64_R64", {"op1": a, "op2": a}),
                Instr("SETC_R8", {"op1": b}),
                self._chain_instr(mv, a, b)])
            self.lat_setc = max(comp - self.lat_test - self.lat_movsx, 0.0)
        else:
            self.lat_setc = 1.0
        # type-crossing chain candidates: (vec->gpr) and (gpr->vec) movers
        self.cross = {"to_gpr": [], "to_vec": []}
        for s in self.isa:
            ops = s.explicit_operands
            if len(ops) != 2 or any(o.otype == IMM for o in ops):
                continue
            d, src = ops[0], ops[1]
            if d.written and not d.read and src.read:
                if d.otype == GPR and src.otype == VEC:
                    self.cross["to_gpr"].append(s.name)
                elif d.otype == VEC and src.otype == GPR:
                    self.cross["to_vec"].append(s.name)

    # -- link builders ------------------------------------------------------
    def _breakers(self, spec: InstrSpec, skip: set) -> list[Instr]:
        """Dependency-breaking instructions for RMW operands not under test."""
        out = []
        for o in spec.operands:
            if o.name in skip or not o.rmw:
                continue
            if o.otype == FLAGS:
                out.append(self._flags_break())
        # flags written by chain XORs etc. are broken by the same TEST
        return out

    def _assign(self, spec: InstrSpec, fixed: dict) -> dict:
        """Registers for all explicit operands; unfixed ones get aux regs."""
        regs = dict(fixed)
        gi = vi = 0
        for o in spec.explicit_operands:
            if o.name in regs or o.otype == IMM:
                continue
            if o.otype == GPR:
                regs[o.name] = AUX_GPR[gi % len(AUX_GPR)]
                gi += 1
            elif o.otype == VEC:
                regs[o.name] = AUX_VEC[vi % len(AUX_VEC)]
                vi += 1
            elif o.otype == MEM:
                regs[o.name] = MEM_BASE
        return regs

    # -- per-case measurements ----------------------------------------------
    def _reg_reg(self, spec, s, d, value_hint="low"):
        """Same-type register→register (gpr or vec)."""
        otype = s.otype
        ca, cb = CHAIN_GPR if otype == GPR else CHAIN_VEC
        chains = ({"MOVSX_R64_R32": self.lat_movsx} if otype == GPR
                  else self.vec_chains)
        links, offsets = [], []
        for cname, clat in chains.items():
            link: list[Instr] = []
            if s.name == d.name:
                regs = self._assign(spec, {s.name: ca})
                link += self._breakers(spec, {s.name})
                link.append(Instr(spec.name, regs, value_hint))
                offsets.append(0.0)
            else:
                fixed = {s.name: ca, d.name: cb}
                regs = self._assign(spec, fixed)
                link += self._breakers(spec, {s.name, d.name})
                if d.read:  # RMW dest: break the old-value dependency
                    link.append(self._reg_break(cb, otype))
                link.append(Instr(spec.name, regs, value_hint))
                link.append(self._chain_instr(cname, ca, cb))
                offsets.append(clat)
            links.append(link)
        per_chain = {cname: cyc - off for cname, cyc, off
                     in zip(chains, self._cycles_wave(links), offsets)}
        val = min(per_chain.values())
        e = LatencyEntry(s.name, d.name, val, "exact",
                         chain="|".join(per_chain), per_chain=per_chain)
        # same-register scenario (§7.3.2)
        ex_regs = [o for o in spec.explicit_operands
                   if o.otype == otype]
        if s.name != d.name and len(ex_regs) >= 2:
            regs = self._assign(spec, {s.name: ca, d.name: ca})
            link = self._breakers(spec, {s.name, d.name})
            link.append(Instr(spec.name, regs, value_hint))
            e.same_reg = self._cycles(link)
        return e

    def _flags_to_reg(self, spec, s, d):
        ca = CHAIN_GPR[0]
        link = []
        link.append(Instr("TEST_R64_R64", {"op1": ca, "op2": ca}))
        if d.read:
            link.append(self._reg_break(ca, GPR))
        regs = self._assign(spec, {d.name: ca})
        link.append(Instr(spec.name, regs))
        return LatencyEntry(s.name, d.name,
                            self._cycles(link) - self.lat_test,
                            "exact", chain="TEST")

    def _reg_to_flags(self, spec, s, d):
        if s.otype != GPR:
            return None
        ca, cb = CHAIN_GPR
        regs = self._assign(spec, {s.name: ca})
        link = self._breakers(spec, {s.name, d.name})
        link.append(Instr(spec.name, regs))
        link.append(Instr("SETC_R8", {"op1": cb}))
        # width-matched MOVSX: SETC writes 8 bits; reading wider would incur
        # a partial-register stall and corrupt the measurement (§5.2.1)
        mv = "MOVSX_R64_R8" if "MOVSX_R64_R8" in self.isa else "MOVSX_R64_R32"
        link.append(self._chain_instr(mv, ca, cb))
        val = self._cycles(link) - self.lat_setc - self.lat_movsx
        return LatencyEntry(s.name, d.name, val, "exact", chain="SETC+MOVSX")

    def _flags_to_flags(self, spec, s, d):
        link = [Instr(spec.name, self._assign(spec, {}))]
        return LatencyEntry(s.name, d.name, self._cycles(link), "exact",
                            chain="self")

    def _mem_to_reg(self, spec, s, d):
        """Double-XOR trick: address depends on the loaded result (§5.2.2)."""
        rb = MEM_BASE
        regs = self._assign(spec, {s.name: rb})
        rd = regs.get(d.name)
        if d.otype == VEC:
            # vec dest: compose with vec->gpr mover for an upper bound
            links = []
            for mv in self.cross["to_gpr"]:
                link = []
                if d.read:  # break the RMW old-value loop (e.g. AESDEC m128)
                    link.append(self._reg_break(regs[d.name], VEC))
                link += [Instr(spec.name, regs),
                         Instr(mv, {"op1": CHAIN_GPR[0], "op2": regs[d.name]}),
                         Instr("XOR_R64_R64", {"op1": rb, "op2": CHAIN_GPR[0]}),
                         Instr("XOR_R64_R64", {"op1": rb, "op2": CHAIN_GPR[0]}),
                         self._flags_break()]
                links.append(link)
            per = {mv: cyc - 2 * self.lat_xor for mv, cyc
                   in zip(self.cross["to_gpr"], self._cycles_wave(links))}
            best = min(per.values())
            return LatencyEntry(s.name, d.name, max(best - 1, 0),
                                "upper_bound", chain="xor2+cross",
                                per_chain=per)
        link = self._breakers(spec, {s.name, d.name})
        link.append(Instr(spec.name, regs))
        link.append(Instr("XOR_R64_R64", {"op1": rb, "op2": rd}))
        link.append(Instr("XOR_R64_R64", {"op1": rb, "op2": rd}))
        link.append(self._flags_break())
        return LatencyEntry(s.name, d.name,
                            self._cycles(link) - 2 * self.lat_xor,
                            "exact", chain="xor2")

    def _reg_to_mem(self, spec, s, d):
        """Store: measure a store→load round trip (§5.2.4)."""
        rb = MEM_BASE
        if s.otype == VEC:
            if "MOVAPS_X_M" not in self.isa:
                return None
            load, ca, cb = "MOVAPS_X_M", CHAIN_VEC[0], CHAIN_VEC[1]
            chain = next(iter(self.vec_chains)) if self.vec_chains else None
            clat = self.vec_chains.get(chain, 1.0)
        else:
            load, ca, cb = "MOV_R64_M64", CHAIN_GPR[0], CHAIN_GPR[1]
            chain, clat = "MOVSX_R64_R32", self.lat_movsx
        regs = self._assign(spec, {s.name: ca, d.name: rb})
        link = [Instr(spec.name, regs),
                Instr(load, {"op1": cb, "mem": rb})]
        if chain:
            link.append(self._chain_instr(chain, ca, cb))
        val = self._cycles(link) - clat
        return LatencyEntry(s.name, d.name, val, "roundtrip",
                            chain=f"store+{load}")

    def _cross_type(self, spec, s, d):
        """Different register types: compositions, upper bound (§5.2.1)."""
        movers, links = [], []
        if d.otype == VEC and s.otype == GPR:
            movers = self.cross["to_gpr"]  # vec result -> gpr source
            for mv in movers:
                regs = self._assign(spec, {s.name: CHAIN_GPR[0],
                                           d.name: CHAIN_VEC[0]})
                link = self._breakers(spec, {s.name, d.name})
                if d.read:
                    link.append(self._reg_break(CHAIN_VEC[0], VEC))
                link.append(Instr(spec.name, regs))
                link.append(Instr(mv, {"op1": CHAIN_GPR[0],
                                       "op2": CHAIN_VEC[0]}))
                links.append(link)
        elif d.otype == GPR and s.otype == VEC:
            movers = self.cross["to_vec"]
            for mv in movers:
                regs = self._assign(spec, {s.name: CHAIN_VEC[0],
                                           d.name: CHAIN_GPR[0]})
                link = self._breakers(spec, {s.name, d.name})
                if d.read:
                    link.append(self._reg_break(CHAIN_GPR[0], GPR))
                link.append(Instr(spec.name, regs))
                link.append(Instr(mv, {"op1": CHAIN_VEC[0],
                                       "op2": CHAIN_GPR[0]}))
                links.append(link)
        per = dict(zip(movers, self._cycles_wave(links)))
        if not per:
            return None
        return LatencyEntry(s.name, d.name, max(min(per.values()) - 1, 0),
                            "upper_bound", chain="compose", per_chain=per)

    # -- public entry point ---------------------------------------------------
    def analyze(self, instr: InstrSpec | str) -> LatencyResult:
        spec = self.isa[instr] if isinstance(instr, str) else instr
        res = LatencyResult(spec.name)
        for s in spec.sources:
            if s.otype == IMM:
                continue
            for d in spec.dests:
                e = self._pair(spec, s, d)
                if e is not None:
                    if spec.uses_divider and e.kind == "exact":
                        eh = self._pair(spec, s, d, value_hint="high")
                        if eh is not None:
                            e.high_value = eh.value
                    res.entries[(s.name, d.name)] = e
        return res

    def _pair(self, spec, s, d, value_hint="low"):
        if s.otype == FLAGS and d.otype == FLAGS:
            return self._flags_to_flags(spec, s, d)
        if s.otype == FLAGS:
            if d.otype != GPR:
                return None
            return self._flags_to_reg(spec, s, d)
        if d.otype == FLAGS:
            return self._reg_to_flags(spec, s, d)
        if s.otype == MEM:
            return self._mem_to_reg(spec, s, d)
        if d.otype == MEM:
            return self._reg_to_mem(spec, s, d)
        if s.otype == d.otype:
            return self._reg_reg(spec, s, d, value_hint)
        return self._cross_type(spec, s, d)

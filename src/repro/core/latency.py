"""Per-operand-pair latency inference (§4.1 definition, §5.2 algorithms).

lat: S × D → ℕ maps every (source operand, destination operand) pair to its
own latency — the paper's central definitional contribution. Inference
builds cyclic dependency chains per pair:

  * gpr→gpr: MOVSX chain (avoids move elimination and partial-register
    stalls — the reasons the paper rejects MOV/MOVZX, §5.2.1),
  * vec→vec: both an integer (PSHUFD) and an fp (MOVSHDUP) non-destructive
    shuffle, to expose bypass-delay differences,
  * type-crossing pairs: compositions with every candidate chain instruction;
    min composite − 1 reported as an upper bound,
  * flags→reg: TEST R,R closes the loop (§5.2.3); reg→flags via SETC,
  * mem→reg: the double-XOR address trick (§5.2.2),
  * reg→mem: store→load round trip (store-to-load forwarding caveat, §5.2.4),
  * dividers: operand values pinned with AND/OR idempotent masking (§5.2.5).

Unwanted implicit dependencies (status flags, read-modify-write operands not
under test) are cut with dependency-breaking instructions: TEST on an
independent register for flags, a zero idiom for registers.

Each register→register pair with two explicit same-type operands is also
measured with *the same register* for both operands — the scenario that
explains the SHLD discrepancies between published numbers (§7.3.2).

The inference is expressed as :mod:`repro.core.plan` measurement plans:
:class:`LatencyPlans` is the machine-free plan factory — its one-wave
``boot`` plan measures the chain-instruction latencies (§5.2: 'known or
easy to determine in isolation'), and ``analyze`` plans fork one sub-plan
per (source, dest) operand pair, so a :class:`~repro.core.plan
.WaveScheduler` fuses chain benchmarks across pairs *and* across
instructions. :class:`LatencyAnalyzer` remains the run-to-completion
wrapper with the original eager-boot constructor.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.engine import Experiment, as_engine
from repro.core.isa import FLAGS, GPR, IMM, ISA, MEM, VEC, InstrSpec
from repro.core.plan import Fork, MeasurementPlan, run_plan
from repro.core.simulator import Instr

# dedicated registers (never handed out by pools sized 16/16/8)
CHAIN_GPR = ("R24", "R25")
CHAIN_VEC = ("X24", "X25")
BREAK_GPR = "R26"
AUX_GPR = ("R27", "R28")
AUX_VEC = ("X27", "X28")
MEM_BASE = "R23"


@dataclass
class LatencyEntry:
    src: str
    dst: str
    value: float
    kind: str = "exact"  # exact | upper_bound | roundtrip
    chain: str = ""
    same_reg: float | None = None
    high_value: float | None = None  # divider high-latency operand class
    per_chain: dict = field(default_factory=dict)


@dataclass
class LatencyResult:
    instr: str
    entries: dict = field(default_factory=dict)  # (src,dst) -> LatencyEntry

    def get(self, src: str, dst: str) -> LatencyEntry | None:
        return self.entries.get((src, dst))

    def max_latency(self) -> int:
        vals = [e.value for e in self.entries.values()]
        vals += [e.high_value for e in self.entries.values()
                 if e.high_value is not None]
        # quarantined measurements surface as NaN sentinels: they carry no
        # latency information, and must not abort the campaign here
        vals = [v for v in vals if v == v]
        return max(1, round(max(vals))) if vals else 1


class LatencyPlans:
    """Machine-free plan factory for per-pair latency inference.

    One instance per characterization: ``boot_gen`` measures the chain
    instruments once (idempotent — concurrent analyze plans that race into
    it request identical experiments, which the engine dedups; they compute
    identical constants). All measurement happens through plan yields, so
    the same instance serves both the sequential wrapper and a scheduler
    interleaving many instructions."""

    def __init__(self, isa: ISA):
        self.isa = isa
        self._booted = False
        self.lat_test = 1.0
        self.lat_movsx = 0.0
        self.lat_xor = 1.0
        self.lat_setc = 1.0
        self.vec_chains: dict[str, float] = {}
        self.cross: dict[str, list] = {"to_gpr": [], "to_vec": []}

    # -- low-level plan fragments (composed with ``yield from``) ------------
    def _cycles(self, seq: list[Instr]):
        c = yield [Experiment.of(seq)]
        return c[0].cycles

    def _cycles_wave(self, seqs: list[list[Instr]]):
        """Batched request of independent chain benchmarks."""
        cs = yield [Experiment.of(s) for s in seqs]
        return [c.cycles for c in cs]

    def _flags_break(self) -> Instr:
        return Instr("TEST_R64_R64", {"op1": BREAK_GPR, "op2": BREAK_GPR})

    def _reg_break(self, reg: str, otype: str) -> Instr:
        """Overwrite ``reg`` without depending on it and — crucial for the
        flags→reg chains — without touching the status flags (a zero-idiom
        XOR would overwrite FLAGS and cut the dependency under test)."""
        if otype == GPR:
            return Instr("MOV_R64_R64", {"op1": reg, "op2": BREAK_GPR})
        return Instr("PCMPGTQ_X_X", {"op1": reg, "op2": reg})

    def _chain_instr(self, name: str, dst: str, src: str) -> Instr:
        return Instr(name, {"op1": dst, "op2": src})

    def boot_gen(self):
        """Chain-instruction latencies (§5.2), one wave. TEST's reg→flags
        latency is the single bootstrap assumption (= 1 cycle), as in the
        paper's methodology. Idempotent: a booted factory yields nothing."""
        if self._booted:
            return
        isa = self.isa
        a, b = CHAIN_GPR
        va, vb = CHAIN_VEC
        wave: list[tuple[str, list[Instr]]] = []
        # MOVSX self-chain: MOVSX a,b ; MOVSX b,a
        wave.append(("movsx", [self._chain_instr("MOVSX_R64_R32", a, b),
                               self._chain_instr("MOVSX_R64_R32", b, a)]))
        vec_names = [nm for nm in ("PSHUFD_X_X", "MOVSHDUP_X_X")
                     if nm in isa]
        for nm in vec_names:
            wave.append((nm, [self._chain_instr(nm, va, vb),
                              self._chain_instr(nm, vb, va)]))
        # XOR lat(op1,op1): XOR a, aux (RMW self-chain; flags written only)
        if "XOR_R64_R64" in isa:
            wave.append(("xor", [Instr("XOR_R64_R64",
                                       {"op1": a, "op2": AUX_GPR[0]})]))
        # SETC via TEST+SETC+MOVSX loop
        have_setc = "TEST_R64_R64" in isa and "SETC_R8" in isa
        if have_setc:
            mv = ("MOVSX_R64_R8" if "MOVSX_R64_R8" in isa
                  else "MOVSX_R64_R32")
            wave.append(("setc", [
                Instr("TEST_R64_R64", {"op1": a, "op2": a}),
                Instr("SETC_R8", {"op1": b}),
                self._chain_instr(mv, a, b)]))
        cycles = yield from self._cycles_wave([seq for _, seq in wave])
        got = dict(zip((k for k, _ in wave), cycles))
        self.lat_test = 1.0
        self.lat_movsx = got["movsx"] / 2
        self.vec_chains = {nm: got[nm] / 2 for nm in vec_names}
        self.lat_xor = got.get("xor", 1.0)
        self.lat_setc = (max(got["setc"] - self.lat_test - self.lat_movsx,
                             0.0) if have_setc else 1.0)
        # type-crossing chain candidates: (vec->gpr) and (gpr->vec) movers
        self.cross = {"to_gpr": [], "to_vec": []}
        for s in isa:
            ops = s.explicit_operands
            if len(ops) != 2 or any(o.otype == IMM for o in ops):
                continue
            d, src = ops[0], ops[1]
            if d.written and not d.read and src.read:
                if d.otype == GPR and src.otype == VEC:
                    self.cross["to_gpr"].append(s.name)
                elif d.otype == VEC and src.otype == GPR:
                    self.cross["to_vec"].append(s.name)
        self._booted = True

    def boot_plan(self) -> MeasurementPlan:
        return MeasurementPlan(self.boot_gen(), name="latency-boot",
                               phase="latency")

    # -- link builders ------------------------------------------------------
    def _breakers(self, spec: InstrSpec, skip: set) -> list[Instr]:
        """Dependency-breaking instructions for RMW operands not under test."""
        out = []
        for o in spec.operands:
            if o.name in skip or not o.rmw:
                continue
            if o.otype == FLAGS:
                out.append(self._flags_break())
        # flags written by chain XORs etc. are broken by the same TEST
        return out

    def _assign(self, spec: InstrSpec, fixed: dict) -> dict:
        """Registers for all explicit operands; unfixed ones get aux regs."""
        regs = dict(fixed)
        gi = vi = 0
        for o in spec.explicit_operands:
            if o.name in regs or o.otype == IMM:
                continue
            if o.otype == GPR:
                regs[o.name] = AUX_GPR[gi % len(AUX_GPR)]
                gi += 1
            elif o.otype == VEC:
                regs[o.name] = AUX_VEC[vi % len(AUX_VEC)]
                vi += 1
            elif o.otype == MEM:
                regs[o.name] = MEM_BASE
        return regs

    # -- per-case measurements ----------------------------------------------
    def _reg_reg(self, spec, s, d, value_hint="low"):
        """Same-type register→register (gpr or vec)."""
        otype = s.otype
        ca, cb = CHAIN_GPR if otype == GPR else CHAIN_VEC
        chains = ({"MOVSX_R64_R32": self.lat_movsx} if otype == GPR
                  else self.vec_chains)
        links, offsets = [], []
        for cname, clat in chains.items():
            link: list[Instr] = []
            if s.name == d.name:
                regs = self._assign(spec, {s.name: ca})
                link += self._breakers(spec, {s.name})
                link.append(Instr(spec.name, regs, value_hint))
                offsets.append(0.0)
            else:
                fixed = {s.name: ca, d.name: cb}
                regs = self._assign(spec, fixed)
                link += self._breakers(spec, {s.name, d.name})
                if d.read:  # RMW dest: break the old-value dependency
                    link.append(self._reg_break(cb, otype))
                link.append(Instr(spec.name, regs, value_hint))
                link.append(self._chain_instr(cname, ca, cb))
                offsets.append(clat)
            links.append(link)
        cycles = yield from self._cycles_wave(links)
        per_chain = {cname: cyc - off for cname, cyc, off
                     in zip(chains, cycles, offsets)}
        val = min(per_chain.values())
        e = LatencyEntry(s.name, d.name, val, "exact",
                         chain="|".join(per_chain), per_chain=per_chain)
        # same-register scenario (§7.3.2)
        ex_regs = [o for o in spec.explicit_operands
                   if o.otype == otype]
        if s.name != d.name and len(ex_regs) >= 2:
            regs = self._assign(spec, {s.name: ca, d.name: ca})
            link = self._breakers(spec, {s.name, d.name})
            link.append(Instr(spec.name, regs, value_hint))
            e.same_reg = yield from self._cycles(link)
        return e

    def _flags_to_reg(self, spec, s, d):
        ca = CHAIN_GPR[0]
        link = []
        link.append(Instr("TEST_R64_R64", {"op1": ca, "op2": ca}))
        if d.read:
            link.append(self._reg_break(ca, GPR))
        regs = self._assign(spec, {d.name: ca})
        link.append(Instr(spec.name, regs))
        cyc = yield from self._cycles(link)
        return LatencyEntry(s.name, d.name, cyc - self.lat_test,
                            "exact", chain="TEST")

    def _reg_to_flags(self, spec, s, d):
        if s.otype != GPR:
            return None
        ca, cb = CHAIN_GPR
        regs = self._assign(spec, {s.name: ca})
        link = self._breakers(spec, {s.name, d.name})
        link.append(Instr(spec.name, regs))
        link.append(Instr("SETC_R8", {"op1": cb}))
        # width-matched MOVSX: SETC writes 8 bits; reading wider would incur
        # a partial-register stall and corrupt the measurement (§5.2.1)
        mv = ("MOVSX_R64_R8" if "MOVSX_R64_R8" in self.isa
              else "MOVSX_R64_R32")
        link.append(self._chain_instr(mv, ca, cb))
        cyc = yield from self._cycles(link)
        val = cyc - self.lat_setc - self.lat_movsx
        return LatencyEntry(s.name, d.name, val, "exact", chain="SETC+MOVSX")

    def _flags_to_flags(self, spec, s, d):
        link = [Instr(spec.name, self._assign(spec, {}))]
        cyc = yield from self._cycles(link)
        return LatencyEntry(s.name, d.name, cyc, "exact", chain="self")

    def _mem_to_reg(self, spec, s, d):
        """Double-XOR trick: address depends on the loaded result (§5.2.2)."""
        rb = MEM_BASE
        regs = self._assign(spec, {s.name: rb})
        rd = regs.get(d.name)
        if d.otype == VEC:
            # vec dest: compose with vec->gpr mover for an upper bound
            links = []
            for mv in self.cross["to_gpr"]:
                link = []
                if d.read:  # break the RMW old-value loop (e.g. AESDEC m128)
                    link.append(self._reg_break(regs[d.name], VEC))
                link += [Instr(spec.name, regs),
                         Instr(mv, {"op1": CHAIN_GPR[0], "op2": regs[d.name]}),
                         Instr("XOR_R64_R64", {"op1": rb, "op2": CHAIN_GPR[0]}),
                         Instr("XOR_R64_R64", {"op1": rb, "op2": CHAIN_GPR[0]}),
                         self._flags_break()]
                links.append(link)
            cycles = yield from self._cycles_wave(links)
            per = {mv: cyc - 2 * self.lat_xor for mv, cyc
                   in zip(self.cross["to_gpr"], cycles)}
            best = min(per.values())
            return LatencyEntry(s.name, d.name, max(best - 1, 0),
                                "upper_bound", chain="xor2+cross",
                                per_chain=per)
        link = self._breakers(spec, {s.name, d.name})
        link.append(Instr(spec.name, regs))
        link.append(Instr("XOR_R64_R64", {"op1": rb, "op2": rd}))
        link.append(Instr("XOR_R64_R64", {"op1": rb, "op2": rd}))
        link.append(self._flags_break())
        cyc = yield from self._cycles(link)
        return LatencyEntry(s.name, d.name, cyc - 2 * self.lat_xor,
                            "exact", chain="xor2")

    def _reg_to_mem(self, spec, s, d):
        """Store: measure a store→load round trip (§5.2.4)."""
        rb = MEM_BASE
        if s.otype == VEC:
            if "MOVAPS_X_M" not in self.isa:
                return None
            load, ca, cb = "MOVAPS_X_M", CHAIN_VEC[0], CHAIN_VEC[1]
            chain = next(iter(self.vec_chains)) if self.vec_chains else None
            clat = self.vec_chains.get(chain, 1.0)
        else:
            load, ca, cb = "MOV_R64_M64", CHAIN_GPR[0], CHAIN_GPR[1]
            chain, clat = "MOVSX_R64_R32", self.lat_movsx
        regs = self._assign(spec, {s.name: ca, d.name: rb})
        link = [Instr(spec.name, regs),
                Instr(load, {"op1": cb, "mem": rb})]
        if chain:
            link.append(self._chain_instr(chain, ca, cb))
        cyc = yield from self._cycles(link)
        val = cyc - clat
        return LatencyEntry(s.name, d.name, val, "roundtrip",
                            chain=f"store+{load}")

    def _cross_type(self, spec, s, d):
        """Different register types: compositions, upper bound (§5.2.1)."""
        movers, links = [], []
        if d.otype == VEC and s.otype == GPR:
            movers = self.cross["to_gpr"]  # vec result -> gpr source
            for mv in movers:
                regs = self._assign(spec, {s.name: CHAIN_GPR[0],
                                           d.name: CHAIN_VEC[0]})
                link = self._breakers(spec, {s.name, d.name})
                if d.read:
                    link.append(self._reg_break(CHAIN_VEC[0], VEC))
                link.append(Instr(spec.name, regs))
                link.append(Instr(mv, {"op1": CHAIN_GPR[0],
                                       "op2": CHAIN_VEC[0]}))
                links.append(link)
        elif d.otype == GPR and s.otype == VEC:
            movers = self.cross["to_vec"]
            for mv in movers:
                regs = self._assign(spec, {s.name: CHAIN_VEC[0],
                                           d.name: CHAIN_GPR[0]})
                link = self._breakers(spec, {s.name, d.name})
                if d.read:
                    link.append(self._reg_break(CHAIN_GPR[0], GPR))
                link.append(Instr(spec.name, regs))
                link.append(Instr(mv, {"op1": CHAIN_VEC[0],
                                       "op2": CHAIN_GPR[0]}))
                links.append(link)
        cycles = yield from self._cycles_wave(links)
        per = dict(zip(movers, cycles))
        if not per:
            return None
        return LatencyEntry(s.name, d.name, max(min(per.values()) - 1, 0),
                            "upper_bound", chain="compose", per_chain=per)

    # -- pair dispatch -------------------------------------------------------
    def _pair_gen(self, spec, s, d, value_hint="low"):
        if s.otype == FLAGS and d.otype == FLAGS:
            return (yield from self._flags_to_flags(spec, s, d))
        if s.otype == FLAGS:
            if d.otype != GPR:
                return None
            return (yield from self._flags_to_reg(spec, s, d))
        if d.otype == FLAGS:
            return (yield from self._reg_to_flags(spec, s, d))
        if s.otype == MEM:
            return (yield from self._mem_to_reg(spec, s, d))
        if d.otype == MEM:
            return (yield from self._reg_to_mem(spec, s, d))
        if s.otype == d.otype:
            return (yield from self._reg_reg(spec, s, d, value_hint))
        return (yield from self._cross_type(spec, s, d))

    def _pair_full_gen(self, spec, s, d):
        e = yield from self._pair_gen(spec, s, d)
        if e is not None and spec.uses_divider and e.kind == "exact":
            eh = yield from self._pair_gen(spec, s, d, value_hint="high")
            if eh is not None:
                e.high_value = eh.value
        return e

    # -- per-instruction plan ------------------------------------------------
    def analyze_gen(self, spec: InstrSpec):
        yield from self.boot_gen()
        pairs = [(s, d) for s in spec.sources if s.otype != IMM
                 for d in spec.dests]
        entries = yield Fork([
            MeasurementPlan(self._pair_full_gen(spec, s, d),
                            name=f"lat[{spec.name}:{s.name}->{d.name}]",
                            phase="latency")
            for s, d in pairs])
        res = LatencyResult(spec.name)
        for (s, d), e in zip(pairs, entries):
            if e is not None:
                res.entries[(s.name, d.name)] = e
        return res

    def analyze_plan(self, instr: InstrSpec | str) -> MeasurementPlan:
        spec = self.isa[instr] if isinstance(instr, str) else instr
        return MeasurementPlan(self.analyze_gen(spec),
                               name=f"latency[{spec.name}]", phase="latency")


def latency_plan(spec: InstrSpec | str, isa: ISA,
                 plans: LatencyPlans | None = None) -> MeasurementPlan:
    """Per-operand-pair latency inference for one instruction as a plan.

    Pass a shared :class:`LatencyPlans` so many instructions' plans reuse
    one boot (a fresh factory boots itself on first use)."""
    return (plans or LatencyPlans(isa)).analyze_plan(spec)


class LatencyAnalyzer:
    """Per-pair latency inference, run to completion on one machine.

    ``machine`` may be a machine or a :class:`MeasurementEngine`; every
    dependency-chain benchmark is a declarative Experiment requested by the
    underlying :class:`LatencyPlans`, so chains shared between pairs (or
    re-run across analyses) execute once. Boot measurements happen eagerly
    at construction, as before; boot constants (``lat_movsx``,
    ``vec_chains``, ``cross``, …) remain readable on the analyzer."""

    def __init__(self, machine, isa: ISA):
        self.engine = as_engine(machine)
        self.machine = self.engine.machine
        self.isa = isa
        self.plans = LatencyPlans(isa)
        run_plan(self.engine, self.plans.boot_plan())

    def __getattr__(self, name):
        # boot constants (lat_movsx, lat_setc, vec_chains, cross, ...)
        if name == "plans":    # guard: no recursion before __init__ sets it
            raise AttributeError(name)
        return getattr(self.plans, name)

    def analyze(self, instr: InstrSpec | str) -> LatencyResult:
        return run_plan(self.engine, self.plans.analyze_plan(instr))

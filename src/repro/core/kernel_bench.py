"""Kernel-level unit-occupancy attribution via blocking kernels.

The counter-free variant of Algorithm 1, one level up: on machines without
per-unit counters, co-schedule the target kernel K with each blocking kernel
B_u (kernels/microbench.py saturates one unit each) and classify from the
contention signature

    overlap(K, B_u) = (t(K) + t(B_u) - t(K ; B_u)) / min(t(K), t(B_u))

≈ 1: K and B_u use *different* units (their execution overlaps fully);
≈ 0: same unit (serialized — the unit is the contended resource).

On this CPU container everything serializes (overlap ≈ 0 across the board);
the harness is validated for protocol invariants (t(K;B) between max and
sum + slack) and produces real attributions when run on a TPU.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax


@dataclass
class KernelProfile:
    name: str
    alone_ns: float
    overlap: dict = field(default_factory=dict)  # unit -> coefficient

    def likely_units(self, threshold: float = 0.5) -> list[str]:
        return [u for u, c in self.overlap.items() if c < threshold]


def _time(f, reps: int = 5) -> float:
    jax.block_until_ready(f())
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter_ns()
        jax.block_until_ready(f())
        best = min(best, time.perf_counter_ns() - t0)
    return best


def profile_kernel(name: str, target_fn, blockers: dict) -> KernelProfile:
    """target_fn and each blocker: nullary callables returning arrays."""
    t_k = _time(jax.jit(target_fn))
    prof = KernelProfile(name, t_k)
    for unit, blk in blockers.items():
        t_b = _time(jax.jit(blk))

        def both(blk=blk):
            return target_fn(), blk()

        t_kb = _time(jax.jit(both))
        denom = min(t_k, t_b)
        prof.overlap[unit] = ((t_k + t_b - t_kb) / denom) if denom else 0.0
    return prof

"""Cycle-level simulated core with per-port μop performance counters.

This plays the role the physical processors play in the paper: a black box
that executes instruction sequences and exposes exactly two observables —
elapsed core cycles and the number of μops executed on each port (§3.3).
The inference algorithms (blocking/port_usage/latency/throughput) only ever
call :meth:`SimMachine.run`; the ground-truth tables in ``uarch.py`` stay
hidden from them, and property tests check the algorithms recover them.

Machine model (§3.1): μops issue in program order at ``issue_width``/cycle
into a scheduler; each dispatches to one allowed port no earlier than (a) its
operands are ready, (b) its issue cycle, (c) the port has a free slot (ports
accept one μop per cycle; divider μops occupy their port for ``occupancy``
cycles — not fully pipelined). Port choice is earliest-available, tie-broken
by least cumulative load (this reproduces the uniform port distribution that
isolation measurements show, including the MOVQ2DQ fallacy of §7.3.3).
Register renaming is implicit (dependencies are tracked through architectural
names per the benchmark's operand assignment). The reorder buffer's special
handling is modeled: move elimination (periodically failing, as the paper
observed: ~1/3 of chained MOVs execute), zero idioms, NOPs.

The run includes a fixed measurement-harness overhead (serializing
instructions + counter reads, Algorithm 2), which the measurement protocol
in ``machine.py`` must cancel via the n=10/110 differencing — faithfully
reproducing why the paper needs that protocol at all.

``SimMachine`` is the *scalar reference oracle*: ``run`` interprets one μop
per Python iteration and is the semantics every backend must match.  The
hot path, however, is ``run_batch`` — the measurement engine submits whole
waves of experiments, and ``run_batch`` forwards them to the compiled
:class:`~repro.core.batch_sim.BatchSimMachine`, which executes the wave as
one vectorized array program, bit-identical to this oracle (differential
tests in ``tests/test_batch_sim.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.isa import FLAGS, GPR, IMM, ISA, MEM, InstrSpec
from repro.core.uarch import InstrBehavior, UArch


@dataclass(frozen=True)
class Instr:
    """An instruction instance: spec name + concrete operand assignment.

    ``regs`` maps operand name -> architectural register name ("R0".."R31",
    "X0".."X31", "FLAGS", memory base register for mem operands).
    ``value_hint`` ("low"/"high") selects divider operand classes (§5.2.5) —
    the stand-in for actually loading those values into registers."""
    spec: str
    regs: dict
    value_hint: str = "low"

    def __repr__(self):  # compact debug form
        rs = ",".join(f"{k}={v}" for k, v in self.regs.items())
        return f"{self.spec}({rs})"


@dataclass
class Counters:
    cycles: float
    port_uops: dict = field(default_factory=dict)

    @property
    def total_uops(self) -> float:
        return sum(self.port_uops.values())


def _implicit_reg(opname: str, otype: str) -> str:
    if otype == FLAGS:
        return "FLAGS"
    return {"hi": "RDX", "op1": "RAX"}.get(opname, "R_IMPL_" + opname)


class SimMachine:
    """The measurable black box.

    ``backend`` selects the batched wave-execution kernel (``numpy``,
    ``jax``, or ``pallas``; default: the ``REPRO_SIM_BACKEND`` environment
    variable, else ``numpy``) — results are bit-identical on every
    backend.  ``min_lanes`` is the thin-chunk scalar-oracle crossover
    forwarded to :class:`~repro.core.batch_sim.BatchSimMachine` (default:
    the measured crossover, see ``bench_batch_sim``).  ``devices``
    selects the device placement for the jax/pallas backends (an integer
    count, ``"all"``, or an explicit jax device sequence; default: the
    ``REPRO_SIM_DEVICES`` environment variable, else all available) —
    more than one device shards wave lanes across a 1-D mesh, still
    bit-identical (see :mod:`repro.core.device_mesh`)."""

    counters_available = True

    def __init__(self, uarch: UArch, isa: ISA, backend: str | None = None,
                 min_lanes: int | None = None, devices=None):
        self.uarch = uarch
        self.isa = isa
        self.name = uarch.name
        self.ports = uarch.ports
        self.backend = backend
        self.min_lanes = min_lanes
        self.devices = devices
        self._batch = None        # lazy BatchSimMachine (False: unavailable)
        self._table_index = None  # shared UopTableIndex (set by Campaign)

    # ------------------------------------------------------------------
    def set_table_index(self, index) -> None:
        """Adopt a campaign-wide :class:`~repro.core.uarch_compile
        .UopTableIndex` so compiled tables share instruction numbering
        across the campaign's machines."""
        self._table_index = index
        self._batch = None

    def set_devices(self, devices) -> None:
        """Adopt a device placement for the batched backend (count,
        ``"all"``, or an explicit jax device sequence).  ``Campaign.run``
        uses this to place machines on disjoint device subsets; results
        are bit-identical for every placement."""
        self.devices = devices
        if self._batch:
            self._batch.set_devices(devices)

    @property
    def lowering_stats(self) -> dict:
        """The batched backend's lowering-cache counters (empty until the
        first wave builds the backend); surfaced through ``engine_stats``."""
        return self._batch.lowering_stats if self._batch else {}

    def device_stats(self) -> dict:
        """The batched backend's device-kernel telemetry (compile counts
        per shape bucket — the CI recompile probe reads this)."""
        return self._batch.device_stats() if self._batch else {}

    def degraded_stats(self) -> dict:
        """Per-transition backend degradation counters (e.g. ``"numpy->
        scalar"``); surfaced through ``engine_stats`` so campaigns over
        lazy machines report degradations the same way direct
        :class:`~repro.core.batch_sim.BatchSimMachine` campaigns do."""
        return self._batch.degraded_stats() if self._batch else {}

    def run_batch(self, codes, kernel_lock=None) -> list:
        """Execute a wave of sequences through the compiled batched
        backend (bit-identical to per-sequence :meth:`run`); falls back
        to the scalar loop when the array backend is unavailable.

        Degenerate waves (fewer than ``min(4, min_lanes)`` sequences) run
        the scalar loop directly without building the batched backend:
        the array program's fixed per-step cost exceeds the interpreter
        loop it replaces (bit-identical either way); the batched backend
        additionally routes thin padded chunks to the scalar oracle (see
        ``BatchSimMachine.min_lanes`` — ``min_lanes=1`` forces every wave
        through the kernel).  ``kernel_lock`` serializes GIL-bound kernel
        execution — host lowering/packing stays concurrent across
        schedulers sharing the lock, and GIL-releasing device kernels
        hold it only around dispatch (see
        ``BatchSimMachine.run_batch``)."""
        codes = list(codes)
        degenerate = 4 if self.min_lanes is None else \
            min(4, max(self.min_lanes, 1))
        if len(codes) < degenerate:
            if kernel_lock is not None:
                with kernel_lock:
                    return [self.run(list(c)) for c in codes]
            return [self.run(list(c)) for c in codes]
        if self._batch is None:
            try:
                from repro.core.batch_sim import (  # noqa: PLC0415
                    DEFAULT_MIN_LANES, BatchSimMachine)
                import os  # noqa: PLC0415
                backend = self.backend or os.environ.get(
                    "REPRO_SIM_BACKEND", "numpy")
                min_lanes = (DEFAULT_MIN_LANES if self.min_lanes is None
                             else self.min_lanes)
                try:
                    self._batch = BatchSimMachine(
                        self.uarch, self.isa, backend=backend,
                        table_index=self._table_index, min_lanes=min_lanes,
                        devices=self.devices)
                except RuntimeError:   # jax backend requested, jax missing
                    import warnings  # noqa: PLC0415
                    warnings.warn(f"sim backend {backend!r} unavailable "
                                  "(jax not importable); falling back to "
                                  "numpy", stacklevel=2)
                    self._batch = BatchSimMachine(
                        self.uarch, self.isa, backend="numpy",
                        table_index=self._table_index, min_lanes=min_lanes)
            except ImportError:   # no numpy: scalar fallback
                self._batch = False
        if self._batch:
            return self._batch.run_batch(codes, kernel_lock=kernel_lock)
        if kernel_lock is not None:
            with kernel_lock:
                return [self.run(list(c)) for c in codes]
        return [self.run(list(c)) for c in codes]

    # ------------------------------------------------------------------
    def run(self, code: list[Instr]) -> Counters:
        """Execute ``code`` once, returning cycles + per-port μop counts
        (including the constant measurement-harness overhead)."""
        ua = self.uarch
        reg_ready: dict[str, float] = {}
        reg_width: dict[str, int] = {}  # width of the last write (partial-reg)
        mem_ready: dict[str, float] = {}
        mem_stored: dict[str, bool] = {}
        port_free: dict[str, float] = {p: 0.0 for p in ua.ports}
        port_count: dict[str, int] = {p: 0 for p in ua.ports}
        elim_counter: dict[str, int] = {}
        width = ua.issue_width
        uop_index = 0
        t_end = 0.0

        for ins in code:
            spec = self.isa[ins.spec]
            behavior: InstrBehavior = ua.behaviors[ins.spec]
            regs = dict(ins.regs)
            for o in spec.operands:
                if o.name not in regs and o.otype != IMM:
                    regs[o.name] = _implicit_reg(o.name, o.otype)

            same_reg = self._same_reg(spec, regs)
            if behavior.same_reg is not None and same_reg:
                behavior = behavior.same_reg

            # zero idiom: same register on both explicit operands
            if spec.zero_idiom and same_reg:
                ready = 0.0  # dependency broken: inputs ignored
                if behavior.zero_uop_same_reg:
                    for o in spec.dests:
                        reg_ready[regs[o.name]] = ready
                    continue
                self._exec_uops(behavior.uops, regs, spec, ins, reg_ready,
                                mem_ready, mem_stored, port_free, port_count,
                                uop_index, width, reg_width,
                                ignore_reads=True)
                uop_index += len(behavior.uops)
                continue

            # move elimination (reorder-buffer, no ports, zero latency)
            if spec.may_eliminate and behavior.elim_period:
                c = elim_counter.get(ins.spec, 0)
                elim_counter[ins.spec] = c + 1
                if c % behavior.elim_period != 0:
                    src = next(o for o in spec.sources if o.otype != IMM)
                    dst = spec.dests[0]
                    reg_ready[regs[dst.name]] = reg_ready.get(
                        regs[src.name], 0.0)
                    continue

            done = self._exec_uops(behavior.uops, regs, spec, ins, reg_ready,
                                   mem_ready, mem_stored, port_free,
                                   port_count, uop_index, width, reg_width,
                                   divider_extra=(behavior.divider_extra
                                                  if ins.value_hint == "high"
                                                  else 0))
            uop_index += len(behavior.uops)
            t_end = max(t_end, done)

        t_end = max([t_end] + list(reg_ready.values()) + list(mem_ready.values()))
        return Counters(t_end + ua.overhead_cycles, port_count)

    # ------------------------------------------------------------------
    def _exec_uops(self, uops, regs, spec: InstrSpec, ins: Instr, reg_ready,
                   mem_ready, mem_stored, port_free, port_count, uop_index,
                   width, reg_width=None, ignore_reads=False,
                   divider_extra=0):
        ua = self.uarch
        reg_width = reg_width if reg_width is not None else {}
        tmp_ready: dict[str, float] = {}
        done_max = 0.0
        mem_ops = {o.name: o for o in spec.operands if o.otype == MEM}
        # all μops read the *source* operand values: snapshot ready times
        # before any μop of this instruction writes its destinations.
        # Partial-register stall (§5.2.1): reading wider than the register's
        # last sub-64-bit write inserts a merge penalty — the reason the
        # paper's chains use width-matched MOVSX variants.
        src_snapshot = {}
        for o in spec.operands:
            if o.otype == MEM:
                continue
            r = regs.get(o.name, o.name)
            t = reg_ready.get(r, 0.0)
            if (o.read and o.otype == GPR
                    and o.width > reg_width.get(r, 64)):
                t += ua.partial_stall_penalty
            src_snapshot[o.name] = t
        for u in uops:
            ready = float(uop_index // width)  # front-end issue cycle
            if not ignore_reads:
                for r in u.reads:
                    if r.startswith("%"):
                        ready = max(ready, tmp_ready.get(r, 0.0))
                    elif r in mem_ops and mem_ops[r].read:
                        base = regs[r]
                        ready = max(ready, reg_ready.get(base, 0.0),
                                    mem_ready.get(base, 0.0))
                    elif r in src_snapshot:
                        ready = max(ready, src_snapshot[r])
                    else:
                        ready = max(ready, reg_ready.get(regs.get(r, r), 0.0))
            lat = u.latency + divider_extra
            occ = u.occupancy + divider_extra
            # load latency reduction via store-to-load forwarding
            if any(r in mem_ops and mem_ops[r].read for r in u.reads):
                base = next(regs[r] for r in u.reads if r in mem_ops)
                if mem_stored.get(base):
                    lat = min(lat, ua.store_forward_latency)
            # dispatch: earliest available allowed port
            best_port, best_t = None, None
            for p in sorted(u.ports):
                t = max(ready, port_free[p])
                if best_t is None or t < best_t or (
                        t == best_t and port_count[p] < port_count[best_port]):
                    best_port, best_t = p, t
            if best_port is None:  # 0-port uop (shouldn't happen)
                continue
            # a μop occupies its port for its *effective* occupancy —
            # including the value-dependent divider extra, so a high-value
            # divide blocks the divider even on a 1-occupancy μop
            port_free[best_port] = best_t + (occ if occ > 1 else 1)
            port_count[best_port] += 1
            done = best_t + lat
            done_max = max(done_max, done)
            for w in u.writes:
                if w.startswith("%"):
                    tmp_ready[w] = done
                elif w in mem_ops:
                    base = regs[w]
                    mem_ready[base] = done
                    mem_stored[base] = True
                else:
                    rw = regs.get(w, w)
                    reg_ready[rw] = done
                    wop = next((o for o in spec.operands if o.name == w),
                               None)
                    if wop is not None:
                        reg_width[rw] = wop.width
            uop_index += 1
        return done_max

    @staticmethod
    def _same_reg(spec: InstrSpec, regs) -> bool:
        ex = [o for o in spec.explicit_operands
              if o.otype not in (IMM, MEM, FLAGS)]
        if len(ex) < 2:
            return False
        names = {regs[o.name] for o in ex}
        return len(names) == 1

"""Lane-axis device mesh: the substrate for multi-device wave execution.

Characterization campaigns are embarrassingly parallel — thousands of
independent microbenchmark lanes per wave — so the natural multi-device
decomposition is a **1-D mesh over a single ``lanes`` axis**: every device
runs the same bucketed dispatch kernel on its own block of experiment
lanes (``shard_map`` with a lane-axis ``PartitionSpec``; the kernel has no
cross-lane communication, so the partitions are fully independent SPMD).

This module owns the pieces of that substrate that are *not* kernel code:

* **Device resolution** — :func:`resolve_devices` turns a user-facing
  spec (``devices=`` constructor argument or the ``REPRO_SIM_DEVICES``
  environment variable: an integer count, ``"all"``, or an explicit
  device sequence) into an ordered tuple of jax devices, clamped to what
  the host actually has.  Real accelerators appear here on real hardware;
  CPU CI forces host devices via
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (which must be
  set *before* jax is first imported — hence the subprocess pattern in
  ``tests/test_multidevice.py`` and ``bench_device_scaling``).  With jax
  missing or a single device the resolution degrades gracefully and wave
  execution stays on the PR-5 single-device path, bit-identical.

* **Mesh construction** — :class:`LaneMesh` wraps a
  ``jax.sharding.Mesh`` over an ordered device subset with the
  ``PartitionSpec``/``NamedSharding`` objects the bucketed kernels need:
  lane-sharded ``(E, S)``/``(E, S, R)`` operands and the replicated μop
  port-mask LUT.  Meshes are memoized per device-id tuple so repeated
  kernel-bucket compilations share one mesh object.

* **Per-device dispatch locks** — :func:`dispatch_lock` hands out one
  ``threading.Lock`` per *device subset* (keyed by sorted device ids,
  module-wide).  Machines placed on the same subset share a lock, so
  their GIL-bound kernel dispatch serializes exactly as the campaign-wide
  execute lock used to; machines on **disjoint subsets get different
  locks and their kernels never serialize** — the point of campaign
  device placement.  (Kernels already queued on one device serialize in
  XLA's per-device stream regardless; the lock only covers host-side
  dispatch.)

* **Campaign placement** — :func:`partition` splits the resolved devices
  into per-machine groups: contiguous disjoint blocks when there are at
  least as many devices as machines (a multi-uarch campaign becomes
  wall-clock-bound by one uarch), round-robin shared singletons
  otherwise.
"""
from __future__ import annotations

import os
import threading

from repro.obs import tracer as obs

# user-facing device-count knob (int or "all"); unset means "all available"
ENV_DEVICES = "REPRO_SIM_DEVICES"


def jax_devices() -> tuple:
    """All jax devices, in jax's canonical order; ``()`` when jax is not
    importable (the numpy backend / scalar oracle need no devices)."""
    try:
        import jax  # noqa: PLC0415
    except ImportError:
        return ()
    return tuple(jax.devices())


def resolve_devices(spec=None) -> tuple:
    """Resolve a device spec to an ordered tuple of jax devices.

    ``spec`` may be ``None`` (read ``REPRO_SIM_DEVICES``, default
    ``"all"``), an integer count (clamped to ``[1, available]`` — asking
    for more devices than the host has degrades gracefully to all of
    them), the string ``"all"``, a decimal string, or an explicit
    sequence of jax devices (returned as-is).  Returns ``()`` when jax is
    unavailable."""
    if spec is None:
        spec = os.environ.get(ENV_DEVICES, "").strip() or "all"
    if isinstance(spec, str):
        s = spec.strip().lower()
        n = None if s == "all" else int(s)
    elif isinstance(spec, int):
        n = spec
    else:
        return tuple(spec)
    devs = jax_devices()
    if not devs or n is None:
        return devs
    return devs[:min(max(n, 1), len(devs))]


class LaneMesh:
    """A 1-D ``lanes`` mesh over an ordered device subset, plus the
    shardings the bucketed wave kernels use: ``spec2``/``spec3`` shard the
    leading (lane-major experiment) axis of ``(E, S)`` / ``(E, S, R)``
    operands across ``lanes``; ``replicated`` carries the μop port-mask
    LUT to every device once."""

    __slots__ = ("devices", "n", "mesh", "spec2", "spec3", "repl_spec",
                 "shard2", "shard3", "replicated")

    def __init__(self, devices):
        import numpy as np  # noqa: PLC0415
        from jax.sharding import (  # noqa: PLC0415
            Mesh, NamedSharding, PartitionSpec)
        self.devices = tuple(devices)
        self.n = len(self.devices)
        self.mesh = Mesh(np.array(self.devices), ("lanes",))
        self.spec2 = PartitionSpec("lanes", None)
        self.spec3 = PartitionSpec("lanes", None, None)
        self.repl_spec = PartitionSpec(None, None)
        self.shard2 = NamedSharding(self.mesh, self.spec2)
        self.shard3 = NamedSharding(self.mesh, self.spec3)
        self.replicated = NamedSharding(self.mesh, self.repl_spec)

    @property
    def key(self) -> tuple:
        """Cache identity: the ordered device-id tuple (kernel executables
        are bound to concrete devices, so it keys the AOT cache too)."""
        return tuple(d.id for d in self.devices)

    def __repr__(self):
        return f"<LaneMesh lanes={self.n} devices={list(self.key)}>"


_MESHES: dict = {}
_LOCKS: dict = {}
_REGISTRY_LOCK = threading.Lock()


def lane_mesh(devices) -> LaneMesh:
    """Memoized :class:`LaneMesh` for an ordered device tuple (meshes are
    shared across machines and kernel buckets)."""
    key = tuple(d.id for d in devices)
    with _REGISTRY_LOCK:
        m = _MESHES.get(key)
        if m is None:
            # mesh construction is the one-time cost worth seeing in a
            # trace (NamedSharding setup ahead of kernel compilation)
            with obs.span("mesh.build", devices=list(key)):
                m = _MESHES[key] = LaneMesh(devices)
        return m


def dispatch_lock(devices) -> threading.Lock:
    """The per-device-subset dispatch lock (module-wide, keyed by sorted
    device ids; the empty subset shares one host lock).  Machines placed
    on the same subset serialize their host-side kernel dispatch on it;
    disjoint subsets get independent locks, so their kernels never
    serialize behind one campaign-wide lock."""
    key = tuple(sorted(d.id for d in devices)) if devices else ("host",)
    with _REGISTRY_LOCK:
        lk = _LOCKS.get(key)
        if lk is None:
            lk = _LOCKS[key] = threading.Lock()
        return lk


def partition(devices, n_groups: int) -> list:
    """Split ``devices`` into ``n_groups`` placement groups for a
    campaign's machines: contiguous **disjoint** blocks (balanced to
    within one device) when ``len(devices) >= n_groups``, round-robin
    shared singletons when there are fewer devices than machines, and
    empty groups (single-device fallback: no placement) without jax."""
    devices = tuple(devices)
    if n_groups <= 0:
        return []
    d = len(devices)
    if d == 0:
        groups = [() for _ in range(n_groups)]
    elif d >= n_groups:
        groups = [devices[i * d // n_groups:(i + 1) * d // n_groups]
                  for i in range(n_groups)]
    else:
        groups = [(devices[i % d],) for i in range(n_groups)]
    if obs.enabled():
        # placement ids are trace-only; tests pass plain ints as devices
        obs.instant("mesh.partition", devices=d, groups=n_groups,
                    placement=[[getattr(dev, "id", dev) for dev in g]
                               for g in groups])
    return groups

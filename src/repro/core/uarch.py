"""Microarchitecture descriptions.

Two kinds live here:

1. **Simulated-core ground truths** (`SIM_*`): per-instruction μop tables —
   port sets, μop-level dataflow (which source operands each μop waits on,
   which destination it produces) and latencies. These are the *hidden*
   parameters the paper's algorithms must recover; tests compare inference
   output against them. Several real uops.info findings are planted:
   AESDEC's Sandy-Bridge 8/1-cycle per-operand-pair split (§7.3.1), SHLD's
   Skylake same-register fast path (§7.3.2), MOVQ2DQ's isolation-measurement
   fallacy (§7.3.3), ADC = 1*p0156+1*p06 on Haswell (§5.1), PCMPGTQ as an
   undocumented zero idiom (§7.3.6).

2. **TPU v5e hardware constants** for the roofline analysis, plus the
   TPU-unit port model used by the Pallas kernel characterization
   (`kernels/microbench.py` blocking kernels).
"""
from __future__ import annotations

import random as _random
from dataclasses import dataclass, field, replace

from repro.core.isa import ISA, TEST_ISA

# ---------------------------------------------------------------------------
# TPU v5e roofline constants (per chip)
# ---------------------------------------------------------------------------
TPU_V5E = {
    "name": "tpu_v5e",
    "peak_bf16_flops": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link (~45 GB/s usable)
    "hbm_bytes": 16e9,
    "vmem_bytes": 128 * 2**20,
}

# abstract TPU-core port model for kernel-level characterization
TPU_PORTS = ("MXU", "VPU", "XLU", "LSU", "SFU")


@dataclass(frozen=True)
class Uop:
    """One μop of the ground truth: allowed ports + local dataflow.

    ``reads``/``writes`` name instruction operands ("op1", "flags", "mem")
    or intra-instruction intermediates ("%0", "%1"...). ``occupancy`` > 1
    models non-pipelined units (dividers)."""
    ports: frozenset
    reads: tuple = ()
    writes: tuple = ()
    latency: int = 1
    occupancy: int = 1


def uop(ports, reads=(), writes=(), lat=1, occ=1) -> Uop:
    return Uop(frozenset(ports), tuple(reads), tuple(writes), lat, occ)


@dataclass(frozen=True)
class InstrBehavior:
    uops: tuple[Uop, ...]
    same_reg: "InstrBehavior | None" = None  # alt behavior when op1==op2
    elim_period: int = 0   # move elim: eliminate all but every k-th instance
    dep_breaking_same_reg: bool = False
    zero_uop_same_reg: bool = False
    divider_extra: int = 0  # extra latency+occupancy for "high" operand values


def beh(*uops_, **kw) -> InstrBehavior:
    return InstrBehavior(tuple(uops_), **kw)


@dataclass(frozen=True)
class UArch:
    name: str
    ports: tuple[str, ...]
    issue_width: int
    behaviors: dict[str, InstrBehavior] = field(repr=False)
    load_latency: int = 5
    store_forward_latency: int = 4
    overhead_cycles: int = 85  # measurement-harness overhead (Algorithm 2)
    # partial-register stall (§5.2.1): cycles added when reading a register
    # wider than its last (sub-64-bit) write — why chains use MOVSX
    partial_stall_penalty: int = 3

    def replace(self, **kw) -> "UArch":
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Skylake-like simulated core (8 ports)
# ---------------------------------------------------------------------------

P0156 = frozenset("0156")
P06 = frozenset("06")
P01 = frozenset("01")
P015 = frozenset("015")
P23 = frozenset("23")
P237 = frozenset("237")
P4 = frozenset("4")
P5 = frozenset("5")
P1 = frozenset("1")
P0 = frozenset("0")
P15 = frozenset("15")


def _alu(lat=1, ports=P0156):
    return beh(uop(ports, ("op1", "op2"), ("op1", "flags"), lat))


def _skl_behaviors() -> dict[str, InstrBehavior]:
    b: dict[str, InstrBehavior] = {}
    for nm in ("ADD", "SUB", "AND", "OR"):
        b[f"{nm}_R64_R64"] = _alu()
    b["XOR_R64_R64"] = beh(
        uop(P0156, ("op1", "op2"), ("op1", "flags")),
        dep_breaking_same_reg=True, zero_uop_same_reg=True)
    b["SUBZ_R64_R64"] = beh(
        uop(P0156, ("op1", "op2"), ("op1", "flags")),
        dep_breaking_same_reg=True, zero_uop_same_reg=True)
    b["ADC_R64_R64"] = beh(  # SKL: single uop p06, 1 cycle
        uop(P06, ("op1", "op2", "flags"), ("op1", "flags")))
    b["SBB_R64_R64"] = beh(
        uop(P06, ("op1", "op2", "flags"), ("op1", "flags")))
    b["CMP_R64_R64"] = beh(uop(P0156, ("op1", "op2"), ("flags",)))
    b["TEST_R64_R64"] = beh(uop(P0156, ("op1", "op2"), ("flags",)))
    b["INC_R64"] = beh(uop(P0156, ("op1",), ("op1", "flags")))
    b["NOT_R64"] = beh(uop(P0156, ("op1",), ("op1",)))
    b["LEA_R64"] = beh(uop(frozenset("15"), ("op2",), ("op1",)))
    b["POPCNT_R64_R64"] = beh(uop(P1, ("op2",), ("op1", "flags"), 3))
    b["BSWAP_R32"] = beh(uop(P15, ("op1",), ("op1",)))
    b["BSWAP_R64"] = beh(uop(P06, ("op1",), ("%0",)),
                         uop(P15, ("%0",), ("op1",)))
    b["MOV_R64_R64"] = beh(uop(P0156, ("op2",), ("op1",)), elim_period=3)
    b["MOVSX_R64_R32"] = beh(uop(P0156, ("op2",), ("op1",)))
    b["MOVSX_R64_R8"] = beh(uop(P0156, ("op2",), ("op1",)))
    b["MOVZX_R64_R16"] = beh(uop(P0156, ("op2",), ("op1",)), elim_period=3)
    for nm in ("SHL", "SHR", "SAR", "ROL", "ROR"):
        b[f"{nm}_R64_I8"] = beh(
            uop(P06, ("op1", "flags"), ("op1", "flags")))
    # SHLD SKL (§7.3.2): 3 cycles normally, 1 cycle when op1==op2
    b["SHLD_R64_R64_I8"] = beh(
        uop(P1, ("op1", "op2"), ("op1", "flags"), 3),
        same_reg=beh(uop(P1, ("op1", "op2"), ("op1", "flags"), 1)))
    b["IMUL_R64_R64"] = beh(uop(P1, ("op1", "op2"), ("op1", "flags"), 3))
    b["MUL_R64"] = beh(uop(P1, ("op1", "op2"), ("op1", "flags"), 3),
                       uop(P5, ("op1", "op2"), ("hi",), 4))
    b["DIV_R64"] = beh(
        uop(P0, ("op1", "op2", "hi"), ("op1", "hi", "flags"), 23, occ=6),
        divider_extra=13)
    b["SETC_R8"] = beh(uop(P06, ("flags",), ("op1",)))
    b["CMOVBE_R64_R64"] = beh(uop(P06, ("op1", "op2", "flags"), ("op1",)))
    b["CMC"] = beh(uop(P0156, ("flags",), ("flags",)))
    b["SAHF"] = beh(uop(P06, ("op1",), ("flags",)))
    b["MOV_R64_M64"] = beh(uop(P23, ("mem",), ("op1",), 5))
    b["MOV_M64_R64"] = beh(uop(P237, ("mem",), ("%a",)),      # store addr
                           uop(P4, ("op1", "%a"), ("mem",)))  # store data
    b["ADD_R64_M64"] = beh(uop(P23, ("mem",), ("%0",), 5),
                           uop(P0156, ("op1", "%0"), ("op1", "flags")))
    b["IMUL_R64_M64"] = beh(uop(P23, ("mem",), ("%0",), 5),
                            uop(P1, ("op1", "%0"), ("op1", "flags"), 3))
    for pre in ("P", "VP"):
        b[f"{pre}ADDD_X_X"] = beh(uop(P015, ("op1", "op2"), ("op1",)))
        b[f"{pre}MULD_X_X"] = beh(uop(P01, ("op1", "op2"), ("op1",), 5))
        b[f"{pre}SHUFB_X_X"] = beh(uop(P5, ("op1", "op2"), ("op1",)))
        b[f"{pre}AND_X_X"] = beh(uop(P015, ("op1", "op2"), ("op1",)))
        # §7.3.6: undocumented zero idiom (still uses an execution port)
        b[f"{pre}CMPGTQ_X_X"] = beh(uop(P015, ("op1", "op2"), ("op1",)),
                                    dep_breaking_same_reg=True)
    b["SHUFPS_X_X"] = beh(uop(P5, ("op1", "op2"), ("op1",)))
    b["PSHUFD_X_X"] = beh(uop(P5, ("op2",), ("op1",)))
    b["MOVSHDUP_X_X"] = beh(uop(P5, ("op2",), ("op1",)))
    b["ADDPS_X_X"] = beh(uop(P01, ("op1", "op2"), ("op1",), 4))
    b["MULPS_X_X"] = beh(uop(P01, ("op1", "op2"), ("op1",), 4))
    b["DIVPS_X_X"] = beh(uop(P0, ("op1", "op2"), ("op1",), 11, occ=3),
                         divider_extra=3)
    # AESDEC on SKL-like: single 4-cycle uop (post-Haswell behavior)
    b["AESDEC_X_X"] = beh(uop(P0, ("op1", "op2"), ("op1",), 4))
    b["AESDEC_X_M"] = beh(uop(P23, ("mem",), ("%0",), 5),
                          uop(P0, ("op1", "%0"), ("op1",), 4))
    # MOVQ2DQ (§7.3.3): ground truth 1*p0 + 1*p015
    b["MOVQ2DQ_X_X"] = beh(uop(P0, ("op2",), ("%0",)),
                           uop(P015, ("%0",), ("op1",)))
    b["MOVAPS_X_X"] = beh(uop(P015, ("op2",), ("op1",)), elim_period=3)
    b["MOVD_R64_X"] = beh(uop(P0, ("op2",), ("op1",), 2))
    b["MOVD_X_R64"] = beh(uop(P5, ("op2",), ("op1",), 2))
    b["PEXTRQ_R64_X"] = beh(uop(P5, ("op2",), ("%0",), 2),
                            uop(P0, ("%0",), ("op1",)))
    b["MOVAPS_M_X"] = beh(uop(P237, ("mem",), ("%a",)),
                          uop(P4, ("op1", "%a"), ("mem",)))
    b["MOVAPS_X_M"] = beh(uop(P23, ("mem",), ("op1",), 6))
    b["NOP"] = beh()
    b["PAUSE"] = beh(uop(P0156, (), (), 4), uop(P0156, (), (), 4))
    b["LFENCE"] = beh(uop(P0156, (), (), 6))
    b["CPUID"] = beh(uop(P0156, ("op1",), ("op1",), 100))
    b["RDMSR"] = beh(uop(P0156, (), ("op1",), 100))
    b["JMP_R64"] = beh(uop(P06, ("op1",), (), 1))
    return b


SIM_SKL = UArch("sim_skl", tuple("01234567"), 4, _skl_behaviors())


def _hsw_behaviors() -> dict[str, InstrBehavior]:
    b = dict(_skl_behaviors())
    # §5.1: ADC on Haswell = 1*p0156 + 1*p06 (isolation suggests 2*p0156)
    b["ADC_R64_R64"] = beh(
        uop(P0156, ("op2",), ("%0",)),
        uop(P06, ("op1", "%0", "flags"), ("op1", "flags")))
    b["SBB_R64_R64"] = b["ADC_R64_R64"]
    # AESDEC on Haswell: one 7-cycle uop (§7.3.1)
    b["AESDEC_X_X"] = beh(uop(P5, ("op1", "op2"), ("op1",), 7))
    b["AESDEC_X_M"] = beh(uop(P23, ("mem",), ("%0",), 5),
                          uop(P5, ("op1", "%0"), ("op1",), 7))
    # MOVDQ2Q-style: 1*p5 + 1*p015 (§7.3.4) reusing MOVQ2DQ slot semantics
    b["MOVQ2DQ_X_X"] = beh(uop(P5, ("op2",), ("%0",)),
                           uop(P015, ("%0",), ("op1",)))
    # SHLD on Haswell: no same-register fast path
    b["SHLD_R64_R64_I8"] = beh(
        uop(P1, ("op1", "op2"), ("op1", "flags"), 3))
    return b


SIM_HSW = UArch("sim_hsw", tuple("01234567"), 4, _hsw_behaviors())


def _snb_behaviors() -> dict[str, InstrBehavior]:
    """Sandy-Bridge-like: 6 ports (0,1,5 exec; 2,3 load; 4 store-data)."""
    b = dict(_skl_behaviors())
    snb_remap = {frozenset("0156"): P015, frozenset("06"): frozenset("05"),
                 frozenset("237"): P23}

    def remap(behavior: InstrBehavior) -> InstrBehavior:
        def fix(u: Uop) -> Uop:
            return Uop(snb_remap.get(u.ports, u.ports), u.reads, u.writes,
                       u.latency, u.occupancy)
        return InstrBehavior(
            tuple(fix(u) for u in behavior.uops),
            same_reg=remap(behavior.same_reg) if behavior.same_reg else None,
            elim_period=0,  # SnB: no move elimination yet
            dep_breaking_same_reg=behavior.dep_breaking_same_reg,
            zero_uop_same_reg=False,  # dep-breaking but still executed
            divider_extra=behavior.divider_extra)

    b = {k: remap(v) for k, v in b.items()}
    # AESDEC on SnB (§7.3.1): 2 uops, lat(op1,op1)=8, lat(op2,op1)=1
    b["AESDEC_X_X"] = beh(uop(P1, ("op1",), ("%0",), 7),
                          uop(P015, ("%0", "op2"), ("op1",), 1))
    b["AESDEC_X_M"] = beh(uop(P23, ("mem",), ("%m",), 5),
                          uop(P1, ("op1",), ("%0",), 7),
                          uop(P015, ("%0", "%m"), ("op1",), 1))
    # SHLD on SnB/NHM-like: lat(op1,op1)=3, lat(op2,op1)=4 (§7.3.2)
    b["SHLD_R64_R64_I8"] = beh(
        uop(P5, ("op2",), ("%0",), 1),
        uop(P1, ("op1", "%0"), ("op1", "flags"), 3))
    return b


SIM_SNB = UArch("sim_snb", tuple("012345"), 4, _snb_behaviors())

SIM_UARCHES = {u.name: u for u in (SIM_SKL, SIM_HSW, SIM_SNB)}


# ---------------------------------------------------------------------------
# TPU-unit simulated core: the paper's method one level up.
#
# Ports are functional-unit classes (MXU/VPU/XLU/LSU/SFU); "instructions"
# are kernel-level tile ops (a 128x128 matmul tile, a vector FMA tile, a
# VMEM<->HBM copy, a softmax tile, a flash-attention tile...). The hidden
# ground truth encodes how many issue slots of each unit a fused tile op
# occupies — exactly what `kernels/microbench.py` blocking kernels probe on
# real hardware, and what Algorithm 1 must recover here.
# ---------------------------------------------------------------------------


def _tpu_isa_and_behaviors():
    from repro.core.isa import GPR, ISA, InstrSpec, op  # noqa: PLC0415

    def tile(name):
        return InstrSpec(name, name,
                         (op("op1", GPR, "w"), op("op2", GPR, "r")))

    MXU = frozenset(["MXU"])
    VPU = frozenset(["VPU"])
    XLU = frozenset(["XLU"])
    LSU = frozenset(["LSU"])
    SFU = frozenset(["SFU"])
    isa = ISA()
    b: dict[str, InstrBehavior] = {}
    specs = {
        # 1-slot unit saturators (the blocking-kernel candidates)
        "MATMUL_TILE": beh(uop(MXU, ("op2",), ("op1",), 2)),
        "FMA_TILE": beh(uop(VPU, ("op2",), ("op1",), 1)),
        "TRANSPOSE_TILE": beh(uop(XLU, ("op2",), ("op1",), 1)),
        "COPY_TILE": beh(uop(LSU, ("op2",), ("op1",), 4)),
        "EXP_TILE": beh(uop(SFU, ("op2",), ("op1",), 3)),
        # fused tile ops with multi-unit occupancy (the inference targets)
        "SOFTMAX_TILE": beh(uop(SFU, ("op2",), ("%0",), 3),
                            uop(VPU, ("%0",), ("op1",), 1)),
        "FLASH_ATTN_TILE": beh(uop(LSU, ("op2",), ("%0",), 4),
                               uop(MXU, ("%0",), ("%1",), 2),
                               uop(SFU, ("%1",), ("%2",), 3),
                               uop(MXU, ("%2",), ("%3",), 2),
                               uop(VPU, ("%3",), ("op1",), 1)),
        "RMSNORM_TILE": beh(uop(VPU, ("op2",), ("%0",), 1),
                            uop(SFU, ("%0",), ("%1",), 3),
                            uop(VPU, ("%1",), ("op1",), 1)),
        "SSD_CHUNK_TILE": beh(uop(LSU, ("op2",), ("%0",), 4),
                              uop(MXU, ("%0",), ("%1",), 2),
                              uop(MXU, ("%1",), ("%2",), 2),
                              uop(VPU, ("%2",), ("op1",), 1)),
        "GATHER_TILE": beh(uop(LSU, ("op2",), ("%0",), 4),
                           uop(XLU, ("%0",), ("op1",), 1)),
    }
    for name, behavior in specs.items():
        isa.add(tile(name))
        b[name] = behavior
    return isa, b


def make_tpu_sim():
    """(machine-ready uarch, isa, truth) for the TPU-unit port model."""
    isa, behaviors = _tpu_isa_and_behaviors()
    ua = UArch("sim_tpu", TPU_PORTS, 4, behaviors, overhead_cycles=40)
    truth = {name: {} for name in behaviors}
    for name, behavior in behaviors.items():
        for u in behavior.uops:
            truth[name][u.ports] = truth[name].get(u.ports, 0) + 1
    return ua, isa, truth


# ---------------------------------------------------------------------------
# randomized ground truths for property-based tests
# ---------------------------------------------------------------------------


def random_uarch_and_isa(seed: int, n_instr: int = 6,
                         ports: tuple[str, ...] = tuple("012345")):
    """Draw a random hidden ground truth plus an ISA guaranteed to contain a
    1-μop blocking instruction for every functional-unit port combination
    (the paper's §5.1.1 assumption). Returns (uarch, isa, truth) where
    ``truth[name]`` is the port-usage multiset {frozenset: count}."""
    from repro.core.isa import GPR, InstrSpec, op  # noqa: PLC0415

    rng = _random.Random(seed)
    n_pc = rng.randint(2, 4)
    pcs: list[frozenset] = []
    while len(pcs) < n_pc:
        k = rng.randint(1, min(3, len(ports)))
        pc = frozenset(rng.sample(ports, k))
        if pc not in pcs:
            # keep combinations either disjoint or strictly nested/overlapping
            pcs.append(pc)
    isa = ISA()
    behaviors: dict[str, InstrBehavior] = {}
    truth: dict[str, dict[frozenset, int]] = {}
    # blocking candidates: one 1-uop instr per combination
    for i, pc in enumerate(pcs):
        nm = f"BLK{i}"
        isa.add(InstrSpec(nm, nm, (op("op1", GPR, "w"), op("op2", GPR, "r"))))
        behaviors[nm] = beh(uop(pc, ("op2",), ("op1",)))
        truth[nm] = {pc: 1}
    # random multi-uop instructions over those combinations
    for i in range(n_instr):
        nm = f"INS{i}"
        k = rng.randint(1, 3)
        usage: dict[frozenset, int] = {}
        uops = []
        for j in range(k):
            pc = rng.choice(pcs)
            usage[pc] = usage.get(pc, 0) + 1
            reads = ("op2",) if j == 0 else (f"%{j-1}",)
            writes = ("op1",) if j == k - 1 else (f"%{j}",)
            uops.append(uop(pc, reads, writes, rng.randint(1, 4)))
        isa.add(InstrSpec(nm, nm, (op("op1", GPR, "w"), op("op2", GPR, "r"))))
        behaviors[nm] = InstrBehavior(tuple(uops))
        truth[nm] = usage
    ua = UArch(f"rand{seed}", ports, 6, behaviors, overhead_cycles=50)
    return ua, isa, truth

"""Wall-clock measurement backend for real jitted JAX ops.

This is the paper's hardware-measurement path applied to the op granularity
that exists on an XLA backend: per-port μop counters don't exist here (they
are simulator-only), so this backend produces *latency* (dependent-chain)
and *throughput* (independent-lanes) tables — exactly the situation the
paper faces on microarchitectures IACA doesn't support.

Protocol = Algorithm 2 adapted to wall clock: warm-up compile+run, then time
chains of n_small vs n_large applications and difference — cancelling the
dispatch/jit-call overhead the same way the serializing-instruction overhead
is cancelled on x86.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass
class OpMeasurement:
    name: str
    latency_ns: float      # dependent-chain ns/op
    throughput_ns: float   # independent-lanes ns/op
    flops: float = 0.0     # per application (analytic, from the corpus)

    @property
    def achieved_gflops(self) -> float:
        return (self.flops / self.throughput_ns) if self.throughput_ns else 0.0


def _time_callable(f, *args, reps: int = 5) -> float:
    f(*args)  # warm-up (compile + caches)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter_ns()
        jax.block_until_ready(f(*args))
        best = min(best, time.perf_counter_ns() - t0)
    return best


def _chain(f, n: int):
    def run(x):
        return jax.lax.fori_loop(0, n, lambda _, v: f(v), x)

    return jax.jit(run)


def _lanes(f, n: int, lanes: int):
    vf = jax.vmap(f)

    def run(x):
        return jax.lax.fori_loop(0, n, lambda _, v: vf(v), x)

    return jax.jit(run)


def measure_op(name: str, f, example, *, n_small: int = 8, n_large: int = 72,
               lanes: int = 8, flops: float = 0.0) -> OpMeasurement:
    """f must be shape-preserving (chainable): f(x) -> x-like."""
    t1 = _time_callable(_chain(f, n_small), example)
    t2 = _time_callable(_chain(f, n_large), example)
    lat = max((t2 - t1) / (n_large - n_small), 0.0)
    xs = jnp.stack([example] * lanes)
    t1 = _time_callable(_lanes(f, n_small, lanes), xs)
    t2 = _time_callable(_lanes(f, n_large, lanes), xs)
    tput = max((t2 - t1) / ((n_large - n_small) * lanes), 0.0)
    return OpMeasurement(name, lat, tput, flops)


def characterize_corpus(corpus: dict, **kw) -> dict[str, OpMeasurement]:
    """corpus: name -> (fn, example, flops)."""
    out = {}
    for name, (f, example, flops) in corpus.items():
        out[name] = measure_op(name, f, example, flops=flops, **kw)
    return out

"""Compile microarchitecture behavior tables into dense arrays.

The scalar :class:`~repro.core.simulator.SimMachine` walks the
:class:`~repro.core.uarch.UArch` dataclass tables (frozensets, tuples of
operand names) for every μop it dispatches.  The batched backend in
``batch_sim.py`` cannot afford that: it wants the whole behavior table
*lowered once* into flat integer arrays so that turning an instruction
sequence into tensors is table lookups, not dataclass traversal.

Two artifacts live here:

* :class:`UopTableIndex` — a stable instruction/operand indexing derived
  from an :class:`~repro.core.isa.ISA`.  All uarches compiled against the
  same index share instruction numbering and operand-slot codes, so a
  campaign over several uarches can reuse one index (and, downstream, one
  set of lowered experiment tensors) across machines.

* :class:`CompiledUArch` — one uarch's behavior tables as dense arrays:
  per-μop port bitmasks (over the *sorted* port axis, which is also the
  scalar simulator's tie-break order), latencies, occupancies and
  slot-coded read/write operand lists, plus per-instruction flags
  (elimination period, divider extra, same-register variants, zero-idiom
  handling) and the machine parameters (issue width, harness overhead,
  partial-register stall penalty, store-forward latency).

Slot coding (per instruction): ``0..TEMP_BASE-1`` are operand positions in
``spec.operands`` order; ``TEMP_BASE..EXTRA_BASE-1`` index the
instruction's intra-μop temporaries (``%0``, ``%a``, ...); ``EXTRA_BASE+``
index raw names that are neither (read straight as register names, the
scalar simulator's fallback); ``-1`` is padding.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.isa import FLAGS, GPR, IMM, ISA, MEM
from repro.core.uarch import InstrBehavior, UArch

TEMP_BASE = 32     # slots below this are operand positions
EXTRA_BASE = 64    # slots from here are per-instruction raw names
PAD = -1


@dataclass(frozen=True)
class SpecInfo:
    """Operand-level metadata for one instruction variant (ISA-derived)."""
    name: str
    op_names: tuple            # operand names, spec order
    op_otype: tuple
    op_read: tuple             # bool per operand
    op_written: tuple
    op_width: tuple
    zero_idiom: bool
    may_eliminate: bool
    # derived, in spec-operand order
    same_reg_ops: tuple        # explicit non-IMM/MEM/FLAGS operand names
    dest_names: tuple          # written operand names
    mem_read: dict             # mem operand name -> bool(read)
    elim_src: str | None       # first non-IMM source operand name
    snapshot: tuple            # (op name, gpr_read_check, width) non-MEM ops

    @classmethod
    def of(cls, spec) -> "SpecInfo":
        ex = tuple(o.name for o in spec.explicit_operands
                   if o.otype not in (IMM, MEM, FLAGS))
        mem = {o.name: o.read for o in spec.operands if o.otype == MEM}
        src = next((o.name for o in spec.sources if o.otype != IMM), None)
        snap = tuple((o.name, bool(o.read and o.otype == GPR), o.width)
                     for o in spec.operands if o.otype != MEM)
        return cls(spec.name,
                   tuple(o.name for o in spec.operands),
                   tuple(o.otype for o in spec.operands),
                   tuple(o.read for o in spec.operands),
                   tuple(o.written for o in spec.operands),
                   tuple(o.width for o in spec.operands),
                   spec.zero_idiom, spec.may_eliminate,
                   ex, tuple(o.name for o in spec.dests), mem, src, snap)


class UopTableIndex:
    """Stable instruction + operand-slot numbering for a μISA.

    Built once per ISA and shared by every :class:`CompiledUArch` of a
    campaign, so μop-table row spaces line up across uarches."""

    def __init__(self, specs):
        self.specs: list[SpecInfo] = [SpecInfo.of(s) for s in specs]
        self.names: tuple = tuple(s.name for s in self.specs)
        self.idx: dict = {n: i for i, n in enumerate(self.names)}

    _cache: dict = {}
    _CACHE_MAX = 64   # bounded: a hot-reloading service makes fresh ISAs

    @classmethod
    def for_isa(cls, isa: ISA) -> "UopTableIndex":
        key = id(isa)
        hit = cls._cache.get(key)
        if hit is None or hit[0] is not isa:
            hit = (isa, cls(list(isa)))
            while len(cls._cache) >= cls._CACHE_MAX:
                cls._cache.pop(next(iter(cls._cache)))
            cls._cache[key] = hit
        return hit[1]


# per-instruction flag bits
F_PRESENT = 1        # uarch has a behavior for this instruction
F_HAS_SR = 2         # same-register behavior variant exists
F_DEP_BREAK = 4      # dep_breaking_same_reg
F_ZERO_NOUOP = 8     # zero_uop_same_reg


@dataclass
class CompiledUArch:
    """One uarch's behavior tables lowered to dense arrays."""
    uarch: UArch
    index: UopTableIndex
    ports: tuple               # sorted port names == kernel axis == scalar
    port_pos: dict             # port name -> axis   tie-break order
    issue_width: int
    overhead_cycles: int
    partial_stall_penalty: int
    store_forward_latency: int
    # per-instruction (index order)
    uop_off: np.ndarray        # int32[n_instr]  row offset, primary variant
    n_uops: np.ndarray         # int32[n_instr]  (-1 when not present)
    sr_off: np.ndarray         # int32[n_instr]  same-reg variant rows
    sr_n: np.ndarray           # int32[n_instr]  (-1 when no variant)
    elim_period: np.ndarray    # int32[n_instr]  (per selected variant:
    divider_extra: np.ndarray  # int32[n_instr]   the scalar oracle reads
    zero_nouop: np.ndarray     # bool[n_instr]    these off the behavior
    sr_elim_period: np.ndarray   # int32[n_instr] *after* the same-register
    sr_divider_extra: np.ndarray  # int32[n_instr] switch, so both variants
    sr_zero_nouop: np.ndarray  # bool[n_instr]    are compiled)
    flags: np.ndarray          # uint8[n_instr]  F_* bits
    syms: list = field(default_factory=list)  # per instr: temp+extra names
    # per-μop-row
    port_mask: np.ndarray = None   # uint32[n_rows] bit i = self.ports[i]
    mask_id: np.ndarray = None     # int16[n_rows] compact mask id
    latency: np.ndarray = None     # int32[n_rows]
    occupancy: np.ndarray = None   # int32[n_rows]
    reads: np.ndarray = None       # int16[n_rows, max_reads] slot-coded
    writes: np.ndarray = None      # int16[n_rows, max_writes]
    mask_table: np.ndarray = None  # bool[n_masks, n_ports]
    _dev_lut: object = field(default=None, repr=False, compare=False)

    # ------------------------------------------------------------------
    def device_mask_table(self):
        """The μop port-mask LUT as a device-resident array (memoized).

        The batched device kernels index this table every step; keeping it
        resident means it crosses host→device once per compiled uarch, not
        once per executed wave."""
        if self._dev_lut is None:
            import jax  # noqa: PLC0415 - device path only

            self._dev_lut = jax.device_put(self.mask_table)
        return self._dev_lut

    def decode_slot(self, instr_i: int, slot: int) -> str:
        """Slot code -> name (operand / temp / raw register)."""
        if slot < TEMP_BASE:
            return self.index.specs[instr_i].op_names[slot]
        return self.syms[instr_i][slot - TEMP_BASE]

    def behavior_rows(self, instr_i: int, same_reg: bool):
        """(offset, count) of the μop rows the scalar oracle would use."""
        if not self.flags[instr_i] & F_PRESENT:
            raise KeyError(self.index.names[instr_i])
        if same_reg and self.flags[instr_i] & F_HAS_SR:
            return int(self.sr_off[instr_i]), int(self.sr_n[instr_i])
        return int(self.uop_off[instr_i]), int(self.n_uops[instr_i])


def _slot(info: SpecInfo, syms: list, name: str) -> int:
    """Slot code for a μop read/write name, growing the symbol table."""
    try:
        return info.op_names.index(name)
    except ValueError:
        pass
    try:
        return TEMP_BASE + syms.index(name)
    except ValueError:
        syms.append(name)
        return TEMP_BASE + len(syms) - 1


def compile_uarch(ua: UArch, isa: ISA,
                  index: UopTableIndex | None = None) -> CompiledUArch:
    """Lower ``ua``'s behavior tables against ``index`` (default: the
    ISA's shared index). Memoized per (uarch, index) identity."""
    if index is None:
        index = UopTableIndex.for_isa(isa)
    key = (id(ua), id(index))
    hit = _COMPILE_CACHE.get(key)
    if hit is not None and hit.uarch is ua and hit.index is index:
        return hit

    ports = tuple(sorted(ua.ports))
    port_bit = {p: i for i, p in enumerate(ports)}
    n = len(index.names)
    uop_off = np.full(n, -1, np.int32)
    n_uops = np.full(n, -1, np.int32)
    sr_off = np.full(n, -1, np.int32)
    sr_n = np.full(n, -1, np.int32)
    elim_period = np.zeros(n, np.int32)
    divider_extra = np.zeros(n, np.int32)
    zero_nouop = np.zeros(n, bool)
    sr_elim_period = np.zeros(n, np.int32)
    sr_divider_extra = np.zeros(n, np.int32)
    sr_zero_nouop = np.zeros(n, bool)
    flags = np.zeros(n, np.uint8)
    syms: list = [() for _ in range(n)]

    rows_mask: list = []
    rows_lat: list = []
    rows_occ: list = []
    rows_reads: list = []
    rows_writes: list = []

    def emit(info: SpecInfo, sym_list: list, uops) -> tuple:
        off = len(rows_mask)
        for u in uops:
            m = 0
            for p in u.ports:
                m |= 1 << port_bit[p]
            rows_mask.append(m)
            rows_lat.append(u.latency)
            rows_occ.append(u.occupancy)
            rows_reads.append([_slot(info, sym_list, r) for r in u.reads])
            rows_writes.append([_slot(info, sym_list, w) for w in u.writes])
        return off, len(uops)

    for i, name in enumerate(index.names):
        b: InstrBehavior | None = ua.behaviors.get(name)
        if b is None:
            continue
        info = index.specs[i]
        sym_list: list = []
        flags[i] |= F_PRESENT
        uop_off[i], n_uops[i] = emit(info, sym_list, b.uops)
        if b.same_reg is not None:
            flags[i] |= F_HAS_SR
            sr_off[i], sr_n[i] = emit(info, sym_list, b.same_reg.uops)
            sr_elim_period[i] = b.same_reg.elim_period
            sr_divider_extra[i] = b.same_reg.divider_extra
            sr_zero_nouop[i] = b.same_reg.zero_uop_same_reg
        if b.dep_breaking_same_reg:
            flags[i] |= F_DEP_BREAK
        if b.zero_uop_same_reg:
            flags[i] |= F_ZERO_NOUOP
            zero_nouop[i] = True
        elim_period[i] = b.elim_period
        divider_extra[i] = b.divider_extra
        syms[i] = tuple(sym_list)

    n_rows = len(rows_mask)
    max_r = max((len(r) for r in rows_reads), default=0)
    max_w = max((len(w) for w in rows_writes), default=0)
    reads = np.full((n_rows, max(max_r, 1)), PAD, np.int16)
    writes = np.full((n_rows, max(max_w, 1)), PAD, np.int16)
    for j, r in enumerate(rows_reads):
        reads[j, :len(r)] = r
    for j, w in enumerate(rows_writes):
        writes[j, :len(w)] = w

    port_mask = np.array(rows_mask, np.uint32) if n_rows else \
        np.zeros(0, np.uint32)
    distinct = {}
    mask_id = np.zeros(n_rows, np.int16)
    for j, m in enumerate(rows_mask):
        mask_id[j] = distinct.setdefault(int(m), len(distinct))
    table = np.zeros((max(len(distinct), 1), len(ports)), bool)
    for m, mid in distinct.items():
        for b_ in range(len(ports)):
            table[mid, b_] = bool(m >> b_ & 1)

    out = CompiledUArch(
        ua, index, ports, port_bit, ua.issue_width, ua.overhead_cycles,
        ua.partial_stall_penalty, ua.store_forward_latency,
        uop_off, n_uops, sr_off, sr_n, elim_period, divider_extra,
        zero_nouop, sr_elim_period, sr_divider_extra, sr_zero_nouop, flags,
        syms, port_mask, mask_id,
        np.array(rows_lat, np.int32) if n_rows else np.zeros(0, np.int32),
        np.array(rows_occ, np.int32) if n_rows else np.zeros(0, np.int32),
        reads, writes, table)
    while len(_COMPILE_CACHE) >= _COMPILE_CACHE_MAX:
        _COMPILE_CACHE.pop(next(iter(_COMPILE_CACHE)))
    _COMPILE_CACHE[key] = out
    return out


# bounded (oldest-out): long-lived processes re-characterizing against
# fresh UArch/ISA objects must not pin every compiled table set forever
_COMPILE_CACHE: dict = {}
_COMPILE_CACHE_MAX = 64

"""Port-usage inference — Algorithm 1 of the paper (§5.1.2).

Measuring an instruction in isolation is ambiguous (2*p05 measures the same
as 1*p0+1*p5). The algorithm disambiguates by co-scheduling the instruction
with ``blockRep`` copies of a blocking instruction for each port combination
pc (processed smallest-first): μops of the instruction observed *on the
blocked ports* can run nowhere else; μops attributed to strict subsets pc'
in earlier iterations are subtracted (line 10 of Algorithm 1).

Includes both optimizations from the paper: iterate only over combinations
intersecting the isolation-measurement ports, and exit early once the
attributed μop count reaches the instruction's total μop count.

The algorithm is a :mod:`repro.core.plan` measurement plan
(:func:`port_usage_plan`): the isolation run is one yield, then one yield
per combination-size tier — all |pc|=1 experiments in one batch, then
|pc|=2, ... Attribution (and the early exit) only ever depends on smaller
combinations, so batching within a tier is exact, and the early exit still
skips whole tiers of useless measurements. Under a
:class:`~repro.core.plan.WaveScheduler`, many instructions' tiers fuse into
shared waves; :func:`infer_port_usage` remains the run-to-completion
wrapper over a single instruction's plan.

``n_ports`` (the machine's port count, a lower bound on blockRep) is the
one machine parameter a plan needs; wrappers fill it from the machine, and
:func:`~repro.core.characterize.characterize_plan` threads it through.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from itertools import groupby

from repro.core.blocking import BlockingSet
from repro.core.engine import Experiment, as_engine
from repro.core.isa import ISA, InstrSpec
from repro.core.machine import (RegPool, fresh_instance,
                                independent_experiment, ports_from_counters,
                                uops_from_counters)
from repro.core.plan import MeasurementPlan, run_plan

BLOCK_REP_CAP = 64


@dataclass
class PortUsage:
    """pu: port combination -> μop count, plus bookkeeping."""
    usage: dict = field(default_factory=dict)  # frozenset -> int
    total_uops: float = 0.0
    isolation: dict = field(default_factory=dict)

    def notation(self) -> str:
        """The paper's 3*p015+1*p23 notation."""
        parts = [f"{n}*p{''.join(sorted(pc))}"
                 for pc, n in sorted(self.usage.items(),
                                     key=lambda kv: sorted(kv[0]))]
        return "+".join(parts) if parts else "0"


def _port_usage_gen(spec: InstrSpec, isa: ISA, blocking: BlockingSet,
                    max_latency: int, n_ports: int, block_rep_cap: int):
    pool = RegPool()
    result = PortUsage()
    [iso] = yield [independent_experiment(spec, 12)]
    result.total_uops = round(uops_from_counters(iso, 12), 2)
    result.isolation = ports_from_counters(iso, 12)
    iso_ports = set(result.isolation)

    # optimization 1: only combinations whose ports appear in isolation
    combos = [pc for pc in blocking.combos() if pc & iso_ports]
    combos.sort(key=lambda pc: (len(pc), sorted(pc)))

    block_rep = min(max(8 * max_latency, n_ports), block_rep_cap)

    def blocked_experiment(pc) -> Experiment:
        blk_spec = isa[blocking.instrs[pc]]
        # the analyzed instruction's registers, kept apart from blockers'
        target = fresh_instance(spec, pool)
        avoid = set(target.regs.values())
        code = [fresh_instance(blk_spec, pool, avoid)
                for _ in range(block_rep)]
        code.append(target)
        return Experiment.of(code)

    attributed = 0
    for _, tier in groupby(combos, key=len):
        # optimization 2: early exit (checked per size tier — attribution
        # never depends on equal-or-larger combinations)
        if attributed >= round(result.total_uops):
            break
        tier = list(tier)
        counters = yield [blocked_experiment(pc) for pc in tier]
        for pc, c in zip(tier, counters):
            uops = sum(c.port_uops.get(p, 0.0) for p in pc)
            uops -= block_rep * blocking.uops_on_pc[pc]           # line 7
            for pc2, u2 in result.usage.items():                  # line 8-10
                if pc2 < pc:
                    uops -= u2
            uops_i = round(uops)
            if uops_i > 0:
                result.usage[pc] = uops_i
                attributed += uops_i
            if attributed >= round(result.total_uops):
                break
    return result


def port_usage_plan(spec: InstrSpec, isa: ISA, blocking: BlockingSet,
                    max_latency: int, *, n_ports: int,
                    block_rep_cap: int = BLOCK_REP_CAP) -> MeasurementPlan:
    """Algorithm 1 as a plan. ``max_latency``: max over the instruction's
    latency pairs (§5.2), used to size blockRep = 8 * maxLatency; ``n_ports``
    is the target machine's port count (lower bound on blockRep)."""
    return MeasurementPlan(
        _port_usage_gen(spec, isa, blocking, max_latency, n_ports,
                        block_rep_cap),
        name=f"ports[{spec.name}]", phase="ports")


def infer_port_usage(machine, isa: ISA, instr: InstrSpec | str,
                     blocking: BlockingSet, max_latency: int,
                     block_rep_cap: int = BLOCK_REP_CAP) -> PortUsage:
    """Algorithm 1, run to completion on one machine (wrapper over
    :func:`port_usage_plan`)."""
    engine = as_engine(machine)
    spec = isa[instr] if isinstance(instr, str) else instr
    return run_plan(engine, port_usage_plan(
        spec, isa, blocking, max_latency,
        n_ports=len(engine.machine.ports), block_rep_cap=block_rep_cap))

"""Port-usage inference — Algorithm 1 of the paper (§5.1.2).

Measuring an instruction in isolation is ambiguous (2*p05 measures the same
as 1*p0+1*p5). The algorithm disambiguates by co-scheduling the instruction
with ``blockRep`` copies of a blocking instruction for each port combination
pc (processed smallest-first): μops of the instruction observed *on the
blocked ports* can run nowhere else; μops attributed to strict subsets pc'
in earlier iterations are subtracted (line 10 of Algorithm 1).

Includes both optimizations from the paper: iterate only over combinations
intersecting the isolation-measurement ports, and exit early once the
attributed μop count reaches the instruction's total μop count.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.blocking import BlockingSet
from repro.core.isa import ISA, InstrSpec
from repro.core.machine import (RegPool, fresh_instance, isolation_ports,
                                measure, total_uops)


@dataclass
class PortUsage:
    """pu: port combination -> μop count, plus bookkeeping."""
    usage: dict = field(default_factory=dict)  # frozenset -> int
    total_uops: float = 0.0
    isolation: dict = field(default_factory=dict)

    def notation(self) -> str:
        """The paper's 3*p015+1*p23 notation."""
        parts = [f"{n}*p{''.join(sorted(pc))}"
                 for pc, n in sorted(self.usage.items(),
                                     key=lambda kv: sorted(kv[0]))]
        return "+".join(parts) if parts else "0"


def infer_port_usage(machine, isa: ISA, instr: InstrSpec | str,
                     blocking: BlockingSet, max_latency: int,
                     block_rep_cap: int = 64) -> PortUsage:
    """Algorithm 1. ``max_latency``: max over the instruction's latency
    pairs (§5.2), used to size blockRep = 8 * maxLatency."""
    spec = isa[instr] if isinstance(instr, str) else instr
    pool = RegPool()
    result = PortUsage()
    result.total_uops = round(total_uops(machine, spec), 2)
    result.isolation = isolation_ports(machine, spec)
    iso_ports = set(result.isolation)

    # optimization 1: only combinations whose ports appear in isolation
    combos = [pc for pc in blocking.combos() if pc & iso_ports]
    combos.sort(key=lambda pc: (len(pc), sorted(pc)))

    n_ports = len(machine.ports)
    block_rep = min(max(8 * max_latency, n_ports), block_rep_cap)

    attributed = 0
    for pc in combos:
        blk_spec = isa[blocking.instrs[pc]]
        # the analyzed instruction's registers, kept apart from blockers'
        target = fresh_instance(spec, pool)
        avoid = set(target.regs.values())
        code = [fresh_instance(blk_spec, pool, avoid)
                for _ in range(block_rep)]
        code.append(target)
        c = measure(machine, code)
        uops = sum(c.port_uops.get(p, 0.0) for p in pc)
        uops -= block_rep * blocking.uops_on_pc[pc]           # line 7
        for pc2, u2 in result.usage.items():                  # line 8-10
            if pc2 < pc:
                uops -= u2
        uops_i = round(uops)
        if uops_i > 0:
            result.usage[pc] = uops_i
            attributed += uops_i
        # optimization 2: early exit
        if attributed >= round(result.total_uops):
            break
    return result

"""Minitron-8B — width/depth-pruned Nemotron-4 [arXiv:2407.14679; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    mlp_kind="swiglu",
    tie_embeddings=False,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
)

"""Zamba2-2.7B — Mamba2 backbone + shared attention block [arXiv:2411.15242].

54 Mamba2 layers; one *weight-shared* full attention+MLP block is applied
every ``attn_every`` SSM layers (9 applications). We keep the weight sharing
(the defining feature) and omit the per-invocation LoRA deltas of the original
(noted in DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_conv=4,
    attn_every=6,
    mlp_kind="swiglu",
    tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, ssm_state=16, ssm_headdim=16, attn_every=2,
    ssm_chunk=16,
)

"""Qwen2-VL-2B — M-RoPE, dynamic-resolution VLM [arXiv:2409.12191; hf].

The vision frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings of shape (B, num_patch_tokens, d_model) which the
backbone prepends to the text-token embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    mlp_kind="swiglu",
    rope_theta=1e6,
    num_patch_tokens=256,
    mrope_sections=(16, 24, 24),  # sums to head_dim/2 = 64
    tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, num_patch_tokens=8, mrope_sections=(4, 2, 2),
)

"""Qwen3-8B — GQA + per-head qk-norm [hf:Qwen/Qwen3-8B]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    mlp_kind="swiglu",
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=False,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
)

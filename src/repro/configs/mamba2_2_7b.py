"""Mamba2-2.7B — attention-free SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,  # padded to 50432 for sharding (vocab_padded)
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_conv=4,
    tie_embeddings=True,
    train_microbatches=8,  # HBM fit at train_4k (see EXPERIMENTS §Perf)
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=64, vocab_size=512, ssm_state=16, ssm_headdim=16,
    ssm_chunk=16,
)

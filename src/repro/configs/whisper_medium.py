"""Whisper-medium — encoder-decoder ASR backbone [arXiv:2212.04356].

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings of shape (B, num_audio_frames, d_model). The
transformer backbone is 24 encoder + 24 decoder layers; positions use RoPE in
place of sinusoidal/learned absolute embeddings (shape/FLOP-equivalent;
noted in DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,
    num_decoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,  # padded to 51968 for sharding (vocab_padded)
    num_audio_frames=1500,
    mlp_kind="gelu",
    tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, num_decoder_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512, num_audio_frames=16,
)

"""SmolLM-360M — llama-arch small model [hf:HuggingFaceTB/SmolLM-360M]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    mlp_kind="swiglu",
    tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=60, num_heads=3, num_kv_heads=1, head_dim=20,
    d_ff=128, vocab_size=512,
)

"""Llama-4-Scout-17B-16E — MoE 16 experts top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

Assignment-faithful simplification: all 48 layers are MoE with 16 experts and
top-1 routing (the released model interleaves dense layers and adds a shared
expert; the assignment config specifies "MoE 16e top-1" uniformly).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    num_experts=16,
    experts_per_token=1,
    mlp_kind="swiglu",
    tie_embeddings=False,
    train_microbatches=16,  # HBM fit at train_4k (see EXPERIMENTS §Perf)
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=64, vocab_size=512, num_experts=4, experts_per_token=1,
)

"""Phi-3.5-MoE 42B (6.6B active) — 16 experts, top-2 routing
[hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    num_experts=16,
    experts_per_token=2,
    mlp_kind="swiglu",
    tie_embeddings=False,
    train_microbatches=8,  # HBM fit at train_4k (see EXPERIMENTS §Perf)
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=64, vocab_size=512, num_experts=4, experts_per_token=2,
)

"""Configuration dataclasses for architectures and input shapes.

Every assigned architecture gets a module ``repro.configs.<id>`` exporting
``CONFIG`` (the exact assigned full-scale config) and ``SMOKE_CONFIG`` (a
reduced same-family config used by CPU smoke tests). The full configs are
exercised only via the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Any

import jax.numpy as jnp

VOCAB_ALIGN = 256  # pad embedding tables so vocab shards evenly & MXU-aligned


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_groups: int = 1
    ssm_chunk: int = 256
    # --- hybrid (zamba2-style): one shared attn+mlp block every k ssm layers
    attn_every: int = 0
    # --- enc-dec (whisper-style) ---
    num_decoder_layers: int = 0
    num_audio_frames: int = 1500  # encoder input length (frontend stub)
    # --- vlm (qwen2-vl-style) ---
    num_patch_tokens: int = 0  # patch embeddings prepended (frontend stub)
    mrope_sections: tuple[int, ...] = ()  # M-RoPE section split of head_dim/2
    # --- misc ---
    mlp_kind: str = "swiglu"  # swiglu | gelu
    qk_norm: bool = False
    rope_theta: float = 1e4
    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    # attention implementation: "dense" (jnp, XLA-compiled; used for dry-runs
    # since Pallas/Mosaic only lowers for real TPUs) or "pallas" (TPU target).
    attn_impl: str = "dense"
    remat: str = "full"  # full | dots | none — activation checkpoint policy
    # cross-entropy implementation: "gather" (take_along_axis over the
    # model-sharded vocab; GSPMD inserts a full logits all-gather — the
    # measured baseline pathology) or "vocab_parallel" (shard_map with local
    # gold-logit extraction + psum'd softmax statistics).
    ce_impl: str = "gather"
    # SSD intra-chunk precision: fp32 (reference-faithful) or bf16 inputs
    # with fp32 state accumulation (the TPU-native mixed mode).
    ssd_dtype: str = "fp32"
    # gradient-accumulation microbatches for the train step (HBM fit)
    train_microbatches: int = 4
    # embedding-table sharding: "model_data" (vocab over model + ZeRO over
    # data; baseline) or "model_only" (pure vocab-TP: required for
    # vocab-parallel CE to avoid per-chunk data-axis table gathers)
    embed_sharding: str = "model_data"
    # decode layer loop: "scan" (lax.scan with the KV cache as stacked ys —
    # XLA double-buffers the cache) or "fori" (full cache as a while-loop
    # carry: in-place dynamic updates, single cache buffer)
    decode_loop: str = "scan"
    # query-chunk size for the HLO-level flash attention blocking
    attn_q_chunk: int = 1024
    # force bf16 tensor-parallel all-reduces: place an optimization barrier
    # after the TP matmul outputs so XLA's collective-promotion pass cannot
    # upcast the (B,S,D) all-reduces to fp32 (measured 2x wire on minitron)
    bf16_all_reduce: bool = False
    # Unroll lax.scan loops when lowering. XLA's cost_analysis counts a
    # while-loop body ONCE regardless of trip count (verified empirically),
    # so the roofline cost-compile unrolls; the memory/multi-pod compiles
    # keep scans for fast compilation. (The tiny SSD inter-chunk recurrence
    # stays scanned either way — its FLOPs are negligible; see DESIGN.md.)
    unroll_scans: bool = False

    @property
    def vocab_padded(self) -> int:
        v = self.vocab_size
        return (v + VOCAB_ALIGN - 1) // VOCAB_ALIGN * VOCAB_ALIGN

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + per-layer), for 6ND math."""
        d, f, v = self.d_model, self.d_ff, self.vocab_padded
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.mlp_kind == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        n = emb
        if self.family in ("dense", "vlm"):
            n += self.num_layers * (attn + mlp)
        elif self.family == "moe":
            n += self.num_layers * (attn + self.num_experts * mlp + d * self.num_experts)
        elif self.family == "ssm":
            n += self.num_layers * self._ssm_block_params()
        elif self.family == "hybrid":
            n += self.num_layers * self._ssm_block_params()
            n += attn + mlp  # one shared block
        elif self.family == "encdec":
            n += self.num_layers * (attn + mlp)  # encoder
            n += self.num_decoder_layers * (2 * attn + mlp)  # self+cross
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE uses top-k of experts)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        mlp = 3 * d * f if self.mlp_kind == "swiglu" else 2 * d * f
        dense = self.param_count() - self.num_layers * self.num_experts * mlp
        return dense + self.num_layers * self.experts_per_token * mlp

    def _ssm_block_params(self) -> int:
        d, di, n, g = self.d_model, self.d_inner, self.ssm_state, self.ssm_groups
        h = self.ssm_heads
        in_proj = d * (2 * di + 2 * g * n + h)
        conv = self.ssm_conv * (di + 2 * g * n)
        return in_proj + conv + 3 * h + di * d + di  # + A, D, dt_bias, out, norm


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "minitron_8b",
    "qwen3_8b",
    "smollm_360m",
    "phi3_mini_3_8b",
    "qwen2_vl_2b",
    "zamba2_2_7b",
    "mamba2_2_7b",
    "whisper_medium",
    "phi3_5_moe_42b",
    "llama4_scout_17b",
]

# long_500k requires sub-quadratic sequence handling; pure full-attention
# archs skip it (documented in DESIGN.md §Arch-applicability).
LONG_CONTEXT_ARCHS = {"zamba2_2_7b", "mamba2_2_7b"}


def load_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def cell_is_runnable(arch_id: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_id in LONG_CONTEXT_ARCHS
    return True


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCH_IDS for s in SHAPES]


def runnable_cells() -> list[tuple[str, str]]:
    return [(a, s) for a, s in all_cells() if cell_is_runnable(a, s)]

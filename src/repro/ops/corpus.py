"""Deprecated location: the jitted-op corpus moved to
:mod:`repro.corpus.jit_ops` when the corpus subsystem landed (one
"corpus" in the tree). This shim keeps old imports working."""
from repro.corpus.jit_ops import build_corpus, build_jit_corpus

__all__ = ["build_corpus", "build_jit_corpus"]

"""Client for uops-as-a-service: a persistent socket speaking the
newline-delimited JSON protocol, plus a ``local_service`` helper that spins
up registry + service + server in-process (ephemeral port) for CLIs, tests,
and benchmarks.
"""
from __future__ import annotations

import contextlib
import socket

from repro.service import protocol


class ServiceError(RuntimeError):
    """Server answered with a structured error (``resp["error"]``)."""

    def __init__(self, error: dict):
        self.error = dict(error or {})
        super().__init__(self.error.get("message", str(self.error)))

    @property
    def type(self) -> str:
        return self.error.get("type", "")


class ServiceClient:
    """One connection to a prediction server. Not thread-safe: use one
    client per thread (the server side is threaded)."""

    def __init__(self, host: str, port: int, timeout: float = 30.0):
        self.host, self.port = host, port
        self._sock = socket.create_connection((host, port), timeout=timeout)
        self._rfile = self._sock.makefile("rb")
        self._wfile = self._sock.makefile("wb")

    # -- plumbing ----------------------------------------------------------
    def _call(self, msg: dict) -> dict:
        protocol.send_msg(self._wfile, msg)
        resp = protocol.recv_msg(self._rfile)
        if resp is None:
            raise ConnectionError("server closed the connection")
        return resp

    @staticmethod
    def _unwrap(resp: dict):
        if not resp.get("ok"):
            raise ServiceError(resp.get("error"))
        return resp.get("result")

    @staticmethod
    def _as_wire_block(block):
        if isinstance(block, str):
            block = protocol.parse_block(block)
        return protocol.block_to_wire(block)

    # -- endpoints ---------------------------------------------------------
    def ping(self) -> bool:
        return self._unwrap(self._call({"op": "ping"})) == "pong"

    def uarches(self) -> list[str]:
        return self._unwrap(self._call({"op": "uarches"}))

    def stats(self) -> dict:
        return self._unwrap(self._call({"op": "stats"}))

    def metrics(self) -> dict:
        """Canonical metrics snapshot (``{name: {"type": ..., ...}}``, see
        :mod:`repro.obs.metrics`); ``stats()`` keeps the legacy shape."""
        return self._unwrap(self._call({"op": "metrics"}))

    def reload(self, uarch: str | None = None) -> list[str]:
        msg = {"op": "reload"}
        if uarch is not None:
            msg["uarch"] = uarch
        return self._unwrap(self._call(msg))

    def validate(self, uarch: str, block) -> list[str]:
        """Variant names in ``block`` the uarch's model cannot predict."""
        return self._unwrap(self._call({"op": "validate", "uarch": uarch,
                                        "block": self._as_wire_block(block)}))

    def predict(self, uarch: str, block, *, raw: bool = False):
        """Predict one block (textual format or list of Instr). Returns the
        prediction dict; with ``raw=True`` returns the full response
        envelope instead of raising on structured errors."""
        resp = self._call({"op": "predict", "uarch": uarch,
                           "block": self._as_wire_block(block)})
        return resp if raw else self._unwrap(resp)

    def predict_batch(self, uarch: str, blocks) -> list[dict]:
        """Predict many blocks in one request. Returns the per-block
        response envelopes (callers pick apart ok/error per block)."""
        wire = [self._as_wire_block(b) for b in blocks]
        return self._unwrap(self._call({"op": "predict_batch",
                                        "uarch": uarch, "blocks": wire}))

    def predict_all(self, block) -> dict:
        """The CLI's sweep: one prediction per served uarch."""
        return {ua: self.predict(ua, block, raw=True)
                for ua in self.uarches()}

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        for f in (self._rfile, self._wfile):
            with contextlib.suppress(OSError):
                f.close()
        with contextlib.suppress(OSError):
            self._sock.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


@contextlib.contextmanager
def local_service(models_dir, **service_kw):
    """Start server + client against ``models_dir`` on an ephemeral local
    port; yields the connected client, tears everything down after."""
    from repro.service.server import start_server  # noqa: PLC0415

    server = start_server(models_dir, **service_kw)
    client = ServiceClient(server.host, server.port)
    try:
        yield client
    finally:
        client.close()
        server.close()

"""Client for uops-as-a-service: a persistent socket speaking either the
length-prefixed binary wire or the legacy newline-JSON protocol, plus a
``local_service`` helper that spins up registry + service + server
in-process (ephemeral port) for CLIs, tests, and benchmarks.

Wire negotiation (``wire="auto"``, the default): the client opens with a
binary HELLO frame; a new server answers HELLO_ACK and the connection runs
binary, a legacy server fails to parse the frame and closes, upon which
the client transparently reconnects in JSON mode. ``wire="json"`` skips
the probe; ``wire="binary"`` makes a JSON-only server a hard
:class:`ServiceUnavailable` error.

Robustness: ``connect_timeout``/``timeout`` bound every socket operation,
and calls that hit a connection reset are retried on a fresh connection
with exponential backoff (``retries``/``backoff_s``); when the budget is
exhausted — or a read times out — the client raises the typed
:class:`ServiceUnavailable` instead of a raw socket error. A server-side
load shed surfaces as :class:`ServiceOverloaded` (carrying
``queue_depth``/``retry_after_ms`` from the admission controller).
"""
from __future__ import annotations

import contextlib
import json
import random
import socket
import time

from repro.service import protocol


class ServiceError(RuntimeError):
    """Server answered with a structured error (``resp["error"]``)."""

    def __init__(self, error: dict):
        self.error = dict(error or {})
        super().__init__(self.error.get("message", str(self.error)))

    @property
    def type(self) -> str:
        return self.error.get("type", "")


class ServiceOverloaded(ServiceError):
    """The admission controller shed this request (typed ``Overloaded``
    error; ``error["retry_after_ms"]`` suggests a backoff)."""


class ServiceDraining(ServiceError):
    """The server is draining (typed ``Draining`` error): it is finishing
    in-flight work and refusing new requests.  ``error["retry_after_ms"]``
    hints how long until the queue empties — retry against another
    replica, or after the hint if this one will restart."""


class ServiceUnavailable(ConnectionError):
    """The server could not be reached (or kept resetting the connection)
    within the client's retry budget, or a read timed out."""


class ServiceClient:
    """One connection to a prediction server. Not thread-safe: use one
    client per thread (the server multiplexes many connections)."""

    def __init__(self, host: str, port: int, timeout: float = 30.0, *,
                 connect_timeout: float | None = None, wire: str = "auto",
                 retries: int = 2, backoff_s: float = 0.05,
                 retry_overloaded: int = 0):
        if wire not in ("auto", "binary", "json"):
            raise ValueError(f"unknown wire {wire!r}")
        self.host, self.port = host, port
        self.timeout = timeout
        self.connect_timeout = (timeout if connect_timeout is None
                                else connect_timeout)
        self.retries = max(0, int(retries))
        self.backoff_s = backoff_s
        # opt-in retry budget for typed Overloaded/Draining responses on
        # the simple ops; the sleep honors the server's retry_after_ms
        self.retry_overloaded = max(0, int(retry_overloaded))
        self._rng = random.Random()
        self._wire_pref = wire
        self.wire: str | None = None  # negotiated: "binary" | "json"
        self._sock = None
        self._rfile = self._wfile = None
        self._connect_with_retry()

    def _backoff_delay(self, attempt: int,
                       retry_after_ms: float | None = None) -> float:
        """Full-jitter exponential backoff: delay ~ U[0, backoff_s·2^a],
        floored at a server-provided ``retry_after_ms`` hint.  The old
        deterministic ``backoff_s·2^attempt`` schedule made every client
        that failed together retry together — a synchronized retry storm
        against a recovering server; the jitter decorrelates them."""
        delay = self._rng.uniform(0.0, self.backoff_s * (2 ** attempt))
        if retry_after_ms:
            delay = max(delay, float(retry_after_ms) / 1e3)
        return delay

    # -- connection management ---------------------------------------------
    def _open_socket(self):
        sock = socket.create_connection((self.host, self.port),
                                        timeout=self.connect_timeout)
        sock.settimeout(self.timeout)
        return sock, sock.makefile("rb"), sock.makefile("wb")

    def _connect_once(self) -> None:
        sock, rfile, wfile = self._open_socket()
        wire = "json"
        if self._wire_pref in ("auto", "binary"):
            try:
                wfile.write(protocol.hello_frame())
                wfile.flush()
                resp = protocol.read_frame(rfile)
                if resp is None or resp[0] != protocol.K_HELLO_ACK:
                    raise ConnectionError("no binary HELLO_ACK")
                wire = "binary"
            except (ConnectionError, OSError,
                    protocol.BinaryProtocolError) as e:
                # legacy JSON server: it closes (or answers garbage) on the
                # HELLO frame — reconnect plain unless binary was required
                with contextlib.suppress(OSError):
                    sock.close()
                if self._wire_pref == "binary":
                    raise ServiceUnavailable(
                        f"server does not speak the binary wire: {e}"
                    ) from None
                sock, rfile, wfile = self._open_socket()
        self._sock, self._rfile, self._wfile = sock, rfile, wfile
        self.wire = wire

    def _connect_with_retry(self) -> None:
        last: Exception | None = None
        for attempt in range(self.retries + 1):
            try:
                self._connect_once()
                return
            except ServiceUnavailable:
                raise
            except (ConnectionError, OSError) as e:
                last = e
                if attempt < self.retries:
                    time.sleep(self._backoff_delay(attempt))
        raise ServiceUnavailable(
            f"cannot connect to {self.host}:{self.port} after "
            f"{self.retries + 1} attempts: {last}") from last

    def _reconnect(self, mode: str) -> None:
        self.close()
        self._connect_with_retry()
        if self.wire != mode:
            raise ServiceUnavailable(
                f"reconnected on the {self.wire} wire but the in-flight "
                f"request was encoded for {mode}")

    # -- plumbing ----------------------------------------------------------
    def _exchange(self, raw: bytes, mode: str):
        """Write pre-encoded request bytes, read one response — a
        ``(kind, payload)`` frame in binary mode, a raw line in JSON mode —
        retrying on a fresh connection after resets."""
        attempt = 0
        while True:
            try:
                self._wfile.write(raw)
                self._wfile.flush()
                if mode == "binary":
                    resp = protocol.read_frame(self._rfile)
                else:
                    resp = self._rfile.readline() or None
                if resp is None:
                    raise ConnectionError("server closed the connection")
                return resp
            except TimeoutError as e:  # socket.timeout: no blind retry of
                # a request the server may still be chewing on
                self.close()
                raise ServiceUnavailable(
                    f"request timed out after {self.timeout}s") from e
            except (ConnectionError, OSError) as e:
                self.close()
                if attempt >= self.retries:
                    raise ServiceUnavailable(
                        f"connection to {self.host}:{self.port} kept "
                        f"resetting ({attempt + 1} attempts): {e}") from e
                time.sleep(self._backoff_delay(attempt))
                attempt += 1
                self._reconnect(mode)

    def _call(self, msg: dict) -> dict:
        if self.wire == "binary":
            kind, payload = self._exchange(
                protocol.frame(protocol.K_MSG, protocol.pack_value(msg)),
                "binary")
            if kind != protocol.K_RESP:
                raise protocol.BinaryProtocolError(
                    f"unexpected response frame kind {kind}")
            return protocol.unpack_value(payload)
        line = self._exchange(
            json.dumps(msg, separators=(",", ":")).encode() + b"\n", "json")
        return json.loads(line)

    @staticmethod
    def _unwrap(resp: dict):
        if not resp.get("ok"):
            err = resp.get("error") or {}
            if err.get("type") == "Overloaded":
                raise ServiceOverloaded(err)
            if err.get("type") == "Draining":
                raise ServiceDraining(err)
            raise ServiceError(err)
        return resp.get("result")

    def _call_retrying(self, msg: dict):
        """``_call`` + ``_unwrap`` with an opt-in retry budget for typed
        Overloaded/Draining responses (``retry_overloaded``), sleeping a
        full-jitter backoff floored at the server's ``retry_after_ms``
        hint between attempts."""
        attempt = 0
        while True:
            try:
                return self._unwrap(self._call(msg))
            except (ServiceOverloaded, ServiceDraining) as e:
                if attempt >= self.retry_overloaded:
                    raise
                time.sleep(self._backoff_delay(
                    attempt, e.error.get("retry_after_ms")))
                attempt += 1

    @staticmethod
    def _as_packed_block(block):
        if isinstance(block, str):
            block = protocol.parse_block(block)
        return protocol.instrs_to_packed(block)

    @staticmethod
    def _as_wire_block(block):
        if isinstance(block, str):
            block = protocol.parse_block(block)
        return protocol.block_to_wire(block)

    # -- endpoints ---------------------------------------------------------
    def ping(self) -> bool:
        return self._call_retrying({"op": "ping"}) == "pong"

    def uarches(self) -> list[str]:
        return self._call_retrying({"op": "uarches"})

    def stats(self) -> dict:
        return self._call_retrying({"op": "stats"})

    def metrics(self) -> dict:
        """Canonical metrics snapshot (``{name: {"type": ..., ...}}``, see
        :mod:`repro.obs.metrics`); ``stats()`` keeps the legacy shape."""
        return self._call_retrying({"op": "metrics"})

    def health(self) -> dict:
        """Server liveness/readiness: drain state, queue depth, worker
        liveness, model registry status (answered even while draining)."""
        return self._call_retrying({"op": "health"})

    def drain(self) -> dict:
        """Ask the server to drain gracefully (finish in-flight work,
        refuse new work with typed ``Draining`` envelopes)."""
        return self._unwrap(self._call({"op": "drain"}))

    def reload(self, uarch: str | None = None) -> list[str]:
        msg = {"op": "reload"}
        if uarch is not None:
            msg["uarch"] = uarch
        return self._unwrap(self._call(msg))

    def validate(self, uarch: str, block) -> list[str]:
        """Variant names in ``block`` the uarch's model cannot predict."""
        return self._unwrap(self._call({"op": "validate", "uarch": uarch,
                                        "block": self._as_wire_block(block)}))

    def predict(self, uarch: str, block, *, raw: bool = False):
        """Predict one block (textual format or list of Instr). Returns the
        prediction dict; with ``raw=True`` returns the full response
        envelope instead of raising on structured errors."""
        msg = {"op": "predict", "uarch": uarch,
               "block": self._as_wire_block(block)}
        if raw:
            return self._call(msg)
        return self._call_retrying(msg)

    def predict_batch(self, uarch: str, blocks, *,
                      budget_us: float | None = None) -> list[dict]:
        """Predict many blocks in one request. Returns the per-block
        response envelopes (callers pick apart ok/error per block) —
        identical payloads on either wire. ``budget_us`` asks the server
        to shed the request instead of queueing it past that latency."""
        prepared = self.prepare_batch(uarch, blocks, budget_us=budget_us)
        ok, shed, envs = self.send_prepared(prepared, decode=True)
        if not ok:
            self._unwrap(envs[0] if envs else {"ok": False})
        return envs

    def _read_stream(self, mode: str):
        """Read one follow-up response of a streaming op. Unlike
        :meth:`_exchange` there is no retry: replaying mid-stream is not
        safe, so any transport trouble is a hard ServiceUnavailable."""
        try:
            if mode == "binary":
                resp = protocol.read_frame(self._rfile)
            else:
                resp = self._rfile.readline() or None
            if resp is None:
                raise ConnectionError("server closed mid-stream")
            return resp
        except TimeoutError as e:
            self.close()
            raise ServiceUnavailable(
                f"stream read timed out after {self.timeout}s") from e
        except (ConnectionError, OSError) as e:
            self.close()
            raise ServiceUnavailable(
                f"connection lost mid-stream: {e}") from e

    def predict_corpus(self, uarch: str, shards, *,
                       budget_us: float | None = None):
        """Bulk corpus prediction: every shard in one request, responses
        streamed back per shard. Returns ``(shard_envelopes, summary)``
        where ``shard_envelopes[i]`` holds shard *i*'s per-block response
        envelopes — or its single error envelope when that shard was shed
        (typed ``Overloaded``) or failed; the stream carries on either
        way. ``summary`` is the server's end-of-stream tally
        (shards/blocks/errors/shed). Identical envelope payloads on either
        wire."""
        packed = [[self._as_packed_block(b) for b in shard]
                  for shard in shards]
        results: list = [None] * len(packed)
        if self.wire == "binary":
            raw = protocol.frame(
                protocol.K_PREDICT_CORPUS,
                protocol.encode_predict_corpus(uarch, packed,
                                               int(budget_us or 0)))
            kind, payload = self._exchange(raw, "binary")
            while True:
                if kind == protocol.K_PREDICT_CORPUS_SHARD:
                    idx, envs = protocol.decode_corpus_shard(payload)
                    results[idx] = envs
                elif kind == protocol.K_PREDICT_CORPUS_END:
                    return results, protocol.unpack_value(payload)
                elif kind == protocol.K_RESP:
                    # request-level error before any shard was served
                    self._unwrap(protocol.unpack_value(payload))
                    raise protocol.BinaryProtocolError(
                        "non-error K_RESP inside a corpus stream")
                else:
                    raise protocol.BinaryProtocolError(
                        f"unexpected frame kind {kind} in corpus stream")
                kind, payload = self._read_stream("binary")
        msg = {"op": "predict_corpus", "uarch": uarch,
               "shards": [[protocol.packed_to_wire(pb) for pb in shard]
                          for shard in packed]}
        if budget_us:
            msg["budget_us"] = budget_us
        raw = json.dumps(msg, separators=(",", ":")).encode() + b"\n"
        line = self._exchange(raw, "json")
        while True:
            env = json.loads(line)
            if env.get("done"):
                return results, env.get("result")
            if "shard" not in env:
                self._unwrap(env)  # request-level error: raises
                raise ServiceError({"message": "malformed corpus stream "
                                               "response (no shard index)"})
            results[env["shard"]] = (env["result"] if env.get("ok")
                                     else [env])
            line = self._read_stream("json")

    def predict_all(self, block) -> dict:
        """The CLI's sweep: one prediction per served uarch."""
        return {ua: self.predict(ua, block, raw=True)
                for ua in self.uarches()}

    # -- replayable pre-encoded requests (load generation) -----------------
    def prepare_batch(self, uarch: str, blocks, *,
                      budget_us: float | None = None) -> tuple:
        """Pre-encode a ``predict_batch`` request for this connection's
        wire. The returned opaque tuple can be replayed many times with
        :meth:`send_prepared` — encoding cost is paid once, which is what
        an open-loop load generator needs."""
        packed = [self._as_packed_block(b) for b in blocks]
        if self.wire == "binary":
            raw = protocol.frame(
                protocol.K_PREDICT_BATCH,
                protocol.encode_predict_batch(uarch, packed,
                                              int(budget_us or 0)))
            return ("binary", raw, len(packed))
        msg = {"op": "predict_batch", "uarch": uarch,
               "blocks": [protocol.packed_to_wire(pb) for pb in packed]}
        if budget_us:
            msg["budget_us"] = budget_us
        raw = json.dumps(msg, separators=(",", ":")).encode() + b"\n"
        return ("json", raw, len(packed))

    def send_prepared(self, prepared: tuple, *, decode: bool = True):
        """Send a prepared request; returns ``(ok, shed, envelopes)``.
        With ``decode=False`` the response body is only sniffed for
        ok/shed (the load generator's cheap mode) and ``envelopes`` is
        None."""
        mode, raw, _n = prepared
        if mode != self.wire:
            raise ServiceUnavailable(
                f"request prepared for the {mode} wire but connection "
                f"negotiated {self.wire}")
        resp = self._exchange(raw, mode)
        if mode == "binary":
            kind, payload = resp
            if kind == protocol.K_PREDICT_BATCH_RESP:
                if not decode:
                    return True, False, None
                return True, False, protocol.decode_predict_batch_resp(
                    payload)
            env = protocol.unpack_value(payload)
            err = (env.get("error") or {}) if isinstance(env, dict) else {}
            return False, err.get("type") == "Overloaded", [env]
        if not decode:
            if resp.startswith(b'{"ok":true'):
                return True, False, None
            return False, b'"type":"Overloaded"' in resp[:160], None
        envd = json.loads(resp)
        if envd.get("ok"):
            return True, False, envd["result"]
        err = envd.get("error") or {}
        return False, err.get("type") == "Overloaded", [envd]

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        for f in (self._rfile, self._wfile):
            if f is not None:
                with contextlib.suppress(OSError):
                    f.close()
        if self._sock is not None:
            with contextlib.suppress(OSError):
                self._sock.close()
        self._rfile = self._wfile = self._sock = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


@contextlib.contextmanager
def local_service(models_dir, wire: str = "auto", **service_kw):
    """Start server + client against ``models_dir`` on an ephemeral local
    port; yields the connected client, tears everything down after."""
    from repro.service.server import start_server  # noqa: PLC0415

    server = start_server(models_dir, **service_kw)
    client = ServiceClient(server.host, server.port, wire=wire)
    try:
        yield client
    finally:
        client.close()
        server.close()

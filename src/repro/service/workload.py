"""Synthetic prediction workloads: seeded random basic blocks drawn from a
characterized model, used by the service-throughput benchmark and the
batch-vs-reference agreement tests."""
from __future__ import annotations

import random

from repro.core.characterize import PerfModel
from repro.core.isa import FLAGS, GPR, IMM, ISA, MEM, VEC
from repro.core.simulator import Instr

_REG_POOLS = {
    GPR: [f"R{i}" for i in range(16)],
    VEC: [f"X{i}" for i in range(16)],
    MEM: [f"RB{i}" for i in range(8)],
}


def random_block(model: PerfModel, isa: ISA, rng: random.Random,
                 length: int = 4) -> list[Instr]:
    """One block of ``length`` instructions over the model's characterized
    variants, with random (possibly chaining / colliding) registers — the
    interesting regime for the latency bound."""
    names = [n for n in model.instructions if n in isa]
    code = []
    for _ in range(length):
        spec = isa[rng.choice(names)]
        regs = {}
        for o in spec.explicit_operands:
            if o.otype in (IMM, FLAGS):
                continue
            regs[o.name] = rng.choice(_REG_POOLS[o.otype])
        hint = "high" if (spec.uses_divider and rng.random() < 0.3) else "low"
        code.append(Instr(spec.name, regs, hint))
    return code


def random_blocks(model: PerfModel, isa: ISA, n: int, *,
                  min_len: int = 1, max_len: int = 6,
                  seed: int = 0) -> list[list[Instr]]:
    rng = random.Random(seed)
    return [random_block(model, isa, rng, rng.randint(min_len, max_len))
            for _ in range(n)]

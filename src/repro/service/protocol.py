"""Wire protocol and textual block format for uops-as-a-service.

Dependency-free by design (stdlib json + sockets only): the service is the
thing other tools talk *to*, so it must not drag the measurement stack's
optional dependencies along.

Textual basic-block format (the CLI's input), one instruction per line::

    # comment
    IMUL_R64_R64 op1=R0 op2=R1
    DIV_R64 op1=R0 op2=R3 hi=R4 !high

``name=reg`` assigns an architectural register to an operand; ``!high``
selects the high divider operand class (§5.2.5 value hint).

Wire format: newline-delimited JSON messages over a TCP stream. Requests
are ``{"op": ..., ...}``; responses are ``{"ok": true, "result": ...}`` or
``{"ok": false, "error": {"type": ..., "message": ..., ...}}`` — the typed
:class:`~repro.core.predictor.UnknownInstructionError` travels as a
structured error carrying the missing variant names.
"""
from __future__ import annotations

import json

from repro.core.predictor import Prediction, UnknownInstructionError
from repro.core.simulator import Instr

PROTOCOL_VERSION = 1


# ---------------------------------------------------------------------------
# textual block format
# ---------------------------------------------------------------------------


class BlockParseError(ValueError):
    pass


def parse_block(text: str, isa=None) -> list[Instr]:
    """Parse the textual block format into Instr instances. With ``isa``
    given, unknown variant names are rejected at parse time."""
    code: list[Instr] = []
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        parts = line.split()
        spec, args = parts[0], parts[1:]
        if isa is not None and spec not in isa:
            raise BlockParseError(f"line {lineno}: unknown instruction "
                                  f"variant {spec!r}")
        regs: dict[str, str] = {}
        value_hint = "low"
        for tok in args:
            if tok == "!high":
                value_hint = "high"
            elif tok == "!low":
                value_hint = "low"
            elif "=" in tok:
                k, _, v = tok.partition("=")
                regs[k] = v
            else:
                raise BlockParseError(f"line {lineno}: cannot parse operand "
                                      f"token {tok!r} (expected name=reg or "
                                      f"!high/!low)")
        code.append(Instr(spec, regs, value_hint))
    return code


def format_block(code) -> str:
    """Inverse of :func:`parse_block`."""
    lines = []
    for ins in code:
        toks = [ins.spec] + [f"{k}={v}" for k, v in ins.regs.items()]
        if ins.value_hint != "low":
            toks.append(f"!{ins.value_hint}")
        lines.append(" ".join(toks))
    return "\n".join(lines)


def block_key(uarch: str, code):
    """Hashable cache key: uarch + canonical (operand-order-free) block
    form. A nested tuple, not a string — building it is the hot path of a
    warm-cache hit, and tuple construction beats string formatting ~2x
    (``canonical_code`` stays the human-readable / persistent form)."""
    return (uarch, tuple((i.spec, tuple(sorted(i.regs.items())),
                          i.value_hint) for i in code))


# ---------------------------------------------------------------------------
# JSON encoding of Instr / Prediction / errors
# ---------------------------------------------------------------------------


def instr_to_wire(ins: Instr) -> dict:
    return {"spec": ins.spec, "regs": dict(ins.regs),
            "value_hint": ins.value_hint}


def instr_from_wire(d: dict) -> Instr:
    return Instr(d["spec"], dict(d.get("regs") or {}),
                 d.get("value_hint", "low"))


def block_to_wire(code) -> list:
    return [instr_to_wire(i) for i in code]


def block_from_wire(items) -> list:
    return [instr_from_wire(d) for d in items]


def prediction_to_dict(p: Prediction) -> dict:
    return {"cycles": p.cycles, "port_bound": p.port_bound,
            "latency_bound": p.latency_bound,
            "frontend_bound": p.frontend_bound,
            "port_pressure": dict(p.port_pressure),
            "bottleneck": p.bottleneck}


def error_to_dict(exc: BaseException) -> dict:
    out = {"type": type(exc).__name__, "message": str(exc)}
    if isinstance(exc, UnknownInstructionError):
        out["missing"] = list(exc.missing)
        out["uarch"] = exc.uarch
    for attr in ("available", "uarch"):
        if attr not in out and hasattr(exc, attr):
            out[attr] = getattr(exc, attr)
    return out


# ---------------------------------------------------------------------------
# framing: newline-delimited JSON over a socket file
# ---------------------------------------------------------------------------


def send_msg(wfile, obj) -> None:
    wfile.write((json.dumps(obj, separators=(",", ":")) + "\n").encode())
    wfile.flush()


def recv_msg(rfile):
    """Next message, or None on EOF."""
    line = rfile.readline()
    if not line:
        return None
    line = line.strip()
    if not line:
        return None
    return json.loads(line.decode() if isinstance(line, bytes) else line)

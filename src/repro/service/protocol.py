"""Wire protocol and textual block format for uops-as-a-service.

Dependency-free by design (stdlib json + sockets only): the service is the
thing other tools talk *to*, so it must not drag the measurement stack's
optional dependencies along.

Textual basic-block format (the CLI's input), one instruction per line::

    # comment
    IMUL_R64_R64 op1=R0 op2=R1
    DIV_R64 op1=R0 op2=R3 hi=R4 !high

``name=reg`` assigns an architectural register to an operand; ``!high``
selects the high divider operand class (§5.2.5 value hint).

Wire format: newline-delimited JSON messages over a TCP stream. Requests
are ``{"op": ..., ...}``; responses are ``{"ok": true, "result": ...}`` or
``{"ok": false, "error": {"type": ..., "message": ..., ...}}`` — the typed
:class:`~repro.core.predictor.UnknownInstructionError` travels as a
structured error carrying the missing variant names.
"""
from __future__ import annotations

import json
import struct

from repro.core.predictor import Prediction, UnknownInstructionError
from repro.core.simulator import Instr
from repro.faults import plan as _faults  # stdlib-only, keeps the wire dep-free

PROTOCOL_VERSION = 1


# ---------------------------------------------------------------------------
# textual block format
# ---------------------------------------------------------------------------


class BlockParseError(ValueError):
    pass


def parse_block(text: str, isa=None) -> list[Instr]:
    """Parse the textual block format into Instr instances. With ``isa``
    given, unknown variant names are rejected at parse time."""
    code: list[Instr] = []
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        parts = line.split()
        spec, args = parts[0], parts[1:]
        if isa is not None and spec not in isa:
            raise BlockParseError(f"line {lineno}: unknown instruction "
                                  f"variant {spec!r}")
        regs: dict[str, str] = {}
        value_hint = "low"
        for tok in args:
            if tok == "!high":
                value_hint = "high"
            elif tok == "!low":
                value_hint = "low"
            elif "=" in tok:
                k, _, v = tok.partition("=")
                regs[k] = v
            else:
                raise BlockParseError(f"line {lineno}: cannot parse operand "
                                      f"token {tok!r} (expected name=reg or "
                                      f"!high/!low)")
        code.append(Instr(spec, regs, value_hint))
    return code


def format_block(code) -> str:
    """Inverse of :func:`parse_block`."""
    lines = []
    for ins in code:
        toks = [ins.spec] + [f"{k}={v}" for k, v in ins.regs.items()]
        if ins.value_hint != "low":
            toks.append(f"!{ins.value_hint}")
        lines.append(" ".join(toks))
    return "\n".join(lines)


def block_key(uarch: str, code):
    """Hashable cache key: uarch + canonical (operand-order-free) block
    form. A nested tuple, not a string — building it is the hot path of a
    warm-cache hit, and tuple construction beats string formatting ~2x
    (``canonical_code`` stays the human-readable / persistent form)."""
    return (uarch, tuple((i.spec, tuple(sorted(i.regs.items())),
                          i.value_hint) for i in code))


# ---------------------------------------------------------------------------
# JSON encoding of Instr / Prediction / errors
# ---------------------------------------------------------------------------


def instr_to_wire(ins: Instr) -> dict:
    return {"spec": ins.spec, "regs": dict(ins.regs),
            "value_hint": ins.value_hint}


def instr_from_wire(d: dict) -> Instr:
    return Instr(d["spec"], dict(d.get("regs") or {}),
                 d.get("value_hint", "low"))


def block_to_wire(code) -> list:
    return [instr_to_wire(i) for i in code]


def block_from_wire(items) -> list:
    return [instr_from_wire(d) for d in items]


def prediction_to_dict(p: Prediction) -> dict:
    return {"cycles": p.cycles, "port_bound": p.port_bound,
            "latency_bound": p.latency_bound,
            "frontend_bound": p.frontend_bound,
            "port_pressure": dict(p.port_pressure),
            "bottleneck": p.bottleneck}


def error_to_dict(exc: BaseException) -> dict:
    out = {"type": type(exc).__name__, "message": str(exc)}
    if isinstance(exc, UnknownInstructionError):
        out["missing"] = list(exc.missing)
        out["uarch"] = exc.uarch
    for attr in ("available", "uarch"):
        if attr not in out and hasattr(exc, attr):
            out[attr] = getattr(exc, attr)
    return out


# ---------------------------------------------------------------------------
# framing: newline-delimited JSON over a socket file
# ---------------------------------------------------------------------------


def send_msg(wfile, obj) -> None:
    body = json.dumps(obj, separators=(",", ":")).encode()
    if _faults.active():
        # corrupt the body *before* the newline delimiter: framing stays
        # intact, so the peer reads one garbled line and fails with a
        # typed decode error instead of desyncing or hanging
        body = _faults.filter_bytes("wire.frame", body).replace(b"\n", b" ")
        _faults.check("wire.frame")
    wfile.write(body + b"\n")
    wfile.flush()


def recv_msg(rfile):
    """Next message, or None on EOF."""
    line = rfile.readline()
    if not line:
        return None
    line = line.strip()
    if not line:
        return None
    return json.loads(line.decode() if isinstance(line, bytes) else line)


# ---------------------------------------------------------------------------
# binary wire format (negotiated per connection, JSON fallback)
# ---------------------------------------------------------------------------
#
# Frame layout (all multi-byte header fields big-endian)::
#
#     magic  u8   0xB5  (never a valid first byte of a JSON request: '{'
#                        is 0x7B — the server sniffs the first byte of a
#                        connection to pick the wire)
#     kind   u8   frame kind (K_* below)
#     length u32  payload length in bytes
#     payload     `length` bytes
#
# A connection opens with HELLO/HELLO_ACK carrying the binary protocol
# version. The HELLO payload deliberately ends with a newline so a legacy
# newline-JSON server reads one (unparseable) "line", fails, and closes —
# which the client detects and transparently falls back to JSON on a fresh
# connection. Generic requests/responses (K_MSG/K_RESP) carry the same
# dicts as the JSON wire in a compact tag encoding; the bulk-wave hot path
# (K_PREDICT_BATCH/K_PREDICT_BATCH_RESP) uses a specialized layout with
# per-message string tables and bulk struct packing so a wave of blocks is
# a handful of `struct` calls, not a per-field tree walk.

BINARY_MAGIC = 0xB5
BINARY_VERSION = 1
MAX_FRAME = 64 * 1024 * 1024  # hard cap on payload size (desync guard)

K_HELLO = 1
K_HELLO_ACK = 2
K_MSG = 3                 # generic request (tag-encoded dict)
K_RESP = 4                # generic response (tag-encoded dict)
K_PREDICT_BATCH = 5       # specialized bulk-wave request
K_PREDICT_BATCH_RESP = 6  # specialized bulk-wave response
K_PREDICT_CORPUS = 7        # bulk corpus request (many shards, one frame)
K_PREDICT_CORPUS_SHARD = 8  # streamed per-shard response
K_PREDICT_CORPUS_END = 9    # end-of-stream summary

_HDR = struct.Struct(">BBI")


class BinaryProtocolError(ValueError):
    """Malformed or out-of-spec binary frame."""


def hello_frame(version: int = BINARY_VERSION) -> bytes:
    # trailing \n makes legacy JSON servers fail fast (see module note)
    return frame(K_HELLO, bytes([version]) + b"\n")


def frame(kind: int, payload: bytes) -> bytes:
    if _faults.active():
        # corrupt the payload *before* the header is packed: the length
        # field stays consistent with what is sent, so the peer reads one
        # whole (garbled) frame and raises a typed decode error instead
        # of desyncing the stream or blocking on missing bytes
        payload = _faults.filter_bytes("wire.frame", payload)
        _faults.check("wire.frame")
    return _HDR.pack(BINARY_MAGIC, kind, len(payload)) + payload


def write_frame(wfile, kind: int, payload: bytes) -> None:
    wfile.write(frame(kind, payload))
    wfile.flush()


def read_frame(rfile):
    """Next ``(kind, payload)``, or None on clean EOF at a frame boundary.
    Raises :class:`BinaryProtocolError` on desync/oversized frames and
    ConnectionError on mid-frame EOF."""
    hdr = rfile.read(_HDR.size)
    if not hdr:
        return None
    while len(hdr) < _HDR.size:
        more = rfile.read(_HDR.size - len(hdr))
        if not more:
            raise ConnectionError("EOF inside binary frame header")
        hdr += more
    magic, kind, length = _HDR.unpack(hdr)
    if magic != BINARY_MAGIC:
        raise BinaryProtocolError(f"bad frame magic 0x{magic:02x}")
    if length > MAX_FRAME:
        raise BinaryProtocolError(f"frame too large ({length} bytes)")
    chunks = []
    got = 0
    while got < length:
        c = rfile.read(length - got)
        if not c:
            raise ConnectionError("EOF inside binary frame payload")
        chunks.append(c)
        got += len(c)
    return kind, b"".join(chunks)


# -- generic tag-encoded values (msgpack-style, stdlib only) ----------------

_T_NONE, _T_TRUE, _T_FALSE, _T_INT, _T_FLOAT = 0, 1, 2, 3, 4
_T_STR, _T_BYTES, _T_LIST, _T_DICT = 5, 6, 7, 8

_F64 = struct.Struct("<d")


def _pack_varint(out: bytearray, n: int) -> None:
    while n > 0x7F:
        out.append((n & 0x7F) | 0x80)
        n >>= 7
    out.append(n)


def _unpack_varint(buf, off: int):
    n = shift = 0
    while True:
        b = buf[off]
        off += 1
        n |= (b & 0x7F) << shift
        if not b & 0x80:
            return n, off
        shift += 7


def _pack_value(out: bytearray, v) -> None:
    if v is None:
        out.append(_T_NONE)
    elif v is True:
        out.append(_T_TRUE)
    elif v is False:
        out.append(_T_FALSE)
    elif isinstance(v, int):
        out.append(_T_INT)
        _pack_varint(out, (v << 1) ^ (v >> 63) if v >= 0 else ((-v) << 1) - 1)
    elif isinstance(v, float):
        out.append(_T_FLOAT)
        out += _F64.pack(v)
    elif isinstance(v, str):
        b = v.encode()
        out.append(_T_STR)
        _pack_varint(out, len(b))
        out += b
    elif isinstance(v, (bytes, bytearray)):
        out.append(_T_BYTES)
        _pack_varint(out, len(v))
        out += v
    elif isinstance(v, (list, tuple)):
        out.append(_T_LIST)
        _pack_varint(out, len(v))
        for x in v:
            _pack_value(out, x)
    elif isinstance(v, dict):
        out.append(_T_DICT)
        _pack_varint(out, len(v))
        for k, x in v.items():
            _pack_value(out, k)
            _pack_value(out, x)
    else:
        raise TypeError(f"cannot encode {type(v).__name__} on the binary "
                        f"wire")


def _unpack_value(buf, off: int):
    tag = buf[off]
    off += 1
    if tag == _T_NONE:
        return None, off
    if tag == _T_TRUE:
        return True, off
    if tag == _T_FALSE:
        return False, off
    if tag == _T_INT:
        z, off = _unpack_varint(buf, off)
        return (z >> 1) if not z & 1 else -((z + 1) >> 1), off
    if tag == _T_FLOAT:
        return _F64.unpack_from(buf, off)[0], off + 8
    if tag == _T_STR:
        n, off = _unpack_varint(buf, off)
        return bytes(buf[off:off + n]).decode(), off + n
    if tag == _T_BYTES:
        n, off = _unpack_varint(buf, off)
        return bytes(buf[off:off + n]), off + n
    if tag == _T_LIST:
        n, off = _unpack_varint(buf, off)
        out = []
        for _ in range(n):
            v, off = _unpack_value(buf, off)
            out.append(v)
        return out, off
    if tag == _T_DICT:
        n, off = _unpack_varint(buf, off)
        d = {}
        for _ in range(n):
            k, off = _unpack_value(buf, off)
            v, off = _unpack_value(buf, off)
            d[k] = v
        return d, off
    raise BinaryProtocolError(f"unknown value tag {tag}")


def pack_value(v) -> bytes:
    out = bytearray()
    _pack_value(out, v)
    return bytes(out)


def unpack_value(payload):
    try:
        v, off = _unpack_value(payload, 0)
    except (IndexError, struct.error) as exc:
        raise BinaryProtocolError(f"truncated payload: {exc}") from None
    except UnicodeDecodeError as exc:  # corrupted-in-flight string bytes
        raise BinaryProtocolError(f"malformed payload: {exc}") from None
    if off != len(payload):
        raise BinaryProtocolError(f"{len(payload) - off} trailing bytes "
                                  f"after value")
    return v


# -- packed block form (no Instr objects on the warm path) -------------------
#
# A packed block is a tuple of (spec, regs_items_tuple, value_hint). The
# server's warm path builds cache keys straight from this form; Instr
# objects are only materialized on cache misses.


def instrs_to_packed(code):
    return tuple((i.spec, tuple(i.regs.items()), i.value_hint)
                 for i in code)


def packed_to_instrs(pb):
    return [Instr(spec, dict(regs), hint) for spec, regs, hint in pb]


def packed_key(uarch: str, pb):
    """Same value as ``block_key(uarch, packed_to_instrs(pb))``."""
    return (uarch, tuple((spec, tuple(sorted(regs)), hint)
                         for spec, regs, hint in pb))


def packed_to_wire(pb) -> list:
    return [{"spec": spec, "regs": dict(regs), "value_hint": hint}
            for spec, regs, hint in pb]


def wire_to_packed(items):
    return tuple((d["spec"], tuple((d.get("regs") or {}).items()),
                  d.get("value_hint", "low")) for d in items)


# -- specialized bulk-wave request ------------------------------------------
#
# payload := varint budget_us
#            strtab: varint n, n × (varint len, utf8 bytes)
#            varint uarch_idx (into strtab)
#            varint n_blocks, per block varint n_instrs
#            varint n_ints, n_ints × u32 LE (one bulk struct call)
#
# Per instruction the int stream holds: spec_idx, hint_idx, n_regs, then
# n_regs × (name_idx, reg_idx). All strings are interned per message.


def encode_predict_batch(uarch: str, blocks, budget_us: int = 0) -> bytes:
    """``blocks``: iterable of packed blocks (see ``instrs_to_packed``)."""
    strtab: list[str] = []
    idx: dict[str, int] = {}

    def intern(s: str) -> int:
        i = idx.get(s)
        if i is None:
            i = idx[s] = len(strtab)
            strtab.append(s)
        return i

    uarch_idx = intern(uarch)
    ints: list[int] = []
    shape: list[int] = []
    for pb in blocks:
        shape.append(len(pb))
        for spec, regs, hint in pb:
            ints.append(intern(spec))
            ints.append(intern(hint))
            ints.append(len(regs))
            for k, v in regs:
                ints.append(intern(k))
                ints.append(intern(v))

    out = bytearray()
    _pack_varint(out, budget_us)
    _pack_varint(out, len(strtab))
    for s in strtab:
        b = s.encode()
        _pack_varint(out, len(b))
        out += b
    _pack_varint(out, uarch_idx)
    _pack_varint(out, len(shape))
    for n in shape:
        _pack_varint(out, n)
    _pack_varint(out, len(ints))
    out += struct.pack(f"<{len(ints)}I", *ints)
    return bytes(out)


def decode_predict_batch(payload):
    """-> (uarch, budget_us, tuple of packed blocks)."""
    try:
        off = 0
        budget_us, off = _unpack_varint(payload, off)
        n_str, off = _unpack_varint(payload, off)
        strtab = []
        for _ in range(n_str):
            n, off = _unpack_varint(payload, off)
            strtab.append(bytes(payload[off:off + n]).decode())
            off += n
        uarch_idx, off = _unpack_varint(payload, off)
        uarch = strtab[uarch_idx]
        n_blocks, off = _unpack_varint(payload, off)
        shape = []
        for _ in range(n_blocks):
            n, off = _unpack_varint(payload, off)
            shape.append(n)
        n_ints, off = _unpack_varint(payload, off)
        end = off + 4 * n_ints
        if end > len(payload):
            raise BinaryProtocolError("truncated int stream")
        ints = struct.unpack_from(f"<{n_ints}I", payload, off)
        off = end

        blocks = []
        p = 0
        for n_instr in shape:
            pb = []
            for _ in range(n_instr):
                spec = strtab[ints[p]]
                hint = strtab[ints[p + 1]]
                n_regs = ints[p + 2]
                p += 3
                regs = tuple((strtab[ints[p + 2 * j]],
                              strtab[ints[p + 2 * j + 1]])
                             for j in range(n_regs))
                p += 2 * n_regs
                pb.append((spec, regs, hint))
            blocks.append(tuple(pb))
        if p != n_ints:
            raise BinaryProtocolError("int stream length mismatch")
    except BinaryProtocolError:
        raise
    except (IndexError, struct.error, UnicodeDecodeError) as exc:
        raise BinaryProtocolError(f"malformed predict_batch request: "
                                  f"{exc}") from None
    return uarch, budget_us, tuple(blocks)


# -- specialized bulk-wave response -----------------------------------------
#
# payload := str trace_id | str uarch | port table (varint n, n × str)
#            varint n_blocks | n_blocks × chunk
# chunk   := 0x00 packed-prediction segment
#          | 0x01 tag-encoded envelope-remainder dict (errors / fallback)
#
# Packed segment: 4 × f64 LE (cycles, port_bound, latency_bound,
# frontend_bound), bottleneck idx u8, n_pressure u8, n × port idx u8,
# n × f64 LE. Per-block chunks are cached server-side next to the result
# envelope, so a warm bulk wave response is a header plus a bytes join.

BOTTLENECKS = ("ports", "latency", "frontend")
_SEG_HEAD = struct.Struct("<4dBB")


def _pack_str(out: bytearray, s: str) -> None:
    b = s.encode()
    _pack_varint(out, len(b))
    out += b


def _unpack_str(buf, off: int):
    n, off = _unpack_varint(buf, off)
    return bytes(buf[off:off + n]).decode(), off + n


def encode_pred_chunk(env: dict, port_idx: dict) -> bytes:
    """One response chunk for an ok envelope. Falls back to the generic tag
    encoding when the prediction doesn't fit the packed layout (unknown
    port / >255 pressure entries)."""
    result = env["result"]
    pp = result["port_pressure"]
    try:
        bn = BOTTLENECKS.index(result["bottleneck"])
        if len(pp) > 255:
            raise ValueError
        ports = bytes(port_idx[p] for p in pp)
    except (ValueError, KeyError):
        return b"\x01" + pack_value(env)
    out = bytearray(b"\x00")
    out += _SEG_HEAD.pack(result["cycles"], result["port_bound"],
                          result["latency_bound"],
                          result["frontend_bound"], bn, len(pp))
    out += ports
    out += struct.pack(f"<{len(pp)}d", *pp.values())
    return bytes(out)


def encode_error_chunk(env: dict) -> bytes:
    """Chunk for a non-ok envelope (typed error travels generically)."""
    return b"\x01" + pack_value(env)


def encode_predict_batch_resp(trace_id: str, uarch: str, port_names,
                              chunks) -> bytes:
    out = bytearray()
    _pack_str(out, trace_id)
    _pack_str(out, uarch)
    _pack_varint(out, len(port_names))
    for p in port_names:
        _pack_str(out, p)
    _pack_varint(out, len(chunks))
    return bytes(out) + b"".join(chunks)


def decode_predict_batch_resp(payload):
    """-> list of response envelopes, exactly as the JSON wire shapes them
    (``{"ok": true, "uarch": ..., "result": ..., "trace_id": ...}``)."""
    try:
        off = 0
        trace_id, off = _unpack_str(payload, off)
        uarch, off = _unpack_str(payload, off)
        n_ports, off = _unpack_varint(payload, off)
        ports = []
        for _ in range(n_ports):
            p, off = _unpack_str(payload, off)
            ports.append(p)
        n_blocks, off = _unpack_varint(payload, off)
        envs = []
        for _ in range(n_blocks):
            kind = payload[off]
            off += 1
            if kind == 0:
                (cycles, port_bound, latency_bound, frontend_bound, bn,
                 n_pp) = _SEG_HEAD.unpack_from(payload, off)
                off += _SEG_HEAD.size
                pidx = payload[off:off + n_pp]
                off += n_pp
                vals = struct.unpack_from(f"<{n_pp}d", payload, off)
                off += 8 * n_pp
                env = {"ok": True, "uarch": uarch,
                       "result": {"cycles": cycles, "port_bound": port_bound,
                                  "latency_bound": latency_bound,
                                  "frontend_bound": frontend_bound,
                                  "port_pressure": {ports[i]: v for i, v
                                                    in zip(pidx, vals)},
                                  "bottleneck": BOTTLENECKS[bn]},
                       "trace_id": trace_id}
            elif kind == 1:
                env, off = _unpack_value(payload, off)
                env["trace_id"] = trace_id
            else:
                raise BinaryProtocolError(f"unknown chunk kind {kind}")
            envs.append(env)
        if off != len(payload):
            raise BinaryProtocolError("trailing bytes after response")
    except BinaryProtocolError:
        raise
    except (IndexError, struct.error, UnicodeDecodeError) as exc:
        raise BinaryProtocolError(f"malformed predict_batch response: "
                                  f"{exc}") from None
    return envs


# -- bulk corpus op (streamed per-shard responses) ---------------------------
#
# One K_PREDICT_CORPUS request frame carries every shard; the server
# answers with one K_PREDICT_CORPUS_SHARD frame *per shard* (each shard
# individually admission-controlled — a shed shard arrives as an error
# envelope without aborting the stream) and closes the exchange with a
# K_PREDICT_CORPUS_END summary. The per-shard payload embeds the
# predict_batch codecs, so a corpus shard response is byte-for-byte the
# bulk-wave response plus a shard index.
#
# request payload := varint budget_us | varint n_shards
#                    | n_shards × (varint len, predict_batch payload)
# shard payload   := varint shard_idx | u8 kind
#                    kind 0: predict_batch_resp payload
#                    kind 1: tag-encoded error envelope (shed / failure)
# end payload     := tag-encoded summary dict


def encode_predict_corpus(uarch: str, shards, budget_us: int = 0) -> bytes:
    """``shards``: iterable of shard block lists (packed blocks each)."""
    out = bytearray()
    _pack_varint(out, budget_us)
    chunks = [encode_predict_batch(uarch, shard) for shard in shards]
    _pack_varint(out, len(chunks))
    for c in chunks:
        _pack_varint(out, len(c))
        out += c
    return bytes(out)


def decode_predict_corpus(payload):
    """-> (uarch, budget_us, list of per-shard packed-block tuples)."""
    try:
        off = 0
        budget_us, off = _unpack_varint(payload, off)
        n_shards, off = _unpack_varint(payload, off)
        uarch = None
        shards = []
        for _ in range(n_shards):
            n, off = _unpack_varint(payload, off)
            ua, _b, blocks = decode_predict_batch(payload[off:off + n])
            off += n
            if uarch is None:
                uarch = ua
            elif ua != uarch:
                raise BinaryProtocolError(
                    f"corpus shards mix uarches ({uarch!r} vs {ua!r})")
            shards.append(blocks)
        if uarch is None:
            raise BinaryProtocolError("empty corpus request")
        if off != len(payload):
            raise BinaryProtocolError("trailing bytes after corpus request")
    except BinaryProtocolError:
        raise
    except (IndexError, struct.error) as exc:
        raise BinaryProtocolError(f"malformed predict_corpus request: "
                                  f"{exc}") from None
    return uarch, budget_us, shards


def encode_corpus_shard(idx: int, resp_payload: bytes) -> bytes:
    """Shard response riding a predict_batch_resp payload."""
    out = bytearray()
    _pack_varint(out, idx)
    out.append(0)
    return bytes(out) + resp_payload


def encode_corpus_shard_error(idx: int, env: dict) -> bytes:
    out = bytearray()
    _pack_varint(out, idx)
    out.append(1)
    return bytes(out) + pack_value(env)


def decode_corpus_shard(payload):
    """-> (shard_idx, envelopes) — a shed/failed shard yields its single
    error envelope, a served shard the per-block envelopes."""
    try:
        idx, off = _unpack_varint(payload, 0)
        kind = payload[off]
        off += 1
        if kind == 0:
            return idx, decode_predict_batch_resp(payload[off:])
        if kind == 1:
            return idx, [unpack_value(payload[off:])]
        raise BinaryProtocolError(f"unknown corpus shard kind {kind}")
    except BinaryProtocolError:
        raise
    except (IndexError, struct.error) as exc:
        raise BinaryProtocolError(f"malformed corpus shard response: "
                                  f"{exc}") from None

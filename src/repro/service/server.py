"""The uops-as-a-service backend: coalescing, caching prediction service
plus a dependency-free threaded TCP front end.

:class:`PredictionService` is the in-process core. Requests submitted one at
a time are *coalesced*: a background worker drains the queue for a short
window and hands whole per-uarch groups to the vectorized
:class:`~repro.service.batch_predictor.BatchPredictor`, so a burst of
single-block queries costs one array pass, not N predictor calls. Results
land in an LRU cache keyed by ``(model version, uarch, canonical block)``
— the canonical form is operand-order-free, and including the registry's
model version means a hot reload implicitly invalidates every stale entry.

:class:`PredictionServer` wraps the service in a ``socketserver``
ThreadingTCPServer speaking the newline-delimited JSON protocol
(``protocol.py``). Endpoints: predict, predict_batch, uarches, stats,
reload, ping. Per-endpoint stats (request counts, error counts, cache hit
rate, p50/p99 latency, coalesced batch sizes) are served by ``stats``.

Observability (see :mod:`repro.obs`): every prediction request gets a
**trace id** (returned as ``trace_id`` in the response envelope and
attached to the request's spans, so a slow client request can be found in
a Perfetto trace); per-endpoint latency reservoirs are backed by
:class:`repro.obs.metrics.Histogram` instruments (``metrics()`` returns
the canonical registry snapshot, ``stats()`` keeps the legacy shape);
``REPRO_ACCESS_LOG=path`` appends one JSON access record per request
(trace id, endpoint, batch size, cache hits, wall µs), and requests over
the ``REPRO_SLOW_REQUEST_US`` budget are logged at WARNING.
"""
from __future__ import annotations

import json
import logging
import os
import queue
import socketserver
import threading
import time
import uuid
from collections import OrderedDict, deque
from concurrent.futures import Future

from repro.core.isa import TEST_ISA
from repro.core.predictor import UnknownInstructionError, missing_specs
from repro.obs import metrics as obs_metrics
from repro.obs import tracer as obs
from repro.service import protocol
from repro.service.batch_predictor import BatchPredictor
from repro.service.registry import ModelRegistry

_LOG = logging.getLogger("repro.service")

#: env knobs for the access log and the slow-request WARNING budget
ENV_ACCESS_LOG = "REPRO_ACCESS_LOG"
ENV_SLOW_US = "REPRO_SLOW_REQUEST_US"


def _new_trace_id() -> str:
    return uuid.uuid4().hex[:16]


class LRUCache:
    """Thread-safe LRU mapping with hit/miss counters."""

    def __init__(self, capacity: int = 4096):
        self.capacity = capacity
        self._d: OrderedDict = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key):
        with self._lock:
            try:
                val = self._d.pop(key)
            except KeyError:
                self.misses += 1
                return None
            self._d[key] = val
            self.hits += 1
            return val

    def get_many(self, keys) -> list:
        """One lock acquisition for a whole batch of lookups."""
        with self._lock:
            out = []
            for key in keys:
                try:
                    val = self._d.pop(key)
                except KeyError:
                    self.misses += 1
                    out.append(None)
                else:
                    self._d[key] = val
                    self.hits += 1
                    out.append(val)
            return out

    def put(self, key, val) -> None:
        with self._lock:
            self._d.pop(key, None)
            self._d[key] = val
            while len(self._d) > self.capacity:
                self._d.popitem(last=False)

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)

    def stats(self) -> dict:
        with self._lock:
            total = self.hits + self.misses
            return {"size": len(self._d), "capacity": self.capacity,
                    "hits": self.hits, "misses": self.misses,
                    "hit_rate": round(self.hits / max(1, total), 4)}


class EndpointStats:
    """Per-endpoint latency/error accounting, backed by the metrics layer.

    The reservoir is a :class:`repro.obs.metrics.Histogram` (newest 4096
    observations, like the deque it replaced) plus an error
    :class:`~repro.obs.metrics.Counter`; :meth:`summary` renders the
    legacy shape (``requests``/``errors``/``p50_us``/``p99_us`` — see
    ``repro.obs.metrics.ENDPOINT_ALIASES``) from the instruments, so the
    histogram is the single source of truth."""

    def __init__(self, keep: int = 4096, name: str = "endpoint"):
        self.latency = obs_metrics.Histogram(f"{name}.latency_s", keep=keep)
        self._errors = obs_metrics.Counter(f"{name}.errors")

    @property
    def requests(self) -> int:
        return self.latency.count

    @property
    def errors(self) -> int:
        return self._errors.value

    def observe(self, seconds: float, *, error: bool = False) -> None:
        self.latency.observe(seconds)
        if error:
            self._errors.inc()

    def observe_many(self, seconds_each: float, n: int, errors: int) -> None:
        """n requests that shared one batched pass."""
        for _ in range(n):
            self.latency.observe(seconds_each)
        if errors:
            self._errors.inc(errors)

    def summary(self) -> dict:
        snap = self.latency.snapshot()
        out = {"requests": snap["count"], "errors": self._errors.value}
        if snap["count"]:
            out["p50_us"] = round(snap["p50"] * 1e6, 1)
            out["p99_us"] = round(snap["p99"] * 1e6, 1)
        return out


class _Coalescer:
    """Background worker turning single predicts into per-uarch batches.

    Batching is *natural*: the worker drains whatever is already queued and
    serves it as one batch — under load, batches form because serving takes
    time while new requests queue; an idle single request pays no artificial
    delay. ``window_s > 0`` additionally holds a lone request back up to
    that long hoping for company (higher latency, bigger batches)."""

    def __init__(self, service: "PredictionService", max_batch: int,
                 window_s: float):
        self.service = service
        self.max_batch = max_batch
        self.window_s = window_s
        self.queue: queue.Queue = queue.Queue()
        self.batch_sizes: deque = deque(maxlen=4096)
        self.batches = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.closed = False          # guarded by _submit_lock
        self._submit_lock = threading.Lock()

    @staticmethod
    def _closed_response() -> dict:
        return {"ok": False, "error": {"type": "ServiceClosed",
                                       "message": "service closed before "
                                       "the request was served"}}

    def start(self) -> None:
        if self._thread is None:
            with self._submit_lock:
                self.closed = False
            self._stop.clear()  # a stopped coalescer must be restartable
            self._thread = threading.Thread(target=self._run, daemon=True,
                                            name="uops-coalescer")
            self._thread.start()

    def submit(self, item) -> None:
        """Enqueue under the close lock: a submit racing stop() either
        lands before the drain or is refused, never abandoned."""
        with self._submit_lock:
            if self.closed:
                item[2].set_result(self._closed_response())
            else:
                self.queue.put(item)

    def stop(self) -> None:
        self._stop.set()
        self.queue.put(None)  # wake the worker
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
        # fail pending futures instead of abandoning their callers; the
        # lock closes the submit-after-drain window
        with self._submit_lock:
            self.closed = True
            while True:
                try:
                    item = self.queue.get_nowait()
                except queue.Empty:
                    break
                if item is not None and not item[2].done():
                    item[2].set_result(self._closed_response())

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                item = self.queue.get(timeout=0.1)
            except queue.Empty:
                continue
            if item is None:
                continue
            batch = [item]
            deadline = time.monotonic() + self.window_s
            while len(batch) < self.max_batch:
                try:
                    nxt = self.queue.get_nowait()
                except queue.Empty:
                    left = deadline - time.monotonic()
                    if left <= 0:
                        break
                    try:
                        nxt = self.queue.get(timeout=left)
                    except queue.Empty:
                        break
                if nxt is None:
                    break
                batch.append(nxt)
            self.batches += 1
            self.batch_sizes.append(len(batch))
            groups: dict[str, list] = {}
            for uarch, code, fut, tid in batch:
                groups.setdefault(uarch, []).append((code, fut, tid))
            for uarch, entries in groups.items():
                codes = [c for c, _, _ in entries]
                tids = [t for _, _, t in entries]
                try:
                    results, hits = self.service._serve_group(
                        uarch, codes, trace_ids=tids)
                except Exception as e:  # noqa: BLE001 - the worker thread
                    # must survive anything (a dead coalescer hangs every
                    # future client); unexpected errors become responses
                    err = {"ok": False, "error": protocol.error_to_dict(e)}
                    results, hits = [err] * len(entries), [False] * len(entries)
                for (_, fut, _), res, hit in zip(entries, results, hits):
                    # the cache-hit flag rides the future (the shared
                    # envelope must not be mutated per-request); predict()
                    # reads it for the access log
                    fut.cache_hit = hit
                    if not fut.done():
                        fut.set_result(res)

    def stats(self) -> dict:
        sizes = list(self.batch_sizes)
        out = {"batches": self.batches, "queued": self.queue.qsize()}
        if sizes:
            out["mean_batch"] = round(sum(sizes) / len(sizes), 2)
            out["max_batch"] = max(sizes)
        return out


class PredictionService:
    """In-process service: registry + per-uarch batch predictors + cache."""

    def __init__(self, registry: ModelRegistry, isa=None, *,
                 issue_width: int = 4, cache_size: int = 4096,
                 max_batch: int = 64, batch_window_s: float = 0.0,
                 start: bool = True, access_log=None,
                 slow_request_us: float | None = None):
        self.registry = registry
        self.isa = isa if isa is not None else TEST_ISA
        self.issue_width = issue_width
        self.cache = LRUCache(cache_size)
        self.dedup_hits = 0  # identical requests coalesced within one wave
        self.endpoints: dict[str, EndpointStats] = {}
        self._predictors: dict[str, tuple[int, BatchPredictor]] = {}
        self._plock = threading.Lock()
        self.coalescer = _Coalescer(self, max_batch, batch_window_s)
        self.started = time.time()
        # access log (newline-JSON, one record per request) and the
        # slow-request WARNING budget; constructor args override the
        # REPRO_ACCESS_LOG / REPRO_SLOW_REQUEST_US env knobs
        if access_log is None:
            access_log = os.environ.get(ENV_ACCESS_LOG) or None
        if slow_request_us is None:
            env = os.environ.get(ENV_SLOW_US, "").strip()
            slow_request_us = float(env) if env else None
        self.access_log_path = access_log
        self.slow_request_us = slow_request_us
        self._access_fh = None
        self._access_lock = threading.Lock()
        if start:
            self.start()

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> None:
        self.coalescer.start()

    def close(self) -> None:
        self.coalescer.stop()
        with self._access_lock:
            if self._access_fh is not None:
                self._access_fh.close()
                self._access_fh = None

    # -- access log / slow-request flagging --------------------------------
    def _access(self, endpoint: str, trace_id: str, batch: int,
                cache_hits: int, wall_s: float, ok: bool) -> None:
        """One access record per served request (or per explicit batch):
        appended as newline-JSON when ``REPRO_ACCESS_LOG`` is set, and
        escalated to a WARNING when the request exceeded the configured
        latency budget."""
        wall_us = round(wall_s * 1e6, 1)
        if self.access_log_path is not None:
            rec = {"ts": round(time.time(), 3), "trace_id": trace_id,
                   "endpoint": endpoint, "batch": batch,
                   "cache_hits": cache_hits, "wall_us": wall_us, "ok": ok}
            line = json.dumps(rec, sort_keys=True)
            with self._access_lock:
                if self._access_fh is None:
                    self._access_fh = open(self.access_log_path, "a",
                                           buffering=1)
                self._access_fh.write(line + "\n")
        if self.slow_request_us is not None and wall_us > self.slow_request_us:
            _LOG.warning("slow request trace_id=%s endpoint=%s batch=%d "
                         "wall_us=%.1f (budget %.1f)", trace_id, endpoint,
                         batch, wall_us, self.slow_request_us)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- predictors / hot reload ------------------------------------------
    def _predictor(self, uarch: str) -> tuple[int, BatchPredictor]:
        handle = self.registry.get(uarch)  # stats + hot reload happen here
        with self._plock:
            cached = self._predictors.get(uarch)
            if cached is not None and cached[0] == handle.version:
                return cached
            bp = BatchPredictor(handle.model, self.isa, self.issue_width)
            self._predictors[uarch] = (handle.version, bp)
            return self._predictors[uarch]

    # -- core serving ------------------------------------------------------
    def _serve_group(self, uarch: str, codes: list,
                     trace_ids=None) -> tuple[list, list]:
        """Answer many blocks for one uarch: cache lookups, one batched
        predictor pass over the misses, structured errors per block.
        Returns ``(results, cache_hit_flags)``.  Traced as a
        ``server.serve_group`` span carrying the request trace ids; the
        first id is set as ``trace_id`` so nested batch-predictor spans on
        this thread inherit it."""
        with obs.span("server.serve_group", uarch=uarch, batch=len(codes),
                      trace_id=(trace_ids[0] if trace_ids else None),
                      trace_ids=list(trace_ids or ())) as sp:
            try:
                version, bp = self._predictor(uarch)
            except Exception as e:  # noqa: BLE001 - registry/artifact
                # failures (missing model, stale fingerprint, XML
                # ParseError from a half-written artifact, races with file
                # deletion...) must come back as structured errors, never
                # escape into the worker
                err = {"ok": False, "error": protocol.error_to_dict(e)}
                return [err] * len(codes), [False] * len(codes)
            keys = [(version, protocol.block_key(uarch, c)) for c in codes]
            out: list = [None] * len(codes)
            unique: dict = {}   # key -> first index needing computation
            dups: dict = {}     # index -> representative index
            hits = self.cache.get_many(keys)
            for i, (k, hit) in enumerate(zip(keys, hits)):
                if hit is not None:
                    out[i] = hit
                elif k in unique:
                    dups[i] = unique[k]  # identical in-flight request:
                    # compute once
                else:
                    unique[k] = i
            if dups:
                with self._plock:
                    self.dedup_hits += len(dups)
            sp.set(cache_hits=len(codes) - len(unique) - len(dups),
                   misses=len(unique))
            if unique:
                miss_idx = list(unique.values())
                results = bp.predict_batch([codes[i] for i in miss_idx],
                                           on_error="return")
                for i, res in zip(miss_idx, results):
                    if isinstance(res, UnknownInstructionError):
                        out[i] = {"ok": False,
                                  "error": protocol.error_to_dict(res)}
                    else:
                        out[i] = {"ok": True, "uarch": uarch,
                                  "result": protocol.prediction_to_dict(res)}
                        self.cache.put(keys[i], out[i])
            for i, rep in dups.items():
                out[i] = out[rep]
            return out, [h is not None for h in hits]

    def _stats_for(self, endpoint: str) -> EndpointStats:
        st = self.endpoints.get(endpoint)
        if st is None:
            st = self.endpoints.setdefault(
                endpoint, EndpointStats(name=f"server.endpoint.{endpoint}"))
        return st

    # -- public API --------------------------------------------------------
    @staticmethod
    def _copy_env(env: dict) -> dict:
        """Fresh response envelope: cached entries (and dedup aliases, for
        results and errors alike) are shared, so in-process callers get a
        copy they may mutate without poisoning the LRU cache."""
        out = dict(env)
        if "result" in out:
            res = dict(out["result"])
            if "port_pressure" in res:
                res["port_pressure"] = dict(res["port_pressure"])
            out["result"] = res
        if "error" in out:
            out["error"] = dict(out["error"])
        return out

    def submit(self, uarch: str, code) -> Future:
        """Enqueue one block for coalesced prediction. The future resolves
        once a worker is running (``start()``); on ``close()`` pending
        futures resolve to a structured ServiceClosed error.  Each submit
        gets a fresh trace id, carried on the returned future as
        ``fut.trace_id`` and into the serving spans."""
        fut: Future = Future()
        fut.trace_id = _new_trace_id()
        fut.cache_hit = False
        self.coalescer.submit((uarch, list(code), fut, fut.trace_id))
        return fut

    def predict(self, uarch: str, code) -> dict:
        t0 = time.perf_counter()
        fut = self.submit(uarch, code)
        with obs.span("server.predict", uarch=uarch,
                      trace_id=fut.trace_id):
            res = fut.result()
        dt = time.perf_counter() - t0
        self._stats_for("predict").observe(dt, error=not res.get("ok"))
        self._access("predict", fut.trace_id, 1, int(fut.cache_hit), dt,
                     bool(res.get("ok")))
        out = self._copy_env(res)
        out["trace_id"] = fut.trace_id
        return out

    def predict_batch(self, uarch: str, blocks) -> list[dict]:
        """Explicitly batched path (one request, many blocks): bypasses the
        coalescing queue but shares cache and predictors.  The whole batch
        shares one trace id (returned in every envelope) and one access
        record."""
        t0 = time.perf_counter()
        tid = _new_trace_id()
        blocks = [list(b) for b in blocks]
        with obs.span("server.predict_batch", uarch=uarch,
                      batch=len(blocks), trace_id=tid):
            out, hits = self._serve_group(uarch, blocks, trace_ids=[tid])
        dt = time.perf_counter() - t0
        per = dt / max(1, len(blocks))
        self._stats_for("predict_batch").observe_many(
            per, len(out), sum(1 for r in out if not r.get("ok")))
        self._access("predict_batch", tid, len(blocks), sum(hits), dt,
                     all(r.get("ok") for r in out) if out else True)
        copies = [self._copy_env(r) for r in out]
        for c in copies:
            c["trace_id"] = tid
        return copies

    def uarches(self) -> list[str]:
        return self.registry.uarches()

    def reload(self, uarch: str | None = None) -> list[str]:
        return self.registry.reload(uarch)

    def validate_block(self, uarch: str, code) -> list[str]:
        """Missing variant names for a block, without predicting."""
        return missing_specs(self.registry.get(uarch).model, code)

    def stats(self) -> dict:
        """The legacy nested stats shape (kept verbatim — clients and
        benches pin it); every numeric field is also exposed canonically
        through :meth:`metrics`."""
        return {
            "uptime_s": round(time.time() - self.started, 1),
            "endpoints": {k: v.summary()
                          for k, v in list(self.endpoints.items())},
            "cache": {**self.cache.stats(), "dedup_hits": self.dedup_hits},
            "coalescer": self.coalescer.stats(),
            "registry": self.registry.stats(),
        }

    def metrics(self) -> dict:
        """Canonical :class:`~repro.obs.metrics.MetricsRegistry` snapshot
        of the service: the per-endpoint latency histograms (the live
        instruments behind :class:`EndpointStats`) plus every numeric
        field of :meth:`stats` as ``server.*`` gauges."""
        reg = obs_metrics.MetricsRegistry()
        obs_metrics.absorb_server_stats(reg, self.stats())
        snap = reg.snapshot()
        for ep, st in list(self.endpoints.items()):
            snap[f"server.endpoint.{ep}.latency_s"] = st.latency.snapshot()
        return snap


# ---------------------------------------------------------------------------
# TCP front end
# ---------------------------------------------------------------------------


class _Handler(socketserver.StreamRequestHandler):
    def handle(self) -> None:
        service: PredictionService = self.server.service  # type: ignore
        while True:
            try:
                msg = protocol.recv_msg(self.rfile)
            except (ValueError, OSError):
                break
            if msg is None:
                break
            try:
                resp = self._dispatch(service, msg)
            except Exception as e:  # never kill the connection on one op
                resp = {"ok": False, "error": protocol.error_to_dict(e)}
            try:
                protocol.send_msg(self.wfile, resp)
            except OSError:
                break

    @staticmethod
    def _dispatch(service: PredictionService, msg: dict) -> dict:
        op = msg.get("op")
        if op == "ping":
            return {"ok": True, "result": "pong",
                    "version": protocol.PROTOCOL_VERSION}
        if op == "uarches":
            return {"ok": True, "result": service.uarches()}
        if op == "stats":
            return {"ok": True, "result": service.stats()}
        if op == "metrics":
            return {"ok": True, "result": service.metrics()}
        if op == "reload":
            return {"ok": True,
                    "result": service.reload(msg.get("uarch"))}
        if op == "validate":
            code = protocol.block_from_wire(msg["block"])
            return {"ok": True,
                    "result": service.validate_block(msg["uarch"], code)}
        if op == "predict":
            code = protocol.block_from_wire(msg["block"])
            return service.predict(msg["uarch"], code)
        if op == "predict_batch":
            blocks = [protocol.block_from_wire(b) for b in msg["blocks"]]
            return {"ok": True,
                    "result": service.predict_batch(msg["uarch"], blocks)}
        return {"ok": False, "error": {"type": "BadRequest",
                                       "message": f"unknown op {op!r}"}}


class _TCPServer(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True


class PredictionServer:
    """Threaded TCP server around a :class:`PredictionService`."""

    def __init__(self, service: PredictionService, host: str = "127.0.0.1",
                 port: int = 0):
        self.service = service
        self._tcp = _TCPServer((host, port), _Handler)
        self._tcp.service = service  # type: ignore[attr-defined]
        self.host, self.port = self._tcp.server_address[:2]
        self._thread = threading.Thread(target=self._tcp.serve_forever,
                                        daemon=True, name="uops-server")
        self._thread.start()

    def close(self) -> None:
        self._tcp.shutdown()
        self._tcp.server_close()
        self.service.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def start_server(models_dir, host: str = "127.0.0.1", port: int = 0,
                 **service_kw) -> PredictionServer:
    """Registry → service → TCP server, in one call."""
    service = PredictionService(ModelRegistry(models_dir), **service_kw)
    return PredictionServer(service, host, port)

"""The uops-as-a-service backend: coalescing, caching prediction service
plus a multi-worker asyncio front door.

:class:`PredictionService` is the in-process core. Requests submitted one at
a time are *coalesced*: a background worker drains the queue for a short
window and hands whole per-uarch groups to the vectorized
:class:`~repro.service.batch_predictor.BatchPredictor`, so a burst of
single-block queries costs one array pass, not N predictor calls. Results
land in a **sharded** LRU cache (:class:`ShardedLRU` — N independent
locks) keyed by ``(model version, uarch, canonical block)`` — the
canonical form is operand-order-free, and including the registry's model
version means a hot reload implicitly invalidates every stale entry. Each
cache entry also carries the lazily-encoded binary response segment, so a
warm bulk wave on the binary wire is a bytes join.

:class:`PredictionServer` is the **asyncio front door**: one event loop
owns every connection, CPU work runs on a bounded worker pool behind an
:class:`AdmissionController` (typed ``Overloaded`` shed errors instead of
unbounded queueing), and the wire — length-prefixed binary or legacy
newline-JSON — is negotiated per connection by first-byte sniffing
(``protocol.py``). The PR-7 one-thread-per-connection server survives as
:class:`ThreadedPredictionServer` (the saturation bench's baseline).
Endpoints: predict, predict_batch, uarches, stats, metrics, reload,
validate, ping. Per-endpoint stats (request counts, error counts, cache
hit rate, p50/p99 latency, coalesced batch sizes, admission/shed
counters) are served by ``stats``.

Observability (see :mod:`repro.obs`): every prediction request gets a
**trace id** (returned as ``trace_id`` in the response envelope and
attached to the request's spans, so a slow client request can be found in
a Perfetto trace); per-endpoint latency reservoirs are backed by
:class:`repro.obs.metrics.Histogram` instruments (``metrics()`` returns
the canonical registry snapshot, ``stats()`` keeps the legacy shape);
``REPRO_ACCESS_LOG=path`` appends one JSON access record per request
(trace id, endpoint, batch size, cache hits, wall µs), and requests over
the ``REPRO_SLOW_REQUEST_US`` budget are logged at WARNING.
"""
from __future__ import annotations

import asyncio
import json
import logging
import os
import queue
import socketserver
import threading
import time
import uuid
from collections import OrderedDict, deque
from concurrent.futures import Executor, Future

from repro.core.isa import TEST_ISA
from repro.core.predictor import UnknownInstructionError, missing_specs
from repro.obs import metrics as obs_metrics
from repro.obs import tracer as obs
from repro.service import protocol
from repro.service.batch_predictor import BatchPredictor
from repro.service.registry import ModelRegistry

_LOG = logging.getLogger("repro.service")

#: env knobs for the access log and the slow-request WARNING budget
ENV_ACCESS_LOG = "REPRO_ACCESS_LOG"
ENV_SLOW_US = "REPRO_SLOW_REQUEST_US"
#: size-based access-log rotation (keep-1 rollover to ``<path>.1``)
ENV_ACCESS_LOG_MAX = "REPRO_ACCESS_LOG_MAX_BYTES"
#: front-door sizing knobs
ENV_WORKERS = "REPRO_SERVER_WORKERS"
ENV_BUDGET_US = "REPRO_LATENCY_BUDGET_US"


def _new_trace_id() -> str:
    return uuid.uuid4().hex[:16]


class LRUCache:
    """Thread-safe LRU mapping with hit/miss counters."""

    def __init__(self, capacity: int = 4096):
        self.capacity = capacity
        self._d: OrderedDict = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key):
        with self._lock:
            try:
                val = self._d.pop(key)
            except KeyError:
                self.misses += 1
                return None
            self._d[key] = val
            self.hits += 1
            return val

    def get_many(self, keys) -> list:
        """One lock acquisition for a whole batch of lookups."""
        with self._lock:
            out = []
            for key in keys:
                try:
                    val = self._d.pop(key)
                except KeyError:
                    self.misses += 1
                    out.append(None)
                else:
                    self._d[key] = val
                    self.hits += 1
                    out.append(val)
            return out

    def put(self, key, val) -> None:
        with self._lock:
            self._d.pop(key, None)
            self._d[key] = val
            while len(self._d) > self.capacity:
                self._d.popitem(last=False)

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)

    def stats(self) -> dict:
        with self._lock:
            total = self.hits + self.misses
            return {"size": len(self._d), "capacity": self.capacity,
                    "hits": self.hits, "misses": self.misses,
                    "hit_rate": round(self.hits / max(1, total), 4)}


class ShardedLRU:
    """N independent :class:`LRUCache` shards keyed by hash.

    Under concurrent front-door workers a single cache lock serializes
    every warm hit; sharding makes lock contention 1/N while keeping the
    exact LRU semantics per shard. :meth:`stats` keeps the legacy
    aggregate keys and adds a ``shards`` list with per-shard hit rates."""

    def __init__(self, capacity: int = 4096, shards: int = 8):
        shards = max(1, int(shards))
        per = max(1, -(-capacity // shards))  # ceil
        self.capacity = capacity
        self.shards = [LRUCache(per) for _ in range(shards)]
        self._n = shards

    def _shard(self, key) -> LRUCache:
        return self.shards[hash(key) % self._n]

    @property
    def hits(self) -> int:
        return sum(s.hits for s in self.shards)

    @property
    def misses(self) -> int:
        return sum(s.misses for s in self.shards)

    def get(self, key):
        return self._shard(key).get(key)

    def get_many(self, keys) -> list:
        """Batch lookup: one lock acquisition per *touched shard*."""
        if self._n == 1:
            return self.shards[0].get_many(keys)
        by_shard: dict[int, tuple[list, list]] = {}
        sids = []
        for i, k in enumerate(keys):
            s = hash(k) % self._n
            sids.append(s)
            ii, kk = by_shard.setdefault(s, ([], []))
            ii.append(i)
            kk.append(k)
        out = [None] * len(keys)
        for s, (ii, kk) in by_shard.items():
            for i, v in zip(ii, self.shards[s].get_many(kk)):
                out[i] = v
        return out

    def put(self, key, val) -> None:
        self._shard(key).put(key, val)

    def __len__(self) -> int:
        return sum(len(s) for s in self.shards)

    def stats(self) -> dict:
        per = [s.stats() for s in self.shards]
        hits = sum(p["hits"] for p in per)
        misses = sum(p["misses"] for p in per)
        return {"size": sum(p["size"] for p in per),
                "capacity": self.capacity, "hits": hits, "misses": misses,
                "hit_rate": round(hits / max(1, hits + misses), 4),
                "shards": [{"size": p["size"], "hits": p["hits"],
                            "misses": p["misses"],
                            "hit_rate": p["hit_rate"]} for p in per]}


class _CacheEntry:
    """A cached ok-envelope plus its lazily-built binary response chunk:
    a warm binary bulk wave is served as a join of cached byte segments,
    no per-block re-encoding."""

    __slots__ = ("env", "seg")

    def __init__(self, env: dict):
        self.env = env
        self.seg: bytes | None = None


class EndpointStats:
    """Per-endpoint latency/error accounting, backed by the metrics layer.

    The reservoir is a :class:`repro.obs.metrics.Histogram` (newest 4096
    observations, like the deque it replaced) plus an error
    :class:`~repro.obs.metrics.Counter`; :meth:`summary` renders the
    legacy shape (``requests``/``errors``/``p50_us``/``p99_us`` — see
    ``repro.obs.metrics.ENDPOINT_ALIASES``) from the instruments, so the
    histogram is the single source of truth."""

    def __init__(self, keep: int = 4096, name: str = "endpoint"):
        self.latency = obs_metrics.Histogram(f"{name}.latency_s", keep=keep)
        self._errors = obs_metrics.Counter(f"{name}.errors")

    @property
    def requests(self) -> int:
        return self.latency.count

    @property
    def errors(self) -> int:
        return self._errors.value

    def observe(self, seconds: float, *, error: bool = False) -> None:
        self.latency.observe(seconds)
        if error:
            self._errors.inc()

    def observe_many(self, seconds_each: float, n: int, errors: int) -> None:
        """n requests that shared one batched pass (single lock round-trip
        on the histogram — see :meth:`repro.obs.metrics.Histogram.observe_many`)."""
        self.latency.observe_many(seconds_each, n)
        if errors:
            self._errors.inc(errors)

    def summary(self) -> dict:
        snap = self.latency.snapshot()
        out = {"requests": snap["count"], "errors": self._errors.value}
        if snap["count"]:
            out["p50_us"] = round(snap["p50"] * 1e6, 1)
            out["p99_us"] = round(snap["p99"] * 1e6, 1)
        return out


class _Coalescer:
    """Background worker turning single predicts into per-uarch batches.

    Batching is *natural*: the worker drains whatever is already queued and
    serves it as one batch — under load, batches form because serving takes
    time while new requests queue; an idle single request pays no artificial
    delay. ``window_s > 0`` additionally holds a lone request back up to
    that long hoping for company (higher latency, bigger batches)."""

    def __init__(self, service: "PredictionService", max_batch: int,
                 window_s: float):
        self.service = service
        self.max_batch = max_batch
        self.window_s = window_s
        self.queue: queue.Queue = queue.Queue()
        self.batch_sizes: deque = deque(maxlen=4096)
        self.batches = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.closed = False          # guarded by _submit_lock
        self._submit_lock = threading.Lock()

    @staticmethod
    def _closed_response() -> dict:
        return {"ok": False, "error": {"type": "ServiceClosed",
                                       "message": "service closed before "
                                       "the request was served"}}

    def start(self) -> None:
        if self._thread is None:
            with self._submit_lock:
                self.closed = False
            self._stop.clear()  # a stopped coalescer must be restartable
            self._thread = threading.Thread(target=self._run, daemon=True,
                                            name="uops-coalescer")
            self._thread.start()

    def submit(self, item) -> None:
        """Enqueue under the close lock: a submit racing stop() either
        lands before the drain or is refused, never abandoned."""
        with self._submit_lock:
            if self.closed:
                item[2].set_result(self._closed_response())
            else:
                self.queue.put(item)

    def stop(self) -> None:
        self._stop.set()
        self.queue.put(None)  # wake the worker
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
        # fail pending futures instead of abandoning their callers; the
        # lock closes the submit-after-drain window
        with self._submit_lock:
            self.closed = True
            while True:
                try:
                    item = self.queue.get_nowait()
                except queue.Empty:
                    break
                if item is not None and not item[2].done():
                    item[2].set_result(self._closed_response())

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                item = self.queue.get(timeout=0.1)
            except queue.Empty:
                continue
            if item is None:
                continue
            batch = [item]
            deadline = time.monotonic() + self.window_s
            while len(batch) < self.max_batch:
                try:
                    nxt = self.queue.get_nowait()
                except queue.Empty:
                    left = deadline - time.monotonic()
                    if left <= 0:
                        break
                    try:
                        nxt = self.queue.get(timeout=left)
                    except queue.Empty:
                        break
                if nxt is None:
                    break
                batch.append(nxt)
            self.batches += 1
            self.batch_sizes.append(len(batch))
            groups: dict[str, list] = {}
            for uarch, code, fut, tid in batch:
                groups.setdefault(uarch, []).append((code, fut, tid))
            for uarch, entries in groups.items():
                codes = [c for c, _, _ in entries]
                tids = [t for _, _, t in entries]
                try:
                    results, hits = self.service._serve_group(
                        uarch, codes, trace_ids=tids)
                except Exception as e:  # noqa: BLE001 - the worker thread
                    # must survive anything (a dead coalescer hangs every
                    # future client); unexpected errors become responses
                    err = {"ok": False, "error": protocol.error_to_dict(e)}
                    results, hits = [err] * len(entries), [False] * len(entries)
                for (_, fut, _), res, hit in zip(entries, results, hits):
                    # the cache-hit flag rides the future (the shared
                    # envelope must not be mutated per-request); predict()
                    # reads it for the access log
                    fut.cache_hit = hit
                    if not fut.done():
                        fut.set_result(res)

    def stats(self) -> dict:
        sizes = list(self.batch_sizes)
        out = {"batches": self.batches, "queued": self.queue.qsize()}
        if sizes:
            out["mean_batch"] = round(sum(sizes) / len(sizes), 2)
            out["max_batch"] = max(sizes)
        return out


class PredictionService:
    """In-process service: registry + per-uarch batch predictors + cache."""

    def __init__(self, registry: ModelRegistry, isa=None, *,
                 issue_width: int = 4, cache_size: int = 4096,
                 cache_shards: int = 8, wave_cache_size: int = 256,
                 max_batch: int = 64,
                 batch_window_s: float = 0.0, start: bool = True,
                 access_log=None, access_log_max_bytes: int | None = None,
                 slow_request_us: float | None = None,
                 predict_backend: str | None = None,
                 min_device_blocks: int | None = None):
        self.registry = registry
        self.isa = isa if isa is not None else TEST_ISA
        self.issue_width = issue_width
        self.cache = ShardedLRU(cache_size, shards=cache_shards)
        # exact-request cache for the binary wire: the binary encoding is
        # canonical (unlike JSON, where key order / whitespace vary), so
        # identical request payload bytes imply an identical response up
        # to the trace id. Entries are (uarch, model_version, n, tail) and
        # are revalidated against the registry version on every hit.
        self.wave_cache = LRUCache(wave_cache_size)
        self.dedup_hits = 0  # identical requests coalesced within one wave
        self.endpoints: dict[str, EndpointStats] = {}
        self._predictors: dict[str, tuple[int, BatchPredictor]] = {}
        self._plock = threading.Lock()
        self.predict_backend = predict_backend
        self.min_device_blocks = min_device_blocks
        self.coalescer = _Coalescer(self, max_batch, batch_window_s)
        self.started = time.time()
        self._front_door = None  # set by PredictionServer (admission stats)
        self._draining = threading.Event()
        # access log (newline-JSON, one record per request) and the
        # slow-request WARNING budget; constructor args override the
        # REPRO_ACCESS_LOG / REPRO_SLOW_REQUEST_US env knobs
        if access_log is None:
            access_log = os.environ.get(ENV_ACCESS_LOG) or None
        if access_log_max_bytes is None:
            env = os.environ.get(ENV_ACCESS_LOG_MAX, "").strip()
            access_log_max_bytes = int(env) if env else None
        if slow_request_us is None:
            env = os.environ.get(ENV_SLOW_US, "").strip()
            slow_request_us = float(env) if env else None
        self.access_log_path = access_log
        self.access_log_max_bytes = access_log_max_bytes
        self.slow_request_us = slow_request_us
        self._access_fh = None
        self._access_lock = threading.Lock()
        if start:
            self.start()

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> None:
        self.coalescer.start()

    def close(self) -> None:
        self.coalescer.stop()
        with self._access_lock:
            if self._access_fh is not None:
                self._access_fh.close()
                self._access_fh = None

    # -- access log / slow-request flagging --------------------------------
    def _access(self, endpoint: str, trace_id: str, batch: int,
                cache_hits: int, wall_s: float, ok: bool) -> None:
        """One access record per served request (or per explicit batch):
        appended as newline-JSON when ``REPRO_ACCESS_LOG`` is set, and
        escalated to a WARNING when the request exceeded the configured
        latency budget."""
        wall_us = round(wall_s * 1e6, 1)
        if self.access_log_path is not None:
            rec = {"ts": round(time.time(), 3), "trace_id": trace_id,
                   "endpoint": endpoint, "batch": batch,
                   "cache_hits": cache_hits, "wall_us": wall_us, "ok": ok}
            line = json.dumps(rec, sort_keys=True)
            with self._access_lock:
                if self._access_fh is None:
                    self._access_fh = open(self.access_log_path, "a",
                                           buffering=1)
                self._access_fh.write(line + "\n")
                # size-based keep-1 rollover: long-lived servers must not
                # grow the log unboundedly (REPRO_ACCESS_LOG_MAX_BYTES)
                if (self.access_log_max_bytes is not None
                        and self._access_fh.tell()
                        >= self.access_log_max_bytes):
                    self._access_fh.close()
                    self._access_fh = None
                    os.replace(self.access_log_path,
                               str(self.access_log_path) + ".1")
        if self.slow_request_us is not None and wall_us > self.slow_request_us:
            _LOG.warning("slow request trace_id=%s endpoint=%s batch=%d "
                         "wall_us=%.1f (budget %.1f)", trace_id, endpoint,
                         batch, wall_us, self.slow_request_us)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- predictors / hot reload ------------------------------------------
    def _predictor(self, uarch: str) -> tuple[int, BatchPredictor]:
        handle = self.registry.get(uarch)  # stats + hot reload happen here
        with self._plock:
            cached = self._predictors.get(uarch)
            if cached is not None and cached[0] == handle.version:
                return cached
            bp = BatchPredictor(handle.model, self.isa, self.issue_width,
                                backend=self.predict_backend,
                                min_device_blocks=self.min_device_blocks)
            self._predictors[uarch] = (handle.version, bp)
            return self._predictors[uarch]

    # -- core serving ------------------------------------------------------
    def _serve_entries(self, uarch: str, packed, trace_ids=None):
        """Answer many *packed* blocks for one uarch: sharded cache
        lookups, one batched predictor pass over the misses, structured
        errors per block. Returns ``(entries, cache_hit_flags, bp)`` where
        each entry is a :class:`_CacheEntry` (ok) or an error-envelope
        dict, and ``bp`` is the predictor (None if the registry failed).
        Traced as a ``server.serve_group`` span carrying the request trace
        ids; the first id is set as ``trace_id`` so nested batch-predictor
        spans on this thread inherit it."""
        with obs.span("server.serve_group", uarch=uarch, batch=len(packed),
                      trace_id=(trace_ids[0] if trace_ids else None),
                      trace_ids=list(trace_ids or ())) as sp:
            try:
                version, bp = self._predictor(uarch)
            except Exception as e:  # noqa: BLE001 - registry/artifact
                # failures (missing model, stale fingerprint, XML
                # ParseError from a half-written artifact, races with file
                # deletion...) must come back as structured errors, never
                # escape into the worker
                err = {"ok": False, "error": protocol.error_to_dict(e)}
                return [err] * len(packed), [False] * len(packed), None
            keys = [(version, protocol.packed_key(uarch, pb))
                    for pb in packed]
            out: list = [None] * len(packed)
            unique: dict = {}   # key -> first index needing computation
            dups: dict = {}     # index -> representative index
            hits = self.cache.get_many(keys)
            for i, (k, hit) in enumerate(zip(keys, hits)):
                if hit is not None:
                    out[i] = hit
                elif k in unique:
                    dups[i] = unique[k]  # identical in-flight request:
                    # compute once
                else:
                    unique[k] = i
            if dups:
                with self._plock:
                    self.dedup_hits += len(dups)
            sp.set(cache_hits=len(packed) - len(unique) - len(dups),
                   misses=len(unique))
            if unique:
                miss_idx = list(unique.values())
                results = bp.predict_batch(
                    [protocol.packed_to_instrs(packed[i])
                     for i in miss_idx], on_error="return")
                for i, res in zip(miss_idx, results):
                    if isinstance(res, UnknownInstructionError):
                        out[i] = {"ok": False,
                                  "error": protocol.error_to_dict(res)}
                    else:
                        entry = _CacheEntry(
                            {"ok": True, "uarch": uarch,
                             "result": protocol.prediction_to_dict(res)})
                        out[i] = entry
                        self.cache.put(keys[i], entry)
            for i, rep in dups.items():
                out[i] = out[rep]
            return out, [h is not None for h in hits], bp

    def _serve_group(self, uarch: str, codes: list,
                     trace_ids=None) -> tuple[list, list]:
        """Instr-object serving path (coalescer / in-process callers):
        same core as :meth:`_serve_entries`, envelopes unwrapped."""
        packed = [protocol.instrs_to_packed(c) for c in codes]
        entries, hits, _bp = self._serve_entries(uarch, packed, trace_ids)
        return [e.env if isinstance(e, _CacheEntry) else e
                for e in entries], hits

    def serve_wave_cached(self, payload: bytes):
        """Exact-request fast path for the binary wire: if this very
        request payload was answered before (and the model version is
        unchanged), return the encoded response payload with a fresh
        trace id — no decode, no Instr objects, no worker-pool hop. The
        front door serves these inline on the event loop. Returns None
        on a miss (caller falls through to the full path)."""
        ent = self.wave_cache.get(payload)
        if ent is None:
            return None
        uarch, version, n, tail = ent
        t0 = time.perf_counter()
        try:
            if self._predictor(uarch)[0] != version:
                return None  # hot-reloaded model: recompute
        except Exception:  # noqa: BLE001 - registry trouble: full path
            return None
        tid = _new_trace_id()
        dt = time.perf_counter() - t0
        self._stats_for("predict_batch").observe_many(dt / max(1, n), n, 0)
        self._access("predict_batch", tid, n, n, dt, True)
        return b"\x10" + tid.encode() + tail

    def serve_wire_batch(self, uarch: str, packed, *, binary: bool = False,
                         wave_key: bytes | None = None):
        """The front door's bulk-wave fast path: packed blocks in,
        wire-ready payload out — no Instr objects, no envelope deep
        copies on warm hits.

        JSON mode returns ``(envelopes, trace_id)`` where each envelope is
        a shallow copy of the cached one plus the request ``trace_id``
        (the envelope is serialized immediately; nested dicts are shared
        with the cache and must not be mutated). Binary mode returns
        ``(response_payload_bytes, trace_id)`` — per-block byte segments
        are cached next to the envelope, so a warm wave is a bytes join."""
        t0 = time.perf_counter()
        tid = _new_trace_id()
        with obs.span("server.predict_batch", uarch=uarch,
                      batch=len(packed), trace_id=tid):
            entries, hits, bp = self._serve_entries(uarch, packed, [tid])
        errors = 0
        if binary:
            pidx = bp.port_index if bp is not None else {}
            chunks = []
            for e in entries:
                if isinstance(e, _CacheEntry):
                    seg = e.seg
                    if seg is None:
                        seg = protocol.encode_pred_chunk(e.env, pidx)
                        e.seg = seg
                    chunks.append(seg)
                else:
                    errors += 1
                    chunks.append(protocol.encode_error_chunk(e))
            out = protocol.encode_predict_batch_resp(
                tid, uarch, bp.port_names if bp is not None else [], chunks)
            if wave_key is not None and errors == 0 and bp is not None:
                try:
                    version = self.registry.get(uarch).version
                except Exception:  # noqa: BLE001 - raced a reload: skip
                    version = None
                if version is not None:
                    # everything after the trace-id field is id-independent
                    self.wave_cache.put(
                        wave_key,
                        (uarch, version, len(packed), out[1 + len(tid):]))
        else:
            envs = []
            for e in entries:
                if isinstance(e, _CacheEntry):
                    envs.append({**e.env, "trace_id": tid})
                else:
                    errors += 1
                    envs.append({**e, "trace_id": tid})
            out = envs
        dt = time.perf_counter() - t0
        self._stats_for("predict_batch").observe_many(
            dt / max(1, len(packed)), len(entries), errors)
        self._access("predict_batch", tid, len(packed), sum(hits), dt,
                     errors == 0)
        return out, tid

    def _stats_for(self, endpoint: str) -> EndpointStats:
        st = self.endpoints.get(endpoint)
        if st is None:
            st = self.endpoints.setdefault(
                endpoint, EndpointStats(name=f"server.endpoint.{endpoint}"))
        return st

    # -- public API --------------------------------------------------------
    @staticmethod
    def _copy_env(env: dict) -> dict:
        """Fresh response envelope: cached entries (and dedup aliases, for
        results and errors alike) are shared, so in-process callers get a
        copy they may mutate without poisoning the LRU cache."""
        out = dict(env)
        if "result" in out:
            res = dict(out["result"])
            if "port_pressure" in res:
                res["port_pressure"] = dict(res["port_pressure"])
            out["result"] = res
        if "error" in out:
            out["error"] = dict(out["error"])
        return out

    def submit(self, uarch: str, code) -> Future:
        """Enqueue one block for coalesced prediction. The future resolves
        once a worker is running (``start()``); on ``close()`` pending
        futures resolve to a structured ServiceClosed error.  Each submit
        gets a fresh trace id, carried on the returned future as
        ``fut.trace_id`` and into the serving spans."""
        fut: Future = Future()
        fut.trace_id = _new_trace_id()
        fut.cache_hit = False
        self.coalescer.submit((uarch, list(code), fut, fut.trace_id))
        return fut

    def predict(self, uarch: str, code) -> dict:
        t0 = time.perf_counter()
        fut = self.submit(uarch, code)
        with obs.span("server.predict", uarch=uarch,
                      trace_id=fut.trace_id):
            res = fut.result()
        dt = time.perf_counter() - t0
        self._stats_for("predict").observe(dt, error=not res.get("ok"))
        self._access("predict", fut.trace_id, 1, int(fut.cache_hit), dt,
                     bool(res.get("ok")))
        out = self._copy_env(res)
        out["trace_id"] = fut.trace_id
        return out

    def predict_batch(self, uarch: str, blocks) -> list[dict]:
        """Explicitly batched path (one request, many blocks): bypasses the
        coalescing queue but shares cache and predictors.  The whole batch
        shares one trace id (returned in every envelope) and one access
        record."""
        t0 = time.perf_counter()
        tid = _new_trace_id()
        blocks = [list(b) for b in blocks]
        with obs.span("server.predict_batch", uarch=uarch,
                      batch=len(blocks), trace_id=tid):
            out, hits = self._serve_group(uarch, blocks, trace_ids=[tid])
        dt = time.perf_counter() - t0
        per = dt / max(1, len(blocks))
        self._stats_for("predict_batch").observe_many(
            per, len(out), sum(1 for r in out if not r.get("ok")))
        self._access("predict_batch", tid, len(blocks), sum(hits), dt,
                     all(r.get("ok") for r in out) if out else True)
        copies = [self._copy_env(r) for r in out]
        for c in copies:
            c["trace_id"] = tid
        return copies

    def uarches(self) -> list[str]:
        return self.registry.uarches()

    # -- resilience: drain + health ----------------------------------------
    @property
    def draining(self) -> bool:
        return self._draining.is_set()

    def drain(self) -> dict:
        """Graceful drain: stop accepting new work on both wires (work
        ops get a typed ``Draining`` envelope), finish everything already
        in flight, keep answering introspection (ping/stats/metrics/
        health) so orchestrators can watch the queue empty.  Idempotent;
        there is deliberately no un-drain — restart the replica."""
        already = self._draining.is_set()
        self._draining.set()
        fd = self._front_door
        return {"draining": True, "was_draining": already,
                "inflight": (fd.admission.stats()["inflight"]
                             if fd is not None else 0)}

    def health(self) -> dict:
        """Liveness/readiness snapshot: drain state, queue depth and
        worker liveness (when a front door is attached), and model
        registry status — cheap enough to answer inline on the event
        loop, so it stays responsive under saturation."""
        out = {"status": "draining" if self.draining else "ok",
               "draining": self.draining,
               "uptime_s": round(time.time() - self.started, 1),
               "registry": self.registry.stats()}
        fd = self._front_door
        if fd is not None:
            adm = fd.admission.stats()
            out["queue_depth"] = adm["queue_depth"]
            out["inflight"] = adm["inflight"]
            liveness = getattr(fd._pool, "liveness", None)
            if liveness is not None:
                out["workers"] = liveness()
        return out

    def reload(self, uarch: str | None = None) -> list[str]:
        return self.registry.reload(uarch)

    def validate_block(self, uarch: str, code) -> list[str]:
        """Missing variant names for a block, without predicting."""
        return missing_specs(self.registry.get(uarch).model, code)

    def stats(self) -> dict:
        """The legacy nested stats shape (kept verbatim — clients and
        benches pin it); every numeric field is also exposed canonically
        through :meth:`metrics`. With a front door attached, admission
        control, wire-negotiation, and predictor-backend sections ride
        along (absent on a bare in-process service, whose shape is
        pinned)."""
        out = {
            "uptime_s": round(time.time() - self.started, 1),
            "endpoints": {k: v.summary()
                          for k, v in list(self.endpoints.items())},
            "cache": {**self.cache.stats(), "dedup_hits": self.dedup_hits},
            "coalescer": self.coalescer.stats(),
            "registry": self.registry.stats(),
        }
        fd = self._front_door
        if fd is not None:
            out["admission"] = fd.admission.stats()
            out["wire"] = dict(fd.wire_counts)
            out["wave_cache"] = self.wave_cache.stats()
            with self._plock:
                bps = [bp for _, bp in self._predictors.values()]
            if bps:
                agg: dict = {"backend": bps[0].backend}
                for bp in bps:
                    for k, v in bp.backend_stats().items():
                        if isinstance(v, (int, float)) and not isinstance(
                                v, bool):
                            agg[k] = agg.get(k, 0) + v
                out["predictor"] = agg
        return out

    def metrics(self) -> dict:
        """Canonical :class:`~repro.obs.metrics.MetricsRegistry` snapshot
        of the service: the per-endpoint latency histograms (the live
        instruments behind :class:`EndpointStats`) plus every numeric
        field of :meth:`stats` as ``server.*`` gauges."""
        reg = obs_metrics.MetricsRegistry()
        obs_metrics.absorb_server_stats(reg, self.stats())
        snap = reg.snapshot()
        for ep, st in list(self.endpoints.items()):
            snap[f"server.endpoint.{ep}.latency_s"] = st.latency.snapshot()
        return snap


# ---------------------------------------------------------------------------
# TCP front end
# ---------------------------------------------------------------------------


def _corpus_stream(service: "PredictionService", msg: dict):
    """Per-shard response dicts for a JSON ``predict_corpus`` request:
    one ``{"shard": i, ...}`` envelope per shard in request order, then a
    final ``{"done": true}`` summary. Shared by both JSON front ends (the
    asyncio door adds per-shard admission on top)."""
    uarch = msg["uarch"]
    shards = [tuple(protocol.wire_to_packed(b) for b in shard)
              for shard in msg["shards"]]
    blocks = errors = 0
    with obs.span("server.predict_corpus", uarch=uarch, shards=len(shards)):
        for idx, shard in enumerate(shards):
            try:
                envs, _tid = service.serve_wire_batch(uarch, shard)
                blocks += len(shard)
                errors += sum(1 for e in envs if not e.get("ok", True))
                yield {"ok": True, "shard": idx, "result": envs}
            except Exception as e:  # noqa: BLE001 - structured per shard
                errors += 1
                yield {"ok": False, "shard": idx,
                       "error": protocol.error_to_dict(e)}
    yield {"ok": True, "done": True,
           "result": {"shards": len(shards), "blocks": blocks,
                      "errors": errors, "shed": 0}}


class _Handler(socketserver.StreamRequestHandler):
    def handle(self) -> None:
        service: PredictionService = self.server.service  # type: ignore
        while True:
            try:
                msg = protocol.recv_msg(self.rfile)
            except (ValueError, OSError):
                break
            if msg is None:
                break
            if isinstance(msg, dict) and msg.get("op") == "predict_corpus":
                if service.draining:
                    try:
                        protocol.send_msg(self.wfile,
                                          _draining_env(service))
                    except OSError:
                        break
                    continue
                # streaming op: one response line per shard + summary
                try:
                    for resp in _corpus_stream(service, msg):
                        protocol.send_msg(self.wfile, resp)
                except OSError:
                    break
                except Exception as e:  # noqa: BLE001 - malformed request
                    try:
                        protocol.send_msg(self.wfile, {
                            "ok": False,
                            "error": protocol.error_to_dict(e)})
                    except OSError:
                        break
                continue
            try:
                resp = self._dispatch(service, msg)
            except Exception as e:  # never kill the connection on one op
                resp = {"ok": False, "error": protocol.error_to_dict(e)}
            try:
                protocol.send_msg(self.wfile, resp)
            except OSError:
                break

    @staticmethod
    def _dispatch(service: PredictionService, msg: dict) -> dict:
        op = msg.get("op")
        if op == "health":
            return {"ok": True, "result": service.health()}
        if op == "drain":
            return {"ok": True, "result": service.drain()}
        if service.draining and op not in _INTROSPECT_OPS:
            return _draining_env(service)
        if op == "ping":
            return {"ok": True, "result": "pong",
                    "version": protocol.PROTOCOL_VERSION}
        if op == "uarches":
            return {"ok": True, "result": service.uarches()}
        if op == "stats":
            return {"ok": True, "result": service.stats()}
        if op == "metrics":
            return {"ok": True, "result": service.metrics()}
        if op == "reload":
            return {"ok": True,
                    "result": service.reload(msg.get("uarch"))}
        if op == "validate":
            code = protocol.block_from_wire(msg["block"])
            return {"ok": True,
                    "result": service.validate_block(msg["uarch"], code)}
        if op == "predict":
            code = protocol.block_from_wire(msg["block"])
            return service.predict(msg["uarch"], code)
        if op == "predict_batch":
            blocks = [protocol.block_from_wire(b) for b in msg["blocks"]]
            return {"ok": True,
                    "result": service.predict_batch(msg["uarch"], blocks)}
        return {"ok": False, "error": {"type": "BadRequest",
                                       "message": f"unknown op {op!r}"}}


class _TCPServer(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True


class ThreadedPredictionServer:
    """The PR-7 one-thread-per-connection TCP server (JSON wire only).

    Kept as the saturation benchmark's baseline and as a minimal
    dependency-free fallback; the default front door is the asyncio
    :class:`PredictionServer` below."""

    def __init__(self, service: PredictionService, host: str = "127.0.0.1",
                 port: int = 0):
        self.service = service
        self._tcp = _TCPServer((host, port), _Handler)
        self._tcp.service = service  # type: ignore[attr-defined]
        self.host, self.port = self._tcp.server_address[:2]
        self._thread = threading.Thread(target=self._tcp.serve_forever,
                                        daemon=True, name="uops-server")
        self._thread.start()

    def close(self) -> None:
        self._tcp.shutdown()
        self._tcp.server_close()
        self.service.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


# ---------------------------------------------------------------------------
# admission control + asyncio front door
# ---------------------------------------------------------------------------


class WorkerCrashed(RuntimeError):
    """A worker thread died (a ``BaseException`` escaped the job) while
    running this request; the pool respawned a replacement thread and the
    request's future resolves to this typed error instead of hanging."""


class ResilientPool(Executor):
    """Bounded thread pool with worker-crash recovery.

    The stock ``ThreadPoolExecutor`` work item swallows ``BaseException``
    into the future and keeps the (possibly wounded) thread; and a thread
    killed hard enough to die between jobs silently shrinks the pool.
    This executor makes the failure mode explicit: a job that raises an
    ``Exception`` resolves its future with that exception as usual, but a
    ``BaseException`` escaping a job (a) resolves the future with a typed
    :class:`WorkerCrashed` so no caller blocks forever, (b) replenishes
    the pool with a fresh thread, and (c) lets the dying thread die.
    ``liveness()`` feeds the ``health`` op's worker section."""

    def __init__(self, max_workers: int,
                 thread_name_prefix: str = "worker"):
        self._max_workers = max(1, int(max_workers))
        self._prefix = thread_name_prefix
        self._work: queue.SimpleQueue = queue.SimpleQueue()
        self._lock = threading.Lock()
        self._threads: list = []
        self._crashes = 0
        self._seq = 0
        self._down = False
        for _ in range(self._max_workers):
            self._spawn()

    def _spawn(self) -> None:
        with self._lock:
            if self._down:
                return
            self._seq += 1
            t = threading.Thread(target=self._worker, daemon=True,
                                 name=f"{self._prefix}-{self._seq}")
            self._threads.append(t)
        t.start()

    def _worker(self) -> None:
        while True:
            item = self._work.get()
            if item is None:
                return
            fut, fn, args, kwargs = item
            if not fut.set_running_or_notify_cancel():
                continue
            try:
                fut.set_result(fn(*args, **kwargs))
            except Exception as e:  # normal job failure: thread survives
                fut.set_exception(e)
            except BaseException as e:
                # the thread is dying: resolve the future with a typed
                # error, replace the thread, then let this one unwind
                fut.set_exception(WorkerCrashed(
                    f"worker thread crashed mid-request: "
                    f"{type(e).__name__}: {e}"))
                me = threading.current_thread()
                with self._lock:
                    self._crashes += 1
                    self._threads = [t for t in self._threads if t is not me]
                    down = self._down
                if not down:
                    self._spawn()
                return

    def submit(self, fn, /, *args, **kwargs) -> Future:
        if self._down:
            raise RuntimeError("cannot schedule new futures after shutdown")
        fut: Future = Future()
        self._work.put((fut, fn, args, kwargs))
        return fut

    def liveness(self) -> dict:
        with self._lock:
            return {"configured": self._max_workers,
                    "alive": sum(1 for t in self._threads if t.is_alive()),
                    "crashed": self._crashes}

    def shutdown(self, wait: bool = True, *,
                 cancel_futures: bool = False) -> None:
        with self._lock:
            self._down = True
            threads = list(self._threads)
        for _ in range(len(threads) + self._max_workers):
            self._work.put(None)
        if wait:
            for t in threads:
                t.join(timeout=5)


#: ops still answered while draining (introspection + drain itself)
_INTROSPECT_OPS = frozenset(("ping", "uarches", "stats", "metrics",
                             "health", "drain"))


def _draining_env(service: "PredictionService") -> dict:
    """Typed envelope for work refused during graceful drain.  Carries
    the same ``retry_after_ms`` hint as the ``Overloaded`` envelope so
    clients back off — or fail over — instead of hammering a replica on
    its way out."""
    fd = getattr(service, "_front_door", None)
    retry_ms = (fd.admission.retry_hint_ms() if fd is not None
                else 1000.0)
    return {"ok": False,
            "error": {"type": "Draining",
                      "message": "server is draining: finishing in-flight "
                                 "work, not accepting new requests",
                      "retry_after_ms": retry_ms}}


class AdmissionController:
    """Bounded-queue admission with an EWMA-estimated latency budget.

    ``try_admit`` refuses (returns a shed reason) when the queue behind
    the worker pool is full, or when the estimated sojourn time
    ``(queued + 1) × ewma_service_time`` exceeds the request's latency
    budget — the request would blow its deadline anyway, so shedding it
    *now* keeps the queue from growing unboundedly and keeps p99 stable.
    Shed requests get a typed ``Overloaded`` error, never an unbounded
    queue slot."""

    def __init__(self, workers: int, max_queue: int = 256,
                 budget_us: float | None = None):
        self.workers = workers
        self.max_queue = max_queue
        self.default_budget_us = budget_us
        self._lock = threading.Lock()
        self._inflight = 0
        self._peak = 0
        self._admitted = 0
        self._shed_queue = 0
        self._shed_budget = 0
        self._ewma_s = 1e-3  # sojourn-time estimate, seeded at 1 ms

    def try_admit(self, budget_us=None) -> str | None:
        """None when admitted (caller must :meth:`release`), else the
        shed reason (``"queue_full"`` / ``"budget"``)."""
        with self._lock:
            queued = self._inflight - self.workers
            if queued >= self.max_queue:
                self._shed_queue += 1
                return "queue_full"
            b = self.default_budget_us
            if budget_us:
                b = budget_us
            if b and queued > 0 and (queued + 1) * self._ewma_s * 1e6 > b:
                self._shed_budget += 1
                return "budget"
            self._inflight += 1
            self._admitted += 1
            if self._inflight > self._peak:
                self._peak = self._inflight
            return None

    def release(self, elapsed_s: float) -> None:
        with self._lock:
            self._inflight -= 1
            self._ewma_s += 0.2 * (elapsed_s - self._ewma_s)

    def queue_depth(self) -> int:
        with self._lock:
            return max(0, self._inflight - self.workers)

    def retry_hint_ms(self) -> float:
        """The ``retry_after_ms`` hint: estimated time for the current
        queue to clear (shared by Overloaded and Draining envelopes)."""
        with self._lock:
            depth = max(0, self._inflight - self.workers)
            return round(max(1, depth) * self._ewma_s * 1e3, 1)

    @property
    def shed(self) -> int:
        return self._shed_queue + self._shed_budget

    def overloaded_env(self, reason: str) -> dict:
        """The typed load-shed response envelope."""
        with self._lock:
            depth = max(0, self._inflight - self.workers)
            retry_ms = round(max(1, depth) * self._ewma_s * 1e3, 1)
        return {"ok": False,
                "error": {"type": "Overloaded",
                          "message": f"server overloaded ({reason}): "
                                     f"retry after ~{retry_ms}ms",
                          "reason": reason, "queue_depth": depth,
                          "retry_after_ms": retry_ms}}

    def stats(self) -> dict:
        with self._lock:
            return {"workers": self.workers, "max_queue": self.max_queue,
                    "inflight": self._inflight,
                    "queue_depth": max(0, self._inflight - self.workers),
                    "peak_inflight": self._peak,
                    "admitted": self._admitted,
                    "shed": self._shed_queue + self._shed_budget,
                    "shed_queue_full": self._shed_queue,
                    "shed_budget": self._shed_budget,
                    "ewma_service_us": round(self._ewma_s * 1e6, 1),
                    "budget_us": self.default_budget_us or 0}


def _jline(env: dict) -> bytes:
    return json.dumps(env, separators=(",", ":")).encode() + b"\n"


def _bframe(env: dict) -> bytes:
    return protocol.frame(protocol.K_RESP, protocol.pack_value(env))


class PredictionServer:
    """Asyncio multi-worker front door around a :class:`PredictionService`.

    One event loop owns every connection; CPU-bound prediction work runs
    on a bounded worker pool behind the :class:`AdmissionController`
    (cheap introspection ops — ping/stats/metrics/uarches — answer inline
    so the server stays observable under saturation). The wire is
    negotiated per connection by sniffing the first byte: ``0xB5`` opens
    the length-prefixed binary protocol, anything else is the legacy
    newline-JSON (see ``protocol.py``), so old clients keep working
    unchanged. Bulk ``predict_batch`` requests take a zero-copy fast path
    (``PredictionService.serve_wire_batch``): packed blocks straight from
    the decoder to the sharded cache, responses joined from cached byte
    segments on the binary wire."""

    def __init__(self, service: PredictionService, host: str = "127.0.0.1",
                 port: int = 0, *, workers: int | None = None,
                 max_queue: int = 256,
                 latency_budget_us: float | None = None):
        self.service = service
        if workers is None:
            env = os.environ.get(ENV_WORKERS, "").strip()
            workers = int(env) if env else min(8, (os.cpu_count() or 1) * 4)
        if latency_budget_us is None:
            env = os.environ.get(ENV_BUDGET_US, "").strip()
            latency_budget_us = float(env) if env else None
        self.admission = AdmissionController(workers, max_queue,
                                             latency_budget_us)
        self.wire_counts = {"json_conns": 0, "binary_conns": 0,
                            "bad_frames": 0}
        service._front_door = self
        self._pool = ResilientPool(max_workers=workers,
                                   thread_name_prefix="uops-worker")
        self._host_arg, self._port_arg = host, port
        self._loop: asyncio.AbstractEventLoop | None = None
        self._startup = threading.Event()
        self._startup_err: BaseException | None = None
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="uops-frontdoor")
        self._thread.start()
        self._startup.wait(timeout=10)
        if self._startup_err is not None:
            raise self._startup_err

    # -- lifecycle ---------------------------------------------------------
    def _run(self) -> None:
        loop = asyncio.new_event_loop()
        asyncio.set_event_loop(loop)
        self._loop = loop
        try:
            srv = loop.run_until_complete(asyncio.start_server(
                self._handle_conn, self._host_arg, self._port_arg,
                limit=protocol.MAX_FRAME))
        except BaseException as e:  # noqa: BLE001 - surfaced to __init__
            self._startup_err = e
            self._startup.set()
            loop.close()
            return
        self._asrv = srv
        self.host, self.port = srv.sockets[0].getsockname()[:2]
        self._startup.set()
        try:
            loop.run_forever()
        finally:
            srv.close()
            loop.run_until_complete(srv.wait_closed())
            pending = asyncio.all_tasks(loop)
            for t in pending:
                t.cancel()
            if pending:
                loop.run_until_complete(
                    asyncio.gather(*pending, return_exceptions=True))
            loop.close()

    def drain(self) -> dict:
        """Graceful drain of the attached service: new work is refused
        with a typed ``Draining`` envelope, in-flight work finishes."""
        return self.service.drain()

    def close(self) -> None:
        loop = self._loop
        if loop is not None and loop.is_running():
            loop.call_soon_threadsafe(loop.stop)
        self._thread.join(timeout=10)
        self._pool.shutdown(wait=False)
        self.service.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def stats(self) -> dict:
        return {"admission": self.admission.stats(),
                "wire": dict(self.wire_counts)}

    # -- connection handling ----------------------------------------------
    async def _handle_conn(self, reader, writer) -> None:
        try:
            first = await reader.read(1)
            if not first:
                return
            if first[0] == protocol.BINARY_MAGIC:
                self.wire_counts["binary_conns"] += 1
                await self._binary_conn(reader, writer)
            else:
                self.wire_counts["json_conns"] += 1
                await self._json_conn(first, reader, writer)
        except (asyncio.IncompleteReadError, ConnectionError, OSError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _json_conn(self, first: bytes, reader, writer) -> None:
        carry = first
        while True:
            line = await reader.readline()
            if carry:
                line, carry = carry + line, b""
            if not line:
                return
            if not line.strip():
                return  # legacy recv_msg treated a blank line as EOF
            try:
                msg = json.loads(line)
                if not isinstance(msg, dict):
                    raise ValueError("request must be a JSON object")
            except ValueError as e:
                writer.write(_jline({"ok": False,
                                     "error": protocol.error_to_dict(e)}))
                await writer.drain()
                continue  # line framing keeps the stream in sync
            if msg.get("op") == "predict_corpus":
                await self._corpus_json(msg, writer)
                continue
            writer.write(await self._route(msg, _jline))
            await writer.drain()

    async def _binary_conn(self, reader, writer) -> None:
        # the sniffer consumed the magic byte of the HELLO frame
        hdr = await reader.readexactly(5)
        kind, length = hdr[0], int.from_bytes(hdr[1:], "big")
        if kind != protocol.K_HELLO or length > 64:
            self.wire_counts["bad_frames"] += 1
            return
        payload = await reader.readexactly(length)
        version = payload[0] if payload else 0
        if version != protocol.BINARY_VERSION:
            writer.write(_bframe({"ok": False, "error": {
                "type": "BinaryProtocolError",
                "message": f"unsupported binary version {version}"}}))
            await writer.drain()
            return
        writer.write(protocol.frame(protocol.K_HELLO_ACK,
                                    bytes([protocol.BINARY_VERSION])))
        await writer.drain()
        while True:
            try:
                hdr = await reader.readexactly(6)
            except asyncio.IncompleteReadError:
                return  # clean EOF at a frame boundary
            magic, kind = hdr[0], hdr[1]
            length = int.from_bytes(hdr[2:], "big")
            if magic != protocol.BINARY_MAGIC or length > protocol.MAX_FRAME:
                # stream desync: report and close (cannot resynchronize)
                self.wire_counts["bad_frames"] += 1
                writer.write(_bframe({"ok": False, "error": {
                    "type": "BinaryProtocolError",
                    "message": "frame desync (bad magic or oversized "
                               "frame); closing connection"}}))
                await writer.drain()
                return
            payload = await reader.readexactly(length)
            if kind == protocol.K_PREDICT_CORPUS:
                await self._corpus_binary(payload, writer)
                continue
            writer.write(await self._dispatch_binary(kind, payload))
            await writer.drain()

    async def _dispatch_binary(self, kind: int, payload: bytes) -> bytes:
        if kind == protocol.K_PREDICT_BATCH:
            if self.service.draining:
                return _bframe(_draining_env(self.service))
            fast = self.service.serve_wave_cached(payload)
            if fast is not None:  # exact-request hit: answer on the loop
                return protocol.frame(protocol.K_PREDICT_BATCH_RESP, fast)
            try:
                uarch, budget_us, blocks = protocol.decode_predict_batch(
                    payload)
            except protocol.BinaryProtocolError as e:
                self.wire_counts["bad_frames"] += 1
                return _bframe({"ok": False,
                                "error": protocol.error_to_dict(e)})
            service = self.service

            def work() -> bytes:
                try:
                    resp, _tid = service.serve_wire_batch(
                        uarch, blocks, binary=True, wave_key=payload)
                except Exception as e:  # noqa: BLE001 - structured error
                    return _bframe({"ok": False,
                                    "error": protocol.error_to_dict(e)})
                return protocol.frame(protocol.K_PREDICT_BATCH_RESP, resp)

            return await self._admitted(work, budget_us, _bframe)
        if kind == protocol.K_MSG:
            try:
                msg = protocol.unpack_value(payload)
                if not isinstance(msg, dict):
                    raise protocol.BinaryProtocolError(
                        "request must be a dict")
            except protocol.BinaryProtocolError as e:
                self.wire_counts["bad_frames"] += 1
                return _bframe({"ok": False,
                                "error": protocol.error_to_dict(e)})
            return await self._route(msg, _bframe)
        if kind == protocol.K_HELLO:  # redundant HELLO: re-ack
            return protocol.frame(protocol.K_HELLO_ACK,
                                  bytes([protocol.BINARY_VERSION]))
        self.wire_counts["bad_frames"] += 1
        return _bframe({"ok": False, "error": {
            "type": "BinaryProtocolError",
            "message": f"unknown frame kind {kind}"}})

    # -- bulk corpus streaming ---------------------------------------------
    async def _corpus_json(self, msg: dict, writer) -> None:
        """Stream a JSON ``predict_corpus``: one response line per shard
        (each shard individually admission-controlled — a shed shard
        arrives as an ``Overloaded`` envelope tagged with its index, the
        stream carries on) and a final ``done`` summary line."""
        service = self.service
        if service.draining:
            writer.write(_jline(_draining_env(service)))
            await writer.drain()
            return
        try:
            uarch = msg["uarch"]
            shards = [tuple(protocol.wire_to_packed(b) for b in shard)
                      for shard in msg["shards"]]
        except Exception as e:  # noqa: BLE001 - malformed request
            writer.write(_jline({"ok": False,
                                 "error": protocol.error_to_dict(e)}))
            await writer.drain()
            return
        budget_us = msg.get("budget_us")
        loop = asyncio.get_running_loop()
        blocks = errors = shed = 0
        with obs.span("server.predict_corpus", uarch=uarch,
                      shards=len(shards)):
            for idx, shard in enumerate(shards):
                reason = self.admission.try_admit(budget_us)
                if reason is not None:
                    shed += 1
                    env = self.admission.overloaded_env(reason)
                    env["shard"] = idx
                    writer.write(_jline(env))
                    await writer.drain()
                    continue

                def work(idx=idx, shard=shard):
                    try:
                        envs, _tid = service.serve_wire_batch(uarch, shard)
                    except Exception as e:  # noqa: BLE001 - structured
                        return 0, 1, _jline(
                            {"ok": False, "shard": idx,
                             "error": protocol.error_to_dict(e)})
                    bad = sum(1 for e in envs if not e.get("ok", True))
                    return len(shard), bad, _jline(
                        {"ok": True, "shard": idx, "result": envs})

                t0 = time.perf_counter()
                try:
                    n, bad, line = await loop.run_in_executor(
                        self._pool, work)
                finally:
                    self.admission.release(time.perf_counter() - t0)
                blocks += n
                errors += bad
                writer.write(line)
                await writer.drain()
        writer.write(_jline({"ok": True, "done": True,
                             "result": {"shards": len(shards),
                                        "blocks": blocks, "errors": errors,
                                        "shed": shed}}))
        await writer.drain()

    async def _corpus_binary(self, payload: bytes, writer) -> None:
        """Binary-wire twin of :meth:`_corpus_json`: K_PREDICT_CORPUS in,
        one K_PREDICT_CORPUS_SHARD frame per shard out (riding the
        predict_batch response codec), K_PREDICT_CORPUS_END summary
        last."""
        service = self.service
        if service.draining:
            # client treats a K_RESP error inside a corpus stream as a
            # request-level typed failure (raises, never hangs)
            writer.write(_bframe(_draining_env(service)))
            await writer.drain()
            return
        try:
            uarch, budget_us, shards = protocol.decode_predict_corpus(
                payload)
        except protocol.BinaryProtocolError as e:
            self.wire_counts["bad_frames"] += 1
            writer.write(_bframe({"ok": False,
                                  "error": protocol.error_to_dict(e)}))
            await writer.drain()
            return
        loop = asyncio.get_running_loop()
        blocks = errors = shed = 0
        with obs.span("server.predict_corpus", uarch=uarch,
                      shards=len(shards), wire="binary"):
            for idx, shard in enumerate(shards):
                reason = self.admission.try_admit(budget_us)
                if reason is not None:
                    shed += 1
                    env = self.admission.overloaded_env(reason)
                    writer.write(protocol.frame(
                        protocol.K_PREDICT_CORPUS_SHARD,
                        protocol.encode_corpus_shard_error(idx, env)))
                    await writer.drain()
                    continue

                def work(idx=idx, shard=shard):
                    try:
                        resp, _tid = service.serve_wire_batch(
                            uarch, shard, binary=True)
                    except Exception as e:  # noqa: BLE001 - structured
                        return 0, 1, protocol.frame(
                            protocol.K_PREDICT_CORPUS_SHARD,
                            protocol.encode_corpus_shard_error(
                                idx, {"ok": False,
                                      "error": protocol.error_to_dict(e)}))
                    return len(shard), 0, protocol.frame(
                        protocol.K_PREDICT_CORPUS_SHARD,
                        protocol.encode_corpus_shard(idx, resp))

                t0 = time.perf_counter()
                try:
                    n, bad, fr = await loop.run_in_executor(
                        self._pool, work)
                finally:
                    self.admission.release(time.perf_counter() - t0)
                blocks += n
                errors += bad
                writer.write(fr)
                await writer.drain()
        writer.write(protocol.frame(
            protocol.K_PREDICT_CORPUS_END,
            protocol.pack_value({"shards": len(shards), "blocks": blocks,
                                 "errors": errors, "shed": shed})))
        await writer.drain()

    # -- request routing ---------------------------------------------------
    async def _route(self, msg: dict, enc) -> bytes:
        """Dispatch one request dict, returning encoded response bytes.
        Heavy ops run on the worker pool behind admission control; cheap
        introspection answers inline on the event loop."""
        op = msg.get("op")
        service = self.service
        if service.draining and op not in _INTROSPECT_OPS:
            return enc(_draining_env(service))
        if op == "predict_batch":
            try:
                uarch = msg["uarch"]
                blocks = tuple(protocol.wire_to_packed(b)
                               for b in msg["blocks"])
            except Exception as e:  # noqa: BLE001 - malformed request
                return enc({"ok": False,
                            "error": protocol.error_to_dict(e)})

            def work() -> bytes:
                try:
                    envs, _tid = service.serve_wire_batch(uarch, blocks)
                except Exception as e:  # noqa: BLE001 - structured error
                    return enc({"ok": False,
                                "error": protocol.error_to_dict(e)})
                return enc({"ok": True, "result": envs})

            return await self._admitted(work, msg.get("budget_us"), enc)
        if op in _INTROSPECT_OPS:
            return enc(_Handler._dispatch(service, msg))

        def work() -> bytes:
            try:
                return enc(_Handler._dispatch(service, msg))
            except Exception as e:  # noqa: BLE001 - structured error
                return enc({"ok": False,
                            "error": protocol.error_to_dict(e)})

        if op == "predict":
            return await self._admitted(work, msg.get("budget_us"), enc)
        # reload / validate / unknown ops: pooled but never shed
        return await asyncio.get_running_loop().run_in_executor(
            self._pool, work)

    async def _admitted(self, work, budget_us, enc) -> bytes:
        reason = self.admission.try_admit(budget_us)
        if reason is not None:
            return enc(self.admission.overloaded_env(reason))
        t0 = time.perf_counter()
        try:
            return await asyncio.get_running_loop().run_in_executor(
                self._pool, work)
        finally:
            self.admission.release(time.perf_counter() - t0)


def start_server(models_dir, host: str = "127.0.0.1", port: int = 0,
                 workers: int | None = None, max_queue: int = 256,
                 latency_budget_us: float | None = None,
                 **service_kw) -> PredictionServer:
    """Registry → service → front door, in one call."""
    service = PredictionService(ModelRegistry(models_dir), **service_kw)
    return PredictionServer(service, host, port, workers=workers,
                            max_queue=max_queue,
                            latency_budget_us=latency_budget_us)

"""Multi-uarch model registry: the artifact side of uops-as-a-service.

The paper's machine-readable output (§6.4) only pays off if downstream
consumers can *load* it without re-running the tool. The registry discovers
exported XML artifacts (one per microarchitecture, written by
``examples/export_models.py`` or any :class:`~repro.core.engine.Campaign`),
lazy-loads them on first use, and hot-reloads a uarch whose artifact changed
on disk — so a re-characterization campaign becomes visible to a running
service without a restart.

Artifacts carry the measuring machine's parameter fingerprint
(:func:`~repro.core.engine.machine_fingerprint`). For uarches whose live
definition is known (the simulated cores in ``SIM_UARCHES``), the registry
refuses to serve a model whose fingerprint no longer matches: stale models
must never answer fresh queries, mirroring the measurement-cache rule in
``model_io``.
"""
from __future__ import annotations

import threading
import warnings
from dataclasses import dataclass
from pathlib import Path

from repro.core import model_io
from repro.core.characterize import PerfModel


class ModelNotFoundError(KeyError):
    """No artifact for the requested microarchitecture."""

    def __init__(self, uarch: str, available=()):
        self.uarch = uarch
        self.available = sorted(available)
        super().__init__(f"no model artifact for {uarch!r}; "
                         f"available: {self.available}")

    def __str__(self) -> str:
        return self.args[0]

    def __reduce__(self):  # KeyError's reduce would replay the message
        return (type(self), (self.uarch, self.available))


class StaleModelError(RuntimeError):
    """Artifact fingerprint does not match the live uarch definition."""


@dataclass
class ModelHandle:
    """One loaded artifact. ``version`` bumps on every (re)load, so callers
    (e.g. the service's per-uarch predictors and result caches) can detect
    hot reloads without comparing models."""
    uarch: str
    path: Path
    model: PerfModel
    version: int
    mtime_ns: int
    size: int


def default_expected_fingerprints() -> dict:
    """Fingerprints of the live simulated-uarch definitions: an artifact
    claiming one of these names must have been measured on exactly these
    hidden parameters."""
    from repro.core.engine import machine_fingerprint  # noqa: PLC0415
    from repro.core.isa import TEST_ISA  # noqa: PLC0415
    from repro.core.simulator import SimMachine  # noqa: PLC0415
    from repro.core.uarch import SIM_UARCHES  # noqa: PLC0415

    return {name: machine_fingerprint(SimMachine(ua, TEST_ISA))
            for name, ua in SIM_UARCHES.items()}


class ModelRegistry:
    """Discover / validate / lazy-load / hot-reload exported PerfModels."""

    def __init__(self, models_dir, *, validate: bool = True,
                 expected_fingerprints: dict | None = None):
        self.models_dir = Path(models_dir)
        self.validate = validate
        self._expected = expected_fingerprints
        self._handles: dict[str, ModelHandle] = {}
        self._next_version = 1
        self._lock = threading.RLock()
        self.loads = 0
        self.hot_reloads = 0

    # -- discovery ---------------------------------------------------------
    def _path(self, uarch: str) -> Path:
        """Artifact path for a uarch: XML preferred, JSON fallback (both
        §6.4 export formats round-trip losslessly)."""
        xml = self.models_dir / f"{uarch}.xml"
        if xml.exists():
            return xml
        return self.models_dir / f"{uarch}.json"

    def discover(self) -> list[str]:
        """Microarchitectures with an XML or JSON artifact on disk."""
        if not self.models_dir.is_dir():
            return []
        return sorted({p.stem for p in self.models_dir.glob("*.xml")}
                      | {p.stem for p in self.models_dir.glob("*.json")
                         if not p.name.endswith(".meas.json")})

    def uarches(self) -> list[str]:
        return self.discover()

    # -- validation --------------------------------------------------------
    def _expected_fingerprint(self, uarch: str) -> str | None:
        if self._expected is None:
            self._expected = default_expected_fingerprints()
        return self._expected.get(uarch)

    def _check(self, uarch: str, model: PerfModel, path: Path) -> None:
        if not self.validate:
            return
        expect = self._expected_fingerprint(uarch)
        if expect is None:  # unknown uarch: nothing to validate against
            return
        if not model.fingerprint:
            warnings.warn(f"model artifact {path} carries no machine "
                          f"fingerprint; serving it unvalidated",
                          stacklevel=3)
            return
        if model.fingerprint != expect:
            raise StaleModelError(
                f"model artifact {path} was measured on a different "
                f"{uarch} definition (fingerprint {model.fingerprint[:12]}… "
                f"!= live {expect[:12]}…); re-run the characterization "
                f"campaign and re-export")

    # -- loading -----------------------------------------------------------
    def _load(self, uarch: str, path: Path, *, reload: bool) -> ModelHandle:
        st = path.stat()
        loader = (model_io.load_json if path.suffix == ".json"
                  else model_io.load_xml)
        model = loader(path.read_text())
        if model.uarch != uarch:
            raise ValueError(f"artifact {path} declares uarch "
                             f"{model.uarch!r}, expected {uarch!r}")
        self._check(uarch, model, path)
        handle = ModelHandle(uarch, path, model, self._next_version,
                             st.st_mtime_ns, st.st_size)
        self._next_version += 1
        self._handles[uarch] = handle
        self.loads += 1
        self.hot_reloads += int(reload)
        return handle

    def get(self, uarch: str) -> ModelHandle:
        """Handle for ``uarch``, loading lazily and hot-reloading if the
        artifact changed on disk since the last load."""
        with self._lock:
            path = self._path(uarch)
            if not path.exists():
                self._handles.pop(uarch, None)
                raise ModelNotFoundError(uarch, self.discover())
            handle = self._handles.get(uarch)
            if handle is None:
                return self._load(uarch, path, reload=False)
            st = path.stat()
            if (st.st_mtime_ns, st.st_size) != (handle.mtime_ns, handle.size):
                return self._load(uarch, path, reload=True)
            return handle

    def model(self, uarch: str) -> PerfModel:
        return self.get(uarch).model

    def reload(self, uarch: str | None = None) -> list[str]:
        """Force a reload of one uarch (or all discovered ones)."""
        with self._lock:
            names = [uarch] if uarch is not None else self.discover()
            out = []
            for name in names:
                path = self._path(name)
                if not path.exists():
                    raise ModelNotFoundError(name, self.discover())
                self._load(name, path, reload=name in self._handles)
                out.append(name)
            return out

    def stats(self) -> dict:
        with self._lock:
            return {
                "models_dir": str(self.models_dir),
                "discovered": self.discover(),
                "loaded": {u: h.version for u, h in self._handles.items()},
                "loads": self.loads,
                "hot_reloads": self.hot_reloads,
                "validate": self.validate,
            }

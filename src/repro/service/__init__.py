# uops-as-a-service: turn exported machine-readable models (§6.4) into a
# queryable prediction backend — a model registry over XML artifacts, a
# vectorized batch predictor (numpy or device-resident jax closed form),
# an asyncio multi-worker front door with admission control, a sharded
# result cache, a negotiated binary/JSON wire, and a client + CLI.
from repro.service.batch_predictor import BatchPredictor
from repro.service.client import (ServiceClient, ServiceError,
                                  ServiceOverloaded, ServiceUnavailable,
                                  local_service)
from repro.service.registry import (ModelNotFoundError, ModelRegistry,
                                    StaleModelError)
from repro.service.server import (AdmissionController, PredictionServer,
                                  PredictionService,
                                  ThreadedPredictionServer)

__all__ = [
    "AdmissionController", "BatchPredictor", "ModelNotFoundError",
    "ModelRegistry", "PredictionServer", "PredictionService",
    "ServiceClient", "ServiceError", "ServiceOverloaded",
    "ServiceUnavailable", "StaleModelError", "ThreadedPredictionServer",
    "local_service",
]

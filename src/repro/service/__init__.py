# uops-as-a-service: turn exported machine-readable models (§6.4) into a
# queryable prediction backend — a model registry over XML artifacts, a
# vectorized batch predictor, a threaded request server with coalescing and
# an LRU result cache, and a client + CLI.
from repro.service.batch_predictor import BatchPredictor
from repro.service.client import ServiceClient, local_service
from repro.service.registry import (ModelNotFoundError, ModelRegistry,
                                    StaleModelError)
from repro.service.server import PredictionServer, PredictionService

__all__ = [
    "BatchPredictor", "ModelNotFoundError", "ModelRegistry",
    "PredictionServer", "PredictionService", "ServiceClient",
    "StaleModelError", "local_service",
]

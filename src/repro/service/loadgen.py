"""Replayable concurrent-client load generator for the serving tier.

Drives N persistent connections against a prediction server with a
pre-encoded ``predict_batch`` request (the request bytes are built once
per connection and replayed — the generator measures the *server*, not
client-side encoding). Two arrival models:

* **closed loop** (``rate_rps=None``): each connection issues its next
  request as soon as the previous response lands — measures sustained
  capacity at a given concurrency;
* **open loop** (``rate_rps=R``): requests are launched on a global
  Poisson-free fixed schedule of R per second shared across connections,
  and latency is measured from the *scheduled* arrival time, so queueing
  delay under overload is charged to the server (no coordinated
  omission). Overloaded responses (load sheds) are counted separately
  from transport errors — a saturated server that sheds quickly still
  has a healthy p99 for the requests it admits.

Results are plain dicts ready for ``experiments/benchmarks.json`` rows.
"""
from __future__ import annotations

import threading
import time


def _percentile(vals: list, q: float) -> float:
    if not vals:
        return 0.0
    vals = sorted(vals)
    k = min(len(vals) - 1, max(0, int(round(q * (len(vals) - 1)))))
    return vals[k]


def run_load(host: str, port: int, uarch: str, blocks, *,
             wire: str = "auto", conns: int = 4, duration_s: float = 1.0,
             rate_rps: float | None = None, budget_us: float | None = None,
             decode: bool = False, timeout: float = 30.0) -> dict:
    """Drive the server and return an aggregate stats row.

    ``blocks`` is the wave each request carries (list of Instr lists or
    textual blocks). Returns requests/ok/shed/errors counts, achieved
    request and prediction rates, and p50/p99/max latency in ms."""
    from repro.service.client import ServiceClient  # noqa: PLC0415

    n_blocks = len(blocks)
    barrier = threading.Barrier(conns + 1)
    sched_lock = threading.Lock()
    next_slot = [0]
    t0 = [0.0]
    stop_at = [0.0]
    per: list[dict] = [{"ok": 0, "shed": 0, "errors": 0, "lats": []}
                       for _ in range(conns)]

    def worker(res: dict) -> None:
        try:
            client = ServiceClient(host, port, wire=wire, timeout=timeout)
            prepared = client.prepare_batch(uarch, blocks,
                                            budget_us=budget_us)
        except Exception:  # noqa: BLE001 - setup failure counts as error
            res["errors"] += 1
            barrier.wait()
            return
        barrier.wait()
        end = stop_at[0]
        while True:
            now = time.perf_counter()
            if now >= end:
                break
            if rate_rps is not None:
                with sched_lock:
                    slot = next_slot[0]
                    next_slot[0] += 1
                sched = t0[0] + slot / rate_rps
                if sched >= end:
                    break
                delay = sched - time.perf_counter()
                if delay > 0:
                    time.sleep(delay)
                lat_from = sched  # charge queueing lag to the server
            else:
                lat_from = time.perf_counter()
            try:
                ok, shed, _ = client.send_prepared(prepared, decode=decode)
            except Exception:  # noqa: BLE001 - transport failure
                res["errors"] += 1
                break
            lat = time.perf_counter() - lat_from
            if ok:
                res["ok"] += 1
                res["lats"].append(lat)
            elif shed:
                res["shed"] += 1
            else:
                res["errors"] += 1
        client.close()

    threads = [threading.Thread(target=worker, args=(per[i],), daemon=True)
               for i in range(conns)]
    for t in threads:
        t.start()
    # publish the clock BEFORE releasing the barrier: workers read
    # stop_at right after their own barrier.wait() returns
    t0[0] = time.perf_counter()
    stop_at[0] = t0[0] + duration_s
    barrier.wait()
    for t in threads:
        t.join(timeout=duration_s + 10 * timeout)
    wall = time.perf_counter() - t0[0]

    ok = sum(r["ok"] for r in per)
    shed = sum(r["shed"] for r in per)
    errors = sum(r["errors"] for r in per)
    lats = [v for r in per for v in r["lats"]]
    return {
        "wire": wire, "conns": conns, "wave": n_blocks,
        "offered_rps": rate_rps, "duration_s": round(wall, 3),
        "requests": ok + shed + errors, "ok": ok, "shed": shed,
        "errors": errors,
        "rps": round(ok / wall, 1) if wall > 0 else 0.0,
        "predictions_per_s": round(ok * n_blocks / wall, 1)
        if wall > 0 else 0.0,
        "p50_ms": round(_percentile(lats, 0.5) * 1e3, 3),
        "p99_ms": round(_percentile(lats, 0.99) * 1e3, 3),
        "max_ms": round(_percentile(lats, 1.0) * 1e3, 3),
    }

"""Vectorized batch prediction: many basic blocks, one array pass.

``core/predictor.py`` is the single-block *reference*; this module is the
throughput path behind the service. It packs every block's summed port-usage
row into one dense ``(blocks × combos)`` matrix and computes all port bounds
with a single matrix product against the model's precomputed min-cut
candidate sets (the closed form in ``core/lp.py``), instead of solving one
LP per block. Front-end bounds, per-port pressure, the latency bound, and
the bottleneck tie-break reuse the reference helpers, so the results are
bit-identical to calling :func:`repro.core.predictor.predict` per block:

* port-usage μop counts are integers (PortUsage / the XML schema), so the
  matrix product's float64 sums are exact regardless of summation order;
* the min-cut maximum over the model-wide candidate closure equals the
  maximum over each block's own closure (shrinking a candidate to the union
  of the combinations it contains only increases its ratio);
* blocks with more distinct combinations than ``CUT_COMBO_CAP`` fall back
  to the same LP on the same insertion-ordered usage dict as the reference.
"""
from __future__ import annotations

import numpy as np

from repro.obs import tracer as obs
from repro.core.characterize import PerfModel
from repro.core.isa import ISA
from repro.core.lp import CUT_COMBO_CAP, port_bound_from_usage, union_closure
from repro.core.predictor import (Prediction, UnknownInstructionError,
                                  _latency_bound, check_block,
                                  classify_bottleneck, port_pressure,
                                  sum_usage)


class BatchPredictor:
    """Precompiled predictor for one :class:`PerfModel`.

    With a ``machine`` attached (a simulated core or its measurement
    engine), the predictor also offers a *simulate-backed* mode:
    :meth:`simulate_batch` measures whole block waves on the machine —
    batched through its ``run_batch`` backend — giving the ground truth
    the analytic bounds can be judged against at workload scale."""

    def __init__(self, model: PerfModel, isa: ISA, issue_width: int = 4,
                 machine=None):
        self.model = model
        self.isa = isa
        self.issue_width = issue_width
        self.machine = machine
        # distinct port combinations across the model, in a fixed order
        combos: list[frozenset] = []
        index: dict[frozenset, int] = {}
        for im in model.instructions.values():
            if im.port_usage:
                for pc in im.port_usage.usage:
                    if pc not in index:
                        index[pc] = len(combos)
                        combos.append(pc)
        self._combos = combos
        self._combo_idx = index
        # model-wide min-cut candidates: all unions of the model's combos.
        # None => too many to enumerate; per-block closed form / LP instead.
        cand = union_closure(combos) if combos else []
        if cand:
            self._cut_mask = np.array(
                [[float(pc <= s) for pc in combos] for s in cand]).T  # C×S
            self._cut_size = np.array([float(len(s)) for s in cand])
        else:
            self._cut_mask = None
            self._cut_size = None

    # ------------------------------------------------------------------
    def predict(self, code) -> Prediction:
        return self.predict_batch([code])[0]

    def simulate_batch(self, blocks, kernel_lock=None,
                       devices=None) -> list[float]:
        """Measured steady-state cycles per block iteration, for a whole
        wave of blocks at once (Algorithm-2 differencing on the attached
        machine; the engine dedups the wave and executes the miss-set
        through the machine's batched backend — device-resident when the
        machine's backend is ``jax``/``pallas``, with warm waves skipping
        lowering via the machine's lowering cache).  ``kernel_lock``
        serializes GIL-bound kernel execution against other engines
        sharing the lock; host lowering/packing stays concurrent.

        ``devices`` (an integer count, ``"all"``, or an explicit jax
        device sequence) re-places the machine's wave execution before
        this wave — with more than one device the wave's lanes shard
        across a 1-D mesh (see :mod:`repro.core.device_mesh`), falling
        back gracefully to the single-device path when the host has fewer
        devices; results are bit-identical at every device count.
        ``None`` keeps the machine's current placement (the
        ``REPRO_SIM_DEVICES`` default)."""
        if self.machine is None:
            raise ValueError("simulate-backed mode needs a machine "
                             "(BatchPredictor(..., machine=...))")
        from repro.core.engine import Experiment, as_engine  # noqa: PLC0415

        blocks = list(blocks)
        # the span inherits the serving request's trace_id when called
        # from a traced server thread (see repro.obs.tracer)
        with obs.span("predict.simulate", blocks=len(blocks)):
            if devices is not None:
                setter = getattr(self.machine, "set_devices", None)
                if setter is not None:
                    setter(devices)
            engine = as_engine(self.machine)
            res = engine.submit([Experiment.of(b) for b in blocks],
                                kernel_lock=kernel_lock)
            return [c.cycles for c in res]

    def predict_batch(self, blocks, on_error: str = "raise") -> list:
        """Predictions for many blocks in one pass.

        ``on_error="raise"`` raises :class:`UnknownInstructionError` for the
        first block referencing uncharacterized instructions;
        ``on_error="return"`` yields the exception object in that block's
        slot instead (the service's per-request structured errors).

        Traced as a ``predict.batch`` span (inheriting the serving
        request's ``trace_id`` when reached from a traced server
        thread)."""
        codes = [list(b) for b in blocks]
        with obs.span("predict.batch", blocks=len(codes)):
            return self._predict_batch(codes, on_error)

    def _predict_batch(self, codes, on_error: str) -> list:
        errors: dict[int, UnknownInstructionError] = {}
        for i, code in enumerate(codes):
            try:
                check_block(self.model, code, self.isa)
            except UnknownInstructionError as e:
                if on_error == "raise":
                    raise
                errors[i] = e
        valid = [i for i in range(len(codes)) if i not in errors]
        # summed usage per block, in code order (reference semantics)
        sums = {i: sum_usage(self.model, codes[i]) for i in valid}
        port_bounds = self._port_bounds(sums)
        out: list = [None] * len(codes)
        for i in valid:
            usage_sum, uops = sums[i]
            fe = uops / self.issue_width
            lat = _latency_bound(self.model, self.isa, codes[i])
            pb = port_bounds[i]
            cycles = max(pb, lat, fe)
            out[i] = Prediction(cycles, pb, lat, fe,
                                port_pressure(usage_sum),
                                classify_bottleneck(cycles, pb, lat))
        for i, e in errors.items():
            out[i] = e
        return out

    # ------------------------------------------------------------------
    def _port_bounds(self, sums: dict) -> dict:
        """Port bound per block index: one matrix pass over the dense usage
        rows where the closed form applies, LP fallback elsewhere."""
        bounds: dict[int, float] = {}
        if not sums:
            return bounds
        idxs = sorted(sums)
        fast_rows: list[int] = []
        for i in idxs:
            usage_sum, _ = sums[i]
            distinct = sum(1 for n in usage_sum.values() if n > 0)
            if distinct == 0:
                bounds[i] = 0.0
            elif distinct > CUT_COMBO_CAP or self._cut_mask is None:
                # same rule + same insertion-ordered dict as the reference
                bounds[i] = port_bound_from_usage(usage_sum)
            else:
                fast_rows.append(i)
        if fast_rows:
            u = np.zeros((len(fast_rows), len(self._combos)))
            for r, i in enumerate(fast_rows):
                for pc, n in sums[i][0].items():
                    u[r, self._combo_idx[pc]] = n
            demand = u @ self._cut_mask              # rows × candidates
            ratios = demand / self._cut_size
            best = ratios.max(axis=1)
            for r, i in enumerate(fast_rows):
                bounds[i] = float(best[r])
        return bounds

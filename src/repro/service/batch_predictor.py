"""Vectorized batch prediction: many basic blocks, one array pass.

``core/predictor.py`` is the single-block *reference*; this module is the
throughput path behind the service. It packs every block's summed port-usage
row into one dense ``(blocks × combos)`` matrix and computes all port bounds
with a single matrix product against the model's precomputed min-cut
candidate sets (the closed form in ``core/lp.py``), instead of solving one
LP per block. Front-end bounds, per-port pressure, the latency bound, and
the bottleneck tie-break reuse the reference helpers, so the results are
bit-identical to calling :func:`repro.core.predictor.predict` per block:

* port-usage μop counts are integers (PortUsage / the XML schema), so the
  matrix product's float64 sums are exact regardless of summation order;
* the min-cut maximum over the model-wide candidate closure equals the
  maximum over each block's own closure (shrinking a candidate to the union
  of the combinations it contains only increases its ratio);
* blocks with more distinct combinations than ``CUT_COMBO_CAP`` fall back
  to the same LP on the same insertion-ordered usage dict as the reference.

Two vectorized backends share the same integer cut matrices
(:func:`repro.core.lp.cut_matrices`): a numpy matmul (always available) and
a device-resident jax kernel for wide waves — usage rows ship to the device
as one int32 array, ``demand = u @ mask`` runs as an integer matrix product
against the device-resident candidate masks, and the winning candidate per
block is selected with an exact integer cross-multiplication reduce; only
the final ``demand/size`` division happens in float64 on the host, so the
device path is bit-identical to the scalar reference (equal rational ratios
round to the same double). Wave shapes are bucketed with the same
quarter-octave rule as ``core/batch_sim.py`` and each bucket's kernel is
AOT-compiled once.
"""
from __future__ import annotations

import os
import threading

import numpy as np

from repro.obs import tracer as obs
from repro.core.characterize import PerfModel
from repro.core.isa import ISA
from repro.core.lp import (CUT_COMBO_CAP, cut_matrices, port_bound_from_usage,
                           union_closure)
from repro.core.predictor import (Prediction, UnknownInstructionError,
                                  _latency_bound, check_block,
                                  classify_bottleneck, port_pressure,
                                  sum_usage)

# below this many closed-form rows the host↔device round trip costs more
# than the numpy matmul saves; tuned on the bulk-wave benchmark
MIN_DEVICE_BLOCKS = 32


class BatchPredictor:
    """Precompiled predictor for one :class:`PerfModel`.

    With a ``machine`` attached (a simulated core or its measurement
    engine), the predictor also offers a *simulate-backed* mode:
    :meth:`simulate_batch` measures whole block waves on the machine —
    batched through its ``run_batch`` backend — giving the ground truth
    the analytic bounds can be judged against at workload scale."""

    def __init__(self, model: PerfModel, isa: ISA, issue_width: int = 4,
                 machine=None, *, backend: str | None = None,
                 min_device_blocks: int | None = None):
        self.model = model
        self.isa = isa
        self.issue_width = issue_width
        self.machine = machine
        # distinct port combinations across the model, in a fixed order
        combos: list[frozenset] = []
        index: dict[frozenset, int] = {}
        for im in model.instructions.values():
            if im.port_usage:
                for pc in im.port_usage.usage:
                    if pc not in index:
                        index[pc] = len(combos)
                        combos.append(pc)
        self._combos = combos
        self._combo_idx = index
        # model-wide min-cut candidates: all unions of the model's combos.
        # None => too many to enumerate; per-block closed form / LP instead.
        cand = union_closure(combos) if combos else []
        if cand:
            mask_i, size_i = cut_matrices(combos, cand)
            self._mask_i = mask_i                       # C×S int32
            self._size_i = size_i                       # S   int32
            self._cut_mask = mask_i.astype(float)       # C×S
            self._cut_size = size_i.astype(float)
        else:
            self._mask_i = self._size_i = None
            self._cut_mask = None
            self._cut_size = None
        # canonical port table (binary wire + device kernels index into it)
        self.port_names = sorted({p for pc in combos for p in pc})
        self.port_index = {p: i for i, p in enumerate(self.port_names)}
        # vectorized closed-form backend: "numpy" | "jax" | "auto"/None
        if backend is None:
            backend = os.environ.get("REPRO_PREDICT_BACKEND", "auto")
        if backend == "auto":
            try:
                import jax  # noqa: F401, PLC0415
                backend = "jax"
            except Exception:
                backend = "numpy"
        if backend not in ("numpy", "jax"):
            raise ValueError(f"unknown predict backend {backend!r}")
        self.backend = backend
        self.min_device_blocks = (MIN_DEVICE_BLOCKS if min_device_blocks
                                  is None else min_device_blocks)
        self._dev_lock = threading.Lock()
        self._dev_kernels: dict[int, object] = {}   # bucket size -> compiled
        self._dev_mask = None                       # device-resident C×S
        self._stats = {"numpy_waves": 0, "device_waves": 0,
                       "device_blocks": 0, "device_compiles": 0,
                       "device_fallbacks": 0}

    # ------------------------------------------------------------------
    def predict(self, code) -> Prediction:
        return self.predict_batch([code])[0]

    def simulate_batch(self, blocks, kernel_lock=None,
                       devices=None) -> list[float]:
        """Measured steady-state cycles per block iteration, for a whole
        wave of blocks at once (Algorithm-2 differencing on the attached
        machine; the engine dedups the wave and executes the miss-set
        through the machine's batched backend — device-resident when the
        machine's backend is ``jax``/``pallas``, with warm waves skipping
        lowering via the machine's lowering cache).  ``kernel_lock``
        serializes GIL-bound kernel execution against other engines
        sharing the lock; host lowering/packing stays concurrent.

        ``devices`` (an integer count, ``"all"``, or an explicit jax
        device sequence) re-places the machine's wave execution before
        this wave — with more than one device the wave's lanes shard
        across a 1-D mesh (see :mod:`repro.core.device_mesh`), falling
        back gracefully to the single-device path when the host has fewer
        devices; results are bit-identical at every device count.
        ``None`` keeps the machine's current placement (the
        ``REPRO_SIM_DEVICES`` default)."""
        if self.machine is None:
            raise ValueError("simulate-backed mode needs a machine "
                             "(BatchPredictor(..., machine=...))")
        from repro.core.engine import Experiment, as_engine  # noqa: PLC0415

        blocks = list(blocks)
        # the span inherits the serving request's trace_id when called
        # from a traced server thread (see repro.obs.tracer)
        with obs.span("predict.simulate", blocks=len(blocks)):
            if devices is not None:
                setter = getattr(self.machine, "set_devices", None)
                if setter is not None:
                    setter(devices)
            engine = as_engine(self.machine)
            res = engine.submit([Experiment.of(b) for b in blocks],
                                kernel_lock=kernel_lock)
            return [c.cycles for c in res]

    def predict_batch(self, blocks, on_error: str = "raise") -> list:
        """Predictions for many blocks in one pass.

        ``on_error="raise"`` raises :class:`UnknownInstructionError` for the
        first block referencing uncharacterized instructions;
        ``on_error="return"`` yields the exception object in that block's
        slot instead (the service's per-request structured errors).

        Traced as a ``predict.batch`` span (inheriting the serving
        request's ``trace_id`` when reached from a traced server
        thread)."""
        codes = [list(b) for b in blocks]
        with obs.span("predict.batch", blocks=len(codes)):
            return self._predict_batch(codes, on_error)

    def _predict_batch(self, codes, on_error: str) -> list:
        errors: dict[int, UnknownInstructionError] = {}
        for i, code in enumerate(codes):
            try:
                check_block(self.model, code, self.isa)
            except UnknownInstructionError as e:
                if on_error == "raise":
                    raise
                errors[i] = e
        valid = [i for i in range(len(codes)) if i not in errors]
        # summed usage per block, in code order (reference semantics)
        sums = {i: sum_usage(self.model, codes[i]) for i in valid}
        port_bounds = self._port_bounds(sums)
        out: list = [None] * len(codes)
        for i in valid:
            usage_sum, uops = sums[i]
            fe = uops / self.issue_width
            lat = _latency_bound(self.model, self.isa, codes[i])
            pb = port_bounds[i]
            cycles = max(pb, lat, fe)
            out[i] = Prediction(cycles, pb, lat, fe,
                                port_pressure(usage_sum),
                                classify_bottleneck(cycles, pb, lat))
        for i, e in errors.items():
            out[i] = e
        return out

    # ------------------------------------------------------------------
    def _port_bounds(self, sums: dict) -> dict:
        """Port bound per block index: one matrix pass over the dense usage
        rows where the closed form applies, LP fallback elsewhere."""
        bounds: dict[int, float] = {}
        if not sums:
            return bounds
        idxs = sorted(sums)
        fast_rows: list[int] = []
        for i in idxs:
            usage_sum, _ = sums[i]
            distinct = sum(1 for n in usage_sum.values() if n > 0)
            if distinct == 0:
                bounds[i] = 0.0
            elif distinct > CUT_COMBO_CAP or self._cut_mask is None:
                # same rule + same insertion-ordered dict as the reference
                bounds[i] = port_bound_from_usage(usage_sum)
            else:
                fast_rows.append(i)
        if fast_rows:
            best = None
            if (self.backend == "jax"
                    and len(fast_rows) >= self.min_device_blocks):
                best = self._device_bounds(sums, fast_rows)
            if best is None:
                self._stats["numpy_waves"] += 1
                u = np.zeros((len(fast_rows), len(self._combos)))
                for r, i in enumerate(fast_rows):
                    for pc, n in sums[i][0].items():
                        u[r, self._combo_idx[pc]] = n
                demand = u @ self._cut_mask          # rows × candidates
                ratios = demand / self._cut_size
                best = ratios.max(axis=1)
            for r, i in enumerate(fast_rows):
                bounds[i] = float(best[r])
        return bounds

    # ------------------------------------------------------------------
    # device-resident closed form (jax backend)
    # ------------------------------------------------------------------
    def _device_bounds(self, sums: dict, fast_rows: list):
        """All fast-row port bounds in one device call, or None to fall
        back to numpy (non-integer counts, overflow risk, jax trouble).

        The kernel is exact: int32 ``demand = u @ mask`` then an integer
        cross-multiplication argmax over candidates; the single float64
        division happens host-side, so results are bit-identical to the
        scalar reference."""
        n = len(fast_rows)
        u = np.zeros((n, len(self._combos)), dtype=np.int32)
        for r, i in enumerate(fast_rows):
            for pc, cnt in sums[i][0].items():
                v = int(cnt)
                if v != cnt:                    # non-integer μop count
                    self._stats["device_fallbacks"] += 1
                    return None
                u[r, self._combo_idx[pc]] = v
        # cross products stay well inside int32: demand ≤ row total
        if int(u.sum(axis=1).max()) * int(self._size_i.max()) >= 2 ** 31:
            self._stats["device_fallbacks"] += 1
            return None
        try:
            num, den = self._device_call(u)
        except Exception:
            self._stats["device_fallbacks"] += 1
            return None
        self._stats["device_waves"] += 1
        self._stats["device_blocks"] += n
        return num[:n].astype(np.float64) / den[:n].astype(np.float64)

    def _device_call(self, u: np.ndarray):
        from repro.core.batch_sim import _bucket  # noqa: PLC0415

        bucket = _bucket(u.shape[0], 8)
        fn = self._dev_kernels.get(bucket)
        if fn is None:
            fn = self._compile_kernel(bucket)
        if u.shape[0] != bucket:
            u = np.concatenate(
                [u, np.zeros((bucket - u.shape[0], u.shape[1]), u.dtype)])
        num, den = fn(u)
        return np.asarray(num), np.asarray(den)

    def _compile_kernel(self, bucket: int):
        """AOT-compile the port-bound kernel for one shape bucket (same
        quarter-octave buckets as ``core/batch_sim``); the candidate mask
        and sizes live on the device across calls."""
        import jax  # noqa: PLC0415
        import jax.numpy as jnp  # noqa: PLC0415

        with self._dev_lock:
            fn = self._dev_kernels.get(bucket)
            if fn is not None:
                return fn
            if self._dev_mask is None:
                self._dev_mask = jax.device_put(
                    jnp.asarray(self._mask_i, dtype=jnp.int32))
                self._dev_size = jax.device_put(
                    jnp.asarray(self._size_i, dtype=jnp.int32))
            mask_d, size_d = self._dev_mask, self._dev_size

            def port_bound_kernel(u):
                demand = u @ mask_d                     # B×S int32, exact
                den = jnp.broadcast_to(size_d, demand.shape)

                def pick(acc, x):
                    an, ad = acc
                    bn, bd = x
                    take = an * bd < bn * ad            # exact ratio compare
                    return (jnp.where(take, bn, an), jnp.where(take, bd, ad))

                num, den_w = jax.lax.reduce(
                    (demand, den),
                    (jnp.int32(0), jnp.int32(1)), pick, (1,))
                return num, den_w

            shape = jax.ShapeDtypeStruct((bucket, len(self._combos)),
                                         jnp.int32)
            with obs.span("predict.compile", bucket=bucket):
                fn = jax.jit(port_bound_kernel).lower(shape).compile()
            self._stats["device_compiles"] += 1
            self._dev_kernels[bucket] = fn
            return fn

    def backend_stats(self) -> dict:
        """Counters for the vectorized closed-form backend (wave counts,
        device compiles/fallbacks) — absorbed into service metrics."""
        return {"backend": self.backend, **self._stats}

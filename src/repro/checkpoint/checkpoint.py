"""Sharded, compressed, atomic checkpoints with async save and elastic
restore (restore onto a different data-parallel shard count).

Format: a directory ``<step>.ckpt/`` containing ``manifest.json`` plus one
compressed binary file per (leaf, chunk) — zstd when the ``zstandard``
wheel is available, zlib otherwise (the codec is recorded in the manifest,
so either writer's checkpoints restore anywhere). Leaves are chunked along
axis 0 (the FSDP/data-sharded axis), so a checkpoint written with N chunks
can be restored by M != N workers — each worker re-slices to its own shard
(elastic rescale). Writes go to ``.tmp`` and are renamed only after fsync:
a killed writer never corrupts the latest checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from pathlib import Path

import jax
import numpy as np

try:
    import zstandard as zstd
except ModuleNotFoundError:  # optional dep: fall back to stdlib zlib
    zstd = None

DEFAULT_CODEC = "zstd" if zstd is not None else "zlib"


def _compress(data: bytes, codec: str) -> bytes:
    if codec == "zstd":
        return zstd.ZstdCompressor(level=3).compress(data)
    return zlib.compress(data, 3)


def _decompress(data: bytes, codec: str) -> bytes:
    if codec == "zstd":
        if zstd is None:
            raise RuntimeError(
                "checkpoint was written with zstd but the 'zstandard' "
                "package is not installed")
        return zstd.ZstdDecompressor().decompress(data)
    return zlib.decompress(data)


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(directory, step: int, tree, *, chunks: int = 1,
                    metadata: dict | None = None) -> Path:
    """Synchronous save. ``chunks``: shards per leaf along axis 0 (leaves
    with axis0 % chunks != 0 or scalars are stored unchunked)."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"{step:08d}.ckpt"
    tmp = directory / f"{step:08d}.ckpt.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(tree)
    codec = DEFAULT_CODEC
    manifest = {"step": step, "metadata": metadata or {}, "codec": codec,
                "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        dt = str(arr.dtype) if arr.dtype != np.dtype("bfloat16") else "bfloat16"
        n_chunks = chunks if (arr.ndim > 0 and arr.shape[0] % chunks == 0
                              and arr.shape[0] >= chunks) else 1
        rec = {"index": i, "shape": list(arr.shape), "dtype": dt,
               "chunks": n_chunks, "files": []}
        for c in range(n_chunks):
            part = arr[c * arr.shape[0] // n_chunks:
                       (c + 1) * arr.shape[0] // n_chunks] if n_chunks > 1 else arr
            fname = f"leaf{i:05d}_{c:03d}.zst"
            data = _compress(part.tobytes(), codec)
            (tmp / fname).write_bytes(data)
            rec["files"].append(fname)
        manifest["leaves"].append(rec)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    fd = os.open(tmp, os.O_RDONLY)
    os.fsync(fd)
    os.close(fd)
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def restore_checkpoint(path, like_tree, *, shard_index: int = 0,
                       num_shards: int = 1):
    """Restore; with num_shards > 1 only the slice owned by this worker is
    materialized for axis-0-chunked leaves (elastic: the file chunk count
    need not match num_shards). Returns (step, tree, metadata)."""
    path = Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    codec = manifest.get("codec", "zstd")  # pre-codec checkpoints were zstd
    like_leaves, treedef = _flatten(like_tree)
    out = []
    for rec, like in zip(manifest["leaves"], like_leaves):
        dtype = (jax.numpy.bfloat16 if rec["dtype"] == "bfloat16"
                 else np.dtype(rec["dtype"]))
        parts = []
        for fname in rec["files"]:
            raw = _decompress((path / fname).read_bytes(), codec)
            parts.append(np.frombuffer(raw, dtype=dtype))
        arr = np.concatenate(parts) if len(parts) > 1 else parts[0]
        arr = arr.reshape(rec["shape"])
        if num_shards > 1 and arr.ndim > 0 and arr.shape[0] % num_shards == 0:
            n = arr.shape[0] // num_shards
            arr = arr[shard_index * n:(shard_index + 1) * n]
        out.append(jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return manifest["step"], tree, manifest["metadata"]


def latest_checkpoint(directory) -> Path | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    cands = sorted(p for p in directory.iterdir()
                   if p.suffix == ".ckpt" and p.is_dir())
    return cands[-1] if cands else None


class CheckpointManager:
    """Periodic async checkpoints with retention, for the training loop."""

    def __init__(self, directory, *, interval: int = 100, keep: int = 3,
                 chunks: int = 1):
        self.directory = Path(directory)
        self.interval = interval
        self.keep = keep
        self.chunks = chunks
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def maybe_save(self, step: int, tree, metadata=None, *,
                   force: bool = False):
        if not force and (step == 0 or step % self.interval != 0):
            return False
        self.wait()
        # snapshot to host memory on the caller's thread (device buffers may
        # be donated/overwritten by the next step)
        leaves, treedef = _flatten(tree)
        host = jax.tree_util.tree_unflatten(
            treedef, [np.asarray(l) for l in leaves])

        def work():
            try:
                save_checkpoint(self.directory, step, host,
                                chunks=self.chunks, metadata=metadata)
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        cands = sorted(p for p in self.directory.iterdir()
                       if p.suffix == ".ckpt" and p.is_dir())
        for p in cands[:-self.keep]:
            shutil.rmtree(p, ignore_errors=True)

    def restore_latest(self, like_tree, **kw):
        self.wait()
        path = latest_checkpoint(self.directory)
        if path is None:
            return None
        return restore_checkpoint(path, like_tree, **kw)

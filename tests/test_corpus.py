"""Corpus subsystem tests: seeded generation determinism, scoring math,
mega-wave evaluation, per-shard resume, and served-vs-in-process
byte-identity of the accuracy artifact on both wires."""
import json

import pytest

from repro.core import model_io
from repro.core.characterize import characterize
from repro.core.isa import TEST_ISA
from repro.corpus import (CorpusSpec, FAMILIES, client_predict_fn,
                          error_buckets, evaluate_corpus, format_report,
                          generate_blocks, generate_corpus, kendall_tau,
                          load_manifest, mape, score_results)
from repro.corpus.store import read_shard
from repro.service.client import local_service
from repro.service.protocol import parse_block

SPEC = CorpusSpec(seed=7, blocks_per_uarch=48, uarches=("sim_skl",),
                  shard_size=16, min_len=2, max_len=8)


@pytest.fixture(scope="module")
def corpus_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("corpus")
    generate_corpus(out, SPEC)
    return out


@pytest.fixture(scope="module")
def corpus_model(corpus_dir):
    """A model characterized over exactly the variants the corpus uses,
    round-tripped through XML so the in-process and served paths load the
    same artifact bits."""
    man = load_manifest(corpus_dir)
    used = sorted({ins.spec for s in man["shards"]
                   for r in read_shard(corpus_dir, s)
                   for ins in parse_block(r["block"])})
    from repro.core.simulator import SimMachine
    from repro.core.uarch import SIM_SKL

    model = characterize(SimMachine(SIM_SKL, TEST_ISA), TEST_ISA, used)
    return model_io.load_xml(model_io.to_xml(model, TEST_ISA))


# -- generation --------------------------------------------------------------

def test_generation_deterministic_and_stratified(tmp_path):
    a = generate_corpus(tmp_path / "a", SPEC)
    b = generate_corpus(tmp_path / "b", SPEC)
    assert a["corpus_id"] == b["corpus_id"]
    assert (tmp_path / "a" / "manifest.json").read_bytes() == \
        (tmp_path / "b" / "manifest.json").read_bytes()
    for sh in a["shards"]:
        assert (tmp_path / "a" / "shards" / sh["name"]).read_bytes() == \
            (tmp_path / "b" / "shards" / sh["name"]).read_bytes()
    # stratified: every family appears, counts sum to the spec
    fam_counts: dict = {}
    for sh in a["shards"]:
        for fam, n in sh["families"].items():
            fam_counts[fam] = fam_counts.get(fam, 0) + n
    assert set(fam_counts) == set(FAMILIES)
    assert sum(fam_counts.values()) == SPEC.blocks_per_uarch


def test_different_seed_different_corpus(tmp_path):
    import dataclasses
    a = generate_corpus(tmp_path / "a", SPEC)
    b = generate_corpus(tmp_path / "b", dataclasses.replace(SPEC, seed=8))
    assert a["corpus_id"] != b["corpus_id"]


def test_generated_blocks_parse_and_respect_lengths():
    for rec in generate_blocks("sim_skl", SPEC):
        code = parse_block(rec["block"])
        assert SPEC.min_len <= len(code) <= SPEC.max_len
        assert rec["family"] in FAMILIES


# -- scoring math ------------------------------------------------------------

def test_mape_hand_computed():
    # |10-8|/8 = 0.25, |5-5|/5 = 0, |3-4|/4 = 0.25 -> mean 1/6
    assert mape([10, 5, 3], [8, 5, 4]) == pytest.approx(1 / 6)
    assert mape([1, 2], [0, 2]) == 0.0  # zero-measured entries skipped


def test_kendall_tau_hand_computed():
    assert kendall_tau([1, 2, 3], [1, 2, 3]) == pytest.approx(1.0)
    assert kendall_tau([1, 2, 3], [3, 2, 1]) == pytest.approx(-1.0)
    # one discordant pair of three: tau = (2 - 1) / 3
    assert kendall_tau([1, 2, 3], [1, 3, 2]) == pytest.approx(1 / 3)
    # tie-aware (tau-b): x=[1,1,2], y=[1,2,2] -> pairs (0,1) and (1,2) are
    # ties, (0,2) concordant: nc=1, nd=0, n1=n2=1,
    # tau = 1 / sqrt((3-1)*(3-1)) = 0.5
    assert kendall_tau([1, 1, 2], [1, 2, 2]) == pytest.approx(0.5)
    # chunking must not change the result
    import random
    rng = random.Random(0)
    x = [rng.random() for _ in range(300)]
    y = [rng.random() for _ in range(300)]
    assert kendall_tau(x, y, chunk=7) == pytest.approx(
        kendall_tau(x, y, chunk=300))


def test_error_buckets_hand_computed():
    pred = [100, 103, 108, 120, 200]
    true = [100, 100, 100, 100, 100]  # rel err 0, .03, .08, .20, 1.0
    assert error_buckets(pred, true) == {
        "<1%": 1, "1-5%": 1, "5-10%": 1, "10-25%": 1, ">25%": 1}


# -- evaluation --------------------------------------------------------------

def test_evaluate_perfect_predictor_scores_zero(corpus_dir, corpus_model,
                                                tmp_path):
    """predictor == simulator -> MAPE 0, tau 1 (the e2e identity)."""
    results = evaluate_corpus(
        corpus_dir, models={"sim_skl": corpus_model},
        out_dir=tmp_path / "r", wave_width=32,
        predict_fn=lambda ua, blocks: _simulate(corpus_dir, blocks))
    rep = score_results(results)
    sc = rep["uarches"]["sim_skl"]
    assert sc["n"] == SPEC.blocks_per_uarch
    assert sc["mape"] == 0.0
    assert sc["kendall_tau"] == pytest.approx(1.0)
    assert sc["buckets"]["<1%"] == SPEC.blocks_per_uarch
    # fused mega-waves actually formed
    assert rep["wave_stats"]["max_wave_width"] >= 32
    assert "corpus" in format_report(rep)


def _simulate(corpus_dir, blocks):
    from repro.core.engine import as_engine, Experiment
    from repro.core.simulator import SimMachine
    from repro.core.uarch import SIM_SKL

    eng = as_engine(SimMachine(SIM_SKL, TEST_ISA))
    return [c.cycles for c in eng.submit([Experiment.of(b) for b in blocks])]


def test_evaluate_resume_skips_done_shards(corpus_dir, corpus_model,
                                           tmp_path):
    out = tmp_path / "r"
    a = evaluate_corpus(corpus_dir, models={"sim_skl": corpus_model},
                        out_dir=out, wave_width=32)
    b = evaluate_corpus(corpus_dir, models={"sim_skl": corpus_model},
                        out_dir=out, wave_width=32)
    assert b["wave_stats"]["waves"] == 0  # all shards resumed
    assert a["uarches"] == b["uarches"]
    ja = json.dumps(score_results(a)["uarches"], sort_keys=True)
    jb = json.dumps(score_results(b)["uarches"], sort_keys=True)
    assert ja == jb


# -- served path -------------------------------------------------------------

@pytest.mark.parametrize("wire", ["json", "binary"])
def test_served_scores_byte_identical(corpus_dir, corpus_model, tmp_path,
                                      wire):
    """The bulk predict_corpus endpoint returns byte-identical scores to
    the in-process path, on both wire protocols."""
    ref = evaluate_corpus(corpus_dir, models={"sim_skl": corpus_model},
                          out_dir=tmp_path / "ref", wave_width=32)
    ref_json = json.dumps(score_results(ref), sort_keys=True)

    models_dir = tmp_path / "models"
    models_dir.mkdir()
    (models_dir / "sim_skl.xml").write_text(
        model_io.to_xml(corpus_model, TEST_ISA))
    with local_service(models_dir, wire=wire) as client:
        assert client.wire == wire
        got = evaluate_corpus(
            corpus_dir, models={"sim_skl": corpus_model},
            out_dir=tmp_path / f"served_{wire}", wave_width=32,
            predict_fn=client_predict_fn(client, shard_size=16))
    assert json.dumps(score_results(got), sort_keys=True) == ref_json


def test_predict_corpus_summary_and_order(corpus_dir, corpus_model,
                                          tmp_path):
    models_dir = tmp_path / "models"
    models_dir.mkdir()
    (models_dir / "sim_skl.xml").write_text(
        model_io.to_xml(corpus_model, TEST_ISA))
    man = load_manifest(corpus_dir)
    shards = [[r["block"] for r in read_shard(corpus_dir, s)]
              for s in man["shards"]]
    with local_service(models_dir) as client:
        per_shard, summary = client.predict_corpus("sim_skl", shards)
    assert summary["shards"] == len(shards)
    assert summary["blocks"] == sum(len(s) for s in shards)
    assert summary["errors"] == 0 and summary["shed"] == 0
    assert len(per_shard) == len(shards)
    for envs, shard in zip(per_shard, shards):
        assert len(envs) == len(shard)
        assert all(e["ok"] for e in envs)

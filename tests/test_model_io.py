"""Machine-readable output (§6.4): XML/JSON round trips."""
import json

from repro.core import model_io
from repro.core.isa import TEST_ISA


def test_xml_roundtrip(skl_model):
    xml = model_io.to_xml(skl_model, TEST_ISA)
    m2 = model_io.load_xml(xml)
    assert m2.uarch == skl_model.uarch
    assert set(m2.instructions) == set(skl_model.instructions)
    for name, a in skl_model.instructions.items():
        b = m2[name]
        assert a.port_usage.usage == b.port_usage.usage, name
        assert abs(a.throughput.measured - b.throughput.measured) < 1e-5
        if a.throughput.computed_from_ports is not None:
            assert abs(a.throughput.computed_from_ports -
                       b.throughput.computed_from_ports) < 1e-5
        for pair, e in a.latency.entries.items():
            e2 = b.latency.entries[pair]
            assert abs(e.value - e2.value) < 1e-5, (name, pair)
            assert e.kind == e2.kind
            if e.same_reg is not None:
                assert abs(e.same_reg - e2.same_reg) < 1e-5


def test_xml_contains_operand_metadata(skl_model):
    xml = model_io.to_xml(skl_model, TEST_ISA)
    assert '<operand name="op1" type="gpr"' in xml
    assert 'implicit="1"' in xml  # flags operands
    assert "blockingInstructions" in xml


def test_json_export(skl_model):
    d = json.loads(model_io.to_json(skl_model))
    assert d["uarch"] == skl_model.uarch
    rec = d["instructions"]["ADD_R64_R64"]
    assert rec["ports"] == "1*p0156"
    assert rec["latency"]["op2->op1"]["cycles"] == 1.0


def test_blocking_table_exported(skl_model):
    xml = model_io.to_xml(skl_model, TEST_ISA)
    m2 = model_io.load_xml(xml)
    assert m2.blocking == skl_model.blocking

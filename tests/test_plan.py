"""Measurement-plan API: resumable plans driven by a wave scheduler.

Load-bearing claims: (1) driving plans through a WaveScheduler fuses many
plans' experiment batches into shared super-waves (deduped across plans by
the engine) without changing any inference result — fused and sequential
drivers are byte-identical; (2) fork fan-out preserves result order and
nests; (3) the drain-everything-then-execute round structure means no plan
starves; (4) failures cancel cleanly: a raised exception closes sibling
plans, a shared cancel event aborts a scheduler at its next wave boundary,
and a Campaign worker failure surfaces the original error instead of a
hung pool or a partial result.
"""
import threading

import pytest

from repro.core import model_io
from repro.core.blocking import blocking_plan, find_blocking_instructions
from repro.core.characterize import characterize
from repro.core.engine import Campaign, Experiment, MeasurementEngine
from repro.core.isa import TEST_ISA
from repro.core.latency import LatencyAnalyzer, LatencyPlans
from repro.core.machine import RegPool, independent_seq
from repro.core.plan import (Fork, MeasurementPlan, PlanCancelled,
                             SchedulerStats, WaveScheduler, run_plan)
from repro.core.port_usage import infer_port_usage, port_usage_plan
from repro.core.simulator import Counters, SimMachine
from repro.core.throughput import measure_throughput, throughput_plan
from repro.core.uarch import SIM_UARCHES

SUBSET = ["ADD_R64_R64", "ADC_R64_R64", "MOVQ2DQ_X_X", "MUL_R64",
          "SHLD_R64_R64_I8", "MOV_M64_R64", "DIV_R64", "AESDEC_X_X",
          "IMUL_R64_M64", "CMC"]


class StubMachine:
    """Deterministic counter source for scheduler-mechanics tests: cycles =
    sequence length, one port-0 μop per instruction."""

    def __init__(self):
        self.name = "stub"
        self.ports = ("0",)
        self.runs = 0

    def run(self, code):
        self.runs += 1
        return Counters(float(len(code)), {"0": float(len(code))})


def _exp(tag: str, k: int = 1) -> Experiment:
    """Distinct experiments per (tag, k): spec name carries the tag."""
    from repro.core.simulator import Instr
    return Experiment.of([Instr(f"T_{tag}", {"op1": f"R{i}"})
                          for i in range(k)])


def _skl():
    return SimMachine(SIM_UARCHES["sim_skl"], TEST_ISA)


# ---------------------------------------------------------------------------
# scheduler mechanics (stub machine)
# ---------------------------------------------------------------------------


def test_single_plan_yield_receives_counters_in_order():
    def gen():
        c = yield [_exp("a", 1), _exp("b", 2), _exp("a", 1)]
        assert [x.cycles for x in c] == [1.0, 2.0, 1.0]
        return "done"

    sched = WaveScheduler(MeasurementEngine(StubMachine()))
    assert sched.run([gen()]) == ["done"]
    assert sched.stats.waves == 1
    assert sched.stats.experiments == 3


def test_waves_fuse_across_plans_and_dedup_hits_engine_once():
    def gen(tag):
        c = yield [_exp(tag), _exp("shared")]
        c2 = yield [_exp(tag, 2)]
        return (c[0].cycles, c2[0].cycles)

    engine = MeasurementEngine(StubMachine())
    sched = WaveScheduler(engine)
    out = sched.run([gen("x"), gen("y"), gen("z")])
    assert out == [(1.0, 2.0)] * 3
    # both rounds fused: 3 plans x 2 batches -> 2 super-waves, not 6
    assert sched.stats.waves == 2
    assert sched.stats.experiments == 6 + 3
    # "shared" deduped across plans inside the fused wave
    assert engine.stats.dedup_hits == 2
    assert engine.stats.executions == 4 + 3


def test_fork_results_ordered_and_nested():
    def leaf(n):
        c = yield [_exp(f"leaf{n}", n)]
        return c[0].cycles

    def mid(n):
        vals = yield Fork([leaf(n), leaf(n + 1)])
        return vals

    def root():
        a, b = yield Fork([mid(1), mid(3)])
        return (a, b)

    sched = WaveScheduler(MeasurementEngine(StubMachine()))
    assert sched.run([root()]) == [([1.0, 2.0], [3.0, 4.0])]
    # all four leaves fused into one wave
    assert sched.stats.waves == 1
    assert sched.stats.plans_completed == 7  # root + 2 mids + 4 leaves


def test_empty_wave_and_empty_fork_resume_immediately():
    def gen():
        a = yield []
        b = yield Fork([])
        c = yield [_exp("x")]
        return (a, b, c[0].cycles)

    sched = WaveScheduler(MeasurementEngine(StubMachine()))
    assert sched.run([gen()]) == [([], [], 1.0)]
    assert sched.stats.waves == 1


def test_no_plan_starves_rounds_follow_the_longest_plan():
    """Every runnable plan is stepped each round: a 1-round plan and a
    5-round plan co-scheduled -> exactly 5 fused waves, and the short
    plan's result is available after round 1 (checked via completion)."""
    def short():
        yield [_exp("s")]
        return "short"

    def long():
        for i in range(5):
            yield [_exp(f"l{i}")]
        return "long"

    sched = WaveScheduler(MeasurementEngine(StubMachine()))
    assert sched.run([long(), short(), long()]) == ["long", "short", "long"]
    assert sched.stats.waves == 5


def test_cancel_event_aborts_with_plancancelled():
    ev = threading.Event()

    def gen():
        yield [_exp("a")]
        ev.set()                     # set mid-run: next round must abort
        yield [_exp("b")]
        return "never"

    closed = []

    def witness():
        try:
            for i in range(10):
                yield [_exp(f"w{i}")]
        finally:
            closed.append(True)

    sched = WaveScheduler(MeasurementEngine(StubMachine()), cancel=ev)
    with pytest.raises(PlanCancelled):
        sched.run([gen(), witness()])
    assert closed == [True], "sibling plan was not closed on cancellation"


def test_plan_exception_propagates_and_closes_siblings():
    class Boom(RuntimeError):
        pass

    def bad():
        yield [_exp("a")]
        raise Boom("plan failed")

    closed = []

    def witness():
        try:
            for i in range(10):
                yield [_exp(f"w{i}")]
        finally:
            closed.append(True)

    sched = WaveScheduler(MeasurementEngine(StubMachine()))
    with pytest.raises(Boom, match="plan failed"):
        sched.run([bad(), witness()])
    assert closed == [True]


def test_run_plan_sequential_driver_matches_scheduler():
    def gen():
        c = yield [_exp("a"), _exp("b", 2)]
        [d] = yield Fork([_sub(c[1].cycles)])
        return d

    def _sub(x):
        c = yield [_exp("s", int(x))]
        return c[0].cycles + x

    st = SchedulerStats()
    seq = run_plan(MeasurementEngine(StubMachine()), gen(), stats=st)
    fused = WaveScheduler(MeasurementEngine(StubMachine())).run([gen()])[0]
    assert seq == fused == 4.0
    assert st.waves == 2          # sequential: one wave per yield


# ---------------------------------------------------------------------------
# inference plans == legacy wrappers (real machine)
# ---------------------------------------------------------------------------


def test_scheduler_driven_plans_match_legacy_wrappers(skl_machine,
                                                     skl_blocking):
    engine = MeasurementEngine(_skl())
    sched = WaveScheduler(engine)
    lat = LatencyPlans(TEST_ISA)
    names = ["MOVQ2DQ_X_X", "SHLD_R64_R64_I8", "ADC_R64_R64"]
    plans = [blocking_plan(TEST_ISA)]
    plans += [lat.analyze_plan(n) for n in names]
    plans += [port_usage_plan(TEST_ISA[n], TEST_ISA, skl_blocking, 4,
                              n_ports=len(skl_machine.ports))
              for n in names]
    plans += [throughput_plan(TEST_ISA[n], TEST_ISA) for n in names]
    out = sched.run(plans)
    assert sched.stats.waves < len(plans), "no cross-plan fusion happened"

    blocking = out[0]
    assert blocking.instrs == find_blocking_instructions(
        skl_machine, TEST_ISA).instrs
    la = LatencyAnalyzer(_skl(), TEST_ISA)
    for i, n in enumerate(names):
        assert out[1 + i].entries == la.analyze(n).entries
        assert out[4 + i].usage == infer_port_usage(
            _skl(), TEST_ISA, n, skl_blocking, 4).usage
        ref_tp = measure_throughput(_skl(), TEST_ISA, n)
        assert out[7 + i].measured == ref_tp.measured
        assert out[7 + i].by_seq_len == ref_tp.by_seq_len


@pytest.mark.parametrize("uarch", sorted(SIM_UARCHES))
def test_characterize_fused_byte_identical_to_sequential(uarch):
    m = SimMachine(SIM_UARCHES[uarch], TEST_ISA)
    fused = characterize(MeasurementEngine(m), TEST_ISA, SUBSET)
    seq = characterize(MeasurementEngine(SimMachine(SIM_UARCHES[uarch],
                                                    TEST_ISA)),
                       TEST_ISA, SUBSET, sequential=True)
    assert model_io.to_xml(fused, TEST_ISA) == model_io.to_xml(seq, TEST_ISA)
    # the whole point: far fewer, far wider waves
    assert fused.wave_stats["waves"] < seq.wave_stats["waves"] / 4
    assert fused.wave_stats["mean_wave_width"] >= \
        5 * seq.wave_stats["mean_wave_width"]
    assert fused.wave_stats["experiments"] == seq.wave_stats["experiments"]


def test_characterize_records_phase_seconds_and_wave_stats():
    phases = {"blocking", "latency", "uops", "ports", "throughput"}
    model = characterize(MeasurementEngine(_skl()), TEST_ISA,
                         ["ADD_R64_R64", "MUL_R64"])
    assert model.phase_seconds.keys() >= phases
    assert model.wave_stats["mean_wave_width"] > 1
    assert model.engine_stats["requests"] > 0
    # the sequential reference driver records the same telemetry shape
    seq = characterize(MeasurementEngine(_skl()), TEST_ISA,
                       ["ADD_R64_R64", "MUL_R64"], sequential=True)
    assert seq.phase_seconds.keys() >= phases


def test_characterize_rejects_conflicting_driver_arguments():
    engine = MeasurementEngine(_skl())
    sched = WaveScheduler(engine)
    with pytest.raises(ValueError, match="shared scheduler"):
        characterize(engine, TEST_ISA, ["ADD_R64_R64"], scheduler=sched,
                     cancel=threading.Event())
    with pytest.raises(ValueError, match="sequential"):
        characterize(engine, TEST_ISA, ["ADD_R64_R64"], scheduler=sched,
                     sequential=True)
    with pytest.raises(ValueError, match="sequential"):
        characterize(engine, TEST_ISA, ["ADD_R64_R64"], sequential=True,
                     cancel=threading.Event())
    other = MeasurementEngine(_skl())
    with pytest.raises(ValueError, match="different engine"):
        characterize(engine, TEST_ISA, ["ADD_R64_R64"],
                     scheduler=WaveScheduler(other))


def test_shared_scheduler_wave_stats_are_per_run_deltas():
    engine = MeasurementEngine(_skl())
    sched = WaveScheduler(engine)
    m1 = characterize(engine, TEST_ISA, ["ADD_R64_R64", "MUL_R64"],
                      scheduler=sched)
    m2 = characterize(engine, TEST_ISA, ["ADC_R64_R64"], scheduler=sched)
    # the second run's stats must not include the first run's
    assert m2.wave_stats["experiments"] < m1.wave_stats["experiments"]
    assert m2.wave_stats["plans_completed"] < \
        m1.wave_stats["plans_completed"]
    assert m2.wave_stats["max_wave_width"] <= \
        m1.wave_stats["max_wave_width"]
    for m in (m1, m2):
        assert m.wave_stats["mean_wave_width"] == pytest.approx(
            m.wave_stats["experiments"] / max(1, m.wave_stats["waves"]),
            abs=0.01)


# ---------------------------------------------------------------------------
# campaign integration
# ---------------------------------------------------------------------------


def test_campaign_reports_wave_stats_per_uarch():
    machines = [SimMachine(SIM_UARCHES[n], TEST_ISA)
                for n in ("sim_skl", "sim_snb")]
    res = Campaign(instr_names=SUBSET).run(machines, TEST_ISA)
    assert set(res.wave_stats) == {"sim_skl", "sim_snb"}
    for ws in res.wave_stats.values():
        assert ws["mean_wave_width"] > 1
    assert res.mean_wave_width > 1


class FailingMachine:
    """SimMachine facade that blows up after a few waves — mid-run, so the
    campaign is genuinely in flight when the failure happens."""

    def __init__(self, fuse: int = 3):
        self._m = _skl()
        self.name = self._m.name
        self.ports = self._m.ports
        self.uarch = self._m.uarch
        self._fuse = fuse

    def run_batch(self, codes):
        self._fuse -= 1
        if self._fuse <= 0:
            raise RuntimeError("counter MSR read failed")
        return self._m.run_batch(codes)

    def run(self, code):
        return self._m.run(list(code))


def test_campaign_worker_failure_surfaces_original_error_and_cancels():
    machines = [SimMachine(SIM_UARCHES["sim_snb"], TEST_ISA),
                FailingMachine(), SimMachine(SIM_UARCHES["sim_hsw"],
                                             TEST_ISA)]
    camp = Campaign()
    with pytest.raises(RuntimeError, match="counter MSR read failed") as ei:
        camp.run(machines, TEST_ISA)
    # the original traceback (from inside the worker) is preserved
    tb_funcs = []
    tb = ei.value.__traceback__
    while tb is not None:
        tb_funcs.append(tb.tb_frame.f_code.co_name)
        tb = tb.tb_next
    assert "run_batch" in tb_funcs, \
        f"original worker traceback lost, got frames {tb_funcs}"
    # siblings were cancelled via the shared event, not left running: a
    # fresh campaign on the same (healthy) machines still works
    ok = Campaign(instr_names=["ADD_R64_R64"]).run(
        [SimMachine(SIM_UARCHES["sim_snb"], TEST_ISA)], TEST_ISA)
    assert "ADD_R64_R64" in ok.models["sim_snb"].instructions

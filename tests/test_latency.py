"""Per-operand-pair latency (§4.1, §5.2) against planted ground truths,
including every §7.3 case study."""
import pytest

from repro.core.isa import TEST_ISA
from repro.core.latency import LatencyAnalyzer


@pytest.fixture(scope="module")
def skl(skl_machine):
    return LatencyAnalyzer(skl_machine, TEST_ISA)


@pytest.fixture(scope="module")
def hsw(hsw_machine):
    return LatencyAnalyzer(hsw_machine, TEST_ISA)


@pytest.fixture(scope="module")
def snb(snb_machine):
    return LatencyAnalyzer(snb_machine, TEST_ISA)


def test_bootstrap_chain_latencies(skl):
    assert skl.lat_movsx == pytest.approx(1.0, abs=0.05)
    assert skl.lat_xor == pytest.approx(1.0, abs=0.05)
    assert skl.lat_setc == pytest.approx(1.0, abs=0.1)
    for v in skl.vec_chains.values():
        assert v == pytest.approx(1.0, abs=0.05)


def test_alu_all_pairs(skl):
    r = skl.analyze("ADD_R64_R64")
    for pair in [("op1", "op1"), ("op2", "op1"), ("op1", "flags"),
                 ("op2", "flags")]:
        assert r.get(*pair).value == pytest.approx(1.0, abs=0.05), pair


def test_aesdec_sandy_bridge(snb):
    """§7.3.1 flagship: lat(xmm1,xmm1)=8 but lat(xmm2,xmm1)=1 — invisible
    to single-scalar latency definitions."""
    r = snb.analyze("AESDEC_X_X")
    assert r.get("op1", "op1").value == pytest.approx(8.0, abs=0.1)
    assert r.get("op2", "op1").value == pytest.approx(1.0, abs=0.1)


def test_aesdec_haswell_uniform(hsw):
    """On Haswell the same instruction is 1 μop with uniform latency 7."""
    r = hsw.analyze("AESDEC_X_X")
    assert r.get("op1", "op1").value == pytest.approx(7.0, abs=0.1)
    assert r.get("op2", "op1").value == pytest.approx(7.0, abs=0.1)


def test_aesdec_memory_variant_upper_bound(snb):
    """§7.3.1: the memory variant keeps the 8-cycle reg pair; the mem->reg
    pair is reported as an upper bound well below naive load+lat sums."""
    r = snb.analyze("AESDEC_X_M")
    assert r.get("op1", "op1").value == pytest.approx(8.0, abs=0.1)
    mem = r.get("mem", "op1")
    assert mem is not None and mem.kind == "upper_bound"
    assert mem.value <= 8.0


def test_shld_skylake_same_register(skl):
    """§7.3.2: 3 cycles with distinct registers, 1 with the same register —
    explains Granlund/AIDA64 (1) vs manual/Fog (3)."""
    r = skl.analyze("SHLD_R64_R64_I8")
    assert r.get("op1", "op1").value == pytest.approx(3.0, abs=0.05)
    e = r.get("op2", "op1")
    assert e.value == pytest.approx(3.0, abs=0.05)
    assert e.same_reg == pytest.approx(1.0, abs=0.05)


def test_shld_nehalem_like_split(snb):
    """§7.3.2: lat(op1,op1)=3 (Fog's number) vs lat(op2,op1)=4 (manual's) on
    the older core — both are right, for different pairs."""
    r = snb.analyze("SHLD_R64_R64_I8")
    assert r.get("op1", "op1").value == pytest.approx(3.0, abs=0.05)
    assert r.get("op2", "op1").value == pytest.approx(4.0, abs=0.05)


def test_mul_split_destinations(skl):
    """§7.3.5 multi-latency: low result after 3 cycles, high half after 4."""
    r = skl.analyze("MUL_R64")
    assert r.get("op2", "op1").value == pytest.approx(3.0, abs=0.05)
    assert r.get("op2", "hi").value == pytest.approx(4.0, abs=0.05)


def test_flags_producer_consumer(skl):
    r = skl.analyze("ADC_R64_R64")
    assert r.get("flags", "op1").value == pytest.approx(1.0, abs=0.1)
    assert r.get("flags", "flags").value == pytest.approx(1.0, abs=0.1)
    r2 = skl.analyze("CMC")
    assert r2.get("flags", "flags").value == pytest.approx(1.0, abs=0.05)


def test_load_latency(skl, skl_machine):
    r = skl.analyze("MOV_R64_M64")
    assert r.get("mem", "op1").value == pytest.approx(
        skl_machine.uarch.load_latency, abs=0.1)


def test_load_op_compound(skl, skl_machine):
    r = skl.analyze("ADD_R64_M64")
    assert r.get("mem", "op1").value == pytest.approx(
        skl_machine.uarch.load_latency + 1, abs=0.1)
    assert r.get("op1", "op1").value == pytest.approx(1.0, abs=0.05)


def test_store_roundtrip_reports_forwarding(skl, skl_machine):
    """§5.2.4: the round trip reflects store-to-load forwarding, and is
    flagged as a round trip, not a latency."""
    r = skl.analyze("MOV_M64_R64")
    e = r.get("op1", "mem")
    assert e.kind == "roundtrip"
    assert e.value <= skl_machine.uarch.store_forward_latency + 2


def test_divider_value_dependence(skl):
    r = skl.analyze("DIV_R64")
    e = r.get("op1", "op1")
    assert e.value == pytest.approx(23.0, abs=0.2)
    assert e.high_value is not None and e.high_value > e.value


def test_cross_type_upper_bound(skl):
    r = skl.analyze("MOVD_X_R64")  # gpr -> vec
    e = r.get("op2", "op1")
    assert e.kind == "upper_bound"
    # true lat 2; composed with 2-cycle movers: min composite 4, minus 1 = 3
    assert 2.0 <= e.value <= 3.5


def test_zero_idiom_same_reg_latency(skl):
    r = skl.analyze("XOR_R64_R64")
    e = r.get("op2", "op1")
    assert e.value == pytest.approx(1.0, abs=0.05)
    assert e.same_reg == pytest.approx(0.0, abs=0.05)  # dependency broken


def test_pcmpgtq_undocumented_zero_idiom(skl):
    """§7.3.6: PCMPGT* break dependencies — same-register cycles drop to the
    port-bound floor (~1/3 for a p015 μop), far below the 1-cycle latency.
    Unlike XOR it still occupies an execution port."""
    r = skl.analyze("PCMPGTQ_X_X")
    e = r.get("op2", "op1")
    assert e.value == pytest.approx(1.0, abs=0.05)
    assert e.same_reg < 0.5


def test_max_latency(skl):
    assert skl.analyze("MUL_R64").max_latency() == 4
    assert skl.analyze("ADD_R64_R64").max_latency() == 1

"""Batched experiment engine: caching, dedup, campaigns (Algorithm 2 layer).

The load-bearing claims: (1) the content-addressed cache never changes an
inference result — characterize() with the cache enabled is byte-identical
to a cache-disabled run on every simulated uarch; (2) deduplication executes
each unique experiment exactly once, so a characterization issues zero
duplicate simulator executions; (3) caches persist through model_io and make
re-runs incremental; (4) campaigns shard across machines and report stats.
"""
import pytest

from repro.core import model_io
from repro.core.blocking import find_blocking_instructions
from repro.core.characterize import characterize
from repro.core.engine import (Campaign, Experiment, MeasurementEngine,
                               as_engine, canonical_code,
                               machine_fingerprint)
from repro.core.isa import TEST_ISA
from repro.core.machine import RegPool, independent_seq, measure
from repro.core.port_usage import infer_port_usage
from repro.core.simulator import Instr, SimMachine
from repro.core.uarch import SIM_UARCHES, random_uarch_and_isa

SUBSET = ["ADD_R64_R64", "ADC_R64_R64", "MOVQ2DQ_X_X", "MUL_R64",
          "SHLD_R64_R64_I8", "MOV_M64_R64", "DIV_R64"]


class CountingMachine:
    """Wraps a SimMachine, recording every raw run's canonical code."""

    def __init__(self, machine):
        self._m = machine
        self.name = machine.name
        self.ports = machine.ports
        self.runs = []

    def run(self, code):
        self.runs.append(canonical_code(code))
        return self._m.run(code)


def _machine(name="sim_skl"):
    return SimMachine(SIM_UARCHES[name], TEST_ISA)


# ---------------------------------------------------------------------------
# dedup / cache mechanics
# ---------------------------------------------------------------------------


def test_dedup_executes_each_unique_experiment_exactly_once():
    cm = CountingMachine(_machine())
    engine = MeasurementEngine(cm)
    seq_a = independent_seq(TEST_ISA["ADD_R64_R64"], RegPool(), 4)
    seq_b = independent_seq(TEST_ISA["IMUL_R64_R64"], RegPool(), 4)
    ea, eb = Experiment.of(seq_a), Experiment.of(seq_b)
    out = engine.submit([ea, eb, ea, ea, eb])
    assert engine.stats.requests == 5
    assert engine.stats.executions == 2        # one per unique experiment
    assert engine.stats.dedup_hits == 3
    assert engine.stats.machine_runs == 4      # 2 runs (n_small/n_large) each
    assert len(cm.runs) == 4
    # duplicates got the same measurement
    assert out[0].cycles == out[2].cycles == out[3].cycles
    assert out[1].cycles == out[4].cycles
    # a later submission of a known experiment is a cache hit, not a run
    engine.measure(ea)
    assert engine.stats.executions == 2
    assert engine.stats.cache_hits == 1
    assert len(cm.runs) == 4


def test_cached_counters_are_isolated_copies():
    engine = MeasurementEngine(_machine())
    exp = Experiment.of(independent_seq(TEST_ISA["ADD_R64_R64"],
                                        RegPool(), 2))
    c1 = engine.measure(exp)
    c1.port_uops.clear()  # a hostile caller must not corrupt the cache
    c2 = engine.measure(exp)
    assert c2.port_uops, "cache entry was mutated through a returned value"


def test_legacy_measure_path_shares_the_machine_engine():
    m = _machine()
    seq = independent_seq(TEST_ISA["ADD_R64_R64"], RegPool(), 4)
    c1 = measure(m, seq)
    c2 = measure(m, list(seq))
    engine = as_engine(m)
    assert engine.stats.executions == 1
    assert engine.stats.cache_hits == 1
    assert c1.cycles == c2.cycles


# ---------------------------------------------------------------------------
# characterize(): zero duplicate executions, cache-invariant results
# ---------------------------------------------------------------------------


def test_characterize_issues_zero_duplicate_simulator_executions():
    cm = CountingMachine(_machine())
    engine = MeasurementEngine(cm)
    characterize(engine, TEST_ISA, SUBSET)
    assert len(cm.runs) == len(set(cm.runs)), \
        "identical benchmark executed more than once at the machine level"
    # engine-counter view of the same invariant
    assert engine.stats.executions == len(engine.cache)
    assert engine.stats.machine_runs == 2 * engine.stats.executions
    assert engine.stats.cache_hits + engine.stats.dedup_hits > 0


@pytest.mark.parametrize("uarch", sorted(SIM_UARCHES))
def test_characterize_cached_byte_identical_to_uncached(uarch):
    """The cache may only ever change *when* a benchmark runs, not what the
    inference concludes: byte-identical exported models per uarch."""
    m = _machine(uarch)
    blocking = find_blocking_instructions(as_engine(m), TEST_ISA)
    cached = characterize(MeasurementEngine(m), TEST_ISA, SUBSET,
                          blocking=blocking)
    uncached = characterize(MeasurementEngine(m, enabled=False), TEST_ISA,
                            SUBSET, blocking=blocking)
    assert model_io.to_xml(cached, TEST_ISA) == \
        model_io.to_xml(uncached, TEST_ISA)


@pytest.mark.parametrize("seed", [0, 7, 1234])
def test_port_usage_cache_invariant_on_random_ground_truths(seed):
    ua, isa, truth = random_uarch_and_isa(seed)
    m = SimMachine(ua, isa)
    blocking = find_blocking_instructions(as_engine(m), isa,
                                          extensions=("BASE",))
    for name in truth:
        with_cache = infer_port_usage(MeasurementEngine(m), isa, name,
                                      blocking, max_latency=4).usage
        without = infer_port_usage(MeasurementEngine(m, enabled=False), isa,
                                   name, blocking, max_latency=4).usage
        assert with_cache == without == truth[name]


# ---------------------------------------------------------------------------
# cache-key stability
# ---------------------------------------------------------------------------


# Golden keys: Experiment.cache_key is the address of every persisted
# measurement. An accidental change to the canonicalization (operand
# ordering, hint formatting, separator choice, run-param encoding) would
# silently invalidate every on-disk cache — these constants make it loud.
GOLDEN_KEYS = [
    (lambda: Experiment.of([Instr("ADD_R64_R64",
                                  {"op1": "R0", "op2": "R1"})]),
     "280217329b7a9fccd0f54dcdc2e6056076776171b82513e68e72192200dbf6eb"),
    # operand-order independence: same key as above
    (lambda: Experiment.of([Instr("ADD_R64_R64",
                                  {"op2": "R1", "op1": "R0"})]),
     "280217329b7a9fccd0f54dcdc2e6056076776171b82513e68e72192200dbf6eb"),
    # value hint is part of the address
    (lambda: Experiment.of([Instr("DIV_R64", {"op1": "R0"}, "high")]),
     "a108431400fe6d72d07a82e1e0395078182855979d03197cbf85d571fa3a4e9a"),
    # multi-instruction sequence
    (lambda: Experiment.of([Instr("IMUL_R64_R64", {"op1": "R2",
                                                   "op2": "R3"}),
                            Instr("TEST_R64_R64", {"op1": "R4",
                                                   "op2": "R4"})]),
     "0f67b8bf24d4bc8c2460773ed583bdaf23b0b2692290e06f241357aa6bd43717"),
    # Algorithm-2 run params are part of the address
    (lambda: Experiment.of([Instr("ADD_R64_R64",
                                  {"op1": "R0", "op2": "R1"})],
                           n_small=5, n_large=55),
     "56c1d66c9089660fd2ac27dac3380809b5dc94b461beaa56a91771bc33789ad8"),
]


@pytest.mark.parametrize("make,expect",
                         GOLDEN_KEYS, ids=[f"golden{i}" for i in
                                           range(len(GOLDEN_KEYS))])
def test_cache_key_golden_values(make, expect):
    assert make().cache_key("sim_skl") == expect


def test_cache_key_depends_on_uarch():
    e = GOLDEN_KEYS[0][0]()
    assert e.cache_key("sim_hsw") == \
        "c035a4ae88d8ddaee06741943afe537983ce0ab3a332512c3a2d9fc9f6d5f646"
    assert e.cache_key("sim_hsw") != e.cache_key("sim_skl")


# ---------------------------------------------------------------------------
# persistence + campaigns
# ---------------------------------------------------------------------------


def test_persistent_cache_makes_rerun_incremental(tmp_path):
    m1 = _machine()
    e1 = MeasurementEngine(m1)
    model1 = characterize(e1, TEST_ISA, SUBSET)
    path = tmp_path / "skl.meas.json"
    model_io.save_measurement_cache(path, e1)

    m2 = _machine()
    e2 = MeasurementEngine(m2, cache=model_io.load_measurement_cache(path))
    model2 = characterize(e2, TEST_ISA, SUBSET)
    assert e2.stats.executions == 0, "warm cache still executed benchmarks"
    assert e2.stats.hit_rate == 1.0
    assert model_io.to_xml(model2, TEST_ISA) == model_io.to_xml(model1,
                                                               TEST_ISA)


def test_stale_cache_from_changed_uarch_is_not_replayed(tmp_path):
    """A persisted cache is only valid for the exact machine parameters that
    produced it: an edited uarch must re-measure, not replay."""
    m = _machine()
    e = MeasurementEngine(m)
    characterize(e, TEST_ISA, ["ADD_R64_R64"])
    path = tmp_path / "sim_skl.meas.json"
    model_io.save_measurement_cache(path, e)
    # same machine: accepted
    assert model_io.load_measurement_cache(
        path, expect_fingerprint=machine_fingerprint(m))
    # "edited" uarch (same name, different hidden tables): rejected
    changed = SimMachine(SIM_UARCHES["sim_skl"].replace(issue_width=2),
                         TEST_ISA)
    assert machine_fingerprint(changed) != machine_fingerprint(m)
    with pytest.raises(ValueError, match="fingerprint"):
        model_io.load_measurement_cache(
            path, expect_fingerprint=machine_fingerprint(changed))
    # the campaign treats the mismatch as a cold start, then re-persists
    with pytest.warns(UserWarning, match="unusable measurement cache"):
        res = Campaign(instr_names=["ADD_R64_R64"],
                       cache_dir=tmp_path).run([changed], TEST_ISA)
    assert res.stats["sim_skl"]["executions"] > 0
    assert model_io.load_measurement_cache(
        path, expect_fingerprint=machine_fingerprint(changed))


def test_campaign_treats_corrupt_cache_as_cold(tmp_path):
    (tmp_path / "sim_skl.meas.json").write_text("garbage{{{")
    with pytest.warns(UserWarning, match="unusable measurement cache"):
        res = Campaign(instr_names=["ADD_R64_R64"],
                       cache_dir=tmp_path).run([_machine()], TEST_ISA)
    assert "ADD_R64_R64" in res.models["sim_skl"].instructions
    # the save path rewrote a valid cache
    assert model_io.load_measurement_cache(tmp_path / "sim_skl.meas.json")


def test_campaign_shards_across_uarches(tmp_path):
    machines = [_machine(n) for n in ("sim_skl", "sim_snb")]
    camp = Campaign(instr_names=SUBSET, cache_dir=tmp_path)
    res = camp.run(machines, TEST_ISA)
    assert set(res.models) == {"sim_skl", "sim_snb"}
    assert res.models["sim_skl"].blocking != res.models["sim_snb"].blocking
    for name in res.models:
        assert (tmp_path / f"{name}.meas.json").exists()
        assert 0.0 <= res.stats[name]["hit_rate"] <= 1.0
        assert res.phase_seconds[name].keys() >= {"blocking", "latency",
                                                  "ports", "throughput"}
    # models match a plain single-machine characterization
    direct = characterize(MeasurementEngine(_machine("sim_snb")), TEST_ISA,
                          SUBSET)
    assert model_io.to_xml(res.models["sim_snb"], TEST_ISA) == \
        model_io.to_xml(direct, TEST_ISA)
    assert "sim_skl" in res.report()

    # second campaign from the persisted caches: pure replay
    res2 = Campaign(instr_names=SUBSET, cache_dir=tmp_path).run(
        [_machine(n) for n in ("sim_skl", "sim_snb")], TEST_ISA)
    assert res2.hit_rate == 1.0
    assert all(s["executions"] == 0 for s in res2.stats.values())


# ---------------------------------------------------------------------------
# LRU bound on the in-memory cache
# ---------------------------------------------------------------------------


def _exps(*names):
    return [Experiment.of(independent_seq(TEST_ISA[n], RegPool(), 3))
            for n in names]


def test_cache_bound_evicts_oldest_and_counts_evictions():
    engine = MeasurementEngine(_machine(), max_entries=2)
    ea, eb, ec = _exps("ADD_R64_R64", "IMUL_R64_R64", "LEA_R64")
    engine.submit([ea, eb, ec])
    assert len(engine.cache) == 2
    assert engine.stats.evictions == 1
    assert engine.stats.as_dict()["evictions"] == 1
    # the evicted (oldest) experiment re-executes; the retained ones hit
    engine.submit([ea])
    assert engine.stats.executions == 4
    engine.submit([ec])
    assert engine.stats.cache_hits == 1


def test_cache_bound_is_lru_not_fifo():
    engine = MeasurementEngine(_machine(), max_entries=2)
    ea, eb, ec = _exps("ADD_R64_R64", "IMUL_R64_R64", "LEA_R64")
    engine.submit([ea, eb])
    engine.submit([ea])       # touch: ea becomes most-recent
    engine.submit([ec])       # evicts eb, not ea
    hits0 = engine.stats.cache_hits
    engine.submit([ea])
    assert engine.stats.cache_hits == hits0 + 1
    assert engine.stats.executions == 3


def test_unbounded_cache_never_evicts():
    engine = MeasurementEngine(_machine(), max_entries=None)
    engine.submit(_exps("ADD_R64_R64", "IMUL_R64_R64", "LEA_R64",
                        "MUL_R64", "CMC"))
    assert engine.stats.evictions == 0
    assert len(engine.cache) == 5

"""Deterministic fault injection + end-to-end resilience.

The contracts under test:
  * a seeded :class:`FaultPlan` makes identical decisions on identical
    check sequences (every chaos failure replays from its spec);
  * engine waves with a poisoned experiment bisect down to it and
    quarantine it — typed records, NaN sentinels, campaign completes;
  * device/host kernel faults degrade down the backend chain with
    per-transition counters, results stay bit-identical to the oracle;
  * torn/corrupt persistence (measurement cache, corpus shards, shard
    results) is detected typed and recovered cold, never trusted;
  * wire corruption keeps framing intact: peers fail typed, never hang;
  * the service drains gracefully, reports health, and survives worker
    crashes with futures resolved, not abandoned;
  * with no plan installed, characterization output is byte-identical.
"""
import importlib
import io
import json
import math
import random
import sys
import time
import warnings

import pytest

from repro.core import model_io
from repro.core.batch_sim import BatchSimMachine
from repro.core.characterize import characterize
from repro.core.engine import (Campaign, Experiment, MeasurementEngine,
                               is_quarantined)
from repro.core.isa import TEST_ISA
from repro.core.machine import RegPool, independent_seq
from repro.core.simulator import Instr, SimMachine
from repro.core.uarch import SIM_UARCHES
from repro.corpus.evaluate import _load_resumed, _write_rows
from repro.faults import plan as fplan
from repro.faults.plan import POINTS, FaultPlan, InjectedFault
from repro.faults.tolerance import StragglerDetector
from repro.service import protocol
from repro.service.client import (ServiceClient, ServiceDraining,
                                  ServiceError, local_service)
from repro.service.server import ResilientPool, WorkerCrashed

try:
    import jax  # noqa: F401
    HAVE_JAX = True
except ImportError:
    HAVE_JAX = False


@pytest.fixture(autouse=True)
def _no_ambient_plan():
    """Each test starts with injection disabled; restore after."""
    prev = fplan.set_plan(None)
    yield
    fplan.set_plan(prev)


# ---------------------------------------------------------------------------
# plan: spec grammar, determinism, firing discipline
# ---------------------------------------------------------------------------


def test_spec_grammar_round_trip():
    p = FaultPlan.from_spec(
        "seed=42; wave.kernel:raise:p=0.25:match=AESDEC:backend=numpy; "
        "engine.cache_io:torn:max=1:after=2; wire.frame:corrupt; "
        "device.dispatch:latency:ms=5.5")
    assert p.seed == 42 and len(p.rules) == 4
    r = p.rules[0]
    assert (r.point, r.mode, r.p, r.match, r.backend) == \
        ("wave.kernel", "raise", 0.25, "AESDEC", "numpy")
    assert p.rules[1].max_fires == 1 and p.rules[1].after == 2
    assert p.rules[3].ms == 5.5


def test_spec_errors():
    with pytest.raises(ValueError, match="needs"):
        FaultPlan.from_spec("wave.kernel")
    with pytest.raises(ValueError, match="unknown fault mode"):
        FaultPlan.from_spec("wave.kernel:explode")
    with pytest.raises(ValueError, match="unknown fault option"):
        FaultPlan.from_spec("wave.kernel:raise:zap=1")
    with pytest.raises(ValueError, match="not key=value"):
        FaultPlan.from_spec("wave.kernel:raise:p")


def test_disabled_fast_path_is_noop():
    assert not fplan.active()
    fplan.check("wave.kernel", key="anything")
    fplan.check_wave("wave.kernel", ["a", "b"])
    assert fplan.filter_bytes("wire.frame", b"payload") == b"payload"
    assert fplan.get_plan() is None


def test_seeded_replay_determinism():
    def drive(plan):
        for key in ("k1", "k2", "k3", "k1"):
            try:
                plan.check("wave.kernel", key=key)
            except InjectedFault:
                pass
        return [(f.point, f.mode, f.occurrence, f.key)
                for f in plan.fired]

    spec = "seed=7;wave.kernel:raise:p=0.5"
    a, b = drive(FaultPlan.from_spec(spec)), drive(FaultPlan.from_spec(spec))
    assert a == b  # same seed, same checks -> same firings
    rep = FaultPlan.from_spec(spec)
    drive(rep)
    r = rep.report()
    assert r["seed"] == 7 and r["checks"]["wave.kernel"] == 4
    assert all(f["point"] == "wave.kernel" for f in r["fired"])


def test_filter_bytes_corrupt_and_torn_deterministic():
    data = bytes(range(200))
    c1 = FaultPlan.from_spec("seed=9;wire.frame:corrupt").filter_bytes(
        "wire.frame", data, key="x")
    c2 = FaultPlan.from_spec("seed=9;wire.frame:corrupt").filter_bytes(
        "wire.frame", data, key="x")
    assert c1 == c2 != data and len(c1) == len(data)
    assert sum(1 for a, b in zip(c1, data) if a != b) == 3  # 3 byte flips
    t = FaultPlan.from_spec("seed=9;wire.frame:torn").filter_bytes(
        "wire.frame", data, key="x")
    assert len(t) < len(data) and data.startswith(t)


def test_max_fires_caps_transients():
    p = FaultPlan.from_spec("wave.kernel:raise:max=2")
    for _ in range(2):
        with pytest.raises(InjectedFault):
            p.check("wave.kernel")
    p.check("wave.kernel")  # cap reached: transient fault is over
    assert len(p.fired) == 2


def test_latency_mode_sleeps():
    p = FaultPlan.from_spec("engine.cache_io:latency:ms=30")
    t0 = time.perf_counter()
    p.check("engine.cache_io")
    assert time.perf_counter() - t0 >= 0.02
    assert p.fired[0].mode == "latency"


def test_backend_restriction():
    p = FaultPlan.from_spec("wave.kernel:raise:backend=numpy")
    p.check("wave.kernel", key="k", backend="scalar")  # other backend: no-op
    with pytest.raises(InjectedFault):
        p.check("wave.kernel", key="k", backend="numpy")


# ---------------------------------------------------------------------------
# engine: bisecting retry, quarantine, degradation counters
# ---------------------------------------------------------------------------

EXP_NAMES = ("ADD_R64_R64", "XOR_R64_R64", "IMUL_R64_R64",
             "SHLD_R64_R64_I8")
POISON = "AESDEC_X_X"  # 'AESDEC' appears in no other experiment's key


def _machine(backend="numpy"):
    return BatchSimMachine(SIM_UARCHES["sim_skl"], TEST_ISA,
                           backend=backend, min_lanes=1)


def _experiments(poison=True):
    names = EXP_NAMES + ((POISON,) if poison else ())
    return [Experiment.of(independent_seq(TEST_ISA[n], RegPool(), 2), 4, 8)
            for n in names]


def _reference():
    return MeasurementEngine(_machine()).submit(_experiments())


def test_bisection_isolates_poisoned_experiment():
    ref = _reference()
    plan = fplan.set_plan(
        FaultPlan.from_spec(f"wave.kernel:raise:match={POISON}"))
    assert plan is None
    engine = MeasurementEngine(_machine())
    exps = _experiments()
    with pytest.warns(UserWarning, match="quarantined experiment"):
        got = engine.submit(exps)
    fplan.set_plan(None)
    # poison slot is a NaN sentinel, every other slot is bit-identical
    assert is_quarantined(got[-1]) and math.isnan(got[-1].cycles)
    for g, r in zip(got[:-1], ref[:-1]):
        assert g.cycles == r.cycles and g.port_uops == r.port_uops
    s = engine.stats
    assert s.quarantined == 1 and s.bisect_retries >= 1
    assert len(s.quarantine) == 1
    rec = s.quarantine[0]
    assert rec.uarch == "sim_skl" and POISON in rec.code
    assert "InjectedFault" in rec.error
    d = s.as_dict()
    assert d["quarantined"] == 1 and d["quarantine"][0]["uarch"] == "sim_skl"
    # the sentinel was never cached: clean slots replay from cache, the
    # poisoned one re-executes (and re-quarantines) on resubmit
    with pytest.warns(UserWarning, match="quarantined"):
        fplan.set_plan(FaultPlan.from_spec(
            f"wave.kernel:raise:match={POISON}"))
        again = engine.submit(exps)
    assert is_quarantined(again[-1])
    assert engine.stats.cache_hits >= len(exps) - 1


def test_transient_kernel_fault_recovers_without_quarantine():
    # numpy chain is numpy -> scalar: max=2 survives degradation once,
    # fails the wave, and is spent by the time bisection re-runs
    fplan.set_plan(FaultPlan.from_spec(
        f"wave.kernel:raise:match={POISON}:max=2"))
    engine = MeasurementEngine(_machine())
    with pytest.warns(UserWarning, match="degraded numpy->scalar"):
        got = engine.submit(_experiments())
    assert engine.stats.quarantined == 0
    assert engine.stats.bisect_retries >= 1
    for g, r in zip(got, _reference()):
        assert g.cycles == r.cycles and g.port_uops == r.port_uops


def test_backend_restricted_fault_degrades_not_quarantines():
    fplan.set_plan(FaultPlan.from_spec(
        f"wave.kernel:raise:match={POISON}:backend=numpy"))
    engine = MeasurementEngine(_machine())
    with pytest.warns(UserWarning, match="degraded numpy->scalar"):
        got = engine.submit(_experiments())
    s = engine.stats
    assert s.quarantined == 0
    assert s.degraded_chunks >= 1
    assert s.degraded.get("numpy->scalar", 0) >= 1
    assert s.as_dict()["degraded"] == s.degraded
    assert engine.machine.degraded_stats() == s.degraded
    for g, r in zip(got, _reference()):  # scalar oracle is the reference
        assert g.cycles == r.cycles and g.port_uops == r.port_uops


def test_pack_fault_degrades_to_scalar():
    fplan.set_plan(FaultPlan.from_spec("wave.pack:raise:max=1"))
    m = _machine()
    codes = [e.code for e in _experiments(poison=False)]
    with pytest.warns(UserWarning, match="degraded numpy->scalar"):
        got = m.run_batch([list(c) for c in codes])
    scalar = SimMachine(SIM_UARCHES["sim_skl"], TEST_ISA)
    for g, code in zip(got, codes):
        ref = scalar.run(list(code))
        assert g.cycles == ref.cycles and g.port_uops == ref.port_uops
    assert m.degraded_stats().get("numpy->scalar", 0) >= 1


@pytest.mark.skipif(not HAVE_JAX, reason="jax backend unavailable")
def test_dispatch_fault_degrades_device_to_numpy():
    fplan.set_plan(FaultPlan.from_spec("device.dispatch:raise:max=1"))
    m = _machine(backend="jax")
    codes = [e.code for e in _experiments(poison=False)]
    with pytest.warns(UserWarning, match="degraded jax->numpy"):
        got = m.run_batch([list(c) for c in codes])
    scalar = SimMachine(SIM_UARCHES["sim_skl"], TEST_ISA)
    for g, code in zip(got, codes):
        ref = scalar.run(list(code))
        assert g.cycles == ref.cycles and g.port_uops == ref.port_uops
    assert m.degraded_stats().get("jax->numpy", 0) >= 1


def test_campaign_completes_with_quarantine():
    fplan.set_plan(FaultPlan.from_spec(
        f"wave.kernel:raise:match={POISON}"))
    with pytest.warns(UserWarning, match="quarantined"):
        res = Campaign(instr_names=["ADD_R64_R64", "XOR_R64_R64",
                                    POISON]).run([_machine()], TEST_ISA)
    assert "sim_skl" in res.models  # no abort: the campaign finished
    assert res.quarantined >= 1
    recs = res.quarantine["sim_skl"]
    assert all(POISON in r["code"] for r in recs)
    assert "quarantined experiments" in res.report()


# ---------------------------------------------------------------------------
# persistence: torn measurement cache, shard results, corpus shards
# ---------------------------------------------------------------------------


def test_measurement_cache_torn_write_recovers_cold(tmp_path):
    names = ["ADD_R64_R64", "XOR_R64_R64"]
    mk = lambda: SimMachine(SIM_UARCHES["sim_skl"], TEST_ISA)  # noqa: E731
    camp = Campaign(instr_names=names, cache_dir=tmp_path)
    fplan.set_plan(FaultPlan.from_spec("engine.cache_io:torn:match=save"))
    torn = camp.run([mk()], TEST_ISA)
    fplan.set_plan(None)
    path = tmp_path / "sim_skl.meas.json"
    assert path.exists()
    with pytest.raises(ValueError):
        model_io.load_measurement_cache(path)
    # next run detects the torn cache, warns, re-measures cold -- and the
    # rewritten cache is whole again
    with pytest.warns(UserWarning, match="unusable measurement cache"):
        clean = camp.run([mk()], TEST_ISA)
    assert (model_io.to_xml(clean.models["sim_skl"], TEST_ISA)
            == model_io.to_xml(torn.models["sim_skl"], TEST_ISA))
    assert model_io.load_measurement_cache(path)


def test_measurement_cache_save_failure_is_soft(tmp_path):
    camp = Campaign(instr_names=["ADD_R64_R64"], cache_dir=tmp_path)
    fplan.set_plan(FaultPlan.from_spec("engine.cache_io:raise:match=save"))
    with pytest.warns(UserWarning, match="cache save failed"):
        res = camp.run([SimMachine(SIM_UARCHES["sim_skl"], TEST_ISA)],
                       TEST_ISA)
    assert "ADD_R64_R64" in res.models["sim_skl"].instructions
    assert not (tmp_path / "sim_skl.meas.json").exists()


def test_shard_result_write_fault_warns_and_continues(tmp_path):
    shard = {"name": "sim_skl-00000.jsonl", "sha256": "cafe"}
    rows = [{"id": 0, "family": "f", "block": "b",
             "predicted": 1.0, "measured": 1.0}]
    fplan.set_plan(FaultPlan.from_spec("corpus.shard_write:raise"))
    with pytest.warns(UserWarning, match="rows kept in memory"):
        _write_rows(tmp_path, shard, rows)
    assert _load_resumed(tmp_path, shard) is None  # cold resume
    # torn write: file lands but is rejected on resume, not trusted
    fplan.set_plan(FaultPlan.from_spec("corpus.shard_write:torn"))
    _write_rows(tmp_path, shard, rows)
    assert _load_resumed(tmp_path, shard) is None
    fplan.set_plan(None)
    _write_rows(tmp_path, shard, rows)
    assert _load_resumed(tmp_path, shard) == rows


def test_corpus_shard_corruption_detected(tmp_path):
    from repro.corpus.generate import CorpusSpec, generate_corpus
    from repro.corpus.store import load_manifest, read_shard

    spec = CorpusSpec(uarches=("sim_skl",), blocks_per_uarch=16,
                      shard_size=8, seed=5)
    fplan.set_plan(FaultPlan.from_spec("corpus.shard_write:corrupt:"
                                       "match=.jsonl"))
    generate_corpus(tmp_path, spec)
    fplan.set_plan(None)
    manifest = load_manifest(tmp_path)
    with pytest.raises(ValueError, match="does not match manifest"):
        for sh in manifest["shards"]:
            read_shard(tmp_path, sh)


# ---------------------------------------------------------------------------
# wire: corruption stays framed, peers fail typed (never hang)
# ---------------------------------------------------------------------------


def test_json_wire_corruption_is_typed():
    fplan.set_plan(FaultPlan.from_spec("seed=3;wire.frame:corrupt"))
    buf = io.BytesIO()
    protocol.send_msg(buf, {"op": "ping", "payload": "x" * 64})
    raw = buf.getvalue()
    assert raw.endswith(b"\n") and raw.count(b"\n") == 1  # framing intact
    fplan.set_plan(None)
    with pytest.raises(ValueError):
        protocol.recv_msg(io.BytesIO(raw))


def test_json_wire_torn_body_is_typed():
    fplan.set_plan(FaultPlan.from_spec("seed=3;wire.frame:torn"))
    buf = io.BytesIO()
    protocol.send_msg(buf, {"op": "stats", "pad": list(range(32))})
    fplan.set_plan(None)
    assert buf.getvalue().endswith(b"\n")
    with pytest.raises(ValueError):
        protocol.recv_msg(io.BytesIO(buf.getvalue()))


def test_binary_frame_corruption_is_typed():
    payload = protocol.pack_value({"op": "stats"})
    fplan.set_plan(FaultPlan.from_spec("seed=3;wire.frame:corrupt"))
    raw = protocol.frame(protocol.K_MSG, payload)
    fplan.set_plan(None)
    kind, got = protocol.read_frame(io.BytesIO(raw))
    assert kind == protocol.K_MSG and len(got) == len(payload)
    with pytest.raises(protocol.BinaryProtocolError):
        protocol.unpack_value(got)


# ---------------------------------------------------------------------------
# service: health, drain, worker-crash recovery (live server)
# ---------------------------------------------------------------------------

SERVICE_NAMES = ["ADD_R64_R64", "XOR_R64_R64", "IMUL_R64_R64"]
BLOCK = [Instr("ADD_R64_R64", {"op1": "R0", "op2": "R1"})]


@pytest.fixture(scope="module")
def model_dir(tmp_path_factory):
    res = Campaign(instr_names=SERVICE_NAMES).run(
        [SimMachine(SIM_UARCHES["sim_skl"], TEST_ISA)], TEST_ISA)
    out = tmp_path_factory.mktemp("fault_models")
    (out / "sim_skl.xml").write_text(
        model_io.to_xml(res.models["sim_skl"], TEST_ISA))
    return out


def test_health_op(model_dir):
    with local_service(model_dir) as client:
        h = client.health()
        assert h["status"] == "ok" and h["draining"] is False
        assert h["workers"]["alive"] == h["workers"]["configured"] > 0
        assert h["workers"]["crashed"] == 0
        assert h["queue_depth"] >= 0 and h["uptime_s"] >= 0
        assert h["registry"]


@pytest.mark.parametrize("wire", ["json", "binary"])
def test_drain_refuses_work_keeps_introspection(model_dir, wire):
    with local_service(model_dir, wire=wire) as client:
        assert client.predict("sim_skl", BLOCK)["cycles"] > 0
        d = client.drain()
        assert d["draining"] is True and d["was_draining"] is False
        with pytest.raises(ServiceDraining) as ei:
            client.predict("sim_skl", BLOCK)
        assert ei.value.error["retry_after_ms"] > 0
        with pytest.raises(ServiceDraining):
            client.predict_batch("sim_skl", [BLOCK, BLOCK])
        with pytest.raises(ServiceDraining):
            client.predict_corpus("sim_skl", [[BLOCK]])
        # introspection survives the drain; drain is idempotent
        assert client.ping()
        assert client.health()["status"] == "draining"
        assert client.stats() is not None
        assert client.drain()["was_draining"] is True


def test_binary_wire_corruption_live_server(model_dir):
    with local_service(model_dir, wire="binary") as client:
        assert client.ping()
        # corrupt exactly one frame: the client's next request; the
        # server answers a *typed* error envelope on a clean frame
        plan = FaultPlan.from_spec("seed=11;wire.frame:corrupt:max=1")
        fplan.set_plan(plan)
        with pytest.raises(ServiceError):
            client.stats()
        fplan.set_plan(None)
        assert len(plan.fired) == 1
        assert client.ping()  # connection survived, stream in sync


def test_resilient_pool_recovers_from_worker_crash():
    pool = ResilientPool(2, thread_name_prefix="t-fault")
    try:
        assert pool.submit(lambda: 42).result(timeout=5) == 42
        # a normal exception resolves the future, the thread survives
        with pytest.raises(ValueError):
            pool.submit(_raise, ValueError("boom")).result(timeout=5)
        assert pool.liveness()["crashed"] == 0
        # a BaseException kills the thread: the future resolves typed
        # and the pool replenishes
        with pytest.raises(WorkerCrashed, match="SystemExit"):
            pool.submit(_raise, SystemExit(3)).result(timeout=5)
        deadline = time.monotonic() + 5
        while time.monotonic() < deadline:
            live = pool.liveness()
            if live["alive"] == live["configured"]:
                break
            time.sleep(0.01)
        live = pool.liveness()
        assert live["alive"] == live["configured"] == 2
        assert live["crashed"] == 1
        assert pool.submit(lambda: "ok").result(timeout=5) == "ok"
    finally:
        pool.shutdown()


def _raise(exc):
    raise exc


# ---------------------------------------------------------------------------
# client: full-jitter backoff, retry_after_ms hint
# ---------------------------------------------------------------------------


def test_backoff_full_jitter_bounds(model_dir):
    with local_service(model_dir) as client:
        client._rng = random.Random(0)
        for attempt in range(5):
            seen = {client._backoff_delay(attempt) for _ in range(50)}
            hi = client.backoff_s * (2 ** attempt)
            assert all(0.0 <= d <= hi for d in seen)
            assert len(seen) > 1  # jittered, not the old fixed schedule
        # the server's hint floors the jittered delay
        assert client._backoff_delay(0, retry_after_ms=500.0) >= 0.5


def test_retry_overloaded_budget_honors_drain(model_dir):
    with local_service(model_dir) as client:
        client.drain()
        client.retry_overloaded = 2
        client.backoff_s = 0.001
        client._rng = random.Random(1)
        t0 = time.perf_counter()
        with pytest.raises(ServiceDraining):
            # retries the budget, then surfaces the drain (ping itself is
            # introspection and still answers — prediction does not)
            client.predict("sim_skl", BLOCK)
        assert time.perf_counter() - t0 >= 0.0  # returned, no hang
        assert client.ping()  # introspection never blocked


# ---------------------------------------------------------------------------
# stragglers, deprecation shim, disabled-path identity
# ---------------------------------------------------------------------------


def test_straggler_detector_flags_slow_device():
    det = StragglerDetector()
    for _ in range(5):
        det.observe("device:0", 0.10)
        det.observe("device:1", 0.10)
        det.observe("device:2", 1.00)
    snap = det.snapshot()
    assert snap["flagged"] == ["device:2"]
    assert snap["ewma_s"]["device:2"] > 2 * snap["median_s"]


def test_wave_report_surfaces_stragglers():
    from repro.analysis.wave_report import format_wave_report, wave_report

    events = []
    ts = 0.0
    for _ in range(5):
        for dev, dur in (("device:0", 100.0), ("device:1", 100.0),
                         ("device:2", 1000.0)):
            events.append({"ph": "X", "name": "wave.kernel", "ts_us": ts,
                           "dur_us": dur, "tid_name": dev})
            ts += dur
    rep = wave_report(events)
    assert rep["stragglers"]["flagged"] == ["device:2"]
    text = format_wave_report(rep)
    assert "stragglers" in text and "device:2" in text


def test_runtime_fault_tolerance_shim_warns():
    sys.modules.pop("repro.runtime.fault_tolerance", None)
    with pytest.warns(DeprecationWarning, match="repro.faults.tolerance"):
        mod = importlib.import_module("repro.runtime.fault_tolerance")
    from repro.faults import tolerance
    assert mod.StragglerDetector is tolerance.StragglerDetector
    assert mod.FleetMonitor is tolerance.FleetMonitor


def test_characterization_identical_with_armed_never_firing_plan():
    """Every injection point evaluated (p=0 rules at all points) must not
    perturb results: the XML is byte-identical to a plan-free run."""
    names = ["ADD_R64_R64", "XOR_R64_R64", "MUL_R64"]
    clean = characterize(MeasurementEngine(_machine()), TEST_ISA, names)
    spec = ";".join(f"{p}:raise:p=0" for p in POINTS) + ";" + \
        ";".join(f"{p}:corrupt:p=0" for p in POINTS)
    plan = FaultPlan.from_spec(spec)
    fplan.set_plan(plan)
    armed = characterize(MeasurementEngine(_machine()), TEST_ISA, names)
    fplan.set_plan(None)
    assert plan.occurrences() > 0     # the hooks really were traversed
    assert not plan.fired             # and none of them fired
    assert (model_io.to_xml(armed, TEST_ISA)
            == model_io.to_xml(clean, TEST_ISA))

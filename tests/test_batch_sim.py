"""Batched measurement substrate: the vectorized backend must be
bit-identical (cycles AND per-port μop counts) to the scalar oracle
``SimMachine.run`` on every uarch, every wave shape, and random hidden
ground truths — plus the compiled-table and divider-occupancy seams."""
import random

import pytest

from repro.core.batch_sim import BatchSimMachine, _body_period
from repro.core.engine import Campaign, as_engine
from repro.core.isa import TEST_ISA
from repro.core.machine import RegPool, independent_seq
from repro.core.simulator import Instr, SimMachine
from repro.core.uarch import (SIM_SKL, SIM_UARCHES, UArch, beh, make_tpu_sim,
                              random_uarch_and_isa, uop)
from repro.core.uarch_compile import UopTableIndex, compile_uarch

try:
    import hypothesis  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def assert_wave_matches(ua, isa, codes, backend="numpy"):
    scalar = SimMachine(ua, isa)
    # min_lanes=1: force every chunk through the array kernel (the default
    # routes thin chunks to the scalar oracle, which would test nothing)
    batch = BatchSimMachine(ua, isa, backend=backend, min_lanes=1)
    got = batch.run_batch(codes)
    assert len(got) == len(codes)
    for i, code in enumerate(codes):
        ref = scalar.run(list(code))
        assert got[i].cycles == ref.cycles, (i, code[:4])
        assert got[i].port_uops == ref.port_uops, (i, code[:4])


def _interesting_wave(isa):
    """Sequences exercising every special path: zero idioms (both kinds),
    move elimination, same-register variants, dividers (both value
    classes), loads/stores + forwarding, partial-register stalls, flags
    chains, NOP-likes — unrolled the way Algorithm 2 unrolls them."""
    codes = []
    for spec in ("ADD_R64_R64", "MOV_R64_R64", "XOR_R64_R64", "DIV_R64",
                 "SHLD_R64_R64_I8", "MOV_M64_R64", "AESDEC_X_X",
                 "MOVQ2DQ_X_X", "ADC_R64_R64", "MUL_R64", "PCMPGTQ_X_X",
                 "PAUSE", "ADD_R64_M64"):
        body = independent_seq(isa[spec], RegPool(), 3)
        codes.append(body * 10)
        codes.append(body * 110)
    codes += [
        [Instr("SHLD_R64_R64_I8", {"op1": "R0", "op2": "R0"})] * 30,
        [Instr("DIV_R64", {"op1": "R0", "op2": "R1"}, "high")] * 15,
        [Instr("XOR_R64_R64", {"op1": "R3", "op2": "R3"}),
         Instr("IMUL_R64_R64", {"op1": "R3", "op2": "R4"})] * 40,
        [Instr("MOV_R64_R64", {"op1": f"R{(i + 1) % 8}", "op2": f"R{i % 8}"})
         for i in range(8)] * 9,
        [Instr("SETC_R8", {"op1": "R1"}),
         Instr("ADD_R64_R64", {"op1": "R2", "op2": "R1"}),
         Instr("TEST_R64_R64", {"op1": "R2", "op2": "R2"})] * 35,
        [Instr("MOV_M64_R64", {"mem": "RB0", "op1": "R1"}),
         Instr("MOV_R64_M64", {"op1": "R1", "mem": "RB0"})] * 20,
    ]
    return codes


@pytest.mark.parametrize("uarch", sorted(SIM_UARCHES))
def test_batch_identical_to_scalar_on_sim_uarches(uarch):
    ua = SIM_UARCHES[uarch]
    assert_wave_matches(ua, TEST_ISA, _interesting_wave(TEST_ISA))


def test_batch_identical_on_tpu_unit_model():
    ua, isa, truth = make_tpu_sim()
    names = list(truth)
    codes = [[Instr(names[(i + j) % len(names)],
                    {"op1": f"R{j % 4}", "op2": f"R{(j + 1) % 4}"})
              for j in range(4)] * reps for i, reps in
             enumerate((1, 10, 30, 110))]
    assert_wave_matches(ua, isa, codes)


def _random_wave(ua_seed, wave_seed, n_codes=8):
    ua, isa, truth = random_uarch_and_isa(ua_seed)
    rng = random.Random(wave_seed)
    names = list(truth)
    codes = []
    for _ in range(n_codes):
        body = [Instr(rng.choice(names),
                      {"op1": f"R{rng.randint(0, 5)}",
                       "op2": f"R{rng.randint(0, 5)}"})
                for _ in range(rng.randint(1, 5))]
        codes.append(body * rng.choice([1, 3, 10, 37, 110]))
    return ua, isa, codes


@pytest.mark.parametrize("seed", range(6))
def test_batch_identical_on_random_ground_truths(seed):
    """Seeded fallback for the hypothesis property below — always runs."""
    ua, isa, codes = _random_wave(seed, seed + 100)
    assert_wave_matches(ua, isa, codes)


if HAVE_HYPOTHESIS:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @given(ua_seed=st.integers(0, 500), wave_seed=st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def test_batch_identical_property(ua_seed, wave_seed):
        """For ANY hidden ground truth and ANY wave, the array program and
        the scalar interpreter agree bit-for-bit."""
        ua, isa, codes = _random_wave(ua_seed, wave_seed, n_codes=4)
        assert_wave_matches(ua, isa, codes)


# ---------------------------------------------------------------------------
# wave-shape edge cases
# ---------------------------------------------------------------------------


def test_empty_wave():
    assert BatchSimMachine(SIM_SKL, TEST_ISA).run_batch([]) == []


def test_empty_and_single_instruction_sequences():
    codes = [[],                                              # empty code
             [Instr("ADD_R64_R64", {"op1": "R0", "op2": "R1"})],  # single
             [Instr("NOP", {})] * 50]                         # 0-μop body
    assert_wave_matches(SIM_SKL, TEST_ISA, codes)


def test_ragged_wave_lengths():
    body = independent_seq(TEST_ISA["IMUL_R64_R64"], RegPool(), 4)
    codes = [body * 1, body * 37, [], body * 110,
             [Instr("ADD_R64_R64", {"op1": "R0", "op2": "R1"})], body * 10]
    assert_wave_matches(SIM_SKL, TEST_ISA, codes)


def test_jax_backend_matches_when_available():
    pytest.importorskip("jax")
    body = independent_seq(TEST_ISA["ADD_R64_R64"], RegPool(), 3)
    codes = [body * 4, body * 11,
             [Instr("DIV_R64", {"op1": "R0", "op2": "R1"}, "high")] * 6,
             []]
    assert_wave_matches(SIM_SKL, TEST_ISA, codes, backend="jax")


def test_unknown_instruction_raises_keyerror_like_scalar():
    b = BatchSimMachine(SIM_SKL, TEST_ISA)
    with pytest.raises(KeyError):
        b.run_batch([[Instr("NO_SUCH_INSTR", {})] * 4])


def test_wide_port_machine_counts_exact():
    """A uarch with more than 16 ports: the kernel's packed dispatch key
    must keep port counts and tie-breaks exact (regression: the port
    axis once shared bit space with the μop counts)."""
    from repro.core.isa import GPR, ISA, InstrSpec, op
    ports = tuple(f"p{i:02d}" for i in range(18))
    b = {"WADD": beh(uop(frozenset(ports), ("op2",), ("op1",)))}
    ua = UArch("sim_wide", ports, 8, b, overhead_cycles=0)
    isa = ISA([InstrSpec("WADD", "WADD",
                         (op("op1", GPR, "w"), op("op2", GPR, "r")))])
    codes = [[Instr("WADD", {"op1": f"R{i}", "op2": f"R{i + 20}"})
              for i in range(20)] * reps for reps in (1, 5, 11)]
    assert_wave_matches(ua, isa, codes)


def test_body_period_detection():
    a = [Instr("ADD_R64_R64", {"op1": "R0", "op2": "R1"}) for _ in range(3)]
    assert _body_period([id(x) for x in a * 40]) == 3
    assert _body_period([id(x) for x in a]) == 3  # distinct objects
    assert _body_period([id(a[0])] * 7) == 1


# ---------------------------------------------------------------------------
# machine-level protocol: SimMachine routes waves to the batched backend
# ---------------------------------------------------------------------------


def test_simmachine_run_batch_matches_scalar_loop():
    m = SimMachine(SIM_SKL, TEST_ISA)
    codes = _interesting_wave(TEST_ISA)[:10]
    got = m.run_batch(codes)
    for c, code in zip(got, codes):
        ref = m.run(list(code))
        assert c.cycles == ref.cycles and c.port_uops == ref.port_uops


# ---------------------------------------------------------------------------
# satellite: divider-occupancy gate (occ includes the value-dependent extra)
# ---------------------------------------------------------------------------


def _slow_div_uarch():
    """A divider-like μop with *occupancy 1* whose value-dependent extra
    must still block the port: the old ``u.occupancy > 1`` gate dropped
    the blocking entirely for this shape."""
    b = {"SDIV_R64_R64": beh(
        uop(frozenset("0"), ("op2",), ("op1",), lat=5, occ=1),
        divider_extra=10),
        "LEA_R64": beh(uop(frozenset("1"), ("op2",), ("op1",)))}
    return UArch("sim_slowdiv", tuple("01"), 4, b, overhead_cycles=0)


def _sdiv_isa():
    from repro.core.isa import GPR, ISA, InstrSpec, op
    isa = ISA()
    isa.add(InstrSpec("SDIV_R64_R64", "SDIV",
                      (op("op1", GPR, "w"), op("op2", GPR, "r")),
                      uses_divider=True))
    isa.add(InstrSpec("LEA_R64", "LEA",
                      (op("op1", GPR, "w"), op("op2", GPR, "r"))))
    return isa


def test_high_value_divide_occupies_port_on_single_occupancy_uop():
    ua, isa = _slow_div_uarch(), _sdiv_isa()
    m = SimMachine(ua, isa)
    # two independent high-value divides on the same port: the second must
    # wait out the first's effective occupancy (1 + 10), then lat 5 + 10
    hi = [Instr("SDIV_R64_R64", {"op1": "R0", "op2": "R1"}, "high"),
          Instr("SDIV_R64_R64", {"op1": "R2", "op2": "R3"}, "high")]
    assert m.run(hi).cycles == 11 + 15
    # low values: fully pipelined, second dispatches one cycle later
    lo = [Instr("SDIV_R64_R64", {"op1": "R0", "op2": "R1"}),
          Instr("SDIV_R64_R64", {"op1": "R2", "op2": "R3"})]
    assert m.run(lo).cycles == 1 + 5
    # and the batched backend agrees on the whole regression wave
    assert_wave_matches(ua, isa, [hi * 12, lo * 12, hi * 110])


# ---------------------------------------------------------------------------
# compiled tables: round-trip + campaign-wide sharing
# ---------------------------------------------------------------------------


def test_compiled_tables_round_trip_behaviors():
    comp = compile_uarch(SIM_SKL, TEST_ISA)
    index = comp.index
    assert comp.ports == tuple(sorted(SIM_SKL.ports))
    for name, behavior in SIM_SKL.behaviors.items():
        i = index.idx[name]
        off, cnt = comp.behavior_rows(i, same_reg=False)
        assert cnt == len(behavior.uops)
        for j, u in enumerate(behavior.uops):
            row = off + j
            mask = {p for b, p in enumerate(comp.ports)
                    if comp.port_mask[row] >> b & 1}
            assert mask == set(u.ports)
            assert comp.latency[row] == u.latency
            assert comp.occupancy[row] == u.occupancy
            reads = [comp.decode_slot(i, s) for s in comp.reads[row]
                     if s >= 0]
            writes = [comp.decode_slot(i, s) for s in comp.writes[row]
                      if s >= 0]
            assert tuple(reads) == u.reads
            assert tuple(writes) == u.writes
        assert comp.elim_period[i] == behavior.elim_period
        assert comp.divider_extra[i] == behavior.divider_extra
        if behavior.same_reg is not None:
            sr_off, sr_cnt = comp.behavior_rows(i, same_reg=True)
            assert sr_cnt == len(behavior.same_reg.uops)


def test_campaign_shares_one_table_index_across_uarches():
    machines = [SimMachine(ua, TEST_ISA) for ua in SIM_UARCHES.values()]
    Campaign(instr_names=["ADD_R64_R64"]).run(machines, TEST_ISA)
    indexes = {id(m._table_index) for m in machines}
    assert len(indexes) == 1 and None not in {m._table_index
                                              for m in machines}
    # the shared index drives each machine's compiled tables
    comps = [compile_uarch(m.uarch, TEST_ISA, m._table_index)
             for m in machines]
    assert all(c.index is comps[0].index for c in comps)
    assert all(c.index.names == comps[0].index.names for c in comps)


def test_engine_submits_waves_through_run_batch():
    """The measurement engine's miss-set reaches the machine as ONE wave
    (not a per-experiment loop) when the machine speaks the protocol."""
    from repro.core.engine import Experiment, MeasurementEngine

    class WaveRecorder:
        name = "sim_skl"
        counters_available = True

        def __init__(self):
            self._m = SimMachine(SIM_SKL, TEST_ISA)
            self.waves = []

        def run_batch(self, codes):
            self.waves.append(len(codes))
            return self._m.run_batch(codes)

    rec = WaveRecorder()
    eng = MeasurementEngine(rec)
    exps = [Experiment.of(independent_seq(TEST_ISA[n], RegPool(), 3))
            for n in ("ADD_R64_R64", "IMUL_R64_R64", "LEA_R64")]
    eng.submit(exps + exps)   # duplicates dedup away
    assert rec.waves == [6]   # 3 unique experiments x (n_small, n_large)


def test_legacy_measure_results_unchanged_by_batch_default():
    """measure() through the engine equals a hand-rolled scalar
    Algorithm-2 differencing."""
    from repro.core.machine import measure

    seq = independent_seq(TEST_ISA["ADC_R64_R64"], RegPool(), 4)
    m = SimMachine(SIM_SKL, TEST_ISA)
    got = measure(m, seq)
    s = SimMachine(SIM_SKL, TEST_ISA)
    c1, c2 = s.run(seq * 10), s.run(seq * 110)
    assert got.cycles == (c2.cycles - c1.cycles) / 100
    assert as_engine(m).stats.executions == 1

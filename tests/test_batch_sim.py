"""Batched measurement substrate: the vectorized backend must be
bit-identical (cycles AND per-port μop counts) to the scalar oracle
``SimMachine.run`` on every uarch, every wave shape, and random hidden
ground truths — plus the compiled-table and divider-occupancy seams."""
import random

import pytest

from repro.core.batch_sim import BatchSimMachine, _body_period
from repro.core.engine import Campaign, as_engine
from repro.core.isa import TEST_ISA
from repro.core.machine import RegPool, independent_seq
from repro.core.simulator import Instr, SimMachine
from repro.core.uarch import (SIM_SKL, SIM_UARCHES, UArch, beh, make_tpu_sim,
                              random_uarch_and_isa, uop)
from repro.core.uarch_compile import UopTableIndex, compile_uarch

try:
    import hypothesis  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def assert_wave_matches(ua, isa, codes, backend="numpy"):
    scalar = SimMachine(ua, isa)
    # min_lanes=1: force every chunk through the array kernel (the default
    # routes thin chunks to the scalar oracle, which would test nothing)
    batch = BatchSimMachine(ua, isa, backend=backend, min_lanes=1)
    got = batch.run_batch(codes)
    assert len(got) == len(codes)
    for i, code in enumerate(codes):
        ref = scalar.run(list(code))
        assert got[i].cycles == ref.cycles, (i, code[:4])
        assert got[i].port_uops == ref.port_uops, (i, code[:4])


def _interesting_wave(isa):
    """Sequences exercising every special path: zero idioms (both kinds),
    move elimination, same-register variants, dividers (both value
    classes), loads/stores + forwarding, partial-register stalls, flags
    chains, NOP-likes — unrolled the way Algorithm 2 unrolls them."""
    codes = []
    for spec in ("ADD_R64_R64", "MOV_R64_R64", "XOR_R64_R64", "DIV_R64",
                 "SHLD_R64_R64_I8", "MOV_M64_R64", "AESDEC_X_X",
                 "MOVQ2DQ_X_X", "ADC_R64_R64", "MUL_R64", "PCMPGTQ_X_X",
                 "PAUSE", "ADD_R64_M64"):
        body = independent_seq(isa[spec], RegPool(), 3)
        codes.append(body * 10)
        codes.append(body * 110)
    codes += [
        [Instr("SHLD_R64_R64_I8", {"op1": "R0", "op2": "R0"})] * 30,
        [Instr("DIV_R64", {"op1": "R0", "op2": "R1"}, "high")] * 15,
        [Instr("XOR_R64_R64", {"op1": "R3", "op2": "R3"}),
         Instr("IMUL_R64_R64", {"op1": "R3", "op2": "R4"})] * 40,
        [Instr("MOV_R64_R64", {"op1": f"R{(i + 1) % 8}", "op2": f"R{i % 8}"})
         for i in range(8)] * 9,
        [Instr("SETC_R8", {"op1": "R1"}),
         Instr("ADD_R64_R64", {"op1": "R2", "op2": "R1"}),
         Instr("TEST_R64_R64", {"op1": "R2", "op2": "R2"})] * 35,
        [Instr("MOV_M64_R64", {"mem": "RB0", "op1": "R1"}),
         Instr("MOV_R64_M64", {"op1": "R1", "mem": "RB0"})] * 20,
    ]
    return codes


@pytest.mark.parametrize("uarch", sorted(SIM_UARCHES))
def test_batch_identical_to_scalar_on_sim_uarches(uarch):
    ua = SIM_UARCHES[uarch]
    assert_wave_matches(ua, TEST_ISA, _interesting_wave(TEST_ISA))


def test_batch_identical_on_tpu_unit_model():
    ua, isa, truth = make_tpu_sim()
    names = list(truth)
    codes = [[Instr(names[(i + j) % len(names)],
                    {"op1": f"R{j % 4}", "op2": f"R{(j + 1) % 4}"})
              for j in range(4)] * reps for i, reps in
             enumerate((1, 10, 30, 110))]
    assert_wave_matches(ua, isa, codes)


def _random_wave(ua_seed, wave_seed, n_codes=8):
    ua, isa, truth = random_uarch_and_isa(ua_seed)
    rng = random.Random(wave_seed)
    names = list(truth)
    codes = []
    for _ in range(n_codes):
        body = [Instr(rng.choice(names),
                      {"op1": f"R{rng.randint(0, 5)}",
                       "op2": f"R{rng.randint(0, 5)}"})
                for _ in range(rng.randint(1, 5))]
        codes.append(body * rng.choice([1, 3, 10, 37, 110]))
    return ua, isa, codes


@pytest.mark.parametrize("seed", range(6))
def test_batch_identical_on_random_ground_truths(seed):
    """Seeded fallback for the hypothesis property below — always runs."""
    ua, isa, codes = _random_wave(seed, seed + 100)
    assert_wave_matches(ua, isa, codes)


if HAVE_HYPOTHESIS:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @given(ua_seed=st.integers(0, 500), wave_seed=st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def test_batch_identical_property(ua_seed, wave_seed):
        """For ANY hidden ground truth and ANY wave, the array program and
        the scalar interpreter agree bit-for-bit."""
        ua, isa, codes = _random_wave(ua_seed, wave_seed, n_codes=4)
        assert_wave_matches(ua, isa, codes)


# ---------------------------------------------------------------------------
# wave-shape edge cases
# ---------------------------------------------------------------------------


def test_empty_wave():
    assert BatchSimMachine(SIM_SKL, TEST_ISA).run_batch([]) == []


def test_empty_and_single_instruction_sequences():
    codes = [[],                                              # empty code
             [Instr("ADD_R64_R64", {"op1": "R0", "op2": "R1"})],  # single
             [Instr("NOP", {})] * 50]                         # 0-μop body
    assert_wave_matches(SIM_SKL, TEST_ISA, codes)


def test_ragged_wave_lengths():
    body = independent_seq(TEST_ISA["IMUL_R64_R64"], RegPool(), 4)
    codes = [body * 1, body * 37, [], body * 110,
             [Instr("ADD_R64_R64", {"op1": "R0", "op2": "R1"})], body * 10]
    assert_wave_matches(SIM_SKL, TEST_ISA, codes)


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_device_backends_match_when_available(backend):
    pytest.importorskip("jax")
    body = independent_seq(TEST_ISA["ADD_R64_R64"], RegPool(), 3)
    codes = [body * 4, body * 11,
             [Instr("DIV_R64", {"op1": "R0", "op2": "R1"}, "high")] * 6,
             []]
    assert_wave_matches(SIM_SKL, TEST_ISA, codes, backend=backend)


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_device_backends_on_interesting_wave(backend):
    pytest.importorskip("jax")
    assert_wave_matches(SIM_SKL, TEST_ISA, _interesting_wave(TEST_ISA),
                        backend=backend)


def test_unknown_instruction_raises_keyerror_like_scalar():
    b = BatchSimMachine(SIM_SKL, TEST_ISA)
    with pytest.raises(KeyError):
        b.run_batch([[Instr("NO_SUCH_INSTR", {})] * 4])


def test_wide_port_machine_counts_exact():
    """A uarch with more than 16 ports: the kernel's packed dispatch key
    must keep port counts and tie-breaks exact (regression: the port
    axis once shared bit space with the μop counts)."""
    from repro.core.isa import GPR, ISA, InstrSpec, op
    ports = tuple(f"p{i:02d}" for i in range(18))
    b = {"WADD": beh(uop(frozenset(ports), ("op2",), ("op1",)))}
    ua = UArch("sim_wide", ports, 8, b, overhead_cycles=0)
    isa = ISA([InstrSpec("WADD", "WADD",
                         (op("op1", GPR, "w"), op("op2", GPR, "r")))])
    codes = [[Instr("WADD", {"op1": f"R{i}", "op2": f"R{i + 20}"})
              for i in range(20)] * reps for reps in (1, 5, 11)]
    assert_wave_matches(ua, isa, codes)


def test_body_period_detection():
    a = [Instr("ADD_R64_R64", {"op1": "R0", "op2": "R1"}) for _ in range(3)]
    assert _body_period([id(x) for x in a * 40]) == 3
    assert _body_period([id(x) for x in a]) == 3  # distinct objects
    assert _body_period([id(a[0])] * 7) == 1


# ---------------------------------------------------------------------------
# satellite: dispatch tie-break equivalence at port-count boundaries
# ---------------------------------------------------------------------------


def _tie_wave(ports, isa_ports=None):
    """A wave engineered so several ports repeatedly tie on *both*
    dispatch time and cumulative μop count: independent single-μop
    instructions whose port mask spans many ports, plus a narrower mask
    sharing a boundary port, issued wider than the port set so counts
    wrap around and re-equalize."""
    from repro.core.isa import GPR, ISA, InstrSpec, op
    wide_mask = frozenset(ports)
    narrow_mask = frozenset(list(sorted(ports))[:2])
    b = {"TIEW": beh(uop(wide_mask, ("op2",), ("op1",))),
         "TIEN": beh(uop(narrow_mask, ("op2",), ("op1",)))}
    ua = UArch("sim_tie", tuple(ports), 8, b, overhead_cycles=0)
    isa = ISA([InstrSpec("TIEW", "TIEW",
                         (op("op1", GPR, "w"), op("op2", GPR, "r"))),
               InstrSpec("TIEN", "TIEN",
                         (op("op1", GPR, "w"), op("op2", GPR, "r")))])
    codes = []
    for reps in (1, 3, 11):
        codes.append([Instr("TIEW", {"op1": f"R{i}", "op2": f"R{i + 32}"})
                      for i in range(3 * len(ports))] * reps)
        codes.append([Instr(("TIEW", "TIEN")[i % 2],
                            {"op1": f"R{i}", "op2": f"R{i + 40}"})
                      for i in range(2 * len(ports))] * reps)
    return ua, isa, codes


@pytest.mark.parametrize("backend", ["numpy", "jax", "pallas"])
def test_tie_break_equivalence_at_port_count_boundaries(backend):
    """The numpy kernel breaks dispatch ties with one packed
    (time, count, port) argmin key; the device kernels use a two-pass min.
    On waves where several ports tie on both time and count, every backend
    must pick the same port as the scalar oracle — checked on the widest
    SIM_UARCHES port set and on an 18-port machine (so the port axis
    exceeds 16 and the packed key's field widths are exercised)."""
    if backend != "numpy":
        pytest.importorskip("jax")
    widest = max(SIM_UARCHES.values(), key=lambda u: len(u.ports))
    ua, isa, codes = _tie_wave(sorted(widest.ports))
    assert_wave_matches(ua, isa, codes, backend=backend)
    ua18, isa18, codes18 = _tie_wave([f"p{i:02d}" for i in range(18)])
    assert_wave_matches(ua18, isa18, codes18, backend=backend)


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_backends_agree_on_wide_port_machine(backend):
    pytest.importorskip("jax")
    from repro.core.isa import GPR, ISA, InstrSpec, op
    ports = tuple(f"p{i:02d}" for i in range(18))
    b = {"WADD": beh(uop(frozenset(ports), ("op2",), ("op1",)))}
    ua = UArch("sim_wide", ports, 8, b, overhead_cycles=0)
    isa = ISA([InstrSpec("WADD", "WADD",
                         (op("op1", GPR, "w"), op("op2", GPR, "r")))])
    codes = [[Instr("WADD", {"op1": f"R{i}", "op2": f"R{i + 20}"})
              for i in range(20)] * reps for reps in (1, 5, 11)]
    assert_wave_matches(ua, isa, codes, backend=backend)


# ---------------------------------------------------------------------------
# satellite: lowering cache (hits/misses in engine_stats, eviction bound,
# bit-identical warm re-runs)
# ---------------------------------------------------------------------------


def _random_codes(seed, n_bodies=6):
    rng = random.Random(seed)
    names = ["ADD_R64_R64", "IMUL_R64_R64", "SHLD_R64_R64_I8",
             "MOV_R64_M64", "ADC_R64_R64", "DIV_R64"]
    codes = []
    for _ in range(n_bodies):
        body = independent_seq(TEST_ISA[rng.choice(names)], RegPool(),
                               rng.randint(2, 6))
        codes.append(body * 10)
        codes.append(body * 110)
    return codes


def test_lowering_cache_warm_wave_is_bit_identical():
    m = BatchSimMachine(SIM_SKL, TEST_ISA, min_lanes=1)
    rng = random.Random(7)
    names = ["ADD_R64_R64", "IMUL_R64_R64", "SHLD_R64_R64_I8",
             "MOV_R64_M64", "ADC_R64_R64", "DIV_R64"]
    bodies = [independent_seq(TEST_ISA[rng.choice(names)], RegPool(),
                              rng.randint(2, 6)) for _ in range(6)]
    codes = [b * n for b in bodies for n in (10, 110)]
    cold = m.run_batch(codes)
    assert m.lowering_stats["misses"] > 0
    misses0 = m.lowering_stats["misses"]
    # fresh Instr objects (a new wave of content-identical Experiments,
    # unrolled body * n the way the engine does): lowering is skipped
    bodies2 = [[Instr(i.spec, dict(i.regs), i.value_hint) for i in b]
               for b in bodies]
    codes2 = [b * n for b in bodies2 for n in (10, 110)]
    warm = m.run_batch(codes2)
    assert m.lowering_stats["misses"] == misses0
    assert m.lowering_stats["hits"] >= misses0
    for a, b in zip(cold, warm):
        assert a.cycles == b.cycles and a.port_uops == b.port_uops


def test_lowering_cache_hits_when_engine_misses_on_params():
    """The ISSUE scenario: two Experiments share a body but differ in
    Algorithm-2 params — the engine cache misses (different key) but the
    machine's lowering cache hits, and the counters surface through
    engine_stats."""
    from repro.core.engine import Experiment, MeasurementEngine

    m = SimMachine(SIM_SKL, TEST_ISA, min_lanes=1)
    eng = MeasurementEngine(m)
    bodies = [tuple(independent_seq(TEST_ISA[n], RegPool(), 4))
              for n in ("IMUL_R64_R64", "ADC_R64_R64", "SHLD_R64_R64_I8")]
    eng.submit([Experiment.of(b) for b in bodies])
    s = eng.stats.as_dict()
    assert s["lowering_misses"] > 0
    # same bodies, different unroll params: engine miss, lowering hit on
    # the already-lowered n=110 cut (n=30 is a new prefix cut)
    eng.submit([Experiment.of(b, n_small=30, n_large=110)
                for b in bodies])
    s2 = eng.stats.as_dict()
    assert s2["executions"] == 6          # engine cache missed on params
    assert s2["lowering_hits"] > s["lowering_hits"]
    assert s2["lowering_misses"] <= s["lowering_misses"] + len(bodies)


def test_lowering_counters_are_per_engine_deltas():
    """A fresh engine on a warm (reused) machine must report only its own
    share of the backend's lowering work — not the machine's lifetime
    totals, which include prior engines' campaigns."""
    from repro.core.engine import Experiment, MeasurementEngine

    m = SimMachine(SIM_SKL, TEST_ISA, min_lanes=1)
    bodies = [tuple(independent_seq(TEST_ISA[n], RegPool(), 4))
              for n in ("IMUL_R64_R64", "ADC_R64_R64")]
    eng1 = MeasurementEngine(m)
    eng1.submit([Experiment.of(b) for b in bodies])
    assert eng1.stats.lowering_misses == m.lowering_stats["misses"] > 0
    eng2 = MeasurementEngine(m)         # fresh engine, warm machine
    eng2.submit([Experiment.of(b) for b in bodies])
    # identical wave: every lowering probe hits, so THIS engine's miss
    # count is zero even though the machine's totals are not
    assert eng2.stats.lowering_misses == 0
    assert eng2.stats.lowering_hits > 0
    assert m.lowering_stats["misses"] == eng1.stats.lowering_misses


def test_lowering_deltas_survive_backend_rebuild():
    """``set_table_index`` rebuilds the machine's batched backend, whose
    counters restart at zero; a previously attached engine must
    re-baseline (the stats dict identity changed) instead of reporting
    negative lowering deltas against its stale snapshot."""
    from repro.core.engine import Experiment, MeasurementEngine

    m = SimMachine(SIM_SKL, TEST_ISA, min_lanes=1)
    eng = MeasurementEngine(m)
    bodies = [tuple(independent_seq(TEST_ISA[n], RegPool(), 4))
              for n in ("IMUL_R64_R64", "ADC_R64_R64")]
    eng.submit([Experiment.of(b) for b in bodies])
    assert eng.stats.lowering_misses > 0
    m.set_table_index(UopTableIndex.for_isa(TEST_ISA))   # resets backend
    eng.submit([Experiment.of(b, n_small=20) for b in bodies])
    assert eng.stats.lowering_misses >= 0
    assert eng.stats.lowering_hits >= 0


def test_lowering_cache_eviction_bound():
    m = BatchSimMachine(SIM_SKL, TEST_ISA, min_lanes=1,
                        lower_cache_entries=3)
    codes = _random_codes(11, n_bodies=5)   # 10 (body, cut) entries
    ref = [SimMachine(SIM_SKL, TEST_ISA).run(list(c)) for c in codes]
    got = m.run_batch(codes)
    assert len(m._lower_cache) <= 3
    assert m.lowering_stats["evictions"] > 0
    for a, b in zip(ref, got):
        assert a.cycles == b.cycles and a.port_uops == b.port_uops
    # a second pass still returns correct results (some entries evicted)
    got2 = m.run_batch(codes)
    for a, b in zip(ref, got2):
        assert a.cycles == b.cycles and a.port_uops == b.port_uops


# ---------------------------------------------------------------------------
# satellite: min_lanes is a constructor parameter on both machines
# ---------------------------------------------------------------------------


def test_min_lanes_constructor_parameter():
    codes = _random_codes(3, n_bodies=2)
    ref = [SimMachine(SIM_SKL, TEST_ISA).run(list(c)) for c in codes]
    for m in (SimMachine(SIM_SKL, TEST_ISA, min_lanes=1),
              SimMachine(SIM_SKL, TEST_ISA, min_lanes=10 ** 6),
              BatchSimMachine(SIM_SKL, TEST_ISA, min_lanes=1),
              BatchSimMachine(SIM_SKL, TEST_ISA, min_lanes=10 ** 6)):
        got = m.run_batch(codes)
        for a, b in zip(ref, got):
            assert a.cycles == b.cycles and a.port_uops == b.port_uops
    forced = BatchSimMachine(SIM_SKL, TEST_ISA, min_lanes=10 ** 6)
    forced.run_batch(codes)
    assert forced._scalar is not None       # everything went scalar
    kerneled = BatchSimMachine(SIM_SKL, TEST_ISA, min_lanes=1)
    kerneled.run_batch(codes)
    assert kerneled._scalar is None         # everything took the kernel
    # SimMachine forwards its min_lanes to the lazily-built backend
    sm = SimMachine(SIM_SKL, TEST_ISA, min_lanes=5)
    sm.run_batch(codes)
    assert sm._batch.min_lanes == 5


# ---------------------------------------------------------------------------
# satellite/tentpole: device-kernel compile accounting (one per bucket)
# ---------------------------------------------------------------------------


def test_jax_kernel_compiles_at_most_once_per_bucket():
    pytest.importorskip("jax")
    codes = _random_codes(19)
    m = BatchSimMachine(SIM_SKL, TEST_ISA, backend="jax", min_lanes=1)
    m.run_batch(codes)
    st = m.device_stats()
    assert st["compiles"] <= len(st["buckets"])
    compiles0 = st["compiles"]
    calls0 = st["kernel_calls"]
    m.run_batch(codes)                       # warm: same shape buckets
    st2 = m.device_stats()
    assert st2["compiles"] == compiles0, "warm wave recompiled a kernel"
    assert st2["kernel_calls"] > calls0
    # a fresh machine over the same shapes shares the module-wide cache
    m2 = BatchSimMachine(SIM_SKL, TEST_ISA, backend="jax", min_lanes=1)
    m2.run_batch(codes)
    assert m2.device_stats()["compiles"] == 0


def test_backend_env_selection(monkeypatch):
    pytest.importorskip("jax")
    monkeypatch.setenv("REPRO_SIM_BACKEND", "jax")
    m = SimMachine(SIM_SKL, TEST_ISA)
    codes = _random_codes(23, n_bodies=3)
    got = m.run_batch(codes)
    assert m._batch.backend == "jax"
    ref = [SimMachine(SIM_SKL, TEST_ISA, backend="numpy").run(list(c))
           for c in codes]
    for a, b in zip(ref, got):
        assert a.cycles == b.cycles and a.port_uops == b.port_uops


# ---------------------------------------------------------------------------
# tentpole: the kernel lock serializes kernels, not host prep
# ---------------------------------------------------------------------------


class _CountingLock:
    def __init__(self):
        import threading
        self._lock = threading.Lock()
        self.entries = 0

    def __enter__(self):
        self._lock.acquire()
        self.entries += 1
        return self

    def __exit__(self, *exc):
        self._lock.release()
        return False


def test_kernel_lock_reaches_the_machine():
    from repro.core.engine import Experiment, MeasurementEngine

    # numpy explicitly: only the GIL-bound kernels take the campaign
    # kernel lock — device backends serialize dispatch on their own
    # per-device-subset locks instead (see core/device_mesh.py)
    m = SimMachine(SIM_SKL, TEST_ISA, backend="numpy", min_lanes=1)
    eng = MeasurementEngine(m)
    lock = _CountingLock()
    exps = [Experiment.of(independent_seq(TEST_ISA[n], RegPool(), 3))
            for n in ("ADD_R64_R64", "IMUL_R64_R64", "LEA_R64",
                      "ADC_R64_R64")]
    res = eng.submit(exps, kernel_lock=lock)
    assert lock.entries > 0
    ref = eng.submit(exps)   # cached now; also: results sane without lock
    for a, b in zip(res, ref):
        assert a.cycles == b.cycles and a.port_uops == b.port_uops


def test_scheduler_execute_lock_travels_as_kernel_lock():
    from repro.core.engine import Experiment, MeasurementEngine
    from repro.core.plan import WaveScheduler

    # numpy explicitly: the execute lock only serializes GIL-bound kernels
    m = SimMachine(SIM_SKL, TEST_ISA, backend="numpy", min_lanes=1)
    lock = _CountingLock()
    sched = WaveScheduler(MeasurementEngine(m), execute_lock=lock)

    def plan():
        c = yield [Experiment.of(independent_seq(
            TEST_ISA["ADD_R64_R64"], RegPool(), 4))]
        return c[0].cycles

    out = sched.run([plan(), plan()])
    assert out[0] == out[1] > 0
    assert lock.entries > 0


def test_legacy_run_batch_without_kernel_lock_param_still_works():
    """Machines predating the kernel-lock protocol run entirely under the
    lock (machine_run_batch introspects the signature)."""
    from repro.core.engine import machine_run_batch

    class OldMachine:
        name = "sim_skl"

        def __init__(self):
            self._m = SimMachine(SIM_SKL, TEST_ISA)

        def run_batch(self, codes):
            return self._m.run_batch(codes)

    lock = _CountingLock()
    codes = _random_codes(5, n_bodies=2)
    got = machine_run_batch(OldMachine(), codes, kernel_lock=lock)
    assert lock.entries == 1
    ref = [SimMachine(SIM_SKL, TEST_ISA).run(list(c)) for c in codes]
    for a, b in zip(ref, got):
        assert a.cycles == b.cycles and a.port_uops == b.port_uops


# ---------------------------------------------------------------------------
# machine-level protocol: SimMachine routes waves to the batched backend
# ---------------------------------------------------------------------------


def test_simmachine_run_batch_matches_scalar_loop():
    m = SimMachine(SIM_SKL, TEST_ISA)
    codes = _interesting_wave(TEST_ISA)[:10]
    got = m.run_batch(codes)
    for c, code in zip(got, codes):
        ref = m.run(list(code))
        assert c.cycles == ref.cycles and c.port_uops == ref.port_uops


# ---------------------------------------------------------------------------
# satellite: divider-occupancy gate (occ includes the value-dependent extra)
# ---------------------------------------------------------------------------


def _slow_div_uarch():
    """A divider-like μop with *occupancy 1* whose value-dependent extra
    must still block the port: the old ``u.occupancy > 1`` gate dropped
    the blocking entirely for this shape."""
    b = {"SDIV_R64_R64": beh(
        uop(frozenset("0"), ("op2",), ("op1",), lat=5, occ=1),
        divider_extra=10),
        "LEA_R64": beh(uop(frozenset("1"), ("op2",), ("op1",)))}
    return UArch("sim_slowdiv", tuple("01"), 4, b, overhead_cycles=0)


def _sdiv_isa():
    from repro.core.isa import GPR, ISA, InstrSpec, op
    isa = ISA()
    isa.add(InstrSpec("SDIV_R64_R64", "SDIV",
                      (op("op1", GPR, "w"), op("op2", GPR, "r")),
                      uses_divider=True))
    isa.add(InstrSpec("LEA_R64", "LEA",
                      (op("op1", GPR, "w"), op("op2", GPR, "r"))))
    return isa


def test_high_value_divide_occupies_port_on_single_occupancy_uop():
    ua, isa = _slow_div_uarch(), _sdiv_isa()
    m = SimMachine(ua, isa)
    # two independent high-value divides on the same port: the second must
    # wait out the first's effective occupancy (1 + 10), then lat 5 + 10
    hi = [Instr("SDIV_R64_R64", {"op1": "R0", "op2": "R1"}, "high"),
          Instr("SDIV_R64_R64", {"op1": "R2", "op2": "R3"}, "high")]
    assert m.run(hi).cycles == 11 + 15
    # low values: fully pipelined, second dispatches one cycle later
    lo = [Instr("SDIV_R64_R64", {"op1": "R0", "op2": "R1"}),
          Instr("SDIV_R64_R64", {"op1": "R2", "op2": "R3"})]
    assert m.run(lo).cycles == 1 + 5
    # and the batched backend agrees on the whole regression wave
    assert_wave_matches(ua, isa, [hi * 12, lo * 12, hi * 110])


# ---------------------------------------------------------------------------
# compiled tables: round-trip + campaign-wide sharing
# ---------------------------------------------------------------------------


def test_compiled_tables_round_trip_behaviors():
    comp = compile_uarch(SIM_SKL, TEST_ISA)
    index = comp.index
    assert comp.ports == tuple(sorted(SIM_SKL.ports))
    for name, behavior in SIM_SKL.behaviors.items():
        i = index.idx[name]
        off, cnt = comp.behavior_rows(i, same_reg=False)
        assert cnt == len(behavior.uops)
        for j, u in enumerate(behavior.uops):
            row = off + j
            mask = {p for b, p in enumerate(comp.ports)
                    if comp.port_mask[row] >> b & 1}
            assert mask == set(u.ports)
            assert comp.latency[row] == u.latency
            assert comp.occupancy[row] == u.occupancy
            reads = [comp.decode_slot(i, s) for s in comp.reads[row]
                     if s >= 0]
            writes = [comp.decode_slot(i, s) for s in comp.writes[row]
                      if s >= 0]
            assert tuple(reads) == u.reads
            assert tuple(writes) == u.writes
        assert comp.elim_period[i] == behavior.elim_period
        assert comp.divider_extra[i] == behavior.divider_extra
        if behavior.same_reg is not None:
            sr_off, sr_cnt = comp.behavior_rows(i, same_reg=True)
            assert sr_cnt == len(behavior.same_reg.uops)


def test_campaign_shares_one_table_index_across_uarches():
    machines = [SimMachine(ua, TEST_ISA) for ua in SIM_UARCHES.values()]
    Campaign(instr_names=["ADD_R64_R64"]).run(machines, TEST_ISA)
    indexes = {id(m._table_index) for m in machines}
    assert len(indexes) == 1 and None not in {m._table_index
                                              for m in machines}
    # the shared index drives each machine's compiled tables
    comps = [compile_uarch(m.uarch, TEST_ISA, m._table_index)
             for m in machines]
    assert all(c.index is comps[0].index for c in comps)
    assert all(c.index.names == comps[0].index.names for c in comps)


def test_engine_submits_waves_through_run_batch():
    """The measurement engine's miss-set reaches the machine as ONE wave
    (not a per-experiment loop) when the machine speaks the protocol."""
    from repro.core.engine import Experiment, MeasurementEngine

    class WaveRecorder:
        name = "sim_skl"
        counters_available = True

        def __init__(self):
            self._m = SimMachine(SIM_SKL, TEST_ISA)
            self.waves = []

        def run_batch(self, codes):
            self.waves.append(len(codes))
            return self._m.run_batch(codes)

    rec = WaveRecorder()
    eng = MeasurementEngine(rec)
    exps = [Experiment.of(independent_seq(TEST_ISA[n], RegPool(), 3))
            for n in ("ADD_R64_R64", "IMUL_R64_R64", "LEA_R64")]
    eng.submit(exps + exps)   # duplicates dedup away
    assert rec.waves == [6]   # 3 unique experiments x (n_small, n_large)


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_sharded_chunk_with_uniform_lengths(backend):
    """Regression: two lane shards of one chunk map to the SAME shape
    bucket when every sequence has the same length — the shards must not
    share a packing-buffer slot (the second pack would overwrite the
    first's inputs before either kernel dispatches)."""
    pytest.importorskip("jax")
    from repro.core.batch_sim import _DeviceExec
    rng = random.Random(41)
    lanes = 2 * _DeviceExec._SHARD_MIN_LANES
    names = ["ADD_R64_R64", "IMUL_R64_R64", "ADC_R64_R64", "MULPS_X_X"]
    codes = [independent_seq(TEST_ISA[rng.choice(names)], RegPool(), 4) * 12
             for _ in range(lanes)]
    assert_wave_matches(SIM_SKL, TEST_ISA, codes, backend=backend)


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_all_zero_uop_shard(backend):
    """Regression: a lane shard whose programs all lower to zero μops
    (zero-idiom bodies) must fill in overhead-only Counters instead of
    crashing extraction — the all-empty guard has to run per shard, not
    just per chunk."""
    pytest.importorskip("jax")
    from repro.core.batch_sim import _DeviceExec
    lanes = _DeviceExec._SHARD_MIN_LANES
    adds = [[Instr("ADD_R64_R64", {"op1": f"R{i % 8}",
                                   "op2": f"R{i % 8 + 8}"})] * 8
            for i in range(lanes)]
    zeros = [[Instr("XOR_R64_R64", {"op1": f"R{i % 8}",
                                    "op2": f"R{i % 8}"})] * 8
             for i in range(lanes)]
    assert_wave_matches(SIM_SKL, TEST_ISA, adds + zeros, backend=backend)


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_buffer_reuse_with_narrower_read_width(backend):
    """Regression: a reused device buffer whose previous occupant had a
    wider per-μop read width (max_r) must not leak stale producer columns
    into a later lane with a narrower width at the same rows — the
    kernels read ALL R producer columns of every valid row."""
    pytest.importorskip("jax")
    m = BatchSimMachine(SIM_SKL, TEST_ISA, backend=backend, min_lanes=1)
    scalar = SimMachine(SIM_SKL, TEST_ISA)
    # wave A: partial-register-stall pairs — the ADD's second producer
    # column carries a nonzero stall *delta*, which the kernels add
    # unconditionally (not gated on producer >= 0)
    wave_a = [[Instr("SETC_R8", {"op1": f"R{i + 1}"}),
               Instr("ADD_R64_R64",
                     {"op1": f"R{i + 8}", "op2": f"R{i + 1}"})] * 24
              for i in range(6)]   # 48 rows — BSWAP below is 2 μops/instr
    # wave B: identical (S, E, R) bucket — one two-read lane keeps the R
    # bucket at 2 — but the other lanes are fully independent single-read
    # BSWAPs (max_r 1) whose rows overlap wave A's stale delta column; a
    # leaked stall delta inflates their ready times above the real
    # issue-limited critical path
    wave_b = wave_a[:1] + \
             [[Instr("BSWAP_R64", {"op1": f"Q{lane}_{j}"})
               for j in range(24)] for lane in range(5)]
    for wave in (wave_a, wave_b, wave_b):   # later passes reuse slots
        got = m.run_batch(wave)
        for c, code in zip(got, wave):
            ref = scalar.run(list(code))
            assert c.cycles == ref.cycles and c.port_uops == ref.port_uops


def test_device_slot_leased_until_extraction():
    """Regression: a packing-buffer slot must stay leased until its
    chunk's results have been *extracted* (``release()`` in
    ``_finalize_device``), not merely until its kernel future resolves —
    ``_extract`` reads ``pk.vis``, which aliases the slot's vis buffer,
    so freeing the slot at dispatch let a fast same-bucket chunk k+1
    re-zero it mid-extraction and corrupt chunk k's cycle counts."""
    pytest.importorskip("jax")
    from repro.core.batch_sim import _DeviceExec

    m = BatchSimMachine(SIM_SKL, TEST_ISA, backend="jax", min_lanes=1)
    dev = _DeviceExec(m._comp, "jax")
    s1 = dev.acquire(8, 8, 1)
    # with no kernel in flight at all (the state a resolved future used
    # to leave behind), a leased slot must never be handed out again
    s2 = dev.acquire(8, 8, 1)
    assert s2 is not s1
    assert s1.leased and s2.leased
    s1.release()                        # extraction completed
    assert dev.acquire(8, 8, 1) is s1   # only now is the slot reusable


def test_kernel_failure_releases_slots(monkeypatch):
    """A device kernel failure must not leak leased buffer slots: the
    error path waits out in-flight shard kernels and releases every
    slot — and the wave then *recovers* by degrading down the backend
    chain (jax -> numpy), with bit-identical results and the reroute
    counted in ``degraded_stats()``."""
    pytest.importorskip("jax")
    import repro.core.batch_sim as bs

    m = BatchSimMachine(SIM_SKL, TEST_ISA, backend="jax", min_lanes=1)
    codes = _random_codes(12, n_bodies=4)
    real = bs._run_kernel
    calls = []

    def boom(fn, args):
        calls.append(1)
        raise RuntimeError("transient kernel failure")

    monkeypatch.setattr(bs, "_run_kernel", boom)
    ref = [SimMachine(SIM_SKL, TEST_ISA).run(list(c)) for c in codes]
    with pytest.warns(UserWarning, match="degraded jax->numpy"):
        got = m.run_batch(codes)     # degrades to numpy, does not raise
    assert calls
    for ring in m._device._rings.values():
        assert all(not s.leased for s in ring)
    assert m.degraded_stats().get("jax->numpy", 0) >= 1
    for a, b in zip(ref, got):
        assert a.cycles == b.cycles and a.port_uops == b.port_uops
    # with the kernel healthy again the device path serves the next wave
    monkeypatch.setattr(bs, "_run_kernel", real)
    got2 = m.run_batch(codes)
    for a, b in zip(ref, got2):
        assert a.cycles == b.cycles and a.port_uops == b.port_uops


def test_simmachine_degenerate_wave_respects_min_lanes():
    body = independent_seq(TEST_ISA["ADD_R64_R64"], RegPool(), 3)
    m = SimMachine(SIM_SKL, TEST_ISA, min_lanes=1)
    got = m.run_batch([body * 10, body * 110])   # 2 codes: < 4, >= min
    assert m._batch is not None                   # kernel path was taken
    ref = SimMachine(SIM_SKL, TEST_ISA)
    for c, code in zip(got, (body * 10, body * 110)):
        r = ref.run(list(code))
        assert c.cycles == r.cycles and c.port_uops == r.port_uops
    plain = SimMachine(SIM_SKL, TEST_ISA)
    plain.run_batch([body * 10, body * 110])
    assert plain._batch is None                   # default still scalar


def test_characterize_xml_identical_across_backends():
    """End-to-end: a characterization driven through the device backends
    exports byte-identical model XML to the numpy backend (the whole
    pipeline — scheduler fusion, engine cache, lowering cache, bucketed
    kernels, pipelined dispatch — preserves every measured number)."""
    pytest.importorskip("jax")
    from repro.core import model_io
    from repro.core.characterize import characterize
    from repro.core.engine import MeasurementEngine

    names = ["ADD_R64_R64", "MOVQ2DQ_X_X", "DIV_R64", "SHLD_R64_R64_I8",
             "MUL_R64", "AESDEC_X_X"]
    ref = characterize(
        MeasurementEngine(SimMachine(SIM_SKL, TEST_ISA, backend="numpy")),
        TEST_ISA, names)
    ref_xml = model_io.to_xml(ref, TEST_ISA)
    for backend in ("jax", "pallas"):
        m = SimMachine(SIM_SKL, TEST_ISA, backend=backend)
        model = characterize(MeasurementEngine(m), TEST_ISA, names)
        assert model_io.to_xml(model, TEST_ISA) == ref_xml, backend
        assert m.lowering_stats["misses"] > 0
        assert m.device_stats()["compiles"] <= \
            len(m.device_stats()["buckets"])


def test_campaign_runs_on_device_backend(monkeypatch):
    """A threaded multi-uarch campaign with the jax wave-execution backend
    (selected via REPRO_SIM_BACKEND): the shared execute lock rides down
    to the kernels, host prep overlaps, results match the numpy campaign."""
    pytest.importorskip("jax")
    names = ["ADD_R64_R64", "MUL_R64", "ADC_R64_R64"]
    ref = Campaign(instr_names=names).run(
        [SimMachine(ua, TEST_ISA) for ua in SIM_UARCHES.values()],
        TEST_ISA)
    monkeypatch.setenv("REPRO_SIM_BACKEND", "jax")
    res = Campaign(instr_names=names).run(
        [SimMachine(ua, TEST_ISA) for ua in SIM_UARCHES.values()],
        TEST_ISA)
    assert set(res.models) == set(SIM_UARCHES)
    for name, model in res.models.items():
        for n in names:
            assert model[n].port_usage.usage == \
                ref.models[name][n].port_usage.usage
            assert model[n].uops == ref.models[name][n].uops


def test_legacy_measure_results_unchanged_by_batch_default():
    """measure() through the engine equals a hand-rolled scalar
    Algorithm-2 differencing."""
    from repro.core.machine import measure

    seq = independent_seq(TEST_ISA["ADC_R64_R64"], RegPool(), 4)
    m = SimMachine(SIM_SKL, TEST_ISA)
    got = measure(m, seq)
    s = SimMachine(SIM_SKL, TEST_ISA)
    c1, c2 = s.run(seq * 10), s.run(seq * 110)
    assert got.cycles == (c2.cycles - c1.cycles) / 100
    assert as_engine(m).stats.executions == 1

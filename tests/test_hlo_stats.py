"""HLO collective-statistics parser (feeds the roofline collective term)."""
import pytest

from repro.analysis.hlo_stats import parse_collectives

SAMPLE = """
HloModule jit_train_step
%fused (p0: f32[16,4096]) -> f32[16,4096] {
  ROOT %add = f32[16,4096] add(%p0, %p0)
}
ENTRY %main {
  %ag = bf16[64,4096,256]{2,1,0} all-gather(bf16[4,4096,256]{2,1,0} %x), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %ar = f32[4096,4096]{1,0} all-reduce(f32[4096,4096]{1,0} %y), replica_groups=[16,16]<=[256]T(1,0), to_apply=%sum
  %rs = f32[256,4096]{1,0} reduce-scatter(f32[4096,4096]{1,0} %z), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={0}
  %a2a = bf16[8,512]{1,0} all-to-all(bf16[8,512]{1,0} %w), replica_groups=[32,8]<=[256]
  %cp = f32[128,128]{1,0} collective-permute(f32[128,128]{1,0} %v), source_target_pairs={{0,1},{1,2}}
  %ags = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-gather-start(f32[4,16] %q, f32[4,16] %r), replica_groups={{0,1,2,3}}, dimensions={0}
  %agd = f32[16,16]{1,0} all-gather-done(%ags)
}
"""


def test_counts():
    st = parse_collectives(SAMPLE)
    assert st.count["all-gather"] == 2  # plain + -start (done not counted)
    assert st.count["all-reduce"] == 1
    assert st.count["reduce-scatter"] == 1
    assert st.count["all-to-all"] == 1
    assert st.count["collective-permute"] == 1


def test_result_bytes_and_groups():
    st = parse_collectives(SAMPLE)
    ag = 64 * 4096 * 256 * 2
    assert st.result_bytes["all-gather"] == ag + 2 * 16 * 16 * 4
    ar = 4096 * 4096 * 4
    # ring all-reduce wire: 2 * R * (g-1)/g, iota groups [16,16] -> g=16
    assert st.wire_bytes["all-reduce"] == pytest.approx(2 * ar * 15 / 16)
    # reduce-scatter: shard result R, wire = R*(g-1), g=16
    rs = 256 * 4096 * 4
    assert st.wire_bytes["reduce-scatter"] == pytest.approx(rs * 15)
    # all-gather explicit groups of 4: wire = R*(g-1)/g
    assert st.wire_bytes["all-gather"] == pytest.approx(
        ag * 3 / 4 + (2 * 16 * 16 * 4) * 3 / 4)


def test_permute_and_a2a():
    st = parse_collectives(SAMPLE)
    assert st.wire_bytes["collective-permute"] == 128 * 128 * 4
    a2a = 8 * 512 * 2
    assert st.wire_bytes["all-to-all"] == pytest.approx(a2a * 7 / 8)


def test_total():
    st = parse_collectives(SAMPLE)
    assert st.total_wire_bytes == pytest.approx(sum(st.wire_bytes.values()))
    assert st.total_result_bytes == sum(st.result_bytes.values())


def test_ignores_non_collective_lines():
    st = parse_collectives("%add = f32[4] add(%a, %b)\n")
    assert not st.count

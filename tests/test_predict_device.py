"""Device-resident batch prediction (PR-8).

The min-cut closed form (§5.3.2) runs as a jax kernel — integer matmul
against device-resident candidate masks plus an exact integer
cross-multiplication argmax — and must stay *bit-identical* to the
scalar reference ``core/predictor.predict`` on randomized blocks for
every simulated uarch. The numpy backend is the always-available
fallback and must agree too.
"""
import struct

import numpy as np
import pytest

from repro.core.engine import Campaign
from repro.core.isa import TEST_ISA
from repro.core.lp import cut_matrices, union_closure
from repro.core.predictor import predict
from repro.core.simulator import SimMachine
from repro.core.uarch import SIM_UARCHES
from repro.service.batch_predictor import BatchPredictor
from repro.service.workload import random_blocks

NAMES = ["ADD_R64_R64", "IMUL_R64_R64", "MUL_R64", "ADC_R64_R64", "CMC",
         "TEST_R64_R64", "SHLD_R64_R64_I8", "MOVQ2DQ_X_X", "AESDEC_X_X",
         "PSHUFD_X_X", "PADDD_X_X", "MOV_R64_M64"]


@pytest.fixture(scope="module")
def all_models():
    machines = [SimMachine(ua, TEST_ISA) for ua in SIM_UARCHES.values()]
    return Campaign(instr_names=NAMES).run(machines, TEST_ISA).models


def _bits(p):
    """Exact bit pattern of every float field — equality stricter than ==
    (distinguishes -0.0, would catch any ulp drift)."""
    return struct.pack("<4d", p.cycles, p.port_bound, p.latency_bound,
                       p.frontend_bound) + struct.pack(
        f"<{len(p.port_pressure)}d", *p.port_pressure.values())


def test_cut_matrices_encode_subset_relation():
    combos = [frozenset({"p0"}), frozenset({"p1", "p5"}),
              frozenset({"p0", "p1"})]
    cand = union_closure(combos)
    mask, sizes = cut_matrices(combos, cand)
    assert mask.shape == (len(combos), len(cand))
    assert mask.dtype == np.int32 and sizes.dtype == np.int32
    for c, combo in enumerate(combos):
        for s, candidate in enumerate(cand):
            assert mask[c, s] == (1 if combo <= candidate else 0)
    assert list(sizes) == [len(c) for c in cand]


def test_numpy_backend_bit_identical_all_uarches(all_models):
    for name, model in all_models.items():
        bp = BatchPredictor(model, TEST_ISA, backend="numpy")
        blocks = random_blocks(model, TEST_ISA, 50, seed=101, max_len=8)
        got = bp.predict_batch(blocks)
        for code, g in zip(blocks, got):
            ref = predict(model, TEST_ISA, code)
            assert g == ref and _bits(g) == _bits(ref), (name, code)
        st = bp.backend_stats()
        assert st["backend"] == "numpy"
        assert st["numpy_waves"] >= 1 and st["device_waves"] == 0


def test_jax_backend_bit_identical_all_uarches(all_models):
    pytest.importorskip("jax")
    for name, model in all_models.items():
        bp = BatchPredictor(model, TEST_ISA, backend="jax",
                            min_device_blocks=1)
        for seed, n in ((7, 64), (8, 5)):  # two shape buckets
            blocks = random_blocks(model, TEST_ISA, n, seed=seed, max_len=8)
            got = bp.predict_batch(blocks)
            for code, g in zip(blocks, got):
                ref = predict(model, TEST_ISA, code)
                assert g == ref and _bits(g) == _bits(ref), (name, code)
        st = bp.backend_stats()
        assert st["backend"] == "jax"
        assert st["device_waves"] >= 1 and st["device_blocks"] >= 64
        assert st["device_compiles"] >= 1


def test_small_waves_stay_on_host(all_models):
    pytest.importorskip("jax")
    model = all_models["sim_skl"]
    bp = BatchPredictor(model, TEST_ISA, backend="jax")  # default threshold
    blocks = random_blocks(model, TEST_ISA, 4, seed=3)
    assert [p == predict(model, TEST_ISA, b)
            for b, p in zip(blocks, bp.predict_batch(blocks))] == [True] * 4
    st = bp.backend_stats()
    assert st["device_waves"] == 0 and st["numpy_waves"] >= 1


def test_backend_env_knob_and_validation(all_models, monkeypatch):
    model = all_models["sim_skl"]
    monkeypatch.setenv("REPRO_PREDICT_BACKEND", "numpy")
    assert BatchPredictor(model, TEST_ISA).backend == "numpy"
    monkeypatch.delenv("REPRO_PREDICT_BACKEND")
    assert BatchPredictor(model, TEST_ISA).backend in ("numpy", "jax")
    with pytest.raises(ValueError):
        BatchPredictor(model, TEST_ISA, backend="cuda")


def test_non_integer_usage_falls_back_to_numpy(all_models):
    pytest.importorskip("jax")
    import copy

    model = copy.copy(all_models["sim_skl"])
    model.instructions = dict(model.instructions)
    im = copy.deepcopy(model.instructions["ADD_R64_R64"])
    # poison one μop count: the integer-exactness guard must route the
    # whole wave to the numpy path (which handles floats exactly enough
    # for the closed form's float64 sums)
    pc = next(iter(im.port_usage.usage))
    im.port_usage.usage[pc] = im.port_usage.usage[pc] + 0.5
    model.instructions["ADD_R64_R64"] = im
    bp = BatchPredictor(model, TEST_ISA, backend="jax", min_device_blocks=1)
    from repro.core.simulator import Instr
    blocks = random_blocks(model, TEST_ISA, 39, seed=11)
    blocks.append([Instr("ADD_R64_R64", {"op1": "R0", "op2": "R1"})])
    got = bp.predict_batch(blocks)
    for code, g in zip(blocks, got):
        assert g == predict(model, TEST_ISA, code)
    st = bp.backend_stats()
    assert st["device_fallbacks"] + st["numpy_waves"] >= 1
    assert st["device_waves"] == 0 or st["device_fallbacks"] >= 1
